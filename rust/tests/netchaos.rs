//! Networked chaos tests (DESIGN.md §2.0.7): the serve/work runtime
//! must survive real process death and wire damage, not just the
//! in-process fault hooks that `tests/chaos.rs` exercises.
//!
//!  * SIGKILL a worker under `failure=degrade`: the coordinator evicts
//!    the dead rank, completes on survivors, and says so in the summary.
//!  * SIGKILL a worker under `failure=restart`: a replacement process
//!    rejoins the same rank, resumes past the crashed stream's applied
//!    tail, and the run keeps *exact* push accounting end to end.
//!  * Corrupt a pull-stream frame in flight (`corrupt:s0@N`): the
//!    worker names the broken frame kind on stderr, tears the mirror
//!    stream down cleanly, and both processes still exit 0.
//!  * Property tests pin the new control-plane frames (`Heartbeat`,
//!    `ConfigUpdate`) to the wire contract: exact roundtrip, contextual
//!    truncation errors, and no panic under byte flips.
//!
//! Processes are torn down on any failure via a kill-on-drop guard.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use asybadmm::coordinator::wire;
use asybadmm::testutil::forall;
use asybadmm::util::json::Json;
use asybadmm::util::rng::Rng;

const BIN: &str = env!("CARGO_BIN_EXE_asybadmm");

/// Kill-on-drop child guard: a failed assertion must not strand
/// coordinator/worker processes (locally or in CI).
struct Reap(Child);

impl Drop for Reap {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// One curl-free HTTP GET against the stats endpoint.
fn http_get(addr: &str, path: &str) -> std::io::Result<(String, String)> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(2))).ok();
    conn.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw)?;
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    Ok((head.lines().next().unwrap_or("").to_string(), body.to_string()))
}

/// `key=value` token out of the serve summary line.
fn field_u64(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|t| t.strip_prefix(key))
        .unwrap_or_else(|| panic!("no {key:?} field in {line:?}"))
        .trim_end_matches(|c: char| !c.is_ascii_digit())
        .parse()
        .unwrap_or_else(|e| panic!("bad {key:?} field in {line:?}: {e}"))
}

/// Spawn `asybadmm serve` and scrape its announced addresses off
/// stdout.  Returns the guard, the remaining stdout line iterator, the
/// push-lane address, and (when `stats_addr` was in `set`) the stats
/// address.
#[allow(clippy::type_complexity)]
fn spawn_serve(
    set: &str,
) -> (Reap, std::io::Lines<BufReader<std::process::ChildStdout>>, String, Option<String>) {
    let mut serve = Reap(
        Command::new(BIN)
            .args(["serve", "--listen", "127.0.0.1:0", "--set", set])
            .stdout(Stdio::piped())
            .spawn()
            .expect("spawn serve"),
    );
    let want_stats = set.contains("stats_addr=");
    let mut lines = BufReader::new(serve.0.stdout.take().expect("serve stdout")).lines();
    let (mut listen, mut stats) = (None, None);
    while listen.is_none() || (want_stats && stats.is_none()) {
        let line = lines
            .next()
            .expect("serve exited before announcing its addresses")
            .expect("serve stdout");
        if let Some(a) = line.strip_prefix("# listening on ") {
            listen = Some(a.trim().to_string());
        } else if let Some(a) = line.strip_prefix("# stats on ") {
            stats = Some(a.trim().to_string());
        }
    }
    (serve, lines, listen.unwrap(), stats)
}

fn spawn_worker(listen: &str, rank: &str) -> Reap {
    Reap(
        Command::new(BIN)
            .args(["work", "--connect", listen, "--rank", rank])
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn work"),
    )
}

/// Block until `/stats` reports at least `min_pushes` applied pushes —
/// i.e. the join barrier passed and the run is live — so a kill lands
/// mid-run, not mid-handshake.
fn wait_for_pushes(stats: &str, min_pushes: f64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(
            Instant::now() < deadline,
            "run never reached {min_pushes} applied pushes (stats probe timed out)"
        );
        if let Ok((status, body)) = http_get(stats, "/stats") {
            assert!(status.contains("200"), "stats: {status}");
            let snap = Json::parse(&body).expect("stats body is JSON");
            let pushes = snap.get("pushes_total").and_then(Json::as_f64).expect("pushes_total");
            if pushes >= min_pushes {
                return;
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn done_line(lines: &mut std::io::Lines<BufReader<std::process::ChildStdout>>) -> String {
    lines
        .by_ref()
        .map(|l| l.expect("serve stdout"))
        .find(|l| l.starts_with("# done in "))
        .expect("serve exited without a done line")
}

/// SIGKILL one of two ranks mid-run under `failure=degrade`: the
/// coordinator must detect the lost control stream, evict the rank
/// (purging its parked pushes), finish on the survivor's workers, and
/// report `evicted=1` — no hang, exit 0.
#[test]
fn sigkill_under_degrade_evicts_and_completes_on_survivors() {
    const EPOCHS: u64 = 2000;
    let set = "samples=64,n_blocks=6,block_size=16,nnz_per_row=4,blocks_per_worker=3,\
               shared_blocks=2,n_workers=3,n_servers=2,epochs=2000,rho=2,lambda=0.0001,\
               batch=2,net_delay_mean_ms=0.1,log_every=100000,\
               failure=degrade,net_liveness_ms=500,stats_addr=127.0.0.1:0";
    let (mut serve, mut lines, listen, stats) = spawn_serve(set);
    let stats = stats.expect("stats addr");

    // rank 0 drives workers 0 and 2; rank 1 drives worker 1.
    let mut survivor = spawn_worker(&listen, "0/2");
    let mut victim = spawn_worker(&listen, "1/2");

    wait_for_pushes(&stats, 30.0);
    victim.0.kill().expect("SIGKILL rank 1");
    victim.0.wait().expect("reap rank 1");

    let done = done_line(&mut lines);
    assert!(serve.0.wait().expect("wait serve").success(), "serve failed: {done}");
    assert!(survivor.0.wait().expect("wait rank 0").success(), "rank 0/2 failed");

    let applied = field_u64(&done, "pushes=");
    let sent = field_u64(&done, "sent=");
    let evicted = field_u64(&done, "evicted=");
    assert_eq!(evicted, 1, "the killed rank was not evicted: {done}");
    // The survivor's two workers finish all their epochs; the victim's
    // worker contributed only what landed before the kill.
    assert_eq!(sent, 2 * EPOCHS, "survivor accounting broke: {done}");
    assert!(
        applied >= 2 * EPOCHS && applied < 3 * EPOCHS,
        "applied pushes outside the survivor band: {done}"
    );
}

/// SIGKILL a rank mid-run under `failure=restart`, then start a
/// replacement process on the same rank: the rejoin handshake must
/// resume past the crashed stream's applied tail so the run ends with
/// *exact* FIFO accounting — every epoch of every worker applied
/// exactly once, `evicted=0`.
#[test]
fn sigkill_under_restart_rejoins_with_exact_fifo_resume() {
    const EPOCHS: u64 = 2500;
    const N_WORKERS: u64 = 2;
    let set = "samples=64,n_blocks=6,block_size=16,nnz_per_row=4,blocks_per_worker=3,\
               shared_blocks=2,n_workers=2,n_servers=1,epochs=2500,rho=2,lambda=0.0001,\
               batch=2,net_delay_mean_ms=0.2,log_every=100000,\
               failure=restart,net_liveness_ms=1000,join_timeout_ms=30000,\
               stats_addr=127.0.0.1:0";
    let (mut serve, mut lines, listen, stats) = spawn_serve(set);
    let stats = stats.expect("stats addr");

    let mut survivor = spawn_worker(&listen, "0/2");
    let mut victim = spawn_worker(&listen, "1/2");

    wait_for_pushes(&stats, 50.0);
    victim.0.kill().expect("SIGKILL rank 1");
    victim.0.wait().expect("reap rank 1");

    // The replacement races serve's death detection; its join handshake
    // retries with backoff until the monitor marks the rank dead and
    // answers with a resume Welcome.
    let mut replacement = spawn_worker(&listen, "1/2");

    let done = done_line(&mut lines);
    assert!(serve.0.wait().expect("wait serve").success(), "serve failed: {done}");
    assert!(survivor.0.wait().expect("wait rank 0").success(), "rank 0/2 failed");
    assert!(
        replacement.0.wait().expect("wait replacement").success(),
        "replacement rank 1/2 failed"
    );

    let applied = field_u64(&done, "pushes=");
    let evicted = field_u64(&done, "evicted=");
    assert_eq!(evicted, 0, "restart must rejoin, not evict: {done}");
    assert_eq!(
        applied,
        EPOCHS * N_WORKERS,
        "rejoin broke exact FIFO accounting (duplicates or gaps): {done}"
    );
}

/// `corrupt:s0@3` flips bytes of the third pull-stream response in
/// flight.  The worker must fail that frame with a *named* decode
/// error ("PullResp"), retire its mirror stream without panicking, and
/// still finish every epoch; the coordinator logs the injected fault
/// and keeps exact accounting.
#[test]
fn corrupt_pull_frame_names_the_kind_and_tears_down_cleanly() {
    const EPOCHS: u64 = 300;
    let set = "samples=48,n_blocks=4,block_size=16,nnz_per_row=4,blocks_per_worker=4,\
               shared_blocks=1,n_workers=1,n_servers=1,epochs=300,rho=2,lambda=0.0001,\
               batch=2,net_delay_mean_ms=0.1,log_every=100000,faults=corrupt:s0@3";
    let (mut serve, mut lines, listen, _stats) = spawn_serve(set);

    let mut worker = Reap(
        Command::new(BIN)
            .args(["work", "--connect", &listen, "--rank", "0/1"])
            .stdout(Stdio::null())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn work"),
    );

    // The fault ledger drains onto serve stdout just before the summary.
    let mut fault_lines = Vec::new();
    let mut done = None;
    for line in lines.by_ref() {
        let line = line.expect("serve stdout");
        if line.starts_with("# fault: ") {
            fault_lines.push(line);
        } else if line.starts_with("# done in ") {
            done = Some(line);
            break;
        }
    }
    let done = done.expect("serve exited without a done line");
    assert!(serve.0.wait().expect("wait serve").success(), "serve failed: {done}");

    let mut stderr = String::new();
    worker
        .0
        .stderr
        .take()
        .expect("worker stderr")
        .read_to_string(&mut stderr)
        .expect("read worker stderr");
    assert!(worker.0.wait().expect("wait worker").success(), "worker exit: {stderr}");
    assert!(
        stderr.contains("PullResp"),
        "worker must name the corrupted frame kind on stderr: {stderr:?}"
    );

    assert!(
        fault_lines.iter().any(|l| l.contains("corrupted in flight")),
        "serve must log the injected corruption: {fault_lines:?}"
    );
    let applied = field_u64(&done, "pushes=");
    let sent = field_u64(&done, "sent=");
    assert_eq!(applied, EPOCHS, "a dead mirror stream must not cost pushes: {done}");
    assert_eq!(applied, sent, "applied != sent after frame corruption: {done}");
}

// ---------------------------------------------------------------------
// Wire properties for the liveness/config control-plane frames
// ---------------------------------------------------------------------

fn decode_heartbeat_frame(bytes: &[u8]) -> Result<wire::WireHeartbeat, String> {
    let mut slice = bytes;
    let (k, payload) = wire::read_frame(&mut slice)
        .map_err(|e| format!("{e:#}"))?
        .ok_or_else(|| "clean EOF instead of a frame".to_string())?;
    if k != wire::kind::HEARTBEAT {
        return Err(format!("not a heartbeat frame: {}", wire::kind_name(k)));
    }
    let mut cur = wire::Cursor::new(k, &payload).map_err(|e| format!("{e:#}"))?;
    let hb = wire::take_heartbeat(&mut cur).map_err(|e| format!("{e:#}"))?;
    cur.finish().map_err(|e| format!("{e:#}"))?;
    Ok(hb)
}

/// Heartbeat frames: roundtrip exactly; truncation at every byte errors
/// contextually (kind once the header is readable, field once the
/// payload is short); random byte flips never panic.
#[test]
fn prop_wire_heartbeat_frames_roundtrip_truncate_and_survive_flips() {
    forall(
        "wire-heartbeat",
        40,
        |rng| (rng.below(1 << 16) as u32, rng.next_u64(), rng.next_u64()),
        |(rank, seq, flip_seed)| {
            let mut buf = Vec::new();
            wire::put_heartbeat_frame(&mut buf, *rank, *seq);
            let hb = decode_heartbeat_frame(&buf)?;
            if hb != (wire::WireHeartbeat { rank: *rank, seq: *seq }) {
                return Err(format!("roundtrip diverged: {} / {}", hb.rank, hb.seq));
            }
            for cut in 1..buf.len() {
                let err = match decode_heartbeat_frame(&buf[..cut]) {
                    Ok(_) => return Err(format!("decoded a heartbeat cut at {cut}")),
                    Err(e) => e,
                };
                if cut < wire::HEADER {
                    if !err.contains("mid-header") {
                        return Err(format!("cut {cut}: header cut lacks context: {err}"));
                    }
                } else if !err.contains("Heartbeat") {
                    return Err(format!("cut {cut}: error does not name the kind: {err}"));
                }
            }
            // Payload truncation behind an intact envelope: the cursor
            // names the missing field.
            for keep in 0..buf.len() - wire::HEADER {
                let mut f = Vec::new();
                let start = wire::begin_frame(&mut f, wire::kind::HEARTBEAT);
                f.extend_from_slice(&buf[wire::HEADER..wire::HEADER + keep]);
                wire::end_frame(&mut f, start);
                let err = decode_heartbeat_frame(&f).unwrap_err();
                if !err.contains("Heartbeat") || !(err.contains("rank") || err.contains("seq")) {
                    return Err(format!("short payload ({keep}B) lacks kind+field: {err}"));
                }
            }
            // Byte flips: decode may fail (with context) but never panic.
            let mut rng = Rng::new(*flip_seed);
            for _ in 0..32 {
                let mut bad = buf.clone();
                let at = rng.below(bad.len());
                bad[at] ^= 1 + rng.below(255) as u8;
                if at < 4 {
                    let claimed = u32::from_le_bytes(bad[..4].try_into().unwrap()) as usize;
                    if claimed <= wire::MAX_FRAME {
                        bad.resize(wire::HEADER + claimed, 0);
                    }
                }
                match decode_heartbeat_frame(&bad) {
                    Ok(_) => {}
                    Err(e) if e.is_empty() => return Err("empty error context".into()),
                    Err(_) => {}
                }
            }
            Ok(())
        },
    );
}

fn decode_config_update_frame(bytes: &[u8]) -> Result<(u64, String), String> {
    let mut slice = bytes;
    let (k, payload) = wire::read_frame(&mut slice)
        .map_err(|e| format!("{e:#}"))?
        .ok_or_else(|| "clean EOF instead of a frame".to_string())?;
    if k != wire::kind::CONFIG_UPDATE {
        return Err(format!("not a config-update frame: {}", wire::kind_name(k)));
    }
    let mut cur = wire::Cursor::new(k, &payload).map_err(|e| format!("{e:#}"))?;
    let (v, kv) = wire::take_config_update(&mut cur).map_err(|e| format!("{e:#}"))?;
    cur.finish().map_err(|e| format!("{e:#}"))?;
    Ok((v, kv.to_string()))
}

/// ConfigUpdate frames: the `version + kv text` body roundtrips exactly
/// (including the empty and multi-line cases), truncation names the
/// kind and the missing field, and byte flips — which can land in the
/// string length prefix or mid-UTF-8 — never panic.
#[test]
fn prop_wire_config_update_frames_roundtrip_truncate_and_survive_flips() {
    const KEYS: &[&str] =
        &["rebalance_ms", "stall_warn_ms", "net_liveness_ms", "pull_floor_us", "pull_ceil_ms"];
    forall(
        "wire-config-update",
        40,
        |rng| {
            let n = rng.below(4);
            let kv = (0..n)
                .map(|_| format!("{}={}", KEYS[rng.below(KEYS.len())], rng.below(100_000)))
                .collect::<Vec<_>>()
                .join("\n");
            (rng.next_u64(), kv, rng.next_u64())
        },
        |(version, kv, flip_seed)| {
            let mut buf = Vec::new();
            wire::put_config_update_frame(&mut buf, *version, kv);
            let (v, got) = decode_config_update_frame(&buf)?;
            if v != *version || got != *kv {
                return Err(format!("roundtrip diverged: v{v} {got:?}"));
            }
            for cut in 1..buf.len() {
                let err = match decode_config_update_frame(&buf[..cut]) {
                    Ok(_) => return Err(format!("decoded a config update cut at {cut}")),
                    Err(e) => e,
                };
                if cut < wire::HEADER {
                    if !err.contains("mid-header") {
                        return Err(format!("cut {cut}: header cut lacks context: {err}"));
                    }
                } else if !err.contains("ConfigUpdate") {
                    return Err(format!("cut {cut}: error does not name the kind: {err}"));
                }
            }
            for keep in 0..buf.len() - wire::HEADER {
                let mut f = Vec::new();
                let start = wire::begin_frame(&mut f, wire::kind::CONFIG_UPDATE);
                f.extend_from_slice(&buf[wire::HEADER..wire::HEADER + keep]);
                wire::end_frame(&mut f, start);
                let err = decode_config_update_frame(&f).unwrap_err();
                if !err.contains("ConfigUpdate")
                    || !(err.contains("version") || err.contains("kv"))
                {
                    return Err(format!("short payload ({keep}B) lacks kind+field: {err}"));
                }
            }
            let mut rng = Rng::new(*flip_seed);
            for _ in 0..32 {
                let mut bad = buf.clone();
                let at = rng.below(bad.len());
                bad[at] ^= 1 + rng.below(255) as u8;
                if at < 4 {
                    let claimed = u32::from_le_bytes(bad[..4].try_into().unwrap()) as usize;
                    if claimed <= wire::MAX_FRAME {
                        bad.resize(wire::HEADER + claimed, 0);
                    }
                }
                match decode_config_update_frame(&bad) {
                    Ok(_) => {}
                    Err(e) if e.is_empty() => return Err("empty error context".into()),
                    Err(_) => {}
                }
            }
            Ok(())
        },
    );
}

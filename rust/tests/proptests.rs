//! Property-based tests on coordinator/substrate invariants (DESIGN.md
//! §8), driven by the in-tree seeded property harness.

use std::sync::Arc;

use asybadmm::admm::{gather_packed, prox_l1_box, soft_threshold};
use asybadmm::config::PlacementKind;
use asybadmm::coordinator::{
    make_placement, wire, BlockMap, BlockStore, BlockTable, MpscTransport, ProxBackend,
    PushMsg, RwBlockStore, ServerShard, SpscRingTransport, Topology, Transport, TryRecv,
};
use asybadmm::data::{gen_partitioned, BlockGeometry, Dataset, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::sparse::{dense, CsrBuilder, CsrMatrix};
use asybadmm::testutil::forall;
use asybadmm::util::rng::Rng;
use asybadmm::util::AlignedBuf;

fn random_spec(rng: &mut Rng) -> (SynthSpec, usize) {
    let n_blocks = 2 + rng.below(8);
    let db = [4, 8, 16][rng.below(3)];
    let bpw = 1 + rng.below(n_blocks);
    let shared = rng.below(bpw + 1).min(bpw);
    let workers = 1 + rng.below(5);
    let spec = SynthSpec {
        kind: if rng.bernoulli(0.5) { LossKind::Logistic } else { LossKind::Squared },
        samples: 16 + rng.below(64),
        geometry: BlockGeometry::new(n_blocks, db),
        nnz_per_row: 1 + rng.below(6),
        blocks_per_worker: bpw,
        shared_blocks: shared,
        zipf_s: 0.8 + rng.f64(),
        truth_density: 0.1,
        noise: 0.05,
        seed: rng.next_u64(),
    };
    (spec, workers)
}

/// (a) Partition covers every sample exactly once and preserves nnz.
#[test]
fn prop_partition_covers_all_nnz() {
    forall(
        "partition-covers",
        25,
        |rng| random_spec(rng),
        |(spec, workers)| {
            let (ds, shards) = gen_partitioned(spec, *workers);
            let total: usize = shards.iter().map(|s| s.samples()).sum();
            if total != ds.samples() {
                return Err(format!("row cover {total} != {}", ds.samples()));
            }
            let nnz: usize = shards.iter().map(|s| s.a_packed.nnz()).sum();
            if nnz != ds.a.nnz() {
                return Err(format!("nnz cover {nnz} != {}", ds.a.nnz()));
            }
            // contiguity: shard ranges tile [0, m)
            let mut expect = 0;
            for s in &shards {
                if s.rows.0 != expect {
                    return Err(format!("gap at row {expect}"));
                }
                expect = s.rows.1;
            }
            Ok(())
        },
    );
}

/// (b) Every (worker, block) edge maps to exactly one owning server, and
/// the packed slot mapping is bijective.
#[test]
fn prop_topology_routing_is_total_and_unique() {
    forall(
        "routing",
        25,
        |rng| {
            let (spec, workers) = random_spec(rng);
            let servers = 1 + rng.below(spec.geometry.n_blocks);
            (spec, workers, servers)
        },
        |(spec, workers, servers)| {
            let (_, shards) = gen_partitioned(spec, *workers);
            let topo = Topology::build(&shards, spec.geometry.n_blocks, *servers);
            for shard in &shards {
                for (slot, &j) in shard.active_blocks.iter().enumerate() {
                    let srv = topo.server_of_block[j];
                    if !topo.blocks_of_server[srv].contains(&j) {
                        return Err(format!("block {j} not owned by its server {srv}"));
                    }
                    if shard.slot_of_block(j) != Some(slot) {
                        return Err(format!("slot map broken for block {j}"));
                    }
                    if shard.block_of_slot(slot) != j {
                        return Err("slot inverse broken".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// (b2) Placement invariants: under all three policies every block is
/// owned by exactly one shard, the owner map and the per-shard block
/// lists agree, and the bipartite adjacency
/// (`workers_of_block`/`blocks_of_worker`) is mutually consistent and
/// placement-independent.
#[test]
fn prop_placements_own_each_block_exactly_once() {
    forall(
        "placement-ownership",
        25,
        |rng| {
            let (spec, workers) = random_spec(rng);
            let servers = 1 + rng.below(spec.geometry.n_blocks);
            (spec, workers, servers)
        },
        |(spec, workers, servers)| {
            let (_, shards) = gen_partitioned(spec, *workers);
            let n_blocks = spec.geometry.n_blocks;
            let reference = Topology::build(&shards, n_blocks, *servers);
            for kind in [
                PlacementKind::Contiguous,
                PlacementKind::RoundRobin,
                PlacementKind::Hash,
                PlacementKind::Degree,
            ] {
                let placement = make_placement(kind);
                let topo =
                    Topology::build_with(&shards, n_blocks, *servers, placement.as_ref());
                // Each block owned exactly once: the per-shard lists
                // tile 0..n_blocks and match the owner map.
                let mut all: Vec<usize> =
                    topo.blocks_of_server.iter().flatten().copied().collect();
                all.sort_unstable();
                if all != (0..n_blocks).collect::<Vec<_>>() {
                    return Err(format!("{kind:?}: shard lists do not tile blocks: {all:?}"));
                }
                for (s, blocks) in topo.blocks_of_server.iter().enumerate() {
                    for &j in blocks {
                        if topo.server_of_block[j] != s {
                            return Err(format!(
                                "{kind:?}: block {j} listed on shard {s} but owned by {}",
                                topo.server_of_block[j]
                            ));
                        }
                    }
                }
                // Adjacency is a property of the data, not the placement.
                if topo.workers_of_block != reference.workers_of_block
                    || topo.blocks_of_worker != reference.blocks_of_worker
                {
                    return Err(format!("{kind:?}: placement changed the adjacency"));
                }
                for (i, blocks) in topo.blocks_of_worker.iter().enumerate() {
                    for &j in blocks {
                        if !topo.workers_of_block[j].contains(&i) {
                            return Err(format!("{kind:?}: edge ({i},{j}) asymmetric"));
                        }
                    }
                }
                for (j, ws) in topo.workers_of_block.iter().enumerate() {
                    for &i in ws {
                        if !topo.blocks_of_worker[i].contains(&j) {
                            return Err(format!("{kind:?}: edge ({i},{j}) one-way"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// (b3) Lane-granular stealing preserves per-worker FIFO: draining a
/// server's lanes in ANY interleaving — each lane accessed exclusively
/// and sequentially, as `sched.rs`'s CAS lane claim guarantees, but
/// switching lanes at arbitrary points like a thief would — delivers
/// every (worker, server) sub-stream in send order.  Run against both
/// transports, batched and unbatched.
#[test]
fn prop_lane_steal_preserves_per_worker_fifo() {
    forall(
        "lane-steal-fifo",
        12,
        |rng| {
            let workers = 1 + rng.below(4);
            let servers = 1 + rng.below(3);
            let per_worker = 4 + rng.below(24);
            let batch = 1 + rng.below(4);
            let ring = rng.bernoulli(0.5);
            (workers, servers, per_worker, batch, ring, rng.next_u64())
        },
        |&(workers, servers, per_worker, batch, ring, seed)| {
            let transport: Box<dyn Transport> = if ring {
                // Capacity sized for the full pre-filled backlog: the
                // drain below is single-threaded.
                Box::new(SpscRingTransport::new(workers, servers, per_worker, batch))
            } else {
                Box::new(MpscTransport::new(workers, servers, workers * per_worker, batch))
            };
            let mut rng = Rng::new(seed);
            // sent[w][s] = epochs in send order.
            let mut sent = vec![vec![Vec::<usize>::new(); servers]; workers];
            let mut txs: Vec<_> = (0..workers).map(|w| transport.connect_worker(w)).collect();
            for epoch in 0..per_worker {
                for (w, tx) in txs.iter_mut().enumerate() {
                    let s = rng.below(servers);
                    let msg = PushMsg {
                        worker: w,
                        block: 0,
                        w: vec![0.0; 2].into(),
                        worker_epoch: epoch,
                        z_version_used: 0,
                        block_seq: 0,
                        sent_at: None,
                        recycle: None,
                    };
                    tx.send(s, msg).map_err(|e| format!("send failed: {e:#}"))?;
                    sent[w][s].push(epoch);
                }
            }
            for tx in txs.iter_mut() {
                tx.flush().map_err(|e| format!("flush failed: {e:#}"))?;
            }
            drop(txs);
            transport.shutdown();

            // Per-server lanes, drained in a random interleaving.
            let mut next = vec![vec![0usize; servers]; workers];
            let mut received = 0usize;
            let total = workers * per_worker;
            let mut lanes: Vec<(usize, Box<dyn asybadmm::coordinator::PushReceiver>)> =
                (0..servers)
                    .flat_map(|s| {
                        transport
                            .connect_server_lanes(s)
                            .into_iter()
                            .map(move |l| (s, l))
                    })
                    .collect();
            let mut done = vec![false; lanes.len()];
            let mut safety = 0usize;
            while received < total || !done.iter().all(|&d| d) {
                safety += 1;
                if safety > 100 * total + 10_000 {
                    return Err(format!("drain did not terminate: {received}/{total}"));
                }
                let k = rng.below(lanes.len());
                if done[k] {
                    continue;
                }
                let budget = 1 + rng.below(4);
                let (s, lane) = &mut lanes[k];
                for _ in 0..budget {
                    match lane.try_recv() {
                        TryRecv::Msg(m) => {
                            let expect_idx = next[m.worker][*s];
                            let expected = sent[m.worker][*s].get(expect_idx).copied();
                            if expected != Some(m.worker_epoch) {
                                return Err(format!(
                                    "worker {} server {s}: got epoch {} expected {:?}",
                                    m.worker, m.worker_epoch, expected
                                ));
                            }
                            next[m.worker][*s] += 1;
                            received += 1;
                        }
                        TryRecv::Empty => break,
                        TryRecv::Done => {
                            done[k] = true;
                            break;
                        }
                    }
                }
            }
            if received != total {
                return Err(format!("received {received} of {total}"));
            }
            Ok(())
        },
    );
}

/// (b4) Migration safety: random interleavings of sends, owner-map
/// migrations, and partial lane drains (the thief / new-owner shape —
/// each lane accessed exclusively and sequentially, as the sched.rs
/// lane claim guarantees) never lose or reorder a per-(worker, block)
/// push sequence.  The server's seq gate parks early arrivals from the
/// post-migration lane until the old lane's tail drains; by the end
/// every push must have applied, in send order, with nothing left
/// parked.
#[test]
fn prop_migration_preserves_per_worker_block_fifo() {
    forall(
        "migrate-fifo",
        10,
        |rng| {
            let workers = 1 + rng.below(3);
            let servers = 2 + rng.below(2);
            let per_worker = 8 + rng.below(24);
            let batch = 1 + rng.below(3);
            let ring = rng.bernoulli(0.5);
            (workers, servers, per_worker, batch, ring, rng.next_u64())
        },
        |&(workers, servers, per_worker, batch, ring, seed)| {
            let (n_blocks, db) = (4usize, 4usize);
            // Every worker touches every block so any (worker, block)
            // edge is sendable.
            let spec = SynthSpec {
                samples: 8 * workers,
                geometry: BlockGeometry::new(n_blocks, db),
                nnz_per_row: 3,
                blocks_per_worker: n_blocks,
                shared_blocks: n_blocks,
                ..Default::default()
            };
            let (_, data_shards) = gen_partitioned(&spec, workers);
            let topo = Topology::build(&data_shards, n_blocks, servers);
            let store = Arc::new(BlockStore::new(n_blocks, db));
            let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
            let table = Arc::new(BlockTable::new(&topo, store, problem, 2.0, 0.1));
            let map = BlockMap::new(&topo.server_of_block);
            // Non-strict shards over ONE shared table: the dynamic-
            // placement runtime shape.
            let shards: Vec<ServerShard> = (0..servers)
                .map(|sid| ServerShard::with_table(sid, &topo, table.clone(), false))
                .collect();
            // Capacity sized so a single-threaded interleaving can
            // never block in send().
            let transport: Box<dyn Transport> = if ring {
                Box::new(SpscRingTransport::new(workers, servers, workers * per_worker, batch))
            } else {
                Box::new(MpscTransport::new(workers, servers, workers * per_worker, batch))
            };
            let mut rng = Rng::new(seed);
            let mut txs: Vec<_> =
                (0..workers).map(|w| transport.connect_worker(w)).collect();
            let mut lanes: Vec<(usize, Box<dyn asybadmm::coordinator::PushReceiver>)> =
                (0..servers)
                    .flat_map(|s| {
                        transport
                            .connect_server_lanes(s)
                            .into_iter()
                            .map(move |l| (s, l))
                    })
                    .collect();

            let value = |w: usize, j: usize, s: u64| (w * 1000 + j * 100) as f32 + s as f32;
            let mut seq = vec![vec![0u64; n_blocks]; workers];
            let mut sent = vec![0usize; workers];
            let total = workers * per_worker;
            let mut sent_total = 0usize;
            let mut safety = 0usize;
            while sent_total < total {
                safety += 1;
                if safety > 200 * total + 10_000 {
                    return Err("interleaving did not finish".into());
                }
                let dice = rng.below(5);
                if dice == 0 {
                    // Migrate a random block to a random shard.
                    let j = rng.below(n_blocks);
                    map.set_owner(j, rng.below(servers));
                } else if dice <= 2 {
                    // One worker sends its next push for a random
                    // block, routed by the LIVE map.
                    let w = rng.below(workers);
                    if sent[w] < per_worker {
                        let j = rng.below(n_blocks);
                        seq[w][j] += 1;
                        let msg = PushMsg {
                            worker: w,
                            block: j,
                            w: vec![value(w, j, seq[w][j]); db].into(),
                            worker_epoch: sent[w],
                            z_version_used: 0,
                            block_seq: seq[w][j],
                            sent_at: None,
                            recycle: None,
                        };
                        txs[w]
                            .send(map.owner(j), msg)
                            .map_err(|e| format!("send failed: {e:#}"))?;
                        sent[w] += 1;
                        sent_total += 1;
                    }
                } else {
                    // Drain a random lane a little, into ITS shard.
                    let k = rng.below(lanes.len());
                    let budget = 1 + rng.below(4);
                    let (s, lane) = &mut lanes[k];
                    for _ in 0..budget {
                        match lane.try_recv() {
                            TryRecv::Msg(m) => shards[*s]
                                .handle_push(&m, &ProxBackend::Native)
                                .map_err(|e| format!("apply failed: {e:#}"))?,
                            _ => break,
                        }
                    }
                }
            }
            for tx in txs.iter_mut() {
                tx.flush().map_err(|e| format!("flush failed: {e:#}"))?;
            }
            drop(txs);
            transport.shutdown();
            let mut done = vec![false; lanes.len()];
            let mut safety = 0usize;
            while !done.iter().all(|&d| d) {
                safety += 1;
                if safety > 200 * total + 10_000 {
                    return Err("final drain did not terminate".into());
                }
                let k = rng.below(lanes.len());
                if done[k] {
                    continue;
                }
                let (s, lane) = &mut lanes[k];
                match lane.try_recv() {
                    TryRecv::Msg(m) => shards[*s]
                        .handle_push(&m, &ProxBackend::Native)
                        .map_err(|e| format!("apply failed: {e:#}"))?,
                    TryRecv::Done => done[k] = true,
                    TryRecv::Empty => {}
                }
            }

            // Nothing lost, nothing left parked, every (worker, block)
            // chain applied through its full sequence, last write wins.
            let applied: usize = shards.iter().map(|s| s.stats().pushes).sum();
            if applied != total {
                return Err(format!("applied {applied} of {total}"));
            }
            for j in 0..n_blocks {
                if table.pending_len(j) != 0 {
                    return Err(format!(
                        "block {j}: {} parked pushes stranded",
                        table.pending_len(j)
                    ));
                }
                for w in 0..workers {
                    if table.next_seq(j, w) != seq[w][j] + 1 {
                        return Err(format!(
                            "({w},{j}): next_seq {} != sent {} + 1",
                            table.next_seq(j, w),
                            seq[w][j]
                        ));
                    }
                    if seq[w][j] > 0 {
                        let wt = table.w_tilde_of(j, w);
                        let expect = value(w, j, seq[w][j]);
                        if wt[0] != expect {
                            return Err(format!(
                                "({w},{j}): final w̃ {} != last sent {expect}",
                                wt[0]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// (c) Block-store versions strictly increase per write and reads are
/// torn-free under concurrency (single-block consistency).
#[test]
fn prop_block_store_versions_monotone() {
    forall(
        "store-versions",
        20,
        |rng| (1 + rng.below(6), [2usize, 4, 8][rng.below(3)], 1 + rng.below(30)),
        |&(blocks, db, writes)| {
            let store = BlockStore::new(blocks, db);
            let mut rng = Rng::new(42);
            let mut versions = vec![0u64; blocks];
            for _ in 0..writes {
                let j = rng.below(blocks);
                let data: Vec<f32> = (0..db).map(|_| rng.f32()).collect();
                let v = store.write(j, &data);
                if v != versions[j] + 1 {
                    return Err(format!("version jump {} -> {v}", versions[j]));
                }
                versions[j] = v;
                let mut out = vec![0.0f32; db];
                let rv = store.read_into(j, &mut out);
                if rv != v || out != data {
                    return Err("read does not reflect write".into());
                }
            }
            Ok(())
        },
    );
}

/// (d) prox_l1_box is firmly nonexpansive and fixes feasible points
/// when lambda = 0, gamma = 0, w = denom*z.
#[test]
fn prop_prox_nonexpansive_and_fixed_points() {
    forall(
        "prox",
        50,
        |rng| {
            let db = 1 + rng.below(32);
            let u: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let v: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let lam = rng.f32() * 2.0;
            let clip = 0.5 + rng.f32() * 10.0;
            let denom = 0.5 + rng.f32() * 20.0;
            (u, v, lam, clip, denom)
        },
        |(u, v, lam, clip, denom)| {
            let db = u.len();
            let zeros = vec![0.0f32; db];
            let (mut pu, mut pv) = (vec![0.0f32; db], vec![0.0f32; db]);
            prox_l1_box(&zeros, u, 0.0, *denom, *lam, *clip, &mut pu);
            prox_l1_box(&zeros, v, 0.0, *denom, *lam, *clip, &mut pv);
            let din: f64 = u
                .iter()
                .zip(v.iter())
                .map(|(a, b)| (((a - b) / denom) as f64).powi(2))
                .sum();
            let dout: f64 =
                pu.iter().zip(&pv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            if dout > din + 1e-6 {
                return Err(format!("expansive: {dout} > {din}"));
            }
            // fixed point: lam=0, w = denom*z (feasible z)
            let z: Vec<f32> = u.iter().map(|x| (x / 4.0).clamp(-clip, *clip)).collect();
            let w: Vec<f32> = z.iter().map(|x| x * denom).collect();
            let mut pz = vec![0.0f32; db];
            prox_l1_box(&z, &w, 0.0, *denom, 0.0, *clip, &mut pz);
            for (a, b) in pz.iter().zip(&z) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("not a fixed point: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// (e) soft-threshold shrinks toward zero by exactly thr.
#[test]
fn prop_soft_threshold_geometry() {
    forall(
        "soft-threshold",
        100,
        |rng| (rng.normal_f32(0.0, 10.0), rng.f32() * 3.0),
        |&(v, thr)| {
            let s = soft_threshold(v, thr);
            if v.abs() <= thr {
                if s != 0.0 {
                    return Err(format!("inside threshold but {s}"));
                }
            } else {
                if (s.abs() - (v.abs() - thr)).abs() > 1e-6 {
                    return Err("wrong shrink amount".into());
                }
                if s.signum() != v.signum() {
                    return Err("sign flipped".into());
                }
            }
            Ok(())
        },
    );
}

/// (f) sparse spmv == dense spmv on random matrices.
#[test]
fn prop_sparse_matches_dense() {
    forall(
        "spmv",
        30,
        |rng| {
            let rows = 1 + rng.below(24);
            let cols = 1 + rng.below(24);
            let mut b = CsrBuilder::new(rows, cols);
            let mut d = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bernoulli(0.3) {
                        let v = rng.normal_f32(0.0, 1.0);
                        b.push(r, c, v);
                        d[r * cols + c] = v;
                    }
                }
            }
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let s: Vec<f32> = (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (b.build(), d, rows, cols, x, s)
        },
        |(a, d, rows, cols, x, s): &(CsrMatrix, Vec<f32>, usize, usize, Vec<f32>, Vec<f32>)| {
            let mut y = vec![0.0f32; *rows];
            a.matvec(x, &mut y);
            let yd = dense::matvec(d, *rows, *cols, x);
            for (u, v) in y.iter().zip(&yd) {
                if (u - v).abs() > 1e-3 {
                    return Err(format!("matvec {u} vs {v}"));
                }
            }
            let mut g = vec![0.0f32; *cols];
            a.tmatvec_acc(s, &mut g);
            let gd = dense::tmatvec(d, *rows, *cols, s);
            for (u, v) in g.iter().zip(&gd) {
                if (u - v).abs() > 1e-3 {
                    return Err(format!("tmatvec {u} vs {v}"));
                }
            }
            Ok(())
        },
    );
}

/// (f2) The block-slice index kernel equals the dense reference A^T s
/// restricted to [col_lo, col_hi) for random CSR matrices and random
/// block boundaries, and is bit-identical to the partition_point scan.
#[test]
fn prop_block_slice_kernel_matches_dense_reference() {
    forall(
        "block-slices",
        30,
        |rng| {
            let db = 1 + rng.below(10);
            let n_blocks = 1 + rng.below(7);
            let rows = 1 + rng.below(40);
            let cols = n_blocks * db;
            let mut b = CsrBuilder::new(rows, cols);
            let mut d = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bernoulli(0.25) {
                        let v = rng.normal_f32(0.0, 1.0);
                        b.push(r, c, v);
                        d[r * cols + c] = v;
                    }
                }
            }
            let s: Vec<f32> = (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (b.build(), d, rows, db, n_blocks, s)
        },
        |(a, d, rows, db, n_blocks, s): &(CsrMatrix, Vec<f32>, usize, usize, usize, Vec<f32>)| {
            let ix = a.block_slices(*db);
            if ix.n_blocks() != *n_blocks || ix.rows() != *rows {
                return Err("index shape mismatch".into());
            }
            let covered: usize = (0..*n_blocks).map(|b| ix.block_nnz(b)).sum();
            if covered != a.nnz() {
                return Err(format!("index covers {covered} of {} nnz", a.nnz()));
            }
            let gd = dense::tmatvec(d, *rows, n_blocks * db, s);
            for blk in 0..*n_blocks {
                let (lo, hi) = (blk * db, (blk + 1) * db);
                let mut g = vec![0.0f32; *db];
                a.tmatvec_block_sliced(s, &ix, blk, &mut g);
                for (k, v) in g.iter().enumerate() {
                    if (v - gd[lo + k]).abs() > 1e-3 {
                        return Err(format!(
                            "block {blk} elem {k}: sliced {v} vs dense {}",
                            gd[lo + k]
                        ));
                    }
                }
                // The index-free scan accumulates in the same order, so
                // the two kernels must agree exactly, not just closely.
                let mut g_scan = vec![0.0f32; *db];
                a.tmatvec_block_acc(s, lo, hi, &mut g_scan);
                if g != g_scan {
                    return Err(format!("block {blk}: sliced != scan kernel"));
                }
            }
            Ok(())
        },
    );
}

/// (c2) The seqlock store is sequentially indistinguishable from the
/// RwLock reference store under random write/update/read interleavings
/// (differential oracle for the double-buffer + version protocol).
#[test]
fn prop_seqlock_store_matches_rwlock_reference() {
    forall(
        "seqlock-vs-rwlock",
        20,
        |rng| (1 + rng.below(5), 1 + rng.below(12), 5 + rng.below(60), rng.next_u64()),
        |&(blocks, db, ops, seed)| {
            let seq = BlockStore::new(blocks, db);
            let rw = RwBlockStore::new(blocks, db);
            let mut rng = Rng::new(seed);
            let (mut a, mut b) = (vec![0.0f32; db], vec![0.0f32; db]);
            for op in 0..ops {
                let j = rng.below(blocks);
                match rng.below(3) {
                    0 => {
                        let data: Vec<f32> = (0..db).map(|_| rng.f32()).collect();
                        let (va, vb) = (seq.write(j, &data), rw.write(j, &data));
                        if va != vb {
                            return Err(format!("op {op}: write versions {va} vs {vb}"));
                        }
                    }
                    1 => {
                        let delta = rng.normal_f32(0.0, 1.0);
                        let f = |z: &mut [f32]| z.iter_mut().for_each(|x| *x += delta);
                        let (va, vb) = (seq.update_with(j, f), rw.update_with(j, f));
                        if va != vb {
                            return Err(format!("op {op}: update versions {va} vs {vb}"));
                        }
                    }
                    _ => {
                        let (va, vb) = (seq.read_into(j, &mut a), rw.read_into(j, &mut b));
                        if va != vb || a != b {
                            return Err(format!("op {op}: read diverged (v {va} vs {vb})"));
                        }
                    }
                }
                if seq.version(j) != rw.version(j) {
                    return Err(format!("op {op}: version() diverged"));
                }
            }
            Ok(())
        },
    );
}

/// (g) gather_packed is the exact inverse of the packing layout.
#[test]
fn prop_gather_packed_consistent() {
    forall(
        "gather-packed",
        25,
        |rng| random_spec(rng),
        |(spec, workers)| {
            let (ds, shards): (Dataset, _) = gen_partitioned(spec, *workers);
            let d = ds.dim();
            let mut rng = Rng::new(1);
            let z: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for shard in &shards {
                let packed = gather_packed(shard, &z);
                let db = shard.block_size;
                for (slot, &j) in shard.active_blocks.iter().enumerate() {
                    if packed[slot * db..(slot + 1) * db] != z[j * db..(j + 1) * db] {
                        return Err(format!("slot {slot} block {j} mismatch"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Wire format (coordinator/net/wire.rs, DESIGN.md §2.0.5)
// ---------------------------------------------------------------------

/// Random pushes shaped like a TCP sender's pending slot: `k` messages
/// coalesce into one `Push` (k = 1) or `PushBatch` (k > 1) frame.
fn rand_push_set(rng: &mut Rng) -> Vec<PushMsg> {
    let db = 1 + rng.below(48);
    let k = 1 + rng.below(3);
    (0..k)
        .map(|_| PushMsg {
            worker: rng.below(64),
            block: rng.below(256),
            w: (0..db).map(|_| rng.normal_f32(0.0, 10.0)).collect::<Vec<f32>>().into(),
            worker_epoch: rng.below(1 << 20),
            z_version_used: rng.next_u64(),
            block_seq: rng.next_u64(),
            sent_at: None,
            recycle: None,
        })
        .collect()
}

/// Envelope + bodies, exactly as `TcpPushSender::flush_server` encodes
/// a pending slot.
fn encode_push_frame(msgs: &[PushMsg]) -> Vec<u8> {
    let mut buf = Vec::new();
    let start = if msgs.len() == 1 {
        wire::begin_frame(&mut buf, wire::kind::PUSH)
    } else {
        let s = wire::begin_frame(&mut buf, wire::kind::PUSH_BATCH);
        wire::put_u32(&mut buf, msgs.len() as u32);
        s
    };
    for m in msgs {
        wire::put_push_body(&mut buf, m);
    }
    wire::end_frame(&mut buf, start);
    buf
}

/// Full receive-path decode of one encoded frame: envelope, cursor,
/// bodies, trailing-bytes check.  Returns the decoded pushes.
fn decode_push_frame(bytes: &[u8]) -> Result<Vec<wire::WirePush>, String> {
    let mut slice = bytes;
    let (k, payload) = wire::read_frame(&mut slice)
        .map_err(|e| format!("{e:#}"))?
        .ok_or_else(|| "clean EOF instead of a frame".to_string())?;
    let mut cur = wire::Cursor::new(k, &payload).map_err(|e| format!("{e:#}"))?;
    let count = match k {
        wire::kind::PUSH => 1,
        wire::kind::PUSH_BATCH => cur.u32("count").map_err(|e| format!("{e:#}"))? as usize,
        other => return Err(format!("not a push frame: {}", wire::kind_name(other))),
    };
    let mut out = Vec::new();
    for _ in 0..count {
        out.push(
            wire::take_push_body(&mut cur, &mut |n| AlignedBuf::zeroed(n))
                .map_err(|e| format!("{e:#}"))?,
        );
    }
    cur.finish().map_err(|e| format!("{e:#}"))?;
    Ok(out)
}

/// (i) Wire round-trip: random push sets — batched and not — encode
/// through the full envelope and decode back identically, fields and
/// f32 payload bit-for-bit, with the stream left at a clean boundary.
#[test]
fn prop_wire_push_frames_roundtrip() {
    forall(
        "wire-roundtrip",
        40,
        |rng| rand_push_set(rng),
        |msgs| {
            let buf = encode_push_frame(msgs);
            let got = decode_push_frame(&buf)?;
            if got.len() != msgs.len() {
                return Err(format!("decoded {} of {} pushes", got.len(), msgs.len()));
            }
            for (p, m) in got.iter().zip(msgs) {
                if p.worker != m.worker
                    || p.block != m.block
                    || p.worker_epoch != m.worker_epoch
                    || p.z_version_used != m.z_version_used
                    || p.block_seq != m.block_seq
                {
                    return Err(format!("scalar fields diverged: {p:?}"));
                }
                if p.w.len() != m.w.len()
                    || !p.w.iter().zip(m.w.iter()).all(|(a, b)| a.to_bits() == b.to_bits())
                {
                    return Err("w payload not bit-identical".into());
                }
            }
            // The envelope consumed exactly its own bytes: a second read
            // on the remaining stream is a clean EOF.
            let mut rest = &buf[buf.len()..];
            match wire::read_frame(&mut rest) {
                Ok(None) => Ok(()),
                other => Err(format!("stream not at a frame boundary: {other:?}")),
            }
        },
    );
}

/// (i2) Truncation: cutting an encoded frame at ANY byte yields a
/// contextual error — naming the frame kind and the expected length
/// once the header is readable — and never panics or silently decodes
/// a partial frame.
#[test]
fn prop_wire_truncated_frames_error_contextually() {
    forall(
        "wire-truncation",
        40,
        |rng| {
            let buf = encode_push_frame(&rand_push_set(rng));
            let cut = rng.below(buf.len());
            (buf, cut)
        },
        |(buf, cut)| {
            let kind_byte = buf[4];
            let payload_len = buf.len() - wire::HEADER;
            let err = match decode_push_frame(&buf[..*cut]) {
                Ok(_) => return Err(format!("decoded a frame cut at byte {cut}")),
                Err(e) => e,
            };
            if *cut == 0 {
                // A cut before any byte is a clean EOF, reported as such.
                if !err.contains("clean EOF") {
                    return Err(format!("cut at 0 not a clean EOF: {err}"));
                }
            } else if *cut < wire::HEADER {
                if !err.contains("mid-header") {
                    return Err(format!("header cut lacks context: {err}"));
                }
            } else {
                // Header intact: the error must name the frame kind and
                // the payload length the envelope promised.
                if !err.contains(wire::kind_name(kind_byte)) {
                    return Err(format!("error does not name the frame kind: {err}"));
                }
                if !err.contains("truncated") || !err.contains(&format!("{payload_len}")) {
                    return Err(format!("error lacks the expected length: {err}"));
                }
            }
            Ok(())
        },
    );
}

/// (i3) Corruption safety: flipping any byte of an encoded frame (and
/// the targeted worst cases — unknown kind, oversized claimed length)
/// either fails with a contextual error or decodes without panicking;
/// the bounds-checked cursor never reads out of bounds.
#[test]
fn prop_wire_corrupted_frames_never_panic() {
    forall(
        "wire-corruption",
        40,
        |rng| {
            let buf = encode_push_frame(&rand_push_set(rng));
            let at = rng.below(buf.len());
            let flip = 1 + rng.below(255) as u8;
            (buf, at, flip)
        },
        |(buf, at, flip)| {
            let mut bad = buf.clone();
            bad[*at] ^= flip;
            if *at < 4 {
                // Length-field flips claim the wrong payload size: pad
                // so the claimed bytes exist, to exercise the cursor's
                // bounds checks rather than the stream's EOF path.
                let claimed =
                    u32::from_le_bytes(bad[..4].try_into().unwrap()) as usize;
                if claimed <= wire::MAX_FRAME {
                    bad.resize(wire::HEADER + claimed, 0);
                }
            }
            match decode_push_frame(&bad) {
                Ok(_) => {} // payload flips legitimately round-trip
                Err(e) if e.is_empty() => return Err("empty error context".into()),
                Err(_) => {}
            }
            // Targeted worst cases on top of the random flip:
            let mut unknown = buf.clone();
            unknown[4] = 0xEE;
            let err = decode_push_frame(&unknown).unwrap_err();
            if !err.contains("unknown frame kind") {
                return Err(format!("unknown-kind error lacks context: {err}"));
            }
            let mut oversized = buf.clone();
            oversized[..4]
                .copy_from_slice(&((wire::MAX_FRAME + 1) as u32).to_le_bytes());
            let err = decode_push_frame(&oversized).unwrap_err();
            if !err.contains("exceeds") {
                return Err(format!("oversize-length error lacks context: {err}"));
            }
            Ok(())
        },
    );
}

/// Read one Credit frame through the full envelope + cursor path.
fn decode_credit_frame(bytes: &[u8]) -> Result<wire::WireCredit, String> {
    let mut slice = bytes;
    let (k, payload) = wire::read_frame(&mut slice)
        .map_err(|e| format!("{e:#}"))?
        .ok_or_else(|| "clean EOF instead of a frame".to_string())?;
    if k != wire::kind::CREDIT {
        return Err(format!("not a credit frame: {}", wire::kind_name(k)));
    }
    let mut cur = wire::Cursor::new(k, &payload).map_err(|e| format!("{e:#}"))?;
    let c = wire::take_credit(&mut cur).map_err(|e| format!("{e:#}"))?;
    cur.finish().map_err(|e| format!("{e:#}"))?;
    Ok(c)
}

/// (i4) Credit frames: roundtrip exactly; truncation at every byte
/// errors contextually (kind once the header is readable, field once
/// the payload is short); random byte flips never panic.
#[test]
fn prop_wire_credit_frames_roundtrip_truncate_and_survive_flips() {
    forall(
        "wire-credit",
        40,
        |rng| (rng.below(1 << 20) as u32, rng.next_u64(), rng.next_u64()),
        |(frames, hint, flip_seed)| {
            let mut buf = Vec::new();
            wire::put_credit_frame(&mut buf, *frames, *hint);
            let c = decode_credit_frame(&buf)?;
            if c.frames != *frames || c.hint != *hint {
                return Err(format!("roundtrip diverged: {} / {}", c.frames, c.hint));
            }
            // Stream truncation at every byte: contextual, no panic.
            for cut in 1..buf.len() {
                let err = match decode_credit_frame(&buf[..cut]) {
                    Ok(_) => return Err(format!("decoded a credit frame cut at {cut}")),
                    Err(e) => e,
                };
                if cut < wire::HEADER {
                    if !err.contains("mid-header") {
                        return Err(format!("cut {cut}: header cut lacks context: {err}"));
                    }
                } else if !err.contains("Credit") {
                    return Err(format!("cut {cut}: error does not name the kind: {err}"));
                }
            }
            // Payload truncation behind an intact envelope: the cursor
            // names the missing field.
            for keep in 0..buf.len() - wire::HEADER {
                let mut f = Vec::new();
                let start = wire::begin_frame(&mut f, wire::kind::CREDIT);
                f.extend_from_slice(&buf[wire::HEADER..wire::HEADER + keep]);
                wire::end_frame(&mut f, start);
                let err = decode_credit_frame(&f).unwrap_err();
                if !err.contains("Credit") || !(err.contains("frames") || err.contains("hint")) {
                    return Err(format!("short payload ({keep}B) lacks kind+field: {err}"));
                }
            }
            // Byte flips: decode may fail (with context) but never panic.
            let mut rng = Rng::new(*flip_seed);
            for _ in 0..32 {
                let mut bad = buf.clone();
                let at = rng.below(bad.len());
                bad[at] ^= 1 + rng.below(255) as u8;
                if at < 4 {
                    let claimed = u32::from_le_bytes(bad[..4].try_into().unwrap()) as usize;
                    if claimed <= wire::MAX_FRAME {
                        bad.resize(wire::HEADER + claimed, 0);
                    }
                }
                match decode_credit_frame(&bad) {
                    Ok(_) => {}
                    Err(e) if e.is_empty() => return Err("empty error context".into()),
                    Err(_) => {}
                }
            }
            Ok(())
        },
    );
}

/// A random pull-block case: a base block and a mutated copy with an
/// arbitrary change count (including awkward bit patterns), encoded by
/// the serve-side chooser (sparse when it saves bytes, dense
/// otherwise) into a one-block `PullResp` payload.
fn rand_pull_case(rng: &mut Rng) -> (Vec<f32>, Vec<f32>, u64) {
    let db = 1 + rng.below(96);
    let base: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 10.0)).collect();
    let mut new = base.clone();
    for _ in 0..rng.below(db + 1) {
        let lane = rng.below(db);
        new[lane] = if rng.below(8) == 0 {
            f32::from_bits(rng.next_u64() as u32) // NaN payloads, -0.0, denormals
        } else {
            new[lane] + rng.normal_f32(0.0, 1.0)
        };
    }
    (base, new, rng.next_u64())
}

/// (i5) PullResp v2 blocks: the chooser's encoding — sparse delta or
/// dense — reconstructs the new block bit-identically from the base;
/// truncation behind an intact envelope names kind+field; byte flips
/// never panic (bad patch indices and unknown tags error contextually).
#[test]
fn prop_wire_pull_blocks_roundtrip_bit_identically() {
    forall(
        "wire-pull-v2",
        40,
        |rng| rand_pull_case(rng),
        |(base, new, flip_seed)| {
            let db = base.len();
            let (mut idx, mut vals) = (Vec::new(), Vec::new());
            wire::diff_block(base, new, &mut idx, &mut vals);
            let sparse = wire::sparse_saves_bytes(idx.len(), db);
            let mut payload = Vec::new();
            wire::put_u32(&mut payload, 1);
            if sparse {
                wire::put_pull_block_sparse(&mut payload, 7, 3, 2, &idx, &vals);
            } else {
                wire::put_pull_block_dense(&mut payload, 7, 3, new);
            }
            let decode = |payload: &[u8]| -> Result<wire::WirePullBlock, String> {
                let mut cur = wire::Cursor::new(wire::kind::PULL_RESP, payload)
                    .map_err(|e| format!("{e:#}"))?;
                let count = cur.u32("count").map_err(|e| format!("{e:#}"))?;
                if count != 1 {
                    return Err(format!("count {count}"));
                }
                let b = wire::take_pull_block(&mut cur).map_err(|e| format!("{e:#}"))?;
                cur.finish().map_err(|e| format!("{e:#}"))?;
                Ok(b)
            };
            let b = decode(&payload)?;
            if b.block != 7 || b.version != 3 {
                return Err(format!("header fields diverged: {b:?}"));
            }
            let mut rebuilt = base.clone();
            match &b.body {
                wire::WirePullBody::Dense(d) => {
                    if d.len() != db {
                        return Err("dense length diverged".into());
                    }
                    rebuilt.copy_from_slice(d);
                }
                wire::WirePullBody::Sparse { base_version, idx, vals } => {
                    if *base_version != 2 {
                        return Err("base_version diverged".into());
                    }
                    wire::apply_sparse_patch(&mut rebuilt, idx, vals)
                        .map_err(|e| format!("{e:#}"))?;
                }
            }
            if !rebuilt.iter().zip(new.iter()).all(|(a, b)| a.to_bits() == b.to_bits()) {
                return Err(format!(
                    "reconstruction not bit-identical ({} encoding)",
                    if sparse { "sparse" } else { "dense" }
                ));
            }
            // Truncation: every prefix of the payload errors with the
            // kind and a field name — never panics, never half-decodes.
            for cut in 0..payload.len() {
                let err = match decode(&payload[..cut]) {
                    Ok(_) => return Err(format!("decoded a pull block cut at {cut}")),
                    Err(e) => e,
                };
                if !err.contains("PullResp") {
                    return Err(format!("cut {cut}: error does not name the kind: {err}"));
                }
                let fields =
                    ["count", "block", "version", "enc", "n", "data", "base_version", "k",
                     "idx", "vals", "trailing"];
                if !fields.iter().any(|f| err.contains(f)) {
                    return Err(format!("cut {cut}: error names no field: {err}"));
                }
            }
            // Byte flips (tag included): contextual errors or a clean
            // decode of a differently-valid block; apply_sparse_patch
            // rejects out-of-range indices rather than indexing wild.
            let mut rng = Rng::new(*flip_seed);
            for _ in 0..32 {
                let mut bad = payload.clone();
                let at = rng.below(bad.len());
                bad[at] ^= 1 + rng.below(255) as u8;
                match decode(&bad) {
                    Ok(b) => {
                        let mut scratch = base.clone();
                        if let wire::WirePullBody::Sparse { idx, vals, .. } = &b.body {
                            let _ = wire::apply_sparse_patch(&mut scratch, idx, vals);
                        }
                    }
                    Err(e) if e.is_empty() => return Err("empty error context".into()),
                    Err(_) => {}
                }
            }
            Ok(())
        },
    );
}

/// (h) The uniform block sampler covers all of 𝒩(i).
#[test]
fn prop_block_selection_covers_footprint() {
    forall(
        "selection-coverage",
        10,
        |rng| random_spec(rng),
        |(spec, workers)| {
            let (_, shards) = gen_partitioned(spec, *workers);
            let mut rng = Rng::new(9);
            for shard in &shards {
                let n = shard.n_slots();
                let mut seen = vec![false; n];
                for _ in 0..n * 50 {
                    seen[rng.below(n)] = true;
                }
                if !seen.iter().all(|&s| s) {
                    return Err("uniform selection failed to cover slots".into());
                }
            }
            Ok(())
        },
    );
}

//! `kernel=` dispatch differential tests: the runtime-selected kernel
//! family (scalar / unrolled / simd) is a pure speed knob — it must not
//! change the algorithm.  Every family runs the full threaded `Session`
//! with identical push accounting and lands in the same objective
//! neighborhood; on a host without AVX2, `simd` must resolve to the
//! `unrolled` fallback (visible in `Kernels::name`) and still run.

use asybadmm::config::{Config, KernelKind};
use asybadmm::coordinator::Session;
use asybadmm::data::gen_partitioned;
use asybadmm::sparse::{simd_available, Kernels};

#[test]
fn kernel_families_are_differentially_equivalent() {
    let mut cfg = Config::tiny_test();
    cfg.epochs = 240;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let mut objectives = Vec::new();
    for kind in [KernelKind::Scalar, KernelKind::Unrolled, KernelKind::Simd] {
        let resolved = Kernels::select(kind);
        if kind == KernelKind::Simd && !simd_available() {
            // No AVX2 at runtime: `simd` must degrade to the unrolled
            // table, not crash or go scalar.  The run below then
            // exercises the fallback end-to-end.
            assert_eq!(
                resolved.name, "unrolled",
                "kernel=simd resolved to {:?} on a non-AVX2 host",
                resolved.name
            );
        }
        cfg.kernel = kind;
        let r = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
        assert_eq!(
            r.total_pushes(),
            cfg.epochs * shards.len(),
            "kernel={kind:?} (resolved '{}') broke push accounting",
            resolved.name
        );
        let obj = r.final_objective.total();
        assert!(
            obj.is_finite() && obj < 0.66,
            "kernel={kind:?} (resolved '{}') did not converge: {obj}",
            resolved.name
        );
        objectives.push((kind, resolved.name, obj));
    }
    let min = objectives.iter().map(|&(_, _, o)| o).fold(f64::INFINITY, f64::min);
    let max = objectives.iter().map(|&(_, _, o)| o).fold(f64::NEG_INFINITY, f64::max);
    assert!(
        max - min < 0.08,
        "kernel families disagree beyond async noise: {objectives:?}"
    );
}

#[test]
fn auto_kernel_resolves_to_the_best_available_family() {
    let auto = Kernels::auto();
    if simd_available() {
        assert_eq!(auto.name, "simd");
    } else {
        assert_eq!(auto.name, "unrolled");
    }
    // Explicit portable choices are always honored verbatim.
    assert_eq!(Kernels::select(KernelKind::Scalar).name, "scalar");
    assert_eq!(Kernels::select(KernelKind::Unrolled).name, "unrolled");
    // And `auto` is exactly `select(Auto)` — one resolution rule.
    assert!(std::ptr::eq(auto, Kernels::select(KernelKind::Auto)));
}

//! Chaos suite (DESIGN.md §2.0.3, EXPERIMENTS.md E8): deterministic
//! fault injection × failure policy × scheduling matrix, driven by the
//! in-tree seeded property harness.  The differential gates:
//!
//! - `failure=restart` ends with exactly the fault-free push totals and
//!   lands in the fault-free objective neighborhood;
//! - `failure=degrade` completes on the survivors with the victim's
//!   contribution frozen and the event on the record;
//! - per-(worker, block) FIFO holds exactly across a crash/reconnect
//!   window at the transport+table level, batched or not, both rings;
//! - the stall watchdog and checkpoint/resume paths work end to end.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use asybadmm::config::{Config, FailurePolicy, PlacementKind, TransportKind};
use asybadmm::coordinator::{
    BlockMap, BlockStore, BlockTable, FaultEvent, MpscTransport, Observer, Progress,
    ProxBackend, PushMsg, PushReceiver, ServerShard, Session, SpscRingTransport, Topology,
    TrainReport, Transport, TryRecv,
};
use asybadmm::data::{gen_partitioned, BlockGeometry, Dataset, LossKind, SynthSpec, WorkerShard};
use asybadmm::problem::Problem;
use asybadmm::report::Checkpoint;
use asybadmm::testutil::forall;
use asybadmm::util::rng::Rng;

fn tiny(epochs: usize) -> Config {
    let mut cfg = Config::tiny_test();
    cfg.epochs = epochs;
    cfg
}

fn train(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> TrainReport {
    Session::builder(cfg).dataset(ds, shards).run().unwrap()
}

#[test]
fn restart_policy_matches_fault_free_push_accounting_and_objective() {
    let cfg = tiny(200);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let ff = train(&cfg, &ds, &shards);

    let mut cfg_f = tiny(200);
    cfg_f.faults = "crash:w1@30".into();
    cfg_f.failure = FailurePolicy::Restart;
    let r = train(&cfg_f, &ds, &shards);

    // The replacement resumes the seq stream at the crash watermark, so
    // the run ends with EXACTLY the fault-free totals.
    assert_eq!(r.total_pushes(), ff.total_pushes(), "restart lost or duplicated pushes");
    assert_eq!(r.total_pushes(), cfg.epochs * cfg.n_workers);
    assert!(
        r.faults.contains(&FaultEvent::WorkerCrashed { worker: 1, epoch: 30 }),
        "crash not recorded: {:?}",
        r.faults
    );
    assert!(
        r.faults
            .iter()
            .any(|e| matches!(e, FaultEvent::WorkerRestarted { worker: 1, epoch: 30, .. })),
        "restart not recorded: {:?}",
        r.faults
    );
    // Warm-started duals keep the run in the fault-free neighborhood.
    let (a, b) = (r.final_objective.total(), ff.final_objective.total());
    assert!(a.is_finite() && a < 0.68, "restarted run did not converge: {a}");
    assert!((a - b).abs() < 0.1, "restart drifted: {a} vs fault-free {b}");
    // Recovery health metrics survive into the report.
    assert_eq!(r.worker_stats.len(), cfg.n_workers);
    assert!(r.worker_stats[1].epochs == cfg.epochs, "replacement under-ran its budget");
}

#[test]
fn degrade_policy_completes_on_survivors_with_the_fault_on_record() {
    let mut cfg = tiny(60);
    cfg.faults = "crash:w0@5".into();
    cfg.failure = FailurePolicy::Degrade;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r = train(&cfg, &ds, &shards);

    // The victim contributed its 5 pre-crash pushes (drop-flush delivers
    // any batched remainder); the survivors ran the full budget.
    assert_eq!(r.total_pushes(), (cfg.n_workers - 1) * cfg.epochs + 5);
    assert!(
        r.faults
            .iter()
            .any(|e| matches!(e, FaultEvent::WorkerDegraded { worker: 0, epoch: 5, .. })),
        "degrade not recorded: {:?}",
        r.faults
    );
    assert!(r.final_objective.total().is_finite());
    // Stationarity needs every worker's final duals — a degraded run
    // reports NaN rather than a number computed from a ghost.
    assert!(r.stationarity.is_nan());
    assert!(r.consensus_max.is_nan());
}

#[test]
fn die_policy_propagates_the_injected_panic() {
    let mut cfg = tiny(40);
    cfg.faults = "crash:w1@3".into(); // failure=die is the default
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let result = catch_unwind(AssertUnwindSafe(|| {
        Session::builder(&cfg).dataset(&ds, &shards).run()
    }));
    assert!(result.is_err(), "failure=die swallowed the worker panic");
}

#[test]
fn stall_watchdog_fires_once_per_episode_and_reaches_observers() {
    struct FaultSpy {
        events: Arc<std::sync::Mutex<Vec<FaultEvent>>>,
    }
    impl Observer for FaultSpy {
        fn on_sample(&mut self, _p: &Progress<'_>) {}
        fn on_fault(&mut self, ev: &FaultEvent) {
            self.events.lock().unwrap().push(ev.clone());
        }
    }

    let mut cfg = tiny(40);
    // One injected 120ms straggler on shard 0; the watchdog threshold is
    // far below it, so exactly one no-progress episode must be reported.
    cfg.faults = "stall:s0@5+120ms".into();
    cfg.stall_warn_ms = 25;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
    let r = Session::builder(&cfg)
        .dataset(&ds, &shards)
        .observer(FaultSpy { events: seen.clone() })
        .run()
        .unwrap();

    assert!(
        r.faults
            .iter()
            .any(|e| matches!(e, FaultEvent::ServerStalled { server: 0, after_pushes: 5, ms: 120 })),
        "injected stall not recorded: {:?}",
        r.faults
    );
    let stalls: Vec<_> = r
        .faults
        .iter()
        .filter(|e| matches!(e, FaultEvent::Stalled { .. }))
        .collect();
    // One injected episode → one event.  (A second organic episode is
    // possible on a loaded single-core box, so bound rather than pin.)
    assert!(
        !stalls.is_empty() && stalls.len() <= 2,
        "watchdog fired {} times: {:?}",
        stalls.len(),
        r.faults
    );
    if let FaultEvent::Stalled { waited_ms, .. } = stalls[0] {
        assert!(*waited_ms >= cfg.stall_warn_ms, "fired early: {waited_ms}ms");
    }
    // The observer saw the same stream the report recorded.
    let seen = seen.lock().unwrap();
    assert_eq!(&*seen, &r.faults, "observer stream != report.faults");
    // The stall delayed but never dropped anything.
    assert_eq!(r.total_pushes(), cfg.epochs * cfg.n_workers);
}

#[test]
fn periodic_checkpoint_resumes_placement_and_duals() {
    let path = std::env::temp_dir().join(format!("asybadmm_chaos_{}.ckpt", std::process::id()));
    let bin = path.with_extension("bin");
    let mut cfg = tiny(40);
    cfg.placement = PlacementKind::Dynamic;
    cfg.rebalance_ms = 0;
    cfg.checkpoint_every = 10;
    cfg.checkpoint_path = path.clone();
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r1 = train(&cfg, &ds, &shards);
    assert_eq!(r1.total_pushes(), cfg.epochs * cfg.n_workers);

    let ck = Checkpoint::load(&path).unwrap();
    assert!(ck.epoch >= 10 && ck.epoch <= cfg.epochs, "bad watermark {}", ck.epoch);
    assert_eq!(ck.z.len(), cfg.n_blocks * cfg.block_size);
    assert_eq!(ck.block_owners.len(), cfg.n_blocks, "v2 owner map missing");
    assert_eq!(ck.push_counts.len(), cfg.n_blocks, "v2 push counters missing");
    assert_eq!(ck.duals.len(), cfg.n_workers, "v2 per-worker duals missing");
    for (w, y) in ck.duals.iter().enumerate() {
        assert_eq!(y.len(), shards[w].packed_dim(), "worker {w} dual geometry");
    }

    // Resume: same dataset, fresh budget, state warm-started from the
    // checkpoint.  The resumed run must keep exact push accounting and
    // end at least as converged as the checkpoint it started from.
    let mut cfg2 = tiny(40);
    cfg2.placement = PlacementKind::Dynamic;
    cfg2.rebalance_ms = 0;
    let r2 = Session::builder(&cfg2)
        .dataset(&ds, &shards)
        .resume_from(&ck)
        .run()
        .unwrap();
    assert_eq!(r2.total_pushes(), cfg2.epochs * cfg2.n_workers);
    assert!(
        r2.final_objective.total() <= ck.objective + 0.05,
        "resume regressed: {} from checkpoint {}",
        r2.final_objective.total(),
        ck.objective
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&bin);
}

/// Exact per-(worker, block) FIFO across a crash/reconnect window, at
/// the transport + seq-gated table level: a worker's sender is dropped
/// mid-stream (crash: a partial batch drop-flushes), the endpoint is
/// re-opened with `reconnect_worker`, and the replacement continues the
/// same seq stream — randomized over transports, batch sizes, crash
/// points and drain interleavings.
#[test]
fn prop_fifo_holds_exactly_across_the_restart_window() {
    forall(
        "chaos-restart-fifo",
        10,
        |rng| {
            let workers = 1 + rng.below(3);
            let servers = 2 + rng.below(2);
            let per_worker = 8 + rng.below(24);
            let batch = 1 + rng.below(3);
            let ring = rng.bernoulli(0.5);
            // Which worker crashes, and after how many of its sends.
            let victim = rng.below(workers);
            let crash_after = 1 + rng.below(per_worker - 1);
            (workers, servers, per_worker, batch, ring, victim, crash_after, rng.next_u64())
        },
        |&(workers, servers, per_worker, batch, ring, victim, crash_after, seed)| {
            let (n_blocks, db) = (4usize, 4usize);
            let spec = SynthSpec {
                samples: 8 * workers,
                geometry: BlockGeometry::new(n_blocks, db),
                nnz_per_row: 3,
                blocks_per_worker: n_blocks,
                shared_blocks: n_blocks,
                ..Default::default()
            };
            let (_, data_shards) = gen_partitioned(&spec, workers);
            let topo = Topology::build(&data_shards, n_blocks, servers);
            let store = Arc::new(BlockStore::new(n_blocks, db));
            let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
            let table = Arc::new(BlockTable::new(&topo, store, problem, 2.0, 0.1));
            let map = BlockMap::new(&topo.server_of_block);
            let shards: Vec<ServerShard> = (0..servers)
                .map(|sid| ServerShard::with_table(sid, &topo, table.clone(), false))
                .collect();
            let transport: Box<dyn Transport> = if ring {
                Box::new(SpscRingTransport::new(workers, servers, workers * per_worker, batch))
            } else {
                Box::new(MpscTransport::new(workers, servers, workers * per_worker, batch))
            };
            let mut rng = Rng::new(seed);
            let mut txs: Vec<_> =
                (0..workers).map(|w| Some(transport.connect_worker(w))).collect();
            let mut lanes: Vec<(usize, Box<dyn PushReceiver>)> = (0..servers)
                .flat_map(|s| {
                    transport.connect_server_lanes(s).into_iter().map(move |l| (s, l))
                })
                .collect();

            let value = |w: usize, j: usize, s: u64| (w * 1000 + j * 100) as f32 + s as f32;
            let mut seq = vec![vec![0u64; n_blocks]; workers];
            let mut sent = vec![0usize; workers];
            let mut crashed = false;
            let total = workers * per_worker;
            let mut sent_total = 0usize;
            let mut safety = 0usize;
            while sent_total < total {
                safety += 1;
                if safety > 200 * total + 10_000 {
                    return Err("interleaving did not finish".into());
                }
                let dice = rng.below(5);
                if dice <= 2 {
                    let w = rng.below(workers);
                    if sent[w] >= per_worker {
                        continue;
                    }
                    // The crash window: drop the victim's sender cold
                    // (in-flight partial batch drop-flushes, exactly a
                    // worker thread unwinding), then reconnect — the
                    // replacement continues the SAME seq stream, as the
                    // session seeds `push_seq` from the ledger.
                    if w == victim && sent[w] == crash_after && !crashed {
                        crashed = true;
                        txs[w] = None; // old producer dies first (SPSC)
                        txs[w] = Some(transport.reconnect_worker(w));
                    }
                    let j = rng.below(n_blocks);
                    seq[w][j] += 1;
                    let msg = PushMsg {
                        worker: w,
                        block: j,
                        w: vec![value(w, j, seq[w][j]); db].into(),
                        worker_epoch: sent[w],
                        z_version_used: 0,
                        block_seq: seq[w][j],
                        sent_at: None,
                        recycle: None,
                    };
                    txs[w]
                        .as_mut()
                        .unwrap()
                        .send(map.owner(j), msg)
                        .map_err(|e| format!("send failed: {e:#}"))?;
                    sent[w] += 1;
                    sent_total += 1;
                } else {
                    let k = rng.below(lanes.len());
                    let budget = 1 + rng.below(4);
                    let (s, lane) = &mut lanes[k];
                    for _ in 0..budget {
                        match lane.try_recv() {
                            TryRecv::Msg(m) => shards[*s]
                                .handle_push(&m, &ProxBackend::Native)
                                .map_err(|e| format!("apply failed: {e:#}"))?,
                            _ => break,
                        }
                    }
                }
            }
            for tx in txs.iter_mut().flatten() {
                tx.flush().map_err(|e| format!("flush failed: {e:#}"))?;
            }
            drop(txs);
            transport.shutdown();
            let mut done = vec![false; lanes.len()];
            let mut safety = 0usize;
            while !done.iter().all(|&d| d) {
                safety += 1;
                if safety > 200 * total + 10_000 {
                    return Err("final drain did not terminate".into());
                }
                let k = rng.below(lanes.len());
                if done[k] {
                    continue;
                }
                let (s, lane) = &mut lanes[k];
                match lane.try_recv() {
                    TryRecv::Msg(m) => shards[*s]
                        .handle_push(&m, &ProxBackend::Native)
                        .map_err(|e| format!("apply failed: {e:#}"))?,
                    TryRecv::Done => done[k] = true,
                    TryRecv::Empty => {}
                }
            }

            // Nothing lost across the restart window, nothing parked,
            // every chain applied through its full sequence in order.
            let applied: usize = shards.iter().map(|s| s.stats().pushes).sum();
            if applied != total {
                return Err(format!("applied {applied} of {total}"));
            }
            for j in 0..n_blocks {
                if table.pending_len(j) != 0 {
                    return Err(format!("block {j}: parked pushes stranded"));
                }
                for w in 0..workers {
                    if table.next_seq(j, w) != seq[w][j] + 1 {
                        return Err(format!(
                            "({w},{j}): next_seq {} != sent {} + 1",
                            table.next_seq(j, w),
                            seq[w][j]
                        ));
                    }
                    if seq[w][j] > 0 {
                        let wt = table.w_tilde_of(j, w);
                        let expect = value(w, j, seq[w][j]);
                        if wt[0] != expect {
                            return Err(format!(
                                "({w},{j}): final w̃ {} != last sent {expect}",
                                wt[0]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Session-level chaos matrix: a random crash (victim × epoch) under a
/// random policy × placement × transport must complete with the exact
/// per-policy push accounting, a finite objective, and the transition
/// on the record.
#[test]
fn prop_session_survives_random_fault_plans() {
    let epochs = 40usize;
    forall(
        "chaos-session-matrix",
        6,
        |rng| {
            let victim = rng.below(3);
            let at = 1 + rng.below(epochs / 2);
            let restart = rng.bernoulli(0.5);
            let ring = rng.bernoulli(0.5);
            let placement = rng.below(4);
            let batch = 1 + rng.below(2);
            (victim, at, restart, ring, placement, batch)
        },
        |&(victim, at, restart, ring, placement, batch)| {
            let mut cfg = tiny(epochs);
            cfg.faults = format!("crash:w{victim}@{at}");
            cfg.failure =
                if restart { FailurePolicy::Restart } else { FailurePolicy::Degrade };
            cfg.transport = if ring { TransportKind::SpscRing } else { TransportKind::Mpsc };
            cfg.placement = [
                PlacementKind::Contiguous,
                PlacementKind::Hash,
                PlacementKind::Degree,
                PlacementKind::Dynamic,
            ][placement];
            cfg.rebalance_ms = 0;
            cfg.batch = batch;
            let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
            let r = Session::builder(&cfg)
                .dataset(&ds, &shards)
                .run()
                .map_err(|e| format!("run failed: {e:#}"))?;

            // Degrade may legitimately drop parked (gap-blocked) pushes
            // of the victim under live migration — the event records
            // exactly how many, keeping the accounting exact.
            let dropped: usize = r
                .faults
                .iter()
                .filter_map(|e| match e {
                    FaultEvent::WorkerDegraded { worker, parked_dropped, .. }
                        if *worker == victim =>
                    {
                        Some(*parked_dropped)
                    }
                    _ => None,
                })
                .sum();
            let expect = if restart {
                epochs * cfg.n_workers
            } else {
                (cfg.n_workers - 1) * epochs + at - dropped
            };
            if r.total_pushes() != expect {
                return Err(format!(
                    "pushes {} != {expect} (policy {:?}, dropped {dropped})",
                    r.total_pushes(),
                    cfg.failure
                ));
            }
            let survived = if restart {
                r.faults.iter().any(
                    |e| matches!(e, FaultEvent::WorkerRestarted { worker, .. } if *worker == victim),
                )
            } else {
                r.faults.iter().any(
                    |e| matches!(e, FaultEvent::WorkerDegraded { worker, .. } if *worker == victim),
                )
            };
            if !survived {
                return Err(format!("transition missing from record: {:?}", r.faults));
            }
            if !r.final_objective.total().is_finite() {
                return Err("objective not finite".into());
            }
            Ok(())
        },
    );
}

//! Cross-module integration tests: convergence semantics (Theorem 1's
//! observable consequences), async-vs-sync agreement, delay/γ behaviour,
//! DES scaling shape, and data-pipeline round trips.

use asybadmm::baselines::{run_hogwild_sgd, run_locked_admm, run_sync_admm};
use asybadmm::config::{Backend, BlockSelection, Config, DrainKind, PlacementKind, TransportKind};
use asybadmm::coordinator::{make_transport, push_inflight, Session, TrainReport};
use asybadmm::data::{gen_partitioned, parse_libsvm, partition_even, Dataset, LossKind, WorkerShard};
use asybadmm::problem::Problem;
use asybadmm::sim::{run_sim, CostModel};

/// The unified entry point every test trains through (was `run_async`).
fn train(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> TrainReport {
    Session::builder(cfg).dataset(ds, shards).run().unwrap()
}

fn tiny(epochs: usize) -> Config {
    let mut cfg = Config::tiny_test();
    cfg.epochs = epochs;
    cfg
}

fn sim_cost() -> CostModel {
    CostModel {
        compute_fixed_s: 1e-4,
        compute_per_row_s: 1e-5,
        server_service_s: 1e-5,
        net_mean_s: 1e-4,
        ..CostModel::default()
    }
}

#[test]
fn async_matches_sync_final_objective() {
    // Theorem 1's punchline, observably: asynchrony (bounded delay) does
    // not change where the algorithm goes.  Async epochs touch one block
    // per iteration, sync touches all |N(i)| per epoch — compare at
    // matched block-update counts.
    let cfg_sync = {
        let mut c = tiny(60);
        c.gamma = 0.0;
        c
    };
    let (ds, shards) = gen_partitioned(&cfg_sync.synth_spec(), cfg_sync.n_workers);
    let sync = run_sync_admm(&cfg_sync, &ds, &shards).unwrap();

    // Async needs extra epochs: staleness slows per-update progress.
    let mut cfg_async = tiny(60 * 6); // blocks_per_worker = 4 (+50% slack)
    cfg_async.selection = BlockSelection::Cyclic;
    let async_r = train(&cfg_async, &ds, &shards);

    let (a, b) = (sync.final_objective.total(), async_r.final_objective.total());
    assert!(
        (a - b).abs() < 0.04,
        "sync {a} vs async {b} diverged beyond tolerance"
    );
}

#[test]
fn stationarity_residual_decreases_with_training() {
    let (ds, shards) = gen_partitioned(&tiny(1).synth_spec(), 3);
    let short = train(&tiny(20), &ds, &shards);
    let long = train(&tiny(400), &ds, &shards);
    assert!(
        long.stationarity < short.stationarity,
        "P(X,Y,z) should decay: {} -> {}",
        short.stationarity,
        long.stationarity
    );
    assert!(
        long.consensus_max < short.consensus_max * 2.0,
        "consensus gap exploded"
    );
}

#[test]
fn objective_curve_is_mostly_monotone() {
    let cfg = tiny(300);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r = train(&cfg, &ds, &shards);
    // Allow small async jitter, but the curve must trend down: count
    // increases.
    let objs: Vec<f64> = r.samples.iter().map(|s| s.objective).collect();
    let increases = objs.windows(2).filter(|w| w[1] > w[0] + 1e-4).count();
    assert!(
        increases * 5 <= objs.len(),
        "{increases} increases out of {} samples",
        objs.len()
    );
    assert!(objs.last().unwrap() < &(objs[0] * 0.95));
}

#[test]
fn gamma_stabilizes_large_delay() {
    // E5 (paper §4 remark): with heavy staleness, larger γ must not hurt
    // and should help (or at least keep) convergence vs γ≈0.
    let mk = |gamma: f32| {
        let mut c = tiny(400);
        c.gamma = gamma;
        c.seed = 11;
        c
    };
    let (ds, shards) = gen_partitioned(&mk(0.0).synth_spec(), 3);

    // Heavy delay: workers only refresh z every 8 iterations.
    let run_with_hold = |cfg: &Config| {
        // pull_hold is plumbed through DelayPolicy inside the session via
        // net_delay; emulate by enforcing staleness with sim instead:
        let mut cost = sim_cost();
        cost.net_mean_s = 5e-3; // long network -> very stale pulls
        run_sim(cfg, &ds, &shards, &cost).unwrap()
    };
    let loose = run_with_hold(&mk(0.0));
    let tight = run_with_hold(&mk(0.5));
    // Both converge on this small problem; γ>0 must not be worse than
    // γ=0 by more than noise, and the γ=0 run must not be better than
    // γ-regularized by a large margin (stability).
    let (lo, hi) = (loose.final_objective.total(), tight.final_objective.total());
    assert!(hi < lo + 0.02, "gamma hurt badly: {hi} vs {lo}");
}

#[test]
fn enforced_delay_bound_holds_under_injected_latency() {
    let mut cfg = tiny(120);
    cfg.net_delay_mean_ms = 0.2;
    cfg.max_delay = 3;
    cfg.enforce_delay_bound = true;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r = train(&cfg, &ds, &shards);
    for w in &r.worker_stats {
        assert!(w.max_staleness <= 4, "staleness {} > bound+1", w.max_staleness);
    }
    assert!(r.final_objective.total() < 0.69);
}

#[test]
fn cyclic_and_uniform_selection_both_converge() {
    let (ds, shards) = gen_partitioned(&tiny(1).synth_spec(), 3);
    for sel in [BlockSelection::UniformRandom, BlockSelection::Cyclic] {
        let mut cfg = tiny(240);
        cfg.selection = sel;
        let r = train(&cfg, &ds, &shards);
        assert!(
            r.final_objective.total() < 0.66,
            "{sel:?}: {}",
            r.final_objective.total()
        );
    }
}

#[test]
fn all_methods_reach_comparable_objectives() {
    // ADMM variants agree; HOGWILD-SGD heads the same direction.
    let cfg = tiny(200);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let asy = train(&cfg, &ds, &shards).final_objective.total();
    let locked = {
        // full-vector epochs do 4 blocks each; add slack for its slower
        // per-pass progress under the single global latch.
        run_locked_admm(&tiny(250), &ds, &shards).unwrap().final_objective.total()
    };
    let sgd = run_hogwild_sgd(&tiny(200), &ds, &shards, 0.5)
        .unwrap()
        .final_objective
        .total();
    assert!((asy - locked).abs() < 0.08, "asy {asy} vs locked {locked}");
    assert!(sgd < 0.693, "sgd did not descend: {sgd}");
}

#[test]
fn sim_speedup_is_near_linear_then_saturates() {
    // Shape of paper Table 1: strong scaling to p workers, less than
    // ideal at the top end due to server contention.
    let k = 30;
    let mut times = Vec::new();
    for p in [1usize, 2, 4, 8] {
        let mut cfg = tiny(k);
        cfg.n_workers = p;
        cfg.samples = 192;
        cfg.blocks_per_worker = 4;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), p);
        // Compute-dominated cost model (the paper's regime): per-row
        // work dwarfs the fixed dispatch + network terms, so strong
        // scaling is visible. The Amdahl'd regime is covered by
        // examples/speedup_table1.
        let cost = CostModel {
            compute_fixed_s: 1e-5,
            compute_per_row_s: 2e-4,
            server_service_s: 1e-5,
            net_mean_s: 2e-5,
            ..CostModel::default()
        };
        let r = run_sim(&cfg, &ds, &shards, &cost).unwrap();
        times.push((p, r.time_to_epoch[k]));
    }
    let t1 = times[0].1;
    for &(p, tp) in &times[1..] {
        let speedup = t1 / tp;
        assert!(
            speedup > 0.55 * p as f64,
            "p={p}: speedup {speedup:.2} too far from linear"
        );
        assert!(speedup < 1.3 * p as f64, "p={p}: superlinear {speedup:.2}?");
    }
}

#[test]
fn sim_virtual_time_scales_with_cost_model() {
    let cfg = tiny(40);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let slow = CostModel { compute_per_row_s: 1e-4, ..sim_cost() };
    let fast = CostModel { compute_per_row_s: 1e-6, ..sim_cost() };
    let r_slow = run_sim(&cfg, &ds, &shards, &slow).unwrap();
    let r_fast = run_sim(&cfg, &ds, &shards, &fast).unwrap();
    assert!(r_slow.virtual_time_s > r_fast.virtual_time_s * 2.0);
    // identical numerics regardless of the cost model (same event order
    // is NOT guaranteed, but convergence neighborhood is)
    assert!(
        (r_slow.final_objective.total() - r_fast.final_objective.total()).abs() < 0.02
    );
}

#[test]
fn libsvm_pipeline_end_to_end() {
    // Tiny hand-written libsvm text -> partition -> sync ADMM.
    let mut text = String::new();
    let mut rng = asybadmm::util::rng::Rng::new(4);
    for i in 0..64 {
        let y = if i % 2 == 0 { 1 } else { -1 };
        let f1 = 1 + (i % 8);
        let v = (y as f32) * (1.0 + rng.f32());
        text.push_str(&format!("{y} {f1}:{v} {}:{:.3}\n", 9 + (i % 4), rng.f32()));
    }
    let ds = parse_libsvm(&text, LossKind::Logistic, 4).unwrap();
    let shards = partition_even(&ds, 2);
    let mut cfg = tiny(60);
    cfg.samples = 64;
    cfg.n_blocks = ds.geometry.n_blocks;
    cfg.block_size = 4;
    cfg.n_workers = 2;
    cfg.n_servers = 2;
    cfg.blocks_per_worker = cfg.n_blocks;
    let r = run_sync_admm(&cfg, &ds, &shards).unwrap();
    assert!(r.final_objective.total() < 0.6, "{}", r.final_objective.total());
}

#[test]
fn lasso_squared_loss_converges() {
    let mut cfg = tiny(200);
    cfg.loss = LossKind::Squared;
    cfg.lambda = 1e-3;
    cfg.rho = 4.0;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r = train(&cfg, &ds, &shards);
    let first = r.samples.first().unwrap().objective;
    assert!(
        r.final_objective.total() < first * 0.75,
        "{first} -> {}",
        r.final_objective.total()
    );
}

#[test]
fn single_worker_single_server_degenerates_to_star() {
    // p=1, M servers=1: the architecture degenerates to the Spark-style
    // star topology the paper mentions — must still work.
    let mut cfg = tiny(120);
    cfg.n_workers = 1;
    cfg.n_servers = 1;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), 1);
    let r = train(&cfg, &ds, &shards);
    assert!(r.final_objective.total() < 0.67);
    assert_eq!(r.worker_stats.len(), 1);
}

#[test]
fn transports_are_differentially_equivalent() {
    // Same seed/config under MpscTransport vs SpscRingTransport: the
    // push accounting must be identical (every worker pushes exactly
    // once per epoch regardless of queueing discipline) and both must
    // land in the same objective neighborhood.
    let mut cfg = tiny(240);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let mut run_with = |kind: TransportKind| {
        cfg.transport = kind;
        Session::builder(&cfg).dataset(&ds, &shards).run().unwrap()
    };
    let a = run_with(TransportKind::Mpsc);
    let b = run_with(TransportKind::SpscRing);
    assert_eq!(a.total_pushes(), b.total_pushes(), "push counts diverged");
    assert_eq!(a.total_pushes(), 240 * shards.len());
    let (oa, ob) = (a.final_objective.total(), b.final_objective.total());
    assert!(oa < 0.66, "mpsc did not converge: {oa}");
    assert!(ob < 0.66, "ring did not converge: {ob}");
    assert!((oa - ob).abs() < 0.08, "transports disagree: mpsc {oa} vs ring {ob}");
}

#[test]
fn placement_drain_transport_matrix_is_differentially_equivalent() {
    // The scheduling layer must not change the algorithm: every
    // placement × drain × transport combination — including the
    // adaptive `dynamic` placement migrating blocks mid-run — performs
    // exactly one push per worker epoch and lands in the same objective
    // neighborhood.  (Which shard applies a push and in which
    // interleaving is free; what is applied is not.)
    let mut cfg = tiny(160);
    cfg.batch = 2; // exercise batched slots + the worker's final flush
    cfg.rebalance_ms = 0; // dynamic: scan on every monitor wakeup
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let mut objectives = Vec::new();
    for placement in [
        PlacementKind::Contiguous,
        PlacementKind::Hash,
        PlacementKind::Degree,
        PlacementKind::Dynamic,
    ] {
        for drain in [DrainKind::Owned, DrainKind::Steal] {
            for transport in [TransportKind::Mpsc, TransportKind::SpscRing] {
                cfg.placement = placement;
                cfg.drain = drain;
                cfg.transport = transport;
                let tag = format!("{placement:?}/{drain:?}/{transport:?}");
                let r = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
                assert_eq!(
                    r.total_pushes(),
                    160 * shards.len(),
                    "{tag}: push accounting broke"
                );
                let obj = r.final_objective.total();
                assert!(obj.is_finite() && obj < 0.68, "{tag} did not converge: {obj}");
                objectives.push((tag, obj));
            }
        }
    }
    let min = objectives.iter().map(|(_, o)| *o).fold(f64::INFINITY, f64::min);
    let max = objectives.iter().map(|(_, o)| *o).fold(f64::NEG_INFINITY, f64::max);
    assert!(
        max - min < 0.08,
        "combinations disagree beyond async noise: {objectives:?}"
    );
}

#[test]
fn dynamic_placement_migrates_and_matches_static_objectives() {
    // The adaptive runtime's differential gate: `placement=dynamic`
    // must (a) actually migrate under a Zipf-skewed workload, (b) keep
    // the exact push accounting of the static placements, (c) land in
    // the same objective neighborhood, and (d) spread the applied-push
    // load at least as well as the contiguous baseline it starts from.
    let epochs = 1200usize;
    let mut cfg = tiny(epochs);
    cfg.rebalance_ms = 0; // scan on every monitor wakeup
    // Decisively skewed workload: 3 of each worker's 4 active blocks
    // are the shared low-index head, which the contiguous start parks
    // on shard 0 (≥ 75% of the push rate) — the rebalancer has an
    // unambiguous signal regardless of where the random tails land.
    cfg.shared_blocks = 3;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let run_with = |placement: PlacementKind, cfg: &mut Config| {
        cfg.placement = placement;
        let r = Session::builder(cfg).dataset(&ds, &shards).run().unwrap();
        let counts: Vec<usize> = r.server_stats.iter().map(|s| s.pushes).collect();
        let max = *counts.iter().max().unwrap() as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        (r, max / mean)
    };
    let (r_contig, contig_skew) = run_with(PlacementKind::Contiguous, &mut cfg);
    let (r_degree, _) = run_with(PlacementKind::Degree, &mut cfg);
    let (r_dyn, dyn_skew) = run_with(PlacementKind::Dynamic, &mut cfg);

    assert_eq!(r_dyn.total_pushes(), epochs * shards.len(), "dynamic lost pushes");
    assert_eq!(r_dyn.total_pushes(), r_degree.total_pushes());
    assert_eq!(r_contig.migrations, 0, "static placement migrated");
    assert!(r_dyn.migrations > 0, "no migrations under a Zipf-hot head");

    let (od, og, oc) = (
        r_dyn.final_objective.total(),
        r_degree.final_objective.total(),
        r_contig.final_objective.total(),
    );
    assert!(od.is_finite() && od < 0.66, "dynamic did not converge: {od}");
    assert!((od - og).abs() < 0.08, "dynamic {od} vs degree {og}");
    assert!((od - oc).abs() < 0.08, "dynamic {od} vs contiguous {oc}");

    // Load balance: the whole point of adapting.  Attribution lags the
    // migration (early pushes applied under the contiguous map), so
    // allow slack — but dynamic must not end up worse than the naive
    // static start it began from.
    assert!(
        dyn_skew <= contig_skew + 0.05,
        "dynamic applied-push skew {dyn_skew:.3} worse than contiguous {contig_skew:.3}"
    );
}

#[test]
fn elastic_thread_pool_is_differentially_equivalent() {
    // `server_threads != n_servers` (1 thread for 2 shards, and 3
    // threads for 2 shards) across both transports and the adaptive
    // placement: same pushes, same objective neighborhood.
    let mut cfg = tiny(160);
    cfg.rebalance_ms = 0;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let mut objectives = Vec::new();
    for threads in [1usize, 3] {
        for placement in [PlacementKind::Contiguous, PlacementKind::Dynamic] {
            for transport in [TransportKind::Mpsc, TransportKind::SpscRing] {
                cfg.server_threads = threads;
                cfg.placement = placement;
                cfg.transport = transport;
                let tag = format!("threads={threads}/{placement:?}/{transport:?}");
                let r = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
                assert_eq!(
                    r.total_pushes(),
                    160 * shards.len(),
                    "{tag}: push accounting broke"
                );
                let obj = r.final_objective.total();
                assert!(obj.is_finite() && obj < 0.68, "{tag} did not converge: {obj}");
                objectives.push((tag, obj));
            }
        }
    }
    let min = objectives.iter().map(|(_, o)| *o).fold(f64::INFINITY, f64::min);
    let max = objectives.iter().map(|(_, o)| *o).fold(f64::NEG_INFINITY, f64::max);
    assert!(
        max - min < 0.08,
        "elastic combos disagree beyond async noise: {objectives:?}"
    );
}

#[test]
fn degree_placement_spreads_pushes_across_shards() {
    // Under contiguous placement the Zipf-hot low-index blocks all land
    // on shard 0; degree placement must spread the applied-push load.
    let mut cfg = tiny(200);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let spread = |placement: PlacementKind, cfg: &mut Config| {
        cfg.placement = placement;
        let r = Session::builder(cfg).dataset(&ds, &shards).run().unwrap();
        let counts: Vec<usize> = r.server_stats.iter().map(|s| s.pushes).collect();
        let max = *counts.iter().max().unwrap() as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        (max / mean, counts)
    };
    let (contig_skew, contig_counts) = spread(PlacementKind::Contiguous, &mut cfg);
    let (degree_skew, degree_counts) = spread(PlacementKind::Degree, &mut cfg);
    assert!(
        degree_skew <= contig_skew + 0.05,
        "degree placement did not reduce applied-push skew: \
         contiguous {contig_counts:?} ({contig_skew:.3}) vs degree {degree_counts:?} ({degree_skew:.3})"
    );
}

#[test]
fn explicit_transport_override_is_honored() {
    let cfg = tiny(80);
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let transport = make_transport(
        TransportKind::SpscRing,
        cfg.n_workers,
        cfg.n_servers,
        push_inflight(cfg.n_workers),
        1,
    );
    assert_eq!(transport.name(), "ring");
    let r = Session::builder(&cfg)
        .dataset(&ds, &shards)
        .transport(transport)
        .run()
        .unwrap();
    assert_eq!(r.total_pushes(), 80 * cfg.n_workers);
    assert!(r.final_objective.total().is_finite());
}

#[test]
fn backend_enum_roundtrip_and_config_validation() {
    assert_eq!(Backend::parse("xla").unwrap(), Backend::Xla);
    let mut cfg = Config::default();
    cfg.apply_kv("backend", "xla").unwrap();
    assert_eq!(cfg.backend, Backend::Xla);
    let p = Problem::new(LossKind::Logistic, 1e-5, 1e4);
    assert_eq!(p.curvature_bound(), 0.25);
}

//! XLA-artifact vs native-engine numeric parity — the end-to-end proof
//! that the three layers agree: the Pallas kernels (checked against the
//! jnp oracle by pytest) are lowered to HLO, compiled by the rust PJRT
//! runtime, and must match the rust-native re-implementation of the same
//! formulas on identical inputs.
//!
//! Requires `make artifacts` (the "tiny" shape set). Tests skip with a
//! message if artifacts are missing, and `make test` always builds them
//! first.

use std::path::Path;
use std::rc::Rc;

use asybadmm::admm::{worker_update, NativeEngine};
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::runtime::{Manifest, ServerProxXla, WorkerXla, XlaEngine};
use asybadmm::testutil::assert_allclose;
use asybadmm::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn tiny_setup(
    kind: LossKind,
    samples: usize,
) -> (asybadmm::data::Dataset, Vec<asybadmm::data::WorkerShard>) {
    gen_partitioned(
        &SynthSpec {
            kind,
            samples,
            geometry: BlockGeometry::new(8, 16),
            nnz_per_row: 6,
            blocks_per_worker: 4,
            shared_blocks: 1,
            seed: 7,
            ..Default::default()
        },
        2,
    )
}

#[test]
fn worker_step_xla_matches_native_logistic() {
    let Some(m) = manifest() else { return };
    let (ds, shards) = tiny_setup(LossKind::Logistic, 64);
    let shard = &shards[0];
    let problem = Problem::new(LossKind::Logistic, 1e-4, 1e4);
    let weight = 1.0 / ds.samples() as f32;

    let engine = XlaEngine::new(&m, "logistic", 32, 64, 16).unwrap();
    let mut xla = WorkerXla::new(engine, shard, weight).unwrap();
    let mut native = NativeEngine::new(shard, problem, weight);

    let mut rng = Rng::new(3);
    for slot in 0..shard.n_slots() {
        let z: Vec<f32> = (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let y: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 0.2)).collect();
        let rho = 2.5f32;

        let (wx, yx, xx, loss_x) = xla.step(&z, &y, slot, rho).unwrap();

        let mut g = vec![0.0f32; 16];
        let loss_n = native.grad_block(&z, slot, &mut g);
        let (lo, hi) = shard.slot_range(slot);
        let (mut wn, mut yn, mut xn) = (vec![0.0f32; 16], vec![0.0f32; 16], vec![0.0f32; 16]);
        worker_update(&g, &y, &z[lo..hi], rho, &mut wn, &mut yn, &mut xn);

        assert_allclose(&wx, &wn, 1e-4, 1e-5).unwrap();
        assert_allclose(&yx, &yn, 1e-4, 1e-5).unwrap();
        assert_allclose(&xx, &xn, 1e-4, 1e-5).unwrap();
        assert!((loss_x - loss_n).abs() < 1e-5, "loss {loss_x} vs {loss_n}");
    }
}

#[test]
fn worker_step_xla_matches_native_squared() {
    let Some(m) = manifest() else { return };
    let (ds, shards) = tiny_setup(LossKind::Squared, 64);
    let shard = &shards[1];
    let problem = Problem::new(LossKind::Squared, 0.0, 1e4);
    let weight = 1.0 / ds.samples() as f32;

    let engine = XlaEngine::new(&m, "squared", 32, 64, 16).unwrap();
    let mut xla = WorkerXla::new(engine, shard, weight).unwrap();
    let mut native = NativeEngine::new(shard, problem, weight);

    let mut rng = Rng::new(11);
    let z: Vec<f32> = (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 0.3)).collect();
    let y = vec![0.05f32; 16];
    let (wx, _, _, loss_x) = xla.step(&z, &y, 0, 4.0).unwrap();

    let mut g = vec![0.0f32; 16];
    let loss_n = native.grad_block(&z, 0, &mut g);
    let (mut wn, mut yn, mut xn) = (vec![0.0f32; 16], vec![0.0f32; 16], vec![0.0f32; 16]);
    worker_update(&g, &y, &z[0..16], 4.0, &mut wn, &mut yn, &mut xn);
    assert_allclose(&wx, &wn, 1e-3, 1e-4).unwrap();
    assert!((loss_x - loss_n).abs() / loss_n.abs().max(1e-6) < 1e-3);
}

#[test]
fn multi_chunk_reduction_matches_single_shard_math() {
    // 96 samples at m_chunk=32 => 3 chunks + padding logic in play.
    let Some(m) = manifest() else { return };
    let (ds, shards) = tiny_setup(LossKind::Logistic, 96 * 2);
    let shard = &shards[0]; // 96 rows -> 3 chunks
    assert!(shard.samples() > 64, "want a multi-chunk shard");
    let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
    let weight = 1.0 / ds.samples() as f32;

    let engine = XlaEngine::new(&m, "logistic", 32, 64, 16).unwrap();
    let mut xla = WorkerXla::new(engine, shard, weight).unwrap();
    assert!(xla.n_chunks() >= 3);
    let mut native = NativeEngine::new(shard, problem, weight);

    let mut rng = Rng::new(5);
    let z: Vec<f32> = (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 0.4)).collect();
    let (gx, loss_x) = xla.grad_block(&z, 2).unwrap();
    let mut gn = vec![0.0f32; 16];
    let loss_n = native.grad_block(&z, 2, &mut gn);
    assert_allclose(&gx, &gn, 1e-4, 1e-5).unwrap();
    assert!((loss_x - loss_n).abs() < 1e-5);
}

#[test]
fn server_prox_xla_matches_native() {
    let Some(m) = manifest() else { return };
    let sp = ServerProxXla::load(&m, 16).unwrap();
    let mut rng = Rng::new(9);
    for case in 0..5 {
        let zt: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let ws: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 10.0)).collect();
        let (gamma, denom, lam, clip) = (0.01f32, 6.01f32, 1e-3f32, 0.5f32);
        let zx = sp.prox(&zt, &ws, gamma, denom, lam, clip).unwrap();
        let mut zn = vec![0.0f32; 16];
        asybadmm::admm::prox_l1_box(&zt, &ws, gamma, denom, lam, clip, &mut zn);
        assert_allclose(&zx, &zn, 1e-5, 1e-6).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert!(zx.iter().all(|v| v.abs() <= clip + 1e-6));
    }
}

#[test]
fn objective_artifact_matches_native() {
    let Some(m) = manifest() else { return };
    let (ds, shards) = tiny_setup(LossKind::Logistic, 64);
    let shard = &shards[0];
    let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
    let weight = 1.0 / ds.samples() as f32;
    let engine = XlaEngine::new(&m, "logistic", 32, 64, 16).unwrap();
    let mut xla = WorkerXla::new(engine, shard, weight).unwrap();
    let mut native = NativeEngine::new(shard, problem, weight);
    let mut rng = Rng::new(13);
    let x: Vec<f32> = (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let lx = xla.data_loss(&x).unwrap();
    let ln = native.data_loss(&x);
    assert!((lx - ln).abs() < 1e-5, "{lx} vs {ln}");
}

#[test]
fn full_training_run_xla_vs_native_same_seed() {
    // The strongest parity statement: whole async training runs under the
    // two backends land in the same objective neighborhood. (Not
    // bit-identical: thread interleaving differs.)
    let Some(_) = manifest() else { return };
    let mut cfg = asybadmm::config::Config::tiny_test();
    cfg.epochs = 60;
    cfg.n_workers = 2;
    cfg.n_servers = 1;
    cfg.artifacts_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);

    use asybadmm::coordinator::Session;
    let r_native = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
    let mut cfg_x = cfg.clone();
    cfg_x.backend = asybadmm::config::Backend::Xla;
    let r_xla = Session::builder(&cfg_x).dataset(&ds, &shards).run().unwrap();

    let (a, b) = (r_native.final_objective.total(), r_xla.final_objective.total());
    assert!(
        (a - b).abs() < 0.02,
        "backends diverged: native {a} vs xla {b}"
    );
}

#[test]
fn engine_shape_mismatch_is_loud() {
    let Some(m) = manifest() else { return };
    // Asking for a shape set that does not exist must error with a hint.
    let Err(err) = XlaEngine::new(&m, "logistic", 1234, 64, 16) else {
        panic!("expected shape-mismatch error");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn rc_engine_shared_across_workers() {
    // Two workers on one thread share one compiled engine (Rc).
    let Some(m) = manifest() else { return };
    let (ds, shards) = tiny_setup(LossKind::Logistic, 64);
    let weight = 1.0 / ds.samples() as f32;
    let engine = XlaEngine::new(&m, "logistic", 32, 64, 16).unwrap();
    let mut a = WorkerXla::new(Rc::clone(&engine), &shards[0], weight).unwrap();
    let mut b = WorkerXla::new(engine, &shards[1], weight).unwrap();
    let za = vec![0.0f32; shards[0].packed_dim()];
    let zb = vec![0.0f32; shards[1].packed_dim()];
    let (_, la) = a.grad_block(&za, 0).unwrap();
    let (_, lb) = b.grad_block(&zb, 0).unwrap();
    // Both shards at z=0: per-shard weighted loss sums to ~log(2) overall.
    assert!(((la + lb) as f64 - std::f64::consts::LN_2).abs() < 1e-4);
}

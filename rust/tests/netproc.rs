//! Multi-process differential test (DESIGN.md §2.0.5): one `asybadmm
//! serve` coordinator + two `asybadmm work` processes over real loopback
//! sockets must
//!  * keep exact push accounting (frames applied == frames sent),
//!  * migrate blocks under `placement=dynamic` with a Zipf-hot head,
//!  * land in the same objective neighborhood as the in-process runtime
//!    on an identical config, and
//!  * answer `GET /stats` with live per-shard load + placement mid-run
//!    (probed with a bare `TcpStream` — the CI job stays curl-free).
//!
//! Processes are torn down on any failure via a kill-on-drop guard.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use asybadmm::config::Config;
use asybadmm::coordinator::Session;
use asybadmm::data::gen_partitioned;
use asybadmm::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_asybadmm");

/// Config shared verbatim by the serve process and the in-process
/// reference run.  The shape mirrors `tests/integration.rs`'s dynamic-
/// placement gate: a Zipf-hot 3-block shared head that the contiguous
/// start parks on shard 0, so the rebalancer has an unambiguous signal;
/// `rebalance_ms=0` scans on every monitor wakeup.  The injected
/// 0.1ms-mean network delay keeps the run long enough (>= ~120ms) for
/// the /stats probe to land mid-run without changing where it converges.
const SET: &[(&str, &str)] = &[
    ("samples", "96"),
    ("n_blocks", "8"),
    ("block_size", "16"),
    ("nnz_per_row", "6"),
    ("blocks_per_worker", "4"),
    ("shared_blocks", "3"),
    ("n_workers", "3"),
    ("n_servers", "2"),
    ("epochs", "1200"),
    ("m_chunk", "32"),
    ("d_pad", "64"),
    ("rho", "2"),
    ("lambda", "0.0001"),
    ("placement", "dynamic"),
    ("rebalance_ms", "0"),
    ("batch", "2"),
    ("net_delay_mean_ms", "0.1"),
    ("log_every", "100000"),
];

const EPOCHS: usize = 1200;
const N_WORKERS: usize = 3;

fn set_string(extra: &str) -> String {
    let mut s: String =
        SET.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(",");
    if !extra.is_empty() {
        s.push(',');
        s.push_str(extra);
    }
    s
}

/// Kill-on-drop child guard: a failed assertion must not strand
/// coordinator/worker processes (locally or in CI).
struct Reap(Child);

impl Drop for Reap {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// One curl-free HTTP GET against the stats endpoint.
fn http_get(addr: &str, path: &str) -> std::io::Result<(String, String)> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(2))).ok();
    conn.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw)?;
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    Ok((head.lines().next().unwrap_or("").to_string(), body.to_string()))
}

/// `key=value` token out of the serve summary line.
fn field_u64(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|t| t.strip_prefix(key))
        .unwrap_or_else(|| panic!("no {key:?} field in {line:?}"))
        .trim_end_matches(|c: char| !c.is_ascii_digit())
        .parse()
        .unwrap_or_else(|e| panic!("bad {key:?} field in {line:?}: {e}"))
}

fn objective_of(line: &str) -> f64 {
    line.split("objective ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no objective in {line:?}"))
        .parse()
        .unwrap_or_else(|e| panic!("bad objective in {line:?}: {e}"))
}

#[test]
fn two_worker_processes_match_the_in_process_run() {
    // -- coordinator ---------------------------------------------------
    let mut serve = Reap(
        Command::new(BIN)
            .args([
                "serve",
                "--listen",
                "127.0.0.1:0",
                "--set",
                &set_string("stats_addr=127.0.0.1:0"),
            ])
            .stdout(Stdio::piped())
            .spawn()
            .expect("spawn serve"),
    );
    let mut lines = BufReader::new(serve.0.stdout.take().expect("serve stdout")).lines();
    let (mut listen, mut stats) = (None, None);
    while listen.is_none() || stats.is_none() {
        let line = lines
            .next()
            .expect("serve exited before announcing its addresses")
            .expect("serve stdout");
        if let Some(a) = line.strip_prefix("# listening on ") {
            listen = Some(a.trim().to_string());
        } else if let Some(a) = line.strip_prefix("# stats on ") {
            stats = Some(a.trim().to_string());
        }
    }
    let (listen, stats) = (listen.unwrap(), stats.unwrap());

    // -- two worker processes, ranks 0/2 and 1/2 ----------------------
    let spawn_worker = |rank: &str| {
        Reap(
            Command::new(BIN)
                .args(["work", "--connect", &listen, "--rank", rank])
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn work"),
        )
    };
    let mut w0 = spawn_worker("0/2");
    let mut w1 = spawn_worker("1/2");

    // -- live /stats probe (bare TcpStream; no curl) -------------------
    // serve publishes liveness detail on /healthz: JSON with an overall
    // status ("starting" until the join barrier sizes the rank board,
    // "ok"/"degraded" after) and a per-rank array.
    let (status, body) = http_get(&stats, "/healthz").expect("healthz");
    assert!(status.contains("200"), "healthz: {status}");
    let health = Json::parse(&body).expect("healthz body is JSON");
    let state = health
        .get("status")
        .and_then(|s| match s {
            Json::Str(s) => Some(s.clone()),
            _ => None,
        })
        .expect("healthz status field");
    assert!(
        ["starting", "ok", "degraded"].contains(&state.as_str()),
        "unexpected healthz status {state:?} in {body}"
    );
    assert!(health.get("ranks").is_some(), "healthz must carry a ranks array: {body}");
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut live = None;
    while live.is_none() {
        assert!(
            Instant::now() < deadline,
            "stats probe never saw a live run (pushes_total stayed 0)"
        );
        if let Ok((status, body)) = http_get(&stats, "/stats") {
            assert!(status.contains("200"), "stats: {status}");
            let snap = Json::parse(&body).expect("stats body is JSON");
            let pushes = snap.get("pushes_total").and_then(Json::as_f64).expect("pushes_total");
            if pushes > 0.0 {
                live = Some(snap);
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let snap = live.unwrap();
    match snap.get("placement") {
        Some(Json::Arr(owners)) => {
            assert_eq!(owners.len(), 8, "placement map must cover every block");
            for o in owners {
                let o = o.as_f64().expect("owner index");
                assert!(o == 0.0 || o == 1.0, "owner {o} outside the 2 shards");
            }
        }
        other => panic!("/stats placement missing or not an array: {other:?}"),
    }
    match snap.get("shard_load") {
        Some(Json::Arr(load)) => assert_eq!(load.len(), 2, "one load entry per shard"),
        other => panic!("/stats shard_load missing or not an array: {other:?}"),
    }

    // -- completion + accounting ---------------------------------------
    let done = lines
        .by_ref()
        .map(|l| l.expect("serve stdout"))
        .find(|l| l.starts_with("# done in "))
        .expect("serve exited without a done line");
    assert!(serve.0.wait().expect("wait serve").success(), "serve failed");
    assert!(w0.0.wait().expect("wait rank 0").success(), "rank 0/2 failed");
    assert!(w1.0.wait().expect("wait rank 1").success(), "rank 1/2 failed");

    let applied = field_u64(&done, "pushes=");
    let sent = field_u64(&done, "sent=");
    let migrations = field_u64(&done, "migrations=");
    let pull_rounds = field_u64(&done, "pull_rounds=");
    let pull_empty = field_u64(&done, "pull_empty=");
    assert!(pull_rounds > 0, "no mirror-sync rounds recorded: {done}");
    assert!(pull_empty <= pull_rounds, "empty polls exceed total rounds: {done}");
    assert_eq!(
        applied,
        (EPOCHS * N_WORKERS) as u64,
        "push accounting broke across processes: {done}"
    );
    assert_eq!(applied, sent, "applied != sent across the wire: {done}");
    assert!(migrations > 0, "no migrations under a Zipf-hot head: {done}");

    // -- differential: same config, in-process runtime -----------------
    let obj_mp = objective_of(&done);
    let mut cfg = Config::default();
    for (k, v) in SET {
        cfg.apply_kv(k, v).unwrap();
    }
    cfg.validate().unwrap();
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    let r = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
    let obj_ip = r.final_objective.total();
    assert!(obj_mp.is_finite() && obj_mp < 0.68, "multi-process did not converge: {obj_mp}");
    // The worker processes iterate against a pulled mirror of z (up to
    // ~one poll interval stale) instead of the live store, so allow a
    // slightly wider neighborhood than the in-process transport matrix.
    assert!(
        (obj_mp - obj_ip).abs() < 0.1,
        "multi-process {obj_mp} vs in-process {obj_ip} beyond async noise"
    );
}

/// Adaptive pull cadence (DESIGN.md §2.0.6): with one slow worker
/// (20ms mean injected delay between pushes) the mirror stream is idle
/// almost all the time, so the exponential backoff must issue far
/// fewer round-trips than the old fixed 500µs poll would have.  The
/// serve summary's aggregated pull accounting proves it.
#[test]
fn adaptive_pull_cadence_beats_fixed_polling_on_an_idle_tail() {
    let set = "samples=32,n_blocks=4,block_size=16,nnz_per_row=4,blocks_per_worker=4,\
               shared_blocks=1,n_workers=1,n_servers=1,epochs=40,rho=2,lambda=0.0001,\
               batch=1,net_delay_mean_ms=20,log_every=100000";
    let mut serve = Reap(
        Command::new(BIN)
            .args(["serve", "--listen", "127.0.0.1:0", "--set", set])
            .stdout(Stdio::piped())
            .spawn()
            .expect("spawn serve"),
    );
    let mut lines = BufReader::new(serve.0.stdout.take().expect("serve stdout")).lines();
    let listen = loop {
        let line = lines
            .next()
            .expect("serve exited before announcing its address")
            .expect("serve stdout");
        if let Some(a) = line.strip_prefix("# listening on ") {
            break a.trim().to_string();
        }
    };
    let mut worker = Reap(
        Command::new(BIN)
            .args(["work", "--connect", &listen, "--rank", "0/1"])
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn work"),
    );
    let done = lines
        .by_ref()
        .map(|l| l.expect("serve stdout"))
        .find(|l| l.starts_with("# done in "))
        .expect("serve exited without a done line");
    assert!(serve.0.wait().expect("wait serve").success(), "serve failed");
    assert!(worker.0.wait().expect("wait rank 0").success(), "rank 0/1 failed");

    let elapsed_s: f64 = done
        .strip_prefix("# done in ")
        .and_then(|rest| rest.split('s').next())
        .expect("elapsed in done line")
        .parse()
        .expect("elapsed parses");
    let rounds = field_u64(&done, "pull_rounds=");
    let empty = field_u64(&done, "pull_empty=");
    assert!(rounds > 0, "no pull rounds recorded: {done}");
    assert!(empty <= rounds, "empty rounds exceed total: {done}");
    assert!(
        elapsed_s > 0.2,
        "run too short to compare cadences ({elapsed_s}s): raise the injected delay"
    );
    // A fixed 500µs poll would have issued ~elapsed/500µs round-trips;
    // the 500µs→8ms backoff (publish-hint resets included) must cut
    // that by well over half on this mostly-idle stream.
    let fixed_cadence_rounds = elapsed_s / 500e-6;
    assert!(
        (rounds as f64) < fixed_cadence_rounds * 0.5,
        "adaptive cadence did not reduce round-trips: {rounds} rounds in {elapsed_s:.3}s \
         (fixed-cadence estimate {fixed_cadence_rounds:.0}): {done}"
    );
}

#[test]
fn serve_rejects_malformed_listen_addr_naming_the_form() {
    let out = Command::new(BIN)
        .args(["serve", "--listen", "not-an-addr", "--set", "epochs=1"])
        .output()
        .expect("run serve");
    assert!(!out.status.success(), "serve accepted a malformed listen address");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("host:port"), "error should show the form: {stderr}");
}

#[test]
fn work_rejects_out_of_range_rank() {
    let out = Command::new(BIN)
        .args(["work", "--connect", "127.0.0.1:9", "--rank", "5/2"])
        .output()
        .expect("run work");
    assert!(!out.status.success(), "work accepted rank 5/2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("rank must be in 0..2"), "unexpected error: {stderr}");
}

//! Experiment/runtime configuration: one struct, three sources layered in
//! order — defaults, config file (TOML-subset `key = value` lines, with
//! `[section]` headers allowed and flattened), CLI `--set key=value`
//! overrides.  Every run logs its full resolved config so experiments in
//! EXPERIMENTS.md are reproducible from the header alone.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::data::LossKind;

/// Which compute backend executes the worker/server numeric steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT XLA artifacts via PJRT — the production three-layer path.
    Xla,
    /// Pure-rust CSR math — ablation baseline + DES numeric engine.
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "xla" => Ok(Backend::Xla),
            "native" => Ok(Backend::Native),
            other => anyhow::bail!("unknown backend {other:?} (xla|native)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Xla => "xla",
            Backend::Native => "native",
        }
    }
}

/// Which push transport carries worker→server messages
/// (see `coordinator/transport.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// One bounded `std::sync::mpsc::sync_channel` per server shard —
    /// simple, but all workers serialize on the channel's internal lock.
    Mpsc,
    /// Per-(worker, server) SPSC rings with atomic head/tail — no
    /// shared queue lock anywhere on the push path.
    SpscRing,
    /// Per-(worker, server) loopback TCP sockets with the same FIFO /
    /// bounded-in-flight / drain contract (`coordinator/net/tcp.rs`) —
    /// the single-process face of the multi-process runtime
    /// (`asybadmm serve` / `asybadmm work`).
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "mpsc" => Ok(TransportKind::Mpsc),
            "ring" => Ok(TransportKind::SpscRing),
            "tcp" => Ok(TransportKind::Tcp),
            other => anyhow::bail!("unknown transport {other:?} (mpsc|ring|tcp)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TransportKind::Mpsc => "mpsc",
            TransportKind::SpscRing => "ring",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Block→server-shard placement policy
/// (see `coordinator/placement.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// Equal contiguous block-id ranges per shard (the default; load-
    /// blind, so the Zipf-hot low-index blocks all land on shard 0).
    Contiguous,
    /// Block j → shard j mod S — the pre-placement-layer hard-coded
    /// assignment, kept selectable for continuity.
    RoundRobin,
    /// Multiplicative hash of the block id — production-PS key spread.
    Hash,
    /// Greedy largest-degree-first packing by |𝒩(j)| so hot blocks land
    /// on distinct shards.
    Degree,
    /// Adaptive: start contiguous, then migrate hot blocks between
    /// shards at runtime from observed applied-push rates
    /// (`coordinator/rebalance.rs`; cadence = `rebalance_ms`).
    Dynamic,
}

impl PlacementKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "contiguous" => Ok(PlacementKind::Contiguous),
            "roundrobin" => Ok(PlacementKind::RoundRobin),
            "hash" => Ok(PlacementKind::Hash),
            "degree" => Ok(PlacementKind::Degree),
            "dynamic" => Ok(PlacementKind::Dynamic),
            other => {
                anyhow::bail!(
                    "unknown placement {other:?} (contiguous|roundrobin|hash|degree|dynamic)"
                )
            }
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PlacementKind::Contiguous => "contiguous",
            PlacementKind::RoundRobin => "roundrobin",
            PlacementKind::Hash => "hash",
            PlacementKind::Degree => "degree",
            PlacementKind::Dynamic => "dynamic",
        }
    }
}

/// Server-thread queue-draining policy (see `coordinator/sched.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainKind {
    /// Each server thread drains only its own shard's lanes (the
    /// original behavior).
    Owned,
    /// A thread whose own lanes run dry CAS-claims pending lanes of a
    /// busier shard and drains them — whole lanes, never single
    /// messages, so per-(worker, block) FIFO is preserved.
    Steal,
}

impl DrainKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "owned" => Ok(DrainKind::Owned),
            "steal" => Ok(DrainKind::Steal),
            other => anyhow::bail!("unknown drain policy {other:?} (owned|steal)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            DrainKind::Owned => "owned",
            DrainKind::Steal => "steal",
        }
    }
}

/// Which implementation family the hot-path compute kernels use
/// (spmv / block gradient / prox / w̃-sum; see `sparse/simd.rs` and
/// DESIGN.md §2.0.4).  All variants are gated bit-identical, so this is
/// purely a speed/portability knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Plain one-element-at-a-time loops (the differential reference).
    Scalar,
    /// 4-wide hand-unrolled loops LLVM autovectorizes (the PR-1..5
    /// hot path; portable to every ISA).
    Unrolled,
    /// Explicit AVX2 `std::arch` intrinsics.  Falls back to `unrolled`
    /// at dispatch time when the host lacks AVX2.
    Simd,
    /// `simd` when the host supports it, else `unrolled` (default).
    Auto,
}

impl KernelKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "scalar" => Ok(KernelKind::Scalar),
            "unrolled" => Ok(KernelKind::Unrolled),
            "simd" => Ok(KernelKind::Simd),
            "auto" => Ok(KernelKind::Auto),
            other => anyhow::bail!("unknown kernel kind {other:?} (scalar|unrolled|simd|auto)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Unrolled => "unrolled",
            KernelKind::Simd => "simd",
            KernelKind::Auto => "auto",
        }
    }
}

/// What the session does when a worker thread dies mid-run
/// (see `coordinator/fault.rs` and DESIGN.md §2.0.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Re-raise the worker's panic and tear the run down (the
    /// historical behavior; default).
    Die,
    /// Retire the dead worker: drop its gap-blocked parked pushes,
    /// freeze its dual contribution, finish on the survivors, and
    /// record the event in `TrainReport::faults`.
    Degrade,
    /// Spawn a replacement on the same data partition: wait for the
    /// dead worker's in-flight tail to drain, warm-start duals from the
    /// server-side w̃ cache, and resume the per-(worker, block) seq
    /// stream exactly where it stopped.
    Restart,
}

impl FailurePolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "die" => Ok(FailurePolicy::Die),
            "degrade" => Ok(FailurePolicy::Degrade),
            "restart" => Ok(FailurePolicy::Restart),
            other => anyhow::bail!("unknown failure policy {other:?} (die|degrade|restart)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FailurePolicy::Die => "die",
            FailurePolicy::Degrade => "degrade",
            FailurePolicy::Restart => "restart",
        }
    }
}

/// Block selection rule on workers (paper uses uniform random; cyclic is
/// the variant mentioned for the experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockSelection {
    UniformRandom,
    Cyclic,
}

impl BlockSelection {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "uniform" => Ok(BlockSelection::UniformRandom),
            "cyclic" => Ok(BlockSelection::Cyclic),
            other => anyhow::bail!("unknown block selection {other:?} (uniform|cyclic)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BlockSelection::UniformRandom => "uniform",
            BlockSelection::Cyclic => "cyclic",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Config {
    // -- problem ---------------------------------------------------------
    pub loss: LossKind,
    /// l1 coefficient λ (paper Eq. 22).
    pub lambda: f32,
    /// Box clip C (paper: 1e4).
    pub clip: f32,

    // -- data ------------------------------------------------------------
    pub samples: usize,
    pub n_blocks: usize,
    pub block_size: usize,
    pub nnz_per_row: usize,
    pub blocks_per_worker: usize,
    pub shared_blocks: usize,
    pub zipf_s: f64,
    pub noise: f64,
    /// Optional libsvm file; replaces the synthetic generator.
    pub data_path: Option<PathBuf>,

    // -- topology ----------------------------------------------------------
    pub n_workers: usize,
    pub n_servers: usize,
    /// Block→shard placement policy
    /// (`contiguous` | `roundrobin` | `hash` | `degree` | `dynamic`).
    pub placement: PlacementKind,

    // -- algorithm ---------------------------------------------------------
    /// Penalty ρ_i (paper experiment: 100, uniform across workers).
    pub rho: f32,
    /// Server regularization γ (paper experiment: 0.01).
    pub gamma: f32,
    /// Local epochs per worker (T in Algorithm 1).
    pub epochs: usize,
    pub selection: BlockSelection,
    /// Bounded-delay cap T_ij (Assumption 3); staleness beyond this is a
    /// hard error when `enforce_delay_bound`.
    pub max_delay: usize,
    pub enforce_delay_bound: bool,

    // -- execution ---------------------------------------------------------
    pub backend: Backend,
    /// Worker→server push queueing discipline (`mpsc` | `ring`).
    pub transport: TransportKind,
    /// Server-thread drain policy (`owned` | `steal`).
    pub drain: DrainKind,
    /// Hot-path compute kernel family
    /// (`scalar` | `unrolled` | `simd` | `auto`; `sparse/simd.rs`).
    pub kernel: KernelKind,
    /// Server threads servicing the shards' lanes.  0 (default) = one
    /// thread per shard (the classic shape).  Any other value runs an
    /// elastic pool: every thread services all shards' lanes (own-first
    /// affinity), so oversubscribed shards borrow CPU and
    /// `n_threads != n_servers` exercises the same code shape on 1-core
    /// CI hosts as on many-core machines (`coordinator/sched.rs`).
    pub server_threads: usize,
    /// Milliseconds between dynamic-rebalance scans
    /// (`placement=dynamic` only; 0 = scan on every monitor wakeup).
    pub rebalance_ms: u64,
    /// Max w-blocks coalesced per transport slot (1 = unbatched).  The
    /// ring transport packs whole [`PushMsg`] batches into one slot to
    /// amortize per-message overhead when workers own many blocks.
    pub batch: usize,
    pub artifacts_dir: PathBuf,
    /// Rows per AOT chunk; must match an artifact shape set.
    pub m_chunk: usize,
    /// Padded packed width; must match an artifact shape set.
    pub d_pad: usize,
    /// Injected network delay (virtual/real ms) mean; 0 disables.
    pub net_delay_mean_ms: f64,
    /// Workers refresh their cached z̃ only every `pull_hold` iterations
    /// (1 = every iteration); >1 injects deterministic staleness (E5).
    pub pull_hold: usize,
    pub seed: u64,
    /// Log the objective every `log_every` epochs (0 = only at end).
    pub log_every: usize,

    // -- robustness --------------------------------------------------------
    /// Deterministic fault-injection spec, `;`-separated
    /// (`crash:w<W>@<E>`, `stall:s<S>@<P>+<MS>ms`,
    /// `sendfail:w<W>@<E>x<N>`); empty = no faults and the hooks cost
    /// one branch (`coordinator/fault.rs`).
    pub faults: String,
    /// What a dead worker does to the run (`die` | `degrade` |
    /// `restart`).
    pub failure: FailurePolicy,
    /// Watchdog: warn observers with a `Stalled` event when no worker
    /// publishes progress for this many ms (0 = off).
    pub stall_warn_ms: u64,
    /// Networked runtime: a worker rank whose control stream is silent
    /// for this many ms is declared dead (the `failure` policy decides
    /// what happens next).  Workers heartbeat at a third of this
    /// deadline.  0 (default) = liveness tracking off; socket resets
    /// are still detected immediately.
    pub net_liveness_ms: u64,
    /// Networked runtime: how long `asybadmm serve` waits for all ranks
    /// to join before giving up (naming the missing ranks).
    pub join_timeout_ms: u64,
    /// Pull-cadence floor in microseconds (the worker mirror's fastest
    /// re-poll after a productive round).  Hot-reloadable.
    pub pull_floor_us: u64,
    /// Pull-cadence ceiling in milliseconds (the idle mirror's slowest
    /// re-poll).  Hot-reloadable.
    pub pull_ceil_ms: u64,
    /// Write a v2 checkpoint from the monitor thread every this many
    /// epochs of global progress (0 = off).
    pub checkpoint_every: usize,
    /// Where periodic checkpoints land (header file; `.bin` sidecar
    /// beside it).
    pub checkpoint_path: PathBuf,

    // -- observability -----------------------------------------------------
    /// `host:port` for the hand-rolled HTTP/1.1 stats endpoint
    /// (`GET /stats`, `GET /healthz`; `coordinator/net/http.rs`).
    /// Empty (default) = no endpoint.
    pub stats_addr: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            loss: LossKind::Logistic,
            lambda: 1e-5,
            clip: 1e4,
            samples: 8192,
            n_blocks: 32,
            block_size: 512,
            nnz_per_row: 40,
            blocks_per_worker: 8,
            shared_blocks: 2,
            zipf_s: 1.1,
            noise: 0.05,
            data_path: None,
            n_workers: 4,
            n_servers: 2,
            placement: PlacementKind::Contiguous,
            // Paper uses rho=100 with *unweighted* per-sample losses; this
            // repo weights by 1/m (Eq. 22's mean), which rescales the
            // block Lipschitz constants by 1/m, so the equivalent
            // penalty is O(1).  rho=4 satisfies rho > 4·L_ij for the
            // default synthetic workload (see admm::penalty).
            rho: 4.0,
            gamma: 0.01,
            epochs: 100,
            selection: BlockSelection::UniformRandom,
            max_delay: 16,
            enforce_delay_bound: false,
            backend: Backend::Native,
            transport: TransportKind::Mpsc,
            drain: DrainKind::Owned,
            kernel: KernelKind::Auto,
            server_threads: 0,
            rebalance_ms: 1,
            batch: 1,
            artifacts_dir: PathBuf::from("artifacts"),
            m_chunk: 2048,
            d_pad: 4096,
            net_delay_mean_ms: 0.0,
            pull_hold: 1,
            seed: 42,
            log_every: 5,
            faults: String::new(),
            failure: FailurePolicy::Die,
            stall_warn_ms: 0,
            net_liveness_ms: 0,
            join_timeout_ms: 60_000,
            pull_floor_us: 500,
            pull_ceil_ms: 8,
            checkpoint_every: 0,
            checkpoint_path: PathBuf::from("reports/auto.ckpt"),
            stats_addr: String::new(),
        }
    }
}

impl Config {
    /// A tiny config used across unit/integration tests: matches the
    /// "tiny" artifact shape set (m_chunk=32, d_pad=64, db=16).
    pub fn tiny_test() -> Self {
        Config {
            samples: 96,
            n_blocks: 8,
            block_size: 16,
            nnz_per_row: 6,
            blocks_per_worker: 4,
            shared_blocks: 1,
            n_workers: 3,
            n_servers: 2,
            epochs: 30,
            m_chunk: 32,
            d_pad: 64,
            rho: 2.0,
            lambda: 1e-4,
            log_every: 1,
            ..Default::default()
        }
    }

    /// The "small" artifact shape set (m_chunk=256, d_pad=512, db=64).
    pub fn small() -> Self {
        Config {
            samples: 2048,
            n_blocks: 16,
            block_size: 64,
            nnz_per_row: 16,
            blocks_per_worker: 8,
            shared_blocks: 2,
            n_workers: 4,
            n_servers: 2,
            epochs: 100,
            m_chunk: 256,
            d_pad: 512,
            ..Default::default()
        }
    }

    /// Every key `apply_kv` accepts, for discoverability in error
    /// messages and `--help` text.  Keep in sync with the match below.
    pub const KEYS: &'static [&'static str] = &[
        "loss",
        "lambda",
        "clip",
        "samples",
        "n_blocks",
        "block_size",
        "nnz_per_row",
        "blocks_per_worker",
        "shared_blocks",
        "zipf_s",
        "noise",
        "data_path",
        "n_workers",
        "n_servers",
        "placement",
        "drain",
        "kernel",
        "server_threads",
        "rebalance_ms",
        "batch",
        "rho",
        "gamma",
        "epochs",
        "selection",
        "max_delay",
        "enforce_delay_bound",
        "backend",
        "transport",
        "artifacts_dir",
        "m_chunk",
        "d_pad",
        "net_delay_mean_ms",
        "pull_hold",
        "seed",
        "log_every",
        "faults",
        "failure",
        "stall_warn_ms",
        "net_liveness_ms",
        "join_timeout_ms",
        "pull_floor_us",
        "pull_ceil_ms",
        "checkpoint_every",
        "checkpoint_path",
        "stats_addr",
    ];

    /// The runtime-safe subset `POST /config` may change on a live
    /// `asybadmm serve` (applied atomically, republished to workers via
    /// a `ConfigUpdate` frame).  Everything else shapes data, threads
    /// or wire geometry and requires a restart.
    pub const RELOADABLE_KEYS: &'static [&'static str] = &[
        "rebalance_ms",
        "stall_warn_ms",
        "net_liveness_ms",
        "pull_floor_us",
        "pull_ceil_ms",
    ];

    /// `apply_kv`, restricted to [`Config::RELOADABLE_KEYS`].  A known
    /// but non-reloadable key gets an error listing what *is*
    /// reloadable (mirroring the unknown-key error's shape).
    pub fn apply_reload_kv(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        let k = key.trim();
        if !Self::RELOADABLE_KEYS.contains(&k) {
            anyhow::bail!(
                "config key {k:?} is not hot-reloadable; reloadable keys: {}",
                Self::RELOADABLE_KEYS.join(", ")
            );
        }
        self.apply_kv(k, value)
    }

    pub fn apply_kv(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        // Like unknown *keys*, an unrejectable *value* must say what
        // would have been accepted: the enum `parse` impls list their
        // variants, and scalar parses are wrapped so the error names
        // the key and the offending value instead of a bare
        // "invalid digit found in string".
        fn scalar<T: std::str::FromStr>(key: &str, v: &str) -> anyhow::Result<T>
        where
            T::Err: std::error::Error + Send + Sync + 'static,
        {
            v.parse::<T>()
                .with_context(|| format!("invalid value {v:?} for config key {key:?}"))
        }
        let v = value.trim().trim_matches('"');
        let key = key.trim();
        match key {
            "loss" => self.loss = LossKind::parse(v)?,
            "lambda" => self.lambda = scalar(key, v)?,
            "clip" => self.clip = scalar(key, v)?,
            "samples" => self.samples = scalar(key, v)?,
            "n_blocks" => self.n_blocks = scalar(key, v)?,
            "block_size" => self.block_size = scalar(key, v)?,
            "nnz_per_row" => self.nnz_per_row = scalar(key, v)?,
            "blocks_per_worker" => self.blocks_per_worker = scalar(key, v)?,
            "shared_blocks" => self.shared_blocks = scalar(key, v)?,
            "zipf_s" => self.zipf_s = scalar(key, v)?,
            "noise" => self.noise = scalar(key, v)?,
            "data_path" => self.data_path = Some(PathBuf::from(v)),
            "n_workers" => self.n_workers = scalar(key, v)?,
            "n_servers" => self.n_servers = scalar(key, v)?,
            "placement" => self.placement = PlacementKind::parse(v)?,
            "drain" => self.drain = DrainKind::parse(v)?,
            "kernel" => self.kernel = KernelKind::parse(v)?,
            "server_threads" => self.server_threads = scalar(key, v)?,
            "rebalance_ms" => self.rebalance_ms = scalar(key, v)?,
            "batch" => self.batch = scalar(key, v)?,
            "rho" => self.rho = scalar(key, v)?,
            "gamma" => self.gamma = scalar(key, v)?,
            "epochs" => self.epochs = scalar(key, v)?,
            "selection" => self.selection = BlockSelection::parse(v)?,
            "max_delay" => self.max_delay = scalar(key, v)?,
            "enforce_delay_bound" => self.enforce_delay_bound = scalar(key, v)?,
            "backend" => self.backend = Backend::parse(v)?,
            "transport" => self.transport = TransportKind::parse(v)?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(v),
            "m_chunk" => self.m_chunk = scalar(key, v)?,
            "d_pad" => self.d_pad = scalar(key, v)?,
            "net_delay_mean_ms" => self.net_delay_mean_ms = scalar(key, v)?,
            "pull_hold" => self.pull_hold = scalar(key, v)?,
            "seed" => self.seed = scalar(key, v)?,
            "log_every" => self.log_every = scalar(key, v)?,
            "faults" => self.faults = v.to_string(),
            "failure" => self.failure = FailurePolicy::parse(v)?,
            "stall_warn_ms" => self.stall_warn_ms = scalar(key, v)?,
            "net_liveness_ms" => self.net_liveness_ms = scalar(key, v)?,
            "join_timeout_ms" => self.join_timeout_ms = scalar(key, v)?,
            "pull_floor_us" => self.pull_floor_us = scalar(key, v)?,
            "pull_ceil_ms" => self.pull_ceil_ms = scalar(key, v)?,
            "checkpoint_every" => self.checkpoint_every = scalar(key, v)?,
            "checkpoint_path" => self.checkpoint_path = PathBuf::from(v),
            "stats_addr" => self.stats_addr = v.to_string(),
            other => anyhow::bail!(
                "unknown config key {other:?}; valid keys: {}",
                Self::KEYS.join(", ")
            ),
        }
        Ok(())
    }

    /// Parse a TOML-subset config file: `key = value` lines; `[section]`
    /// headers and `#` comments ignored (sections are flat namespace).
    pub fn apply_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path:?}:{}: expected key = value", lineno + 1))?;
            self.apply_kv(k, v)
                .with_context(|| format!("{path:?}:{}", lineno + 1))?;
        }
        Ok(())
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers > 0, "n_workers must be > 0");
        anyhow::ensure!(self.n_servers > 0, "n_servers must be > 0");
        anyhow::ensure!(
            self.n_servers <= self.n_blocks,
            "n_servers ({}) cannot exceed n_blocks ({})",
            self.n_servers,
            self.n_blocks
        );
        // Upper bound is a sanity ceiling: ring slots and the push pool
        // pre-allocate per-batch capacity, so a fat-fingered
        // `batch=1000000000` would OOM at startup instead of erroring.
        anyhow::ensure!(
            (1..=1024).contains(&self.batch),
            "batch must be in [1, 1024]"
        );
        // Same class of sanity ceiling as `batch`: an elastic pool of a
        // million threads is a typo, not a deployment.
        anyhow::ensure!(
            self.server_threads <= 1024,
            "server_threads must be <= 1024 (0 = one thread per shard)"
        );
        anyhow::ensure!(self.rho > 0.0, "rho must be positive");
        anyhow::ensure!(self.gamma >= 0.0, "gamma must be non-negative");
        anyhow::ensure!(self.lambda >= 0.0, "lambda must be non-negative");
        anyhow::ensure!(self.clip > 0.0, "clip must be positive");
        anyhow::ensure!(
            self.blocks_per_worker >= self.shared_blocks,
            "blocks_per_worker < shared_blocks"
        );
        anyhow::ensure!(
            self.blocks_per_worker <= self.n_blocks,
            "blocks_per_worker > n_blocks"
        );
        anyhow::ensure!(self.d_pad % self.block_size == 0, "d_pad % block_size != 0");
        // The fixed-shape XLA artifacts bound the packed worker width;
        // the native/DES paths handle any width.
        if self.backend == Backend::Xla {
            anyhow::ensure!(
                self.blocks_per_worker * self.block_size <= self.d_pad,
                "worker footprint ({} blocks x {}) exceeds artifact d_pad {}; \
                 regenerate artifacts or lower blocks_per_worker",
                self.blocks_per_worker,
                self.block_size,
                self.d_pad
            );
        }
        // Fail on a malformed fault spec at config time, not mid-run.
        crate::coordinator::FaultPlan::parse(&self.faults)
            .context("invalid value for config key \"faults\"")?;
        anyhow::ensure!(self.join_timeout_ms > 0, "join_timeout_ms must be > 0");
        anyhow::ensure!(self.pull_floor_us > 0, "pull_floor_us must be > 0");
        anyhow::ensure!(
            self.pull_floor_us <= self.pull_ceil_ms.saturating_mul(1000),
            "pull_floor_us ({}us) exceeds pull_ceil_ms ({}ms)",
            self.pull_floor_us,
            self.pull_ceil_ms
        );
        // Fail on a malformed stats address before any thread binds it.
        if !self.stats_addr.is_empty() {
            use std::net::ToSocketAddrs;
            self.stats_addr
                .to_socket_addrs()
                .map(|_| ())
                .map_err(anyhow::Error::from)
                .with_context(|| {
                    format!(
                        "invalid value {:?} for config key \"stats_addr\" (expected host:port, \
                         e.g. 127.0.0.1:8080)",
                        self.stats_addr
                    )
                })?;
        }
        Ok(())
    }

    /// Every non-default setting as `(key, value)` pairs that
    /// [`Config::apply_kv`] accepts — the wire representation the
    /// multi-process handshake ships so `asybadmm work` reconstructs the
    /// coordinator's exact config (`Config::default()` + these).
    /// Defaults are elided to keep the frame small and forward-portable:
    /// a worker build with a newer default set only diverges on keys the
    /// coordinator actually set.
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let d = Config::default();
        let mut kv: Vec<(String, String)> = Vec::new();
        let mut push = |k: &str, v: String, dv: String| {
            if v != dv {
                kv.push((k.to_string(), v));
            }
        };
        push("loss", self.loss.as_str().into(), d.loss.as_str().into());
        push("lambda", self.lambda.to_string(), d.lambda.to_string());
        push("clip", self.clip.to_string(), d.clip.to_string());
        push("samples", self.samples.to_string(), d.samples.to_string());
        push("n_blocks", self.n_blocks.to_string(), d.n_blocks.to_string());
        push("block_size", self.block_size.to_string(), d.block_size.to_string());
        push("nnz_per_row", self.nnz_per_row.to_string(), d.nnz_per_row.to_string());
        push(
            "blocks_per_worker",
            self.blocks_per_worker.to_string(),
            d.blocks_per_worker.to_string(),
        );
        push("shared_blocks", self.shared_blocks.to_string(), d.shared_blocks.to_string());
        push("zipf_s", self.zipf_s.to_string(), d.zipf_s.to_string());
        push("noise", self.noise.to_string(), d.noise.to_string());
        if let Some(p) = &self.data_path {
            kv.push(("data_path".into(), p.display().to_string()));
        }
        push("n_workers", self.n_workers.to_string(), d.n_workers.to_string());
        push("n_servers", self.n_servers.to_string(), d.n_servers.to_string());
        push("placement", self.placement.as_str().into(), d.placement.as_str().into());
        push("drain", self.drain.as_str().into(), d.drain.as_str().into());
        push("kernel", self.kernel.as_str().into(), d.kernel.as_str().into());
        push("server_threads", self.server_threads.to_string(), d.server_threads.to_string());
        push("rebalance_ms", self.rebalance_ms.to_string(), d.rebalance_ms.to_string());
        push("batch", self.batch.to_string(), d.batch.to_string());
        push("rho", self.rho.to_string(), d.rho.to_string());
        push("gamma", self.gamma.to_string(), d.gamma.to_string());
        push("epochs", self.epochs.to_string(), d.epochs.to_string());
        push("selection", self.selection.as_str().into(), d.selection.as_str().into());
        push("max_delay", self.max_delay.to_string(), d.max_delay.to_string());
        push(
            "enforce_delay_bound",
            self.enforce_delay_bound.to_string(),
            d.enforce_delay_bound.to_string(),
        );
        push("backend", self.backend.as_str().into(), d.backend.as_str().into());
        push("transport", self.transport.as_str().into(), d.transport.as_str().into());
        push(
            "artifacts_dir",
            self.artifacts_dir.display().to_string(),
            d.artifacts_dir.display().to_string(),
        );
        push("m_chunk", self.m_chunk.to_string(), d.m_chunk.to_string());
        push("d_pad", self.d_pad.to_string(), d.d_pad.to_string());
        push(
            "net_delay_mean_ms",
            self.net_delay_mean_ms.to_string(),
            d.net_delay_mean_ms.to_string(),
        );
        push("pull_hold", self.pull_hold.to_string(), d.pull_hold.to_string());
        push("seed", self.seed.to_string(), d.seed.to_string());
        push("log_every", self.log_every.to_string(), d.log_every.to_string());
        push("faults", self.faults.clone(), d.faults.clone());
        push("failure", self.failure.as_str().into(), d.failure.as_str().into());
        push("stall_warn_ms", self.stall_warn_ms.to_string(), d.stall_warn_ms.to_string());
        push(
            "net_liveness_ms",
            self.net_liveness_ms.to_string(),
            d.net_liveness_ms.to_string(),
        );
        push(
            "join_timeout_ms",
            self.join_timeout_ms.to_string(),
            d.join_timeout_ms.to_string(),
        );
        push("pull_floor_us", self.pull_floor_us.to_string(), d.pull_floor_us.to_string());
        push("pull_ceil_ms", self.pull_ceil_ms.to_string(), d.pull_ceil_ms.to_string());
        push(
            "checkpoint_every",
            self.checkpoint_every.to_string(),
            d.checkpoint_every.to_string(),
        );
        push(
            "checkpoint_path",
            self.checkpoint_path.display().to_string(),
            d.checkpoint_path.display().to_string(),
        );
        push("stats_addr", self.stats_addr.clone(), d.stats_addr.clone());
        kv
    }

    /// One-line summary for report headers.  Robustness knobs are
    /// appended only when set, so fault-free summaries stay stable.
    pub fn summary(&self) -> String {
        let mut s = self.summary_base();
        if self.failure != FailurePolicy::Die {
            s.push_str(&format!(" failure={}", self.failure.as_str()));
        }
        if !self.faults.is_empty() {
            s.push_str(&format!(" faults={}", self.faults));
        }
        if self.checkpoint_every > 0 {
            s.push_str(&format!(" checkpoint_every={}", self.checkpoint_every));
        }
        s
    }

    fn summary_base(&self) -> String {
        format!(
            "loss={} m={} M={} db={} p={} servers={} threads={} rho={} gamma={} lambda={} T={} sel={} backend={} transport={} placement={} rebalance_ms={} drain={} kernel={} batch={} seed={}",
            self.loss.as_str(),
            self.samples,
            self.n_blocks,
            self.block_size,
            self.n_workers,
            self.n_servers,
            self.server_threads,
            self.rho,
            self.gamma,
            self.lambda,
            self.epochs,
            self.selection.as_str(),
            self.backend.as_str(),
            self.transport.as_str(),
            self.placement.as_str(),
            self.rebalance_ms,
            self.drain.as_str(),
            self.kernel.as_str(),
            self.batch,
            self.seed
        )
    }

    pub fn geometry(&self) -> crate::data::BlockGeometry {
        crate::data::BlockGeometry::new(self.n_blocks, self.block_size)
    }

    pub fn synth_spec(&self) -> crate::data::SynthSpec {
        crate::data::SynthSpec {
            kind: self.loss,
            samples: self.samples,
            geometry: self.geometry(),
            nnz_per_row: self.nnz_per_row,
            blocks_per_worker: self.blocks_per_worker,
            shared_blocks: self.shared_blocks,
            zipf_s: self.zipf_s,
            truth_density: 0.05,
            noise: self.noise,
            seed: self.seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
        Config::tiny_test().validate().unwrap();
        Config::small().validate().unwrap();
    }

    #[test]
    fn kv_overrides() {
        let mut c = Config::default();
        c.apply_kv("n_workers", "16").unwrap();
        c.apply_kv("gamma", "0.5").unwrap();
        c.apply_kv("backend", "xla").unwrap();
        c.apply_kv("selection", "cyclic").unwrap();
        c.apply_kv("transport", "ring").unwrap();
        c.apply_kv("stats_addr", "127.0.0.1:9090").unwrap();
        assert_eq!(c.stats_addr, "127.0.0.1:9090");
        assert_eq!(c.n_workers, 16);
        assert_eq!(c.gamma, 0.5);
        assert_eq!(c.backend, Backend::Xla);
        assert_eq!(c.selection, BlockSelection::Cyclic);
        assert_eq!(c.transport, TransportKind::SpscRing);
        c.apply_kv("transport", "mpsc").unwrap();
        assert_eq!(c.transport, TransportKind::Mpsc);
        c.apply_kv("transport", "tcp").unwrap();
        assert_eq!(c.transport, TransportKind::Tcp);
        c.apply_kv("placement", "degree").unwrap();
        c.apply_kv("drain", "steal").unwrap();
        c.apply_kv("batch", "4").unwrap();
        assert_eq!(c.placement, PlacementKind::Degree);
        assert_eq!(c.drain, DrainKind::Steal);
        assert_eq!(c.batch, 4);
        c.apply_kv("placement", "hash").unwrap();
        assert_eq!(c.placement, PlacementKind::Hash);
        c.apply_kv("placement", "roundrobin").unwrap();
        assert_eq!(c.placement, PlacementKind::RoundRobin);
        c.apply_kv("placement", "dynamic").unwrap();
        assert_eq!(c.placement, PlacementKind::Dynamic);
        c.apply_kv("server_threads", "3").unwrap();
        assert_eq!(c.server_threads, 3);
        c.apply_kv("rebalance_ms", "7").unwrap();
        assert_eq!(c.rebalance_ms, 7);
        c.apply_kv("placement", "contiguous").unwrap();
        c.apply_kv("drain", "owned").unwrap();
        assert_eq!(c.placement, PlacementKind::Contiguous);
        assert_eq!(c.drain, DrainKind::Owned);
        c.apply_kv("kernel", "scalar").unwrap();
        assert_eq!(c.kernel, KernelKind::Scalar);
        c.apply_kv("kernel", "unrolled").unwrap();
        assert_eq!(c.kernel, KernelKind::Unrolled);
        c.apply_kv("kernel", "simd").unwrap();
        assert_eq!(c.kernel, KernelKind::Simd);
        c.apply_kv("kernel", "auto").unwrap();
        assert_eq!(c.kernel, KernelKind::Auto);
        assert!(c.apply_kv("placement", "astrology").is_err());
        assert!(c.apply_kv("drain", "never").is_err());
        assert!(c.apply_kv("kernel", "quantum").is_err());
        assert!(c.apply_kv("transport", "carrier-pigeon").is_err());
        assert!(c.apply_kv("nope", "1").is_err());
        assert!(c.apply_kv("n_workers", "abc").is_err());
        c.apply_kv("faults", "crash:w0@3").unwrap();
        c.apply_kv("failure", "restart").unwrap();
        c.apply_kv("stall_warn_ms", "250").unwrap();
        c.apply_kv("checkpoint_every", "10").unwrap();
        c.apply_kv("checkpoint_path", "/tmp/x.ckpt").unwrap();
        assert_eq!(c.faults, "crash:w0@3");
        assert_eq!(c.failure, FailurePolicy::Restart);
        assert_eq!(c.stall_warn_ms, 250);
        assert_eq!(c.checkpoint_every, 10);
        assert_eq!(c.checkpoint_path, PathBuf::from("/tmp/x.ckpt"));
        assert!(c.apply_kv("failure", "shrug").is_err());
    }

    #[test]
    fn unknown_value_error_lists_valid_variants() {
        // Parity with unknown *keys*: a bad enum value names every
        // accepted variant, and a bad scalar names the key and value.
        let mut c = Config::default();
        let err = format!("{:#}", c.apply_kv("placement", "bogus").unwrap_err());
        for v in ["contiguous", "roundrobin", "hash", "degree", "dynamic"] {
            assert!(err.contains(v), "placement error omits {v:?}: {err}");
        }
        let err = format!("{:#}", c.apply_kv("failure", "bogus").unwrap_err());
        for v in ["die", "degrade", "restart"] {
            assert!(err.contains(v), "failure error omits {v:?}: {err}");
        }
        let err = format!("{:#}", c.apply_kv("loss", "bogus").unwrap_err());
        for v in ["logistic", "squared"] {
            assert!(err.contains(v), "loss error omits {v:?}: {err}");
        }
        let err = format!("{:#}", c.apply_kv("kernel", "bogus").unwrap_err());
        for v in ["scalar", "unrolled", "simd", "auto"] {
            assert!(err.contains(v), "kernel error omits {v:?}: {err}");
        }
        let err = format!("{:#}", c.apply_kv("n_workers", "abc").unwrap_err());
        assert!(err.contains("n_workers"), "scalar error omits the key: {err}");
        assert!(err.contains("abc"), "scalar error omits the value: {err}");
        let err = format!("{:#}", c.apply_kv("transport", "bogus").unwrap_err());
        for v in ["mpsc", "ring", "tcp"] {
            assert!(err.contains(v), "transport error omits {v:?}: {err}");
        }
    }

    #[test]
    fn reload_kv_enforces_the_whitelist() {
        let mut c = Config::default();
        c.apply_reload_kv("rebalance_ms", "25").unwrap();
        c.apply_reload_kv("net_liveness_ms", "400").unwrap();
        c.apply_reload_kv("pull_floor_us", "250").unwrap();
        c.apply_reload_kv("pull_ceil_ms", "16").unwrap();
        assert_eq!(c.rebalance_ms, 25);
        assert_eq!(c.net_liveness_ms, 400);
        assert_eq!(c.pull_floor_us, 250);
        assert_eq!(c.pull_ceil_ms, 16);
        // Known-but-frozen and unknown keys both list the whitelist.
        for frozen in ["epochs", "n_workers", "transport", "not_a_key"] {
            let err = format!("{:#}", c.apply_reload_kv(frozen, "1").unwrap_err());
            assert!(err.contains("not hot-reloadable"), "{err}");
            for valid in Config::RELOADABLE_KEYS {
                assert!(err.contains(valid), "{frozen} error omits {valid}: {err}");
            }
        }
        // A reloadable key with a bad value keeps the apply_kv shape.
        let err = format!("{:#}", c.apply_reload_kv("rebalance_ms", "abc").unwrap_err());
        assert!(err.contains("rebalance_ms") && err.contains("abc"), "{err}");
        // Every reloadable key is a real config key.
        for k in Config::RELOADABLE_KEYS {
            assert!(Config::KEYS.contains(k), "{k} missing from Config::KEYS");
        }
    }

    #[test]
    fn malformed_stats_addr_rejected_with_expected_form() {
        let mut c = Config::default();
        c.stats_addr = "127.0.0.1:9090".into();
        c.validate().unwrap();
        c.stats_addr = "no-port".into();
        let err = format!("{:#}", c.validate().unwrap_err());
        assert!(err.contains("stats_addr"), "{err}");
        assert!(err.contains("host:port"), "error should show the form: {err}");
    }

    #[test]
    fn to_kv_round_trips_through_apply_kv() {
        let mut c = Config::tiny_test();
        c.transport = TransportKind::Tcp;
        c.placement = PlacementKind::Dynamic;
        c.batch = 3;
        c.seed = 777;
        c.stats_addr = "127.0.0.1:0".into();
        c.faults = "crash:w0@3".into();
        let mut rebuilt = Config::default();
        for (k, v) in c.to_kv() {
            rebuilt.apply_kv(&k, &v).unwrap();
        }
        // The handshake contract: defaults + to_kv == the original.
        assert_eq!(rebuilt.summary(), c.summary());
        assert_eq!(rebuilt.stats_addr, c.stats_addr);
        assert_eq!(rebuilt.epochs, c.epochs);
        assert_eq!(rebuilt.n_blocks, c.n_blocks);
        assert_eq!(rebuilt.block_size, c.block_size);
        assert_eq!(rebuilt.samples, c.samples);
        assert_eq!(rebuilt.shared_blocks, c.shared_blocks);
        assert_eq!(rebuilt.lambda, c.lambda);
        assert_eq!(rebuilt.max_delay, c.max_delay);
        // An all-defaults config ships an empty diff.
        assert!(Config::default().to_kv().is_empty());
    }

    #[test]
    fn malformed_fault_spec_rejected_at_validate() {
        let mut c = Config::default();
        c.faults = "crash:w0@3".into();
        c.validate().unwrap();
        c.faults = "explode:w0@3".into();
        let err = format!("{:#}", c.validate().unwrap_err());
        assert!(err.contains("faults"), "{err}");
    }

    #[test]
    fn unknown_key_error_lists_valid_keys() {
        let mut c = Config::default();
        let err = c.apply_kv("n_wokers", "4").unwrap_err().to_string();
        assert!(err.contains("unknown config key"), "{err}");
        // The error is self-documenting: every accepted key is listed.
        for key in Config::KEYS {
            assert!(err.contains(key), "error does not mention {key:?}: {err}");
        }
        assert!(err.contains("transport"), "{err}");
    }

    #[test]
    fn file_parsing() {
        let dir = std::env::temp_dir().join("asybadmm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.toml");
        std::fs::write(
            &p,
            "# experiment\n[algorithm]\nrho = 25.0\ngamma = 0.1 # inline\n\n[data]\nsamples = 100\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_file(&p).unwrap();
        assert_eq!(c.rho, 25.0);
        assert_eq!(c.gamma, 0.1);
        assert_eq!(c.samples, 100);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = Config::default();
        c.n_servers = 0;
        assert!(c.validate().is_err());

        let mut c = Config::default();
        c.n_servers = c.n_blocks + 1;
        assert!(c.validate().is_err());

        let mut c = Config::default();
        c.blocks_per_worker = c.n_blocks + 1;
        assert!(c.validate().is_err());

        let mut c = Config::default();
        c.server_threads = 1025;
        assert!(c.validate().is_err());
        c.server_threads = 1024;
        assert!(c.validate().is_ok());
        c.server_threads = 1; // fewer threads than shards: elastic pool
        assert!(c.validate().is_ok());

        let mut c = Config::default();
        c.batch = 0;
        assert!(c.validate().is_err());
        c.batch = 1025;
        assert!(c.validate().is_err());
        c.batch = 1024;
        assert!(c.validate().is_ok());

        let mut c = Config::default();
        c.blocks_per_worker = 9; // 9 * 512 > 4096: only the XLA backend cares
        assert!(c.validate().is_ok());
        c.backend = Backend::Xla;
        assert!(c.validate().is_err());
    }

    #[test]
    fn summary_mentions_key_params() {
        let s = Config::default().summary();
        assert!(s.contains("rho=4"));
        assert!(s.contains("backend=native"));
    }
}

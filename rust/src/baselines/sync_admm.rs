//! Synchronous block-wise distributed ADMM (paper §3.1).
//!
//! Reference semantics for the asynchronous algorithm: every epoch, each
//! worker updates **all** its blocks from the same z^t snapshot (Eqs.
//! 6-7), then every block performs the Eq. 8 aggregation — a full
//! barrier.  With zero delay Theorem 1 admits γ = 0.  Single-threaded by
//! construction (a barrier serializes the math anyway); the async runtime
//! must reach the same objective neighborhood, which the integration
//! tests assert.

use std::time::Instant;

use anyhow::Result;

use super::BaselineReport;
use crate::admm::{objective_at_z, prox_l1_box, worker_update, NativeEngine};
use crate::config::Config;
use crate::coordinator::{ObjSample, Topology};
use crate::data::{Dataset, WorkerShard};
use crate::problem::Problem;

pub fn run_sync_admm(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> Result<BaselineReport> {
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    let topo = Topology::build(shards, cfg.n_blocks, cfg.n_servers);
    let db = cfg.block_size;
    let d = cfg.n_blocks * db;

    let mut z = vec![0.0f32; d];
    // Per worker: packed x, y, z_local, engine.
    let mut engines: Vec<NativeEngine> = shards
        .iter()
        .map(|s| NativeEngine::new(s, problem, 1.0 / s.samples().max(1) as f32))
        .collect();
    let mut xs: Vec<Vec<f32>> = shards.iter().map(|s| vec![0.0; s.packed_dim()]).collect();
    let mut ys: Vec<Vec<f32>> = shards.iter().map(|s| vec![0.0; s.packed_dim()]).collect();
    // w_{i,j} laid out per (block, worker slot in 𝒩(j)).
    let mut w: Vec<Vec<Vec<f32>>> = (0..cfg.n_blocks)
        .map(|j| vec![vec![0.0f32; db]; topo.workers_of_block[j].len()])
        .collect();

    let mut g = vec![0.0f32; db];
    let mut z_new = vec![0.0f32; db];
    let mut samples = Vec::new();
    let start = Instant::now();
    let log_every = cfg.log_every.max(1);

    for t in 0..cfg.epochs {
        if t % log_every == 0 {
            let obj = objective_at_z(shards, &problem, weight, &z);
            samples.push(ObjSample {
                time_s: start.elapsed().as_secs_f64(),
                epoch: t,
                objective: obj.total(),
                data_loss: obj.data_loss,
                consensus_max: 0.0,
            });
        }
        // -- worker phase: all blocks from the same z^t ---------------------
        for (i, shard) in shards.iter().enumerate() {
            // gather packed z̃ = z^t
            let mut z_local = vec![0.0f32; shard.packed_dim()];
            for (slot, &j) in shard.active_blocks.iter().enumerate() {
                z_local[slot * db..(slot + 1) * db].copy_from_slice(&z[j * db..(j + 1) * db]);
            }
            for (slot, &j) in shard.active_blocks.iter().enumerate() {
                let (lo, hi) = (slot * db, (slot + 1) * db);
                engines[i].grad_block(&z_local, slot, &mut g);
                let wslot =
                    topo.workers_of_block[j].iter().position(|&wk| wk == i).expect("edge");
                // split-borrow x/y slices
                let (x_s, y_s) = (&mut xs[i][lo..hi], &mut ys[i][lo..hi]);
                let mut y_new = vec![0.0f32; db];
                let mut x_new = vec![0.0f32; db];
                worker_update(&g, y_s, &z_local[lo..hi], cfg.rho, &mut w[j][wslot], &mut y_new, &mut x_new);
                x_s.copy_from_slice(&x_new);
                y_s.copy_from_slice(&y_new);
            }
        }
        // -- server phase: Eq. 8 per block (barrier) ------------------------
        for j in 0..cfg.n_blocks {
            let degree = topo.workers_of_block[j].len();
            if degree == 0 {
                continue;
            }
            let mut w_sum = vec![0.0f32; db];
            for wi in &w[j] {
                for (acc, v) in w_sum.iter_mut().zip(wi) {
                    *acc += v;
                }
            }
            let denom = cfg.gamma + cfg.rho * degree as f32;
            prox_l1_box(
                &z[j * db..(j + 1) * db],
                &w_sum,
                cfg.gamma,
                denom,
                problem.lambda,
                problem.clip,
                &mut z_new,
            );
            z[j * db..(j + 1) * db].copy_from_slice(&z_new);
        }
    }

    let final_objective = objective_at_z(shards, &problem, weight, &z);
    samples.push(ObjSample {
        time_s: start.elapsed().as_secs_f64(),
        epoch: cfg.epochs,
        objective: final_objective.total(),
        data_loss: final_objective.data_loss,
        consensus_max: 0.0,
    });
    Ok(BaselineReport {
        samples,
        final_objective,
        z_final: z,
        elapsed_s: start.elapsed().as_secs_f64(),
        epochs: cfg.epochs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_partitioned;

    #[test]
    fn sync_admm_converges_on_tiny_problem() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 80;
        cfg.gamma = 0.0; // sync case allows gamma = 0 (paper §4)
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sync_admm(&cfg, &ds, &shards).unwrap();
        let first = r.samples.first().unwrap().objective;
        let last = r.final_objective.total();
        assert!(last < first * 0.8, "{first} -> {last}");
        // log(2) start for logistic at z=0
        assert!((first - std::f64::consts::LN_2).abs() < 0.02);
    }

    #[test]
    fn iterates_stay_in_box() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 30;
        cfg.clip = 0.05; // tight box to make clipping bite
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sync_admm(&cfg, &ds, &shards).unwrap();
        assert!(r.z_final.iter().all(|v| v.abs() <= 0.05 + 1e-6));
    }

    #[test]
    fn l1_induces_sparsity() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 60;
        cfg.lambda = 5e-3; // strong l1
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sync_admm(&cfg, &ds, &shards).unwrap();
        let nnz = r.z_final.iter().filter(|v| v.abs() > 1e-9).count();
        let mut weak = cfg.clone();
        weak.lambda = 0.0;
        let r2 = run_sync_admm(&weak, &ds, &shards).unwrap();
        let nnz2 = r2.z_final.iter().filter(|v| v.abs() > 1e-9).count();
        assert!(nnz < nnz2, "l1 should sparsify: {nnz} vs {nnz2}");
    }
}

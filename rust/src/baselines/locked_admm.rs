//! Asynchronous **full-vector, globally-locked** ADMM — the prior-art
//! design AsyBADMM replaces (paper §1: "all existing asynchronous
//! distributed ADMM requires locking global consensus variables at the
//! (single) server for each model update").
//!
//! Workers run asynchronously, but each iteration (a) computes the
//! gradient of *all* its blocks at a locked-out snapshot and (b) applies
//! the w/z updates for all its blocks while holding one global mutex —
//! exactly the serialization bottleneck Fig. 1's multi-server layout
//! removes.  Used by the E4 locking ablation bench.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::BaselineReport;
use crate::admm::{objective_at_z, prox_l1_box, worker_update, NativeEngine};
use crate::config::Config;
use crate::coordinator::{ObjSample, Topology};
use crate::data::{Dataset, WorkerShard};
use crate::problem::Problem;

/// Everything a prior-art single server holds, behind ONE lock.
struct GlobalState {
    z: Vec<f32>,
    /// w̃_{i,j} per (block, worker-slot) + running sums.
    w_tilde: Vec<Vec<Vec<f32>>>,
    w_sum: Vec<Vec<f32>>,
}

pub fn run_locked_admm(
    cfg: &Config,
    ds: &Dataset,
    shards: &[WorkerShard],
) -> Result<BaselineReport> {
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    let topo = Topology::build(shards, cfg.n_blocks, cfg.n_servers);
    let db = cfg.block_size;
    let d = cfg.n_blocks * db;

    let state = Mutex::new(GlobalState {
        z: vec![0.0f32; d],
        w_tilde: (0..cfg.n_blocks)
            .map(|j| vec![vec![0.0f32; db]; topo.workers_of_block[j].len()])
            .collect(),
        w_sum: (0..cfg.n_blocks).map(|_| vec![0.0f32; db]).collect(),
    });
    /// Nanoseconds spent inside the global critical section (contention
    /// metric reported by the locking ablation).
    static LOCKED_NS: AtomicU64 = AtomicU64::new(0);
    LOCKED_NS.store(0, Ordering::Relaxed);

    let start = Instant::now();
    std::thread::scope(|scope| {
        for shard in shards {
            let state = &state;
            let topo = &topo;
            scope.spawn(move || {
                let local_w = 1.0 / shard.samples().max(1) as f32;
                let mut eng = NativeEngine::new(shard, problem, local_w);
                let dim = shard.packed_dim();
                let mut z_local = vec![0.0f32; dim];
                let mut x = vec![0.0f32; dim];
                let mut y = vec![0.0f32; dim];
                let mut g_full = vec![0.0f32; dim];
                let (mut w_new, mut y_new, mut x_new) =
                    (vec![0.0f32; db], vec![0.0f32; db], vec![0.0f32; db]);
                let mut z_out = vec![0.0f32; db];
                for _t in 0..cfg.epochs {
                    // Snapshot z under the global lock (prior art: pull
                    // requires the same latch as updates).
                    {
                        let t0 = Instant::now();
                        let st = state.lock().unwrap();
                        for (slot, &j) in shard.active_blocks.iter().enumerate() {
                            z_local[slot * db..(slot + 1) * db]
                                .copy_from_slice(&st.z[j * db..(j + 1) * db]);
                        }
                        LOCKED_NS.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    // Full-vector gradient (all blocks, Hong'17 style).
                    eng.grad_full(&z_local, &mut g_full);
                    // Apply every block's update inside ONE critical
                    // section over the whole model.
                    let t0 = Instant::now();
                    let mut st = state.lock().unwrap();
                    for (slot, &j) in shard.active_blocks.iter().enumerate() {
                        let (lo, hi) = (slot * db, (slot + 1) * db);
                        worker_update(
                            &g_full[lo..hi],
                            &y[lo..hi],
                            &z_local[lo..hi],
                            cfg.rho,
                            &mut w_new,
                            &mut y_new,
                            &mut x_new,
                        );
                        x[lo..hi].copy_from_slice(&x_new);
                        y[lo..hi].copy_from_slice(&y_new);
                        let wslot = topo.workers_of_block[j]
                            .iter()
                            .position(|&wk| wk == shard.worker_id)
                            .expect("edge");
                        let st = &mut *st;
                        let (sums, tildes) = (&mut st.w_sum[j], &mut st.w_tilde[j]);
                        for ((s, nv), ov) in
                            sums.iter_mut().zip(&w_new).zip(tildes[wslot].iter())
                        {
                            *s += nv - ov;
                        }
                        tildes[wslot].copy_from_slice(&w_new);
                        let denom = cfg.gamma + cfg.rho * topo.workers_of_block[j].len() as f32;
                        prox_l1_box(
                            &st.z[j * db..(j + 1) * db],
                            &st.w_sum[j],
                            cfg.gamma,
                            denom,
                            problem.lambda,
                            problem.clip,
                            &mut z_out,
                        );
                        st.z[j * db..(j + 1) * db].copy_from_slice(&z_out);
                    }
                    drop(st);
                    LOCKED_NS.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            });
        }
    });

    let elapsed_s = start.elapsed().as_secs_f64();
    let z_final = state.into_inner().unwrap().z;
    let final_objective = objective_at_z(shards, &problem, weight, &z_final);
    let locked_s = LOCKED_NS.load(Ordering::Relaxed) as f64 / 1e9;
    crate::info!(
        "locked_admm",
        "global-lock time {:.3}s of {:.3}s wall ({:.0}% serialized)",
        locked_s,
        elapsed_s,
        100.0 * locked_s / elapsed_s.max(1e-9)
    );
    Ok(BaselineReport {
        samples: vec![ObjSample {
            time_s: elapsed_s,
            epoch: cfg.epochs,
            objective: final_objective.total(),
            data_loss: final_objective.data_loss,
            consensus_max: 0.0,
        }],
        final_objective,
        z_final,
        elapsed_s,
        epochs: cfg.epochs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_partitioned;

    #[test]
    fn locked_admm_converges_too() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 100;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_locked_admm(&cfg, &ds, &shards).unwrap();
        assert!(
            r.final_objective.total() < std::f64::consts::LN_2 * 0.9,
            "{}",
            r.final_objective.total()
        );
    }
}

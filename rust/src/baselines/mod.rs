//! Baselines (S6) the paper compares against or builds upon:
//!
//! * [`sync_admm`] — the synchronous block-wise distributed ADMM of §3.1
//!   (epoch barrier, γ = 0 allowed): the correctness anchor.
//! * [`locked_admm`] — asynchronous **full-vector** ADMM in the style of
//!   all prior work the paper cites (Zhang-Kwok '14, Hong '17): workers
//!   are asynchronous but every model update serializes through a single
//!   global lock. This is the design AsyBADMM's lock-free block-wise
//!   updates replace (paper §1), and the E4 ablation quantifies the gap.
//! * [`hogwild_sgd`] — lock-free asynchronous proximal SGD (HOGWILD!-
//!   style), the gradient-method alternative mentioned in §1.
//!
//! All three are also reachable through the unified entry point:
//! `Session::builder(&cfg).dataset(..).algo(Algo::SyncAdmm | ..).run()`
//! returns the same `TrainReport` shape as the async runtime.

mod hogwild;
mod locked_admm;
mod sync_admm;

pub use hogwild::run_hogwild_sgd;
pub use locked_admm::run_locked_admm;
pub use sync_admm::run_sync_admm;

use crate::admm::Objective;
use crate::coordinator::ObjSample;

/// Common result shape for baseline runs.
#[derive(Debug)]
pub struct BaselineReport {
    pub samples: Vec<ObjSample>,
    pub final_objective: Objective,
    pub z_final: Vec<f32>,
    pub elapsed_s: f64,
    pub epochs: usize,
}

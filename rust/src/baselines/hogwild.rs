//! HOGWILD!-style asynchronous proximal SGD baseline (paper §1 cites
//! Niu et al. '11 as the lock-free precedent).
//!
//! Workers pick a block uniformly, compute the block gradient of their
//! *local* loss at the current consensus iterate (via the shard's
//! block-slice index), and apply z_j ← clip(soft(z_j − η g, η λ))
//! through the store's per-block read-modify-write (seqlock writer path;
//! concurrent pulls of other blocks never wait) — no dual variables, no
//! server aggregation.  SGD's known weakness on non-smooth composite
//! objectives (paper §1) is visible as a noisier, flatter tail than
//! ADMM's on the same budget.

use std::time::Instant;

use anyhow::Result;

use super::BaselineReport;
use crate::admm::{objective_at_z, soft_threshold, NativeEngine};
use crate::config::Config;
use crate::coordinator::BlockStore;
use crate::data::{Dataset, WorkerShard};
use crate::problem::Problem;
use crate::util::rng::Rng;

pub fn run_hogwild_sgd(
    cfg: &Config,
    ds: &Dataset,
    shards: &[WorkerShard],
    step_size: f32,
) -> Result<BaselineReport> {
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    let db = cfg.block_size;
    let store = BlockStore::new(cfg.n_blocks, db);

    let start = Instant::now();
    std::thread::scope(|scope| {
        for shard in shards {
            let store = &store;
            scope.spawn(move || {
                // SGD uses the local mean too, but its step on z is
                // direct, so divide the step by the block degree-ish
                // factor via step_size at the call site.
                let local_w = 1.0 / shard.samples().max(1) as f32;
                let mut eng = NativeEngine::new(shard, problem, local_w);
                let mut rng = Rng::new(cfg.seed ^ (shard.worker_id as u64 * 0x9E37_79B9));
                let mut z_local = vec![0.0f32; shard.packed_dim()];
                let mut g = vec![0.0f32; db];
                for _t in 0..cfg.epochs {
                    let slot = rng.below(shard.n_slots());
                    let j = shard.active_blocks[slot];
                    for (s, &jj) in shard.active_blocks.iter().enumerate() {
                        store.read_into(jj, &mut z_local[s * db..(s + 1) * db]);
                    }
                    eng.grad_block(&z_local, slot, &mut g);
                    store.update_with(j, |zj| {
                        for (zk, gk) in zj.iter_mut().zip(&g) {
                            let v = *zk - step_size * gk;
                            *zk = soft_threshold(v, step_size * problem.lambda)
                                .clamp(-problem.clip, problem.clip);
                        }
                    });
                }
            });
        }
    });

    let elapsed_s = start.elapsed().as_secs_f64();
    let z_final = store.snapshot();
    let final_objective = objective_at_z(shards, &problem, weight, &z_final);
    Ok(BaselineReport {
        samples: vec![crate::coordinator::ObjSample {
            time_s: elapsed_s,
            epoch: cfg.epochs,
            objective: final_objective.total(),
            data_loss: final_objective.data_loss,
            consensus_max: 0.0,
        }],
        final_objective,
        z_final,
        elapsed_s,
        epochs: cfg.epochs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_partitioned;

    #[test]
    fn hogwild_descends() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 200;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_hogwild_sgd(&cfg, &ds, &shards, 0.5).unwrap();
        assert!(
            r.final_objective.total() < std::f64::consts::LN_2,
            "{}",
            r.final_objective.total()
        );
    }
}

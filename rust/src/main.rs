//! `asybadmm` — CLI launcher for the AsyBADMM parameter-server runtime.
//!
//! Subcommands:
//!   train       threaded async training run (Algorithm 1)
//!   serve       multi-process coordinator: server shards + control plane
//!   work        multi-process worker: joins a serve coordinator over TCP
//!   sim         discrete-event cluster simulation of the same run
//!   sync        synchronous baseline (paper §3.1)
//!   gen-data    emit a synthetic KDDa-like dataset as libsvm text
//!   check       Theorem-1 hyper-parameter feasibility report
//!   artifacts   inspect the AOT artifact manifest
//!
//! Common options are config keys: any `--set key=value` (repeatable via
//! comma list) overrides `--config <file>` which overrides defaults.
//! `asybadmm <cmd> --help` lists the per-command options.

use std::path::PathBuf;

use anyhow::{Context, Result};

use asybadmm::config::Config;
use asybadmm::coordinator::{Algo, Session};
use asybadmm::data::{gen_partitioned, load_libsvm, partition_even, Dataset, WorkerShard};
use asybadmm::problem::Problem;
use asybadmm::report::{write_file, write_trace_csv, Checkpoint};
use asybadmm::runtime::Manifest;
use asybadmm::sim::calibrate_native;
use asybadmm::util::cli::{Args, Parsed};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(String::as_str).unwrap_or("");
    let rest: Vec<String> = std::iter::once(format!("asybadmm {cmd}"))
        .chain(argv.iter().skip(2).cloned())
        .collect();
    let code = match cmd {
        "train" => run("train", &rest),
        "serve" => run("serve", &rest),
        "work" => run("work", &rest),
        "sim" => run("sim", &rest),
        "sync" => run("sync", &rest),
        "gen-data" => run("gen-data", &rest),
        "check" => run("check", &rest),
        "artifacts" => run("artifacts", &rest),
        "--help" | "-h" | "help" | "" => {
            eprintln!(
                "asybadmm — block-wise asynchronous distributed ADMM\n\n\
                 USAGE: asybadmm <train|serve|work|sim|sync|gen-data|check|artifacts> [OPTIONS]\n\
                 Run `asybadmm <cmd> --help` for options.\n\n\
                 Multi-process: `asybadmm serve --listen HOST:PORT [--set ...]` starts the\n\
                 coordinator (server shards + /stats control plane when stats_addr=HOST:PORT\n\
                 is set); `asybadmm work --connect HOST:PORT --rank R/N` runs worker ranks\n\
                 w where w mod N == R against it."
            );
            if cmd.is_empty() {
                2
            } else {
                0
            }
        }
        other => {
            eprintln!("unknown command {other:?}; see `asybadmm --help`");
            2
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, argv: &[String]) -> i32 {
    let result = match cmd {
        "train" => cmd_train(argv, false),
        "serve" => asybadmm::coordinator::serve_main(argv),
        "work" => asybadmm::coordinator::work_main(argv),
        "sim" => cmd_train(argv, true),
        "sync" => cmd_sync(argv),
        "gen-data" => cmd_gen_data(argv),
        "check" => cmd_check(argv),
        "artifacts" => cmd_artifacts(argv),
        _ => unreachable!(),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn config_args(a: Args) -> Args {
    a.opt("config", "", "config file (TOML-subset key = value)")
        .opt(
            "set",
            "",
            "comma-separated key=value config overrides (e.g. \
             transport=mpsc|ring|tcp, placement=contiguous|roundrobin|hash|degree|dynamic, \
             drain=owned|steal, server_threads=N (0 = one per shard), \
             kernel=scalar|unrolled|simd|auto (auto = AVX2 when available), \
             rebalance_ms=MS, batch=N, backend=native|xla, \
             faults=crash:w1@5;stall:s0@100+25ms;sendfail:w2@4x3 \
             (wire-level under serve/work: netdrop:w1@5 severs worker 1's push \
             sockets at epoch 5, netstall:w0@100+25ms freezes its stream 25ms \
             after 100 frames, corrupt:s0@3 flips rank 0's 3rd pull frame), \
             failure=die|degrade|restart, stall_warn_ms=MS, \
             net_liveness_ms=MS (serve: evict/await-restart a rank silent that \
             long; 0 = off), join_timeout_ms=MS (join barrier + rejoin wait), \
             pull_floor_us=US, pull_ceil_ms=MS (mirror-poll cadence bounds), \
             checkpoint_every=EPOCHS, checkpoint_path=FILE, \
             stats_addr=HOST:PORT (live /stats + /healthz + POST /config), \
             n_workers=8; an unknown key lists all valid keys)",
        )
}

fn build_config(p: &Parsed) -> Result<Config> {
    let mut cfg = Config::default();
    let file = p.get("config");
    if !file.is_empty() {
        cfg.apply_file(std::path::Path::new(file))?;
    }
    for kv in p.get("set").split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got {kv:?}"))?;
        cfg.apply_kv(k, v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Generate or load the dataset + shards for a config.
pub fn load_data(cfg: &Config) -> Result<(Dataset, Vec<WorkerShard>)> {
    match &cfg.data_path {
        Some(path) => {
            let ds = load_libsvm(path, cfg.loss, cfg.block_size)?;
            let shards = partition_even(&ds, cfg.n_workers);
            Ok((ds, shards))
        }
        None => Ok(gen_partitioned(&cfg.synth_spec(), cfg.n_workers)),
    }
}

fn cmd_train(argv: &[String], use_sim: bool) -> Result<()> {
    let about = if use_sim {
        "DES cluster simulation of Algorithm 1 (virtual time; calibrated costs)"
    } else {
        "threaded asynchronous training run (Algorithm 1)"
    };
    let p = config_args(Args::new(about))
        .opt("trace-out", "", "write objective trace CSV here")
        .opt("checkpoint-out", "", "save the trained model checkpoint here")
        .parse_from(argv);
    let cfg = build_config(&p)?;
    let (ds, shards) = load_data(&cfg)?;
    println!("# {}", cfg.summary());
    println!(
        "# dataset {}: m={} d={} nnz={}",
        ds.name,
        ds.samples(),
        ds.dim(),
        ds.a.nnz()
    );

    let report = if use_sim {
        let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
        let cost = calibrate_native(&ds, &shards, problem);
        println!(
            "# calibrated cost model: {:.2}us/row, {:.2}us service, {:.2}us net",
            cost.compute_per_row_s * 1e6,
            cost.server_service_s * 1e6,
            cost.net_mean_s * 1e6
        );
        Session::builder(&cfg).dataset(&ds, &shards).algo(Algo::Sim(cost)).run()?
    } else {
        Session::builder(&cfg).dataset(&ds, &shards).run()?
    };
    let extra = match &report.sim {
        Some(sx) => format!(
            "virtual_time={:.3}s pushes={} max_queue={} migrations={}",
            sx.virtual_time_s,
            report.total_pushes(),
            sx.max_queue,
            report.migrations
        ),
        None => format!(
            "pushes={} max_staleness={} stationarity={:.3e} consensus_max={:.3e} migrations={}",
            report.total_pushes(),
            report.max_staleness(),
            report.stationarity,
            report.consensus_max,
            report.migrations
        ),
    };
    let (samples, final_obj, elapsed, z_final) =
        (report.samples, report.final_objective, report.elapsed_s, report.z_final);

    for s in &samples {
        println!(
            "epoch {:>6}  t {:>9.3}s  obj {:.6}  (data {:.6})",
            s.epoch, s.time_s, s.objective, s.data_loss
        );
    }
    println!(
        "# done in {elapsed:.3}s: objective {:.6} (data {:.6} + reg {:.6}); {extra}",
        final_obj.total(),
        final_obj.data_loss,
        final_obj.reg
    );
    let out = p.get("trace-out");
    if !out.is_empty() {
        write_trace_csv(std::path::Path::new(out), &samples)?;
        println!("# trace written to {out}");
    }
    let ckpt = p.get("checkpoint-out");
    if !ckpt.is_empty() {
        // Model-only snapshot (no recovery state): the periodic
        // `--set checkpoint_every=N` path writes full v2 checkpoints
        // with duals + placement from inside the run.
        Checkpoint::model_only(
            cfg.summary(),
            cfg.n_blocks,
            cfg.block_size,
            cfg.epochs,
            final_obj.total(),
            z_final,
        )
        .save(std::path::Path::new(ckpt))?;
        println!("# checkpoint written to {ckpt}");
    }
    Ok(())
}

fn cmd_sync(argv: &[String]) -> Result<()> {
    let p = config_args(Args::new("synchronous block-wise ADMM baseline (paper §3.1)"))
        .opt("trace-out", "", "write objective trace CSV here")
        .parse_from(argv);
    let cfg = build_config(&p)?;
    let (ds, shards) = load_data(&cfg)?;
    println!("# {}", cfg.summary());
    let r = Session::builder(&cfg).dataset(&ds, &shards).algo(Algo::SyncAdmm).run()?;
    for s in &r.samples {
        println!("epoch {:>6}  obj {:.6}", s.epoch, s.objective);
    }
    println!("# done in {:.3}s: objective {:.6}", r.elapsed_s, r.final_objective.total());
    let out = p.get("trace-out");
    if !out.is_empty() {
        write_trace_csv(std::path::Path::new(out), &r.samples)?;
    }
    Ok(())
}

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    let p = config_args(Args::new("emit the synthetic KDDa-like dataset as libsvm text"))
        .opt("out", "reports/synth.svm", "output path")
        .parse_from(argv);
    let cfg = build_config(&p)?;
    let (ds, _) = load_data(&cfg)?;
    let mut text = String::new();
    for r in 0..ds.samples() {
        text.push_str(&format!("{}", ds.labels[r]));
        let (idx, vals) = ds.a.row(r);
        for (&j, &v) in idx.iter().zip(vals) {
            text.push_str(&format!(" {}:{}", j + 1, v));
        }
        text.push('\n');
    }
    let out = PathBuf::from(p.get("out"));
    write_file(&out, &text)?;
    println!(
        "wrote {} ({} samples, {} features, {} nnz)",
        out.display(),
        ds.samples(),
        ds.dim(),
        ds.a.nnz()
    );
    Ok(())
}

fn cmd_check(argv: &[String]) -> Result<()> {
    let p = config_args(Args::new("Theorem-1 feasibility of the configured hyper-parameters"))
        .parse_from(argv);
    let cfg = build_config(&p)?;
    let (_ds, shards) = load_data(&cfg)?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let refs: Vec<&WorkerShard> = shards.iter().collect();
    let r = asybadmm::admm::check_theorem1(
        &refs,
        &problem,
        cfg.n_blocks,
        cfg.rho as f64,
        cfg.gamma as f64,
        cfg.max_delay,
    );
    println!("{}", cfg.summary());
    println!(
        "min alpha_j = {:.4e}   min beta_i = {:.4e}   strict-feasible: {}",
        r.min_alpha, r.min_beta, r.feasible
    );
    if !r.feasible {
        println!(
            "to satisfy Eq. 17/18 strictly: gamma >= {:.4e}, rho >= {:.4e}",
            r.gamma_needed, r.rho_needed
        );
        println!("(the paper's own experiments run outside the strict bound, as do ours)");
    }
    Ok(())
}

fn cmd_artifacts(argv: &[String]) -> Result<()> {
    let p = config_args(Args::new("inspect the AOT artifact manifest")).parse_from(argv);
    let cfg = build_config(&p)?;
    let m = Manifest::load(&cfg.artifacts_dir)?;
    println!("{} artifacts in {:?}:", m.entries.len(), m.dir);
    for e in &m.entries {
        println!(
            "  {:<44} entry={:<13} kind={:<8} m_chunk={:<5} d_pad={:<5} db={}",
            e.name, e.entry, e.kind, e.m_chunk, e.d_pad, e.db
        );
    }
    println!("shape sets: {:?}", m.shape_sets());
    Ok(())
}

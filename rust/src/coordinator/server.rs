//! Server shard: owns a subset of consensus blocks and applies the
//! incremental Eq. 13 update on every received push.
//!
//! Matching the paper's Algorithm 1 (server side): upon receiving
//! w_{i,j}^t it replaces the cached w̃_{i,j}, recomputes
//! z̃_j = prox( (γ z̃_j + Σ_i w̃_{i,j}) / (γ + Σ_i ρ_i) ), and publishes
//! the dirty copy immediately — workers never wait for an epoch barrier.
//! The w̃ running sum makes each update O(db), independent of |𝒩(j)|.
//!
//! ## Ownership / the block write lease / the [`BlockTable`]
//!
//! Through PR 3 the shard was the only *thread* ever applying pushes to
//! its blocks, so "sole writer" was a static property.  Two later
//! layers made the writer role explicitly mobile:
//!
//! * the work-stealing drain policy (`coordinator/sched.rs`, PR 4):
//!   any server thread may drain a lane of this shard;
//! * dynamic re-placement (`coordinator/rebalance.rs`, this PR): the
//!   *shard* owning a block may change at runtime, so a block's pushes
//!   can arrive through two different shards' lanes mid-migration.
//!
//! All mutable per-block state (w̃ cache, running sum, z̃ cache, round
//! accounting, seq gate) therefore lives in a [`BlockTable`] shared by
//! every shard of a run: one `Mutex<BlockState>` per **global** block —
//! the **block write lease**.  Holding the lease spans the whole
//! read-modify-write, *including* the seqlock-store publish, so at any
//! instant each block still has exactly one writer no matter which
//! shard's lane (or which thread) delivered the push.  Without stealing
//! or migration the lease is uncontended by construction (one CAS each
//! way); contention requires two drainers on the *same block* at the
//! same moment — per-block atomicity, which is all Hong's incremental
//! async-ADMM analysis (arXiv:1412.6058) needs.
//!
//! ## Seq-gated application (migration safety)
//!
//! Per-(worker, block) FIFO is what Algorithm 1's staleness accounting
//! assumes.  Lanes preserve it within one (worker, shard) stream, but a
//! migration re-targets a worker's pushes for block j from shard A's
//! lane to shard B's — and B's thread can reach its lane first.  Each
//! worker therefore stamps a per-(worker, block) sequence number
//! ([`super::messages::PushMsg::block_seq`]); under the lease, a push
//! applies only when it is the *next* one for its (worker, block) edge.
//! An early arrival parks (detached from its pooled buffer) in the
//! block's `pending` list and is applied the moment its predecessor
//! lands — the out-of-order window only exists while a migration's
//! in-flight tail drains, so `pending` is empty in steady state and the
//! gate costs one compare per apply.  `block_seq == 0` bypasses the
//! gate (unsequenced test/bench traffic).
//!
//! Hot-path notes: the table keeps an authoritative copy of each z̃_j
//! (`z_cache` inside the lease) and never reads a block back from the
//! store — an apply touches the store once for the version (staleness
//! stat) and once for the write.  The w̃-sum maintenance and the
//! native prox go through the session-resolved kernel dispatch table
//! (`sparse::simd`, `--set kernel=`).  Pushed w buffers are pooled:
//! after the update the shard sends each buffer home on the message's
//! recycle channel instead of freeing it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::block_store::BlockStore;
use super::fault::FaultPlan;
use super::messages::PushMsg;
use super::topology::Topology;
use super::transport::PushReceiver;
use crate::problem::Problem;
use crate::runtime::ServerProxXla;
use crate::sparse::Kernels;
use crate::util::CacheAligned;

/// Prox execution backend for a server thread.
pub enum ProxBackend {
    Native,
    Xla(ServerProxXla),
}

impl ProxBackend {
    #[allow(clippy::too_many_arguments)]
    fn apply(
        &self,
        kernels: &Kernels,
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            ProxBackend::Native => {
                (kernels.prox_l1_box)(z_tilde, w_sum, gamma, denom, lambda, clip, out);
                Ok(())
            }
            ProxBackend::Xla(sp) => {
                let z = sp.prox(z_tilde, w_sum, gamma, denom, lambda, clip)?;
                out.copy_from_slice(&z);
                Ok(())
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub pushes: usize,
    /// Max observed z-version staleness across handled pushes
    /// (Assumption 3 monitor).
    pub max_staleness: u64,
    /// Max queueing delay (send → handle) in seconds, over the sampled
    /// (`sent_at = Some`) messages.
    pub max_queue_s: f64,
    /// Full z_j rounds completed (all of 𝒩(j) contributed since last
    /// round) — the paper's server line 5 epoch counter.
    pub rounds: usize,
}

/// All mutable state of one block, behind its write lease.
struct BlockState {
    /// w̃_{i,j} cache, one vector per worker in 𝒩(j).
    w_tilde: Vec<Vec<f32>>,
    /// Σ_i w̃_{i,j} running sum.
    w_sum: Vec<f32>,
    /// Which workers contributed since the last full round (server
    /// line 5 of Algorithm 1).
    contributed: Vec<bool>,
    /// Authoritative z̃_j — always equals the store's published content
    /// (the lease makes the prox + publish atomic per block).
    z_cache: Vec<f32>,
    /// Prox output scratch, swapped with `z_cache` after publish.
    z_new: Vec<f32>,
    /// Full rounds completed on this block.
    rounds: usize,
    /// Next expected `block_seq` per worker slot (seq gate; 1-based).
    next_seq: Vec<u64>,
    /// Early arrivals parked until their predecessors land (detached
    /// copies; empty in steady state — see module docs).
    pending: Vec<PushMsg>,
}

/// What one [`BlockTable::ingest`] call did (possibly draining parked
/// predecessors' successors along the way).
pub(crate) struct Ingested {
    pub(crate) applied: usize,
    pub(crate) max_staleness: u64,
}

/// 1-in-N apply sampling discipline for the per-block service-time
/// EWMA (same rate as the worker side's `sent_at` stamping: the
/// `Instant::now` syscall pair stays off 63 of 64 applies).
const SVC_SAMPLE: usize = 64;

/// Per-block counters read/written outside the write lease, isolated on
/// their own cache line so adjacent blocks' writers never false-share
/// (two server threads applying to neighboring blocks would otherwise
/// ping-pong one line between cores on every apply).
#[derive(Default)]
struct BlockHot {
    /// Applied pushes (relaxed; the rebalancer's load signal).
    push_count: AtomicUsize,
    /// EWMA (α = 1/8) of the prox + publish service time in
    /// nanoseconds, sampled 1-in-[`SVC_SAMPLE`] applies; 0 = no sample
    /// yet.  The rebalancer's per-block cost weight.
    svc_ewma_ns: AtomicU64,
}

/// Per-block server state for ALL consensus blocks of a run, shared by
/// every [`ServerShard`] (module docs: the block write lease).  Also
/// carries the per-block applied-push counters and service-time EWMAs
/// the dynamic rebalancer samples (`coordinator/rebalance.rs`).
pub struct BlockTable {
    /// The write leases, one line each: a lease holder bounces no other
    /// block's lock word out of its neighbors' caches.
    state: Vec<CacheAligned<Mutex<BlockState>>>,
    /// γ + Σ_{i∈𝒩(j)} ρ_i per block.
    denom: Vec<f32>,
    /// worker id -> slot in w_tilde (per block; usize::MAX = not in 𝒩).
    worker_slot: Vec<Vec<usize>>,
    /// Per-block hot counters (push count + service-time EWMA).
    hot: Vec<CacheAligned<BlockHot>>,
    /// Kernel family for the w̃-sum maintenance and the native prox
    /// (`--set kernel=`; resolved once by the session).
    kernels: &'static Kernels,
    gamma: f32,
    problem: Problem,
    store: Arc<BlockStore>,
}

impl BlockTable {
    pub fn new(
        topo: &Topology,
        store: Arc<BlockStore>,
        problem: Problem,
        rho: f32,
        gamma: f32,
    ) -> Self {
        Self::with_kernels(topo, store, problem, rho, gamma, Kernels::auto())
    }

    /// Like [`BlockTable::new`] with an explicit kernel family.
    pub fn with_kernels(
        topo: &Topology,
        store: Arc<BlockStore>,
        problem: Problem,
        rho: f32,
        gamma: f32,
        kernels: &'static Kernels,
    ) -> Self {
        let db = topo.block_size;
        let mut state = Vec::with_capacity(topo.n_blocks);
        let mut denom = Vec::with_capacity(topo.n_blocks);
        let mut worker_slot = Vec::with_capacity(topo.n_blocks);
        for j in 0..topo.n_blocks {
            let degree = topo.workers_of_block[j].len();
            denom.push(gamma + rho * degree as f32);
            let mut slots = vec![usize::MAX; topo.n_workers];
            for (s, &w) in topo.workers_of_block[j].iter().enumerate() {
                slots[w] = s;
            }
            worker_slot.push(slots);
            // One-time pull so a non-zero store initialization is honored.
            let mut z0 = vec![0.0f32; db];
            store.read_into(j, &mut z0);
            state.push(CacheAligned(Mutex::new(BlockState {
                // Initial w̃_{i,j} = ρ x⁰ + y⁰ = 0 for z⁰ = 0 (Algorithm 1
                // worker lines 1-2), so the running sum starts at zero.
                w_tilde: vec![vec![0.0f32; db]; degree],
                w_sum: vec![0.0f32; db],
                contributed: vec![false; degree],
                z_cache: z0,
                z_new: vec![0.0; db],
                rounds: 0,
                next_seq: vec![1; degree],
                pending: Vec::new(),
            })));
        }
        BlockTable {
            state,
            denom,
            worker_slot,
            hot: (0..topo.n_blocks).map(|_| CacheAligned(BlockHot::default())).collect(),
            kernels,
            gamma,
            problem,
            store,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.state.len()
    }

    /// Applied pushes on block `j` so far (the rebalancer's load
    /// signal; relaxed read).
    pub fn push_count(&self, j: usize) -> usize {
        self.hot[j].push_count.load(Ordering::Relaxed)
    }

    /// All per-block applied-push counters at once (relaxed reads) —
    /// the `/stats` endpoint's per-block load snapshot and the
    /// checkpoint serializer's source.
    pub fn push_counts(&self) -> Vec<usize> {
        self.hot.iter().map(|h| h.push_count.load(Ordering::Relaxed)).collect()
    }

    /// Sampled service-time EWMA for block `j` in nanoseconds (0 until
    /// the first 1-in-[`SVC_SAMPLE`] sample lands).  The rebalancer's
    /// per-block cost weight (`rate × service time`).
    pub fn service_ewma_ns(&self, j: usize) -> u64 {
        self.hot[j].svc_ewma_ns.load(Ordering::Relaxed)
    }

    /// Diagnostic: messages parked behind a seq gap on block `j`
    /// (0 in steady state; tests assert it returns to 0 after drain).
    pub fn pending_len(&self, j: usize) -> usize {
        self.state[j].lock().unwrap().pending.len()
    }

    /// Diagnostic: next expected per-(worker, block) sequence number
    /// (1-based; `sent + 1` once every push from `worker` applied).
    pub fn next_seq(&self, j: usize, worker: usize) -> u64 {
        let slot = self.worker_slot[j][worker];
        assert_ne!(slot, usize::MAX, "worker {worker} not in N({j})");
        self.state[j].lock().unwrap().next_seq[slot]
    }

    /// Diagnostic: current cached w̃_{worker, j}.
    pub fn w_tilde_of(&self, j: usize, worker: usize) -> Vec<f32> {
        let slot = self.worker_slot[j][worker];
        assert_ne!(slot, usize::MAX, "worker {worker} not in N({j})");
        self.state[j].lock().unwrap().w_tilde[slot].clone()
    }

    /// Test/bench hook: current z̃ cache of block `j`.
    pub fn z_cache_of(&self, j: usize) -> Vec<f32> {
        self.state[j].lock().unwrap().z_cache.clone()
    }

    /// Apply one push under the block's write lease, seq-gated (module
    /// docs).  Returns how many pushes were applied — 0 if this one
    /// parked behind a seq gap, possibly > 1 if it unblocked parked
    /// successors — and the max observed staleness among them.
    pub(crate) fn ingest(&self, msg: &PushMsg, prox: &ProxBackend) -> Result<Ingested> {
        let j = msg.block;
        let slot = self.worker_slot[j][msg.worker];
        debug_assert_ne!(slot, usize::MAX, "worker {} not in N({})", msg.worker, j);

        // Take the block write lease for the whole read-modify-write +
        // publish: this is the explicit writer-role handoff that makes
        // work-stealing and migration safe (module docs).
        let mut guard = self.state[j].lock().unwrap();
        let st = &mut *guard;
        let mut out = Ingested { applied: 0, max_staleness: 0 };
        if msg.block_seq != 0 {
            let expect = st.next_seq[slot];
            if msg.block_seq > expect {
                // Predecessors still in another lane (migration tail):
                // park a detached copy; the caller recycles the pooled
                // buffer as usual.
                st.pending.push(msg.detached());
                return Ok(out);
            }
            if msg.block_seq < expect {
                // Transports never duplicate, and worker restart
                // (`FailurePolicy::Restart`) resumes the seq stream from
                // the crashed worker's send ledger *after* the in-flight
                // tail drained — so a stale seq here is a bug, not an
                // expected replay.  Tolerate in release.
                debug_assert!(false, "duplicate push seq {} < {expect}", msg.block_seq);
                return Ok(out);
            }
        }
        let stale = self.apply_locked(st, j, slot, &msg.w, msg.z_version_used, prox)?;
        if msg.block_seq != 0 {
            st.next_seq[slot] += 1;
        }
        out.applied += 1;
        out.max_staleness = out.max_staleness.max(stale);

        // Drain any parked successor now unblocked (any worker of this
        // block; each apply may unblock the next in its chain).
        loop {
            let next = st.pending.iter().position(|p| {
                let s = self.worker_slot[j][p.worker];
                p.block_seq == st.next_seq[s]
            });
            let Some(pos) = next else { break };
            let parked = st.pending.swap_remove(pos);
            let s = self.worker_slot[j][parked.worker];
            let stale =
                self.apply_locked(st, j, s, &parked.w, parked.z_version_used, prox)?;
            st.next_seq[s] += 1;
            out.applied += 1;
            out.max_staleness = out.max_staleness.max(stale);
        }
        Ok(out)
    }

    /// The Eq. 13 incremental update + seqlock publish.  O(db).  Caller
    /// holds block `j`'s lease.
    fn apply_locked(
        &self,
        st: &mut BlockState,
        j: usize,
        slot: usize,
        w: &[f32],
        z_version_used: u64,
        prox: &ProxBackend,
    ) -> Result<u64> {
        // Service-time sample: 1-in-SVC_SAMPLE applies pay the two
        // clock reads; the EWMA feeds the rebalancer's cost model.
        let hot = &*self.hot[j];
        let t0 = (hot.push_count.load(Ordering::Relaxed) % SVC_SAMPLE == 0)
            .then(Instant::now);

        // w_sum += w_new - w̃_old; w̃ := w_new (kernel-dispatched).
        (self.kernels.add_assign_diff)(&mut st.w_sum, w, &st.w_tilde[slot]);
        st.w_tilde[slot].copy_from_slice(w);

        // z̃_j update + publish.  The cached z̃ is authoritative
        // (lease-holder is the sole writer), so only the version is
        // read from the store — no block copy that the prox would
        // overwrite anyway.
        let cur_version = self.store.version(j);
        prox.apply(
            self.kernels,
            &st.z_cache,
            &st.w_sum,
            self.gamma,
            self.denom[j],
            self.problem.lambda,
            self.problem.clip,
            &mut st.z_new,
        )?;
        self.store.write(j, &st.z_new);
        std::mem::swap(&mut st.z_cache, &mut st.z_new);

        // Round accounting (inside the lease: per-block mutable state).
        st.contributed[slot] = true;
        if st.contributed.iter().all(|&c| c) {
            st.contributed.iter_mut().for_each(|c| *c = false);
            st.rounds += 1;
        }

        hot.push_count.fetch_add(1, Ordering::Relaxed);
        if let Some(t0) = t0 {
            // α = 1/8 EWMA in integer nanos; `.max(1)` keeps a fast
            // block distinguishable from "no sample yet" (0).
            let dt = (t0.elapsed().as_nanos() as u64).max(1);
            let prev = hot.svc_ewma_ns.load(Ordering::Relaxed);
            let next = if prev == 0 { dt } else { (prev * 7 + dt) / 8 };
            hot.svc_ewma_ns.store(next, Ordering::Relaxed);
        }
        Ok(cur_version.saturating_sub(z_version_used))
    }

    fn rounds_of(&self, j: usize) -> usize {
        self.state[j].lock().unwrap().rounds
    }

    /// Drop every parked (seq-gapped) message from `worker` across all
    /// blocks and return how many were discarded.  Used by the degrade
    /// failure policy: a dead worker's seq gap would otherwise park its
    /// in-flight successors forever.  Detached copies own no pooled
    /// buffer, so dropping them strands nothing.
    pub fn purge_worker_pending(&self, worker: usize) -> usize {
        let mut dropped = 0;
        for st in &self.state {
            let mut st = st.lock().unwrap();
            let before = st.pending.len();
            st.pending.retain(|p| p.worker != worker);
            dropped += before - st.pending.len();
        }
        dropped
    }

    /// Restore per-block applied-push counters from a checkpoint so the
    /// dynamic rebalancer's load signal resumes where it left off
    /// instead of re-learning from zero.  `counts.len()` must equal
    /// `n_blocks`.
    pub fn seed_push_counts(&self, counts: &[usize]) {
        assert_eq!(counts.len(), self.hot.len(), "push_counts geometry mismatch");
        for (h, &v) in self.hot.iter().zip(counts) {
            h.push_count.store(v, Ordering::Relaxed);
        }
    }
}

pub struct ServerShard {
    pub id: usize,
    /// Blocks this shard owned at topology-build time (static stats
    /// attribution; under dynamic re-placement the live owner is the
    /// rebalancer's `BlockMap`).
    owned: Vec<usize>,
    owned_mask: Vec<bool>,
    /// Reject pushes for blocks outside `owned` (static placements:
    /// routing is fixed, a foreign push is a bug).  Dynamic placement
    /// clears this — in-flight lane traffic legitimately lags the map.
    strict: bool,
    table: Arc<BlockTable>,
    /// Injected fault plan (`--set faults=...`); `None` on every path
    /// that doesn't opt in, so the hot path pays one branch.
    faults: Option<Arc<FaultPlan>>,
    // -- stats (atomic: any server thread may apply to this shard) ------
    pushes: AtomicUsize,
    max_staleness: AtomicU64,
    /// f64 bit pattern of the max queueing delay in seconds (fetch_max
    /// on the bits is order-preserving for non-negative floats).
    max_queue_s_bits: AtomicU64,
}

impl ServerShard {
    /// Standalone shard with a private full [`BlockTable`] (tests,
    /// benches, single-shard tools).  The session path shares one table
    /// across shards via [`ServerShard::with_table`].
    pub fn new(
        id: usize,
        topo: &Topology,
        store: Arc<BlockStore>,
        problem: Problem,
        rho: f32,
        gamma: f32,
    ) -> Self {
        let table = Arc::new(BlockTable::new(topo, store, problem, rho, gamma));
        Self::with_table(id, topo, table, true)
    }

    /// A shard over a (usually shared) block table.  `strict` enforces
    /// static routing (panic on foreign blocks); pass `false` under
    /// dynamic re-placement.
    pub fn with_table(id: usize, topo: &Topology, table: Arc<BlockTable>, strict: bool) -> Self {
        let owned = topo.blocks_of_server[id].clone();
        let mut owned_mask = vec![false; topo.n_blocks];
        for &j in &owned {
            owned_mask[j] = true;
        }
        ServerShard {
            id,
            owned,
            owned_mask,
            strict,
            table,
            faults: None,
            pushes: AtomicUsize::new(0),
            max_staleness: AtomicU64::new(0),
            max_queue_s_bits: AtomicU64::new(0),
        }
    }

    /// The (possibly shared) per-block state table.
    pub fn table(&self) -> &Arc<BlockTable> {
        &self.table
    }

    /// Attach a fault plan (`--set faults=stall:sS@P+MSms`).  Only the
    /// session wires this, and only when the plan is non-empty.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Apply one push (Eq. 13 incremental form, seq-gated). O(db).
    /// `&self`: any server thread holding this block's lane claim may
    /// call it; the per-block lease serializes concurrent appliers.
    pub fn handle_push(&self, msg: &PushMsg, prox: &ProxBackend) -> Result<()> {
        if self.strict && !self.owned_mask[msg.block] {
            panic!("server {} got push for foreign block {}", self.id, msg.block);
        }
        if let Some(f) = &self.faults {
            // Deterministic shard stall (fires once; see fault.rs).
            if let Some(ms) = f.stall_ms(self.id, self.pushes.load(Ordering::Relaxed)) {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        let ingested = self.table.ingest(msg, prox)?;
        if ingested.applied > 0 {
            self.pushes.fetch_add(ingested.applied, Ordering::Relaxed);
            self.max_staleness.fetch_max(ingested.max_staleness, Ordering::Relaxed);
        }
        if let Some(at) = msg.sent_at {
            // Queue-delay histogram: only sampled messages carry a
            // timestamp (the send-side syscall is 1-in-64 epochs).
            let queue_s = at.elapsed().as_secs_f64();
            self.max_queue_s_bits.fetch_max(queue_s.to_bits(), Ordering::Relaxed);
        }
        Ok(())
    }

    /// Snapshot of this shard's counters (pushes/staleness/queue delay
    /// are atomics; rounds are summed over the statically-owned blocks'
    /// leases, so shard totals still partition the run's blocks).
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            pushes: self.pushes.load(Ordering::Relaxed),
            max_staleness: self.max_staleness.load(Ordering::Relaxed),
            max_queue_s: f64::from_bits(self.max_queue_s_bits.load(Ordering::Relaxed)),
            rounds: self.owned.iter().map(|&j| self.table.rounds_of(j)).sum(),
        }
    }

    /// Blocking single-endpoint server loop (the `drain=owned` fast
    /// path and the test harness): drains the transport endpoint until
    /// it reports shutdown, then returns stats.  Pooled push buffers
    /// are returned to their owning worker after each update.  The
    /// work-stealing loop lives in `coordinator/sched.rs`.
    pub fn run(&self, mut rx: Box<dyn PushReceiver>, prox: ProxBackend) -> Result<ServerStats> {
        while let Some(mut p) = rx.recv() {
            let applied = self.handle_push(&p, &prox);
            // Send the buffer home before propagating any error; any
            // message destroyed elsewhere (transport teardown, error
            // unwinding) recycles via `PushMsg::drop`, so pooled
            // buffers can never be stranded.
            p.recycle_now();
            applied?;
        }
        Ok(self.stats())
    }

    pub fn owned_blocks(&self) -> &[usize] {
        &self.owned
    }

    /// Test/bench hook: current z̃ cache of global block `j`.
    #[cfg(test)]
    pub(crate) fn z_cache_of(&self, j: usize) -> Vec<f32> {
        self.table.z_cache_of(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};

    fn setup() -> (Topology, Arc<BlockStore>, Problem) {
        let spec = SynthSpec {
            samples: 32,
            geometry: BlockGeometry::new(4, 4),
            nnz_per_row: 3,
            blocks_per_worker: 2,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 3);
        let topo = Topology::build(&shards, 4, 2);
        let store = Arc::new(BlockStore::new(4, 4));
        (topo, store, Problem::new(LossKind::Logistic, 0.0, 1e4))
    }

    fn push(worker: usize, block: usize, w: Vec<f32>) -> PushMsg {
        PushMsg {
            worker,
            block,
            w: w.into(),
            worker_epoch: 0,
            z_version_used: 0,
            block_seq: 0,
            sent_at: None,
            recycle: None,
        }
    }

    #[test]
    fn incremental_sum_equals_batch_formula() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let workers = topo.workers_of_block[j].clone();
        assert!(!workers.is_empty());

        // Push twice from the same worker: w_sum must hold only the last.
        let w1 = vec![1.0f32; 4];
        let w2 = vec![3.0f32; 4];
        srv.handle_push(&push(workers[0], j, w1), &ProxBackend::Native).unwrap();
        srv.handle_push(&push(workers[0], j, w2.clone()), &ProxBackend::Native).unwrap();

        // Expected z: lambda=0 => z = (gamma*z_prev + sum_w)/denom applied
        // twice; verify against a scratch recomputation.
        let denom = 0.5 + 10.0 * workers.len() as f32;
        let z_after_1 = (0.5 * 0.0 + 1.0) / denom;
        let z_expect = (0.5 * z_after_1 + 3.0) / denom;
        let mut out = vec![0.0f32; 4];
        store.read_into(j, &mut out);
        for v in out {
            assert!((v - z_expect).abs() < 1e-6, "{v} vs {z_expect}");
        }
        assert_eq!(srv.stats().pushes, 2);
    }

    #[test]
    fn z_cache_tracks_store_content() {
        // The shard's cached z̃ must stay identical to what the store
        // publishes, push after push (the write-lease invariant).
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        for k in 0..5 {
            srv.handle_push(&push(w, j, vec![k as f32; 4]), &ProxBackend::Native).unwrap();
            let mut out = vec![0.0f32; 4];
            store.read_into(j, &mut out);
            assert_eq!(out, srv.z_cache_of(j), "push {k}: cache diverged from store");
        }
        assert_eq!(store.version(j), 5);
    }

    #[test]
    fn nonzero_store_initialization_is_honored() {
        let (topo, store, p) = setup();
        let j0 = topo.blocks_of_server[0][0];
        store.write(j0, &[0.25; 4]);
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        assert_eq!(srv.z_cache_of(j0), vec![0.25; 4]);
    }

    #[test]
    fn rounds_counted_when_all_workers_contribute() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let j = *srv
            .owned_blocks()
            .iter()
            .find(|&&j| topo.workers_of_block[j].len() > 1)
            .expect("need a shared block");
        let workers = topo.workers_of_block[j].clone();
        for (k, &w) in workers.iter().enumerate() {
            srv.handle_push(&push(w, j, vec![0.1; 4]), &ProxBackend::Native).unwrap();
            let expect_rounds = usize::from(k == workers.len() - 1);
            assert_eq!(srv.stats().rounds, expect_rounds);
        }
        // next round restarts
        srv.handle_push(&push(workers[0], j, vec![0.2; 4]), &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().rounds, 1);
    }

    #[test]
    #[should_panic(expected = "foreign block")]
    fn foreign_block_panics() {
        let (topo, store, p) = setup();
        // server 0 owns the low contiguous block range by default; find
        // any block placed on shard 1 and push it at shard 0.
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let foreign = (0..4).find(|j| topo.server_of_block[*j] == 1).unwrap();
        let worker = topo.workers_of_block[foreign].first().copied().unwrap_or(0);
        let _ = srv.handle_push(&push(worker, foreign, vec![0.0; 4]), &ProxBackend::Native);
    }

    #[test]
    fn non_strict_shard_applies_foreign_blocks_via_shared_table() {
        // The dynamic-placement shape: two shards over ONE table, the
        // "wrong" shard receiving a block's push mid-migration.  The
        // update must land in the shared state exactly once.
        let (topo, store, p) = setup();
        let table = Arc::new(BlockTable::new(&topo, store.clone(), p, 10.0, 0.5));
        let s0 = ServerShard::with_table(0, &topo, table.clone(), false);
        let s1 = ServerShard::with_table(1, &topo, table.clone(), false);
        let foreign = (0..4).find(|j| topo.server_of_block[*j] == 1).unwrap();
        let worker = topo.workers_of_block[foreign][0];
        s0.handle_push(&push(worker, foreign, vec![1.0; 4]), &ProxBackend::Native).unwrap();
        s1.handle_push(&push(worker, foreign, vec![2.0; 4]), &ProxBackend::Native).unwrap();
        assert_eq!(s0.stats().pushes, 1);
        assert_eq!(s1.stats().pushes, 1);
        assert_eq!(table.push_count(foreign), 2);
        assert_eq!(table.w_tilde_of(foreign, worker), vec![2.0; 4]);
    }

    #[test]
    fn seq_gate_defers_early_arrivals_and_applies_in_order() {
        // Simulate the migration race: seq 2 and 3 arrive (via the new
        // owner's lane) before seq 1 (still in the old owner's lane).
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        let seq_push = |seq: u64, val: f32| {
            let mut m = push(w, j, vec![val; 4]);
            m.block_seq = seq;
            m
        };
        srv.handle_push(&seq_push(2, 2.0), &ProxBackend::Native).unwrap();
        srv.handle_push(&seq_push(3, 3.0), &ProxBackend::Native).unwrap();
        // Nothing applied yet: both parked behind the missing seq 1.
        assert_eq!(srv.stats().pushes, 0);
        assert_eq!(srv.table().pending_len(j), 2);
        // Seq 1 lands: the whole chain applies, in order.
        srv.handle_push(&seq_push(1, 1.0), &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().pushes, 3);
        assert_eq!(srv.table().pending_len(j), 0);
        assert_eq!(srv.table().next_seq(j, w), 4);
        // Final w̃ is the LAST sent value — FIFO preserved.
        assert_eq!(srv.table().w_tilde_of(j, w), vec![3.0; 4]);
    }

    #[test]
    fn purge_worker_pending_drops_only_that_workers_parked_messages() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        let seq_push = |seq: u64, val: f32| {
            let mut m = push(w, j, vec![val; 4]);
            m.block_seq = seq;
            m
        };
        // Seq 2 and 3 park behind the missing seq 1 (the dead worker's
        // in-flight tail after a crash).
        srv.handle_push(&seq_push(2, 2.0), &ProxBackend::Native).unwrap();
        srv.handle_push(&seq_push(3, 3.0), &ProxBackend::Native).unwrap();
        assert_eq!(srv.table().pending_len(j), 2);
        assert_eq!(srv.table().purge_worker_pending(w), 2);
        assert_eq!(srv.table().pending_len(j), 0);
        // Idempotent once empty.
        assert_eq!(srv.table().purge_worker_pending(w), 0);
    }

    #[test]
    fn seed_push_counts_restores_rebalancer_load_signal() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        let counts: Vec<usize> = (0..srv.table().n_blocks()).map(|j| 10 + j).collect();
        srv.table().seed_push_counts(&counts);
        for (j, &c) in counts.iter().enumerate() {
            assert_eq!(srv.table().push_count(j), c);
        }
    }

    #[test]
    fn stall_fault_delays_the_shard_exactly_once() {
        let plan = Arc::new(FaultPlan::parse("stall:s0@1+30ms").unwrap());
        let (topo, store, p) = setup();
        let mut srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        srv.set_faults(plan.clone());
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        // First push: 0 pushes seen so far, below the threshold.
        srv.handle_push(&push(w, j, vec![0.1; 4]), &ProxBackend::Native).unwrap();
        // Second push crosses `after_pushes=1` and stalls once.
        let t0 = std::time::Instant::now();
        srv.handle_push(&push(w, j, vec![0.2; 4]), &ProxBackend::Native).unwrap();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(30));
        // Third push: fired flag set, no further stall event.
        srv.handle_push(&push(w, j, vec![0.3; 4]), &ProxBackend::Native).unwrap();
        let evs = plan.take_events();
        assert_eq!(
            evs,
            vec![crate::coordinator::FaultEvent::ServerStalled {
                server: 0,
                after_pushes: 1,
                ms: 30
            }]
        );
    }

    #[test]
    fn staleness_tracked() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.0);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        // bump version 3 times
        for _ in 0..3 {
            store.write(j, &[0.0; 4]);
        }
        let mut m = push(w, j, vec![1.0; 4]);
        m.z_version_used = 0;
        srv.handle_push(&m, &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().max_staleness, 3);
    }

    #[test]
    fn sampled_sent_at_feeds_queue_delay_stat() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        // Unsampled messages leave the stat untouched.
        srv.handle_push(&push(w, j, vec![0.1; 4]), &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().max_queue_s, 0.0);
        // A sampled message (sent_at = Some) updates it.
        let mut m = push(w, j, vec![0.2; 4]);
        m.sent_at = Some(std::time::Instant::now() - std::time::Duration::from_millis(5));
        srv.handle_push(&m, &ProxBackend::Native).unwrap();
        assert!(srv.stats().max_queue_s >= 4e-3, "{}", srv.stats().max_queue_s);
    }

    #[test]
    fn concurrent_appliers_on_one_shard_lose_no_push() {
        // Two threads hammer the same shard (one shared block each from
        // a different worker + disjoint blocks): the write lease must
        // keep the w̃-sum exact — the final z equals a sequential replay.
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = *srv
            .owned_blocks()
            .iter()
            .find(|&&j| topo.workers_of_block[j].len() > 1)
            .expect("need a shared block");
        let workers = topo.workers_of_block[j].clone();
        let reps = 200usize;
        std::thread::scope(|scope| {
            for &w in workers.iter().take(2) {
                let srv = &srv;
                scope.spawn(move || {
                    for k in 0..reps {
                        let val = (w as f32) + (k % 7) as f32;
                        srv.handle_push(&push(w, j, vec![val; 4]), &ProxBackend::Native)
                            .unwrap();
                    }
                });
            }
        });
        assert_eq!(srv.stats().pushes, 2 * reps);
        assert_eq!(store.version(j), 2 * reps as u64);
        // After all pushes, w_sum must equal the sum of each worker's
        // LAST pushed w (both last values are (w + (reps-1) % 7)):
        // verify via one more deterministic push + closed-form check on
        // the cache being finite and consistent with the store.
        let mut out = vec![0.0f32; 4];
        store.read_into(j, &mut out);
        assert_eq!(out, srv.z_cache_of(j), "cache diverged from store");
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn run_loop_recycles_pooled_buffers_with_either_transport() {
        use crate::config::TransportKind;
        use crate::coordinator::transport::{make_transport, Transport};
        use std::sync::mpsc::channel;
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            for batch in [1usize, 3] {
                let (topo, store, p) = setup();
                let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
                let j = srv.owned_blocks()[0];
                let w = topo.workers_of_block[j][0];
                let transport: Box<dyn Transport> =
                    make_transport(kind, topo.n_workers, topo.n_servers, 4, batch);
                let (home, inbox) = channel::<crate::util::AlignedBuf>();
                let mut msg = push(w, j, vec![0.5; 4]);
                msg.recycle = Some(home);
                let mut tx = transport.connect_worker(w);
                tx.send(0, msg).unwrap();
                drop(tx);
                transport.shutdown();
                let stats = srv.run(transport.connect_server(0), ProxBackend::Native).unwrap();
                assert_eq!(stats.pushes, 1, "{kind:?} batch={batch}");
                let returned = inbox.try_recv().expect("buffer not recycled");
                assert_eq!(returned, vec![0.5; 4], "{kind:?} batch={batch}");
            }
        }
    }
}

//! Server shard: owns a subset of consensus blocks and applies the
//! incremental Eq. 13 update on every received push.
//!
//! Matching the paper's Algorithm 1 (server side): upon receiving
//! w_{i,j}^t it replaces the cached w̃_{i,j}, recomputes
//! z̃_j = prox( (γ z̃_j + Σ_i w̃_{i,j}) / (γ + Σ_i ρ_i) ), and publishes
//! the dirty copy immediately — workers never wait for an epoch barrier.
//! The w̃ running sum makes each update O(db), independent of |𝒩(j)|.
//!
//! ## Ownership / the block write lease
//!
//! Through PR 3 the shard was the only *thread* ever applying pushes to
//! its blocks, so "sole writer" was a static property.  With the
//! work-stealing drain policy (`coordinator/sched.rs`) any server
//! thread may drain a lane of this shard, so the writer role is handed
//! off **explicitly**: all mutable per-block state (w̃ cache, running
//! sum, z̃ cache, round accounting) lives in a per-block
//! `Mutex<BlockState>` — the **block write lease**.  Holding the lease
//! spans the whole read-modify-write, *including* the seqlock-store
//! publish, so at any instant each block still has exactly one writer
//! and the store's per-block writer serialization is never contended
//! from here.  Without stealing the lease is uncontended by
//! construction (one CAS each way); under stealing it is contended
//! only when two drainers hit the *same block* at the same moment —
//! per-block atomicity, which is all Hong's incremental async-ADMM
//! analysis (arXiv:1412.6058) needs.
//!
//! Hot-path notes: the shard keeps an authoritative copy of each owned
//! z̃_j (`z_cache` inside the lease) and never reads a block back from
//! the store — `handle_push` touches the store once for the version
//! (staleness stat) and once for the write.  The w̃-sum maintenance is
//! the 4-wide unrolled [`add_assign_diff`].  Pushed w buffers are
//! pooled: after the update the shard sends each buffer home on the
//! message's recycle channel instead of freeing it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::block_store::BlockStore;
use super::messages::PushMsg;
use super::topology::Topology;
use super::transport::PushReceiver;
use crate::admm::{add_assign_diff, prox_l1_box};
use crate::problem::Problem;
use crate::runtime::ServerProxXla;

/// Prox execution backend for a server thread.
pub enum ProxBackend {
    Native,
    Xla(ServerProxXla),
}

impl ProxBackend {
    fn apply(
        &self,
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            ProxBackend::Native => {
                prox_l1_box(z_tilde, w_sum, gamma, denom, lambda, clip, out);
                Ok(())
            }
            ProxBackend::Xla(sp) => {
                let z = sp.prox(z_tilde, w_sum, gamma, denom, lambda, clip)?;
                out.copy_from_slice(&z);
                Ok(())
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub pushes: usize,
    /// Max observed z-version staleness across handled pushes
    /// (Assumption 3 monitor).
    pub max_staleness: u64,
    /// Max queueing delay (send → handle) in seconds.
    pub max_queue_s: f64,
    /// Full z_j rounds completed (all of 𝒩(j) contributed since last
    /// round) — the paper's server line 5 epoch counter.
    pub rounds: usize,
}

/// All mutable state of one owned block, behind its write lease.
struct BlockState {
    /// w̃_{i,j} cache, one vector per worker in 𝒩(j).
    w_tilde: Vec<Vec<f32>>,
    /// Σ_i w̃_{i,j} running sum.
    w_sum: Vec<f32>,
    /// Which workers contributed since the last full round (server
    /// line 5 of Algorithm 1).
    contributed: Vec<bool>,
    /// Authoritative z̃_j — always equals the store's published content
    /// (the lease makes the prox + publish atomic per block).
    z_cache: Vec<f32>,
    /// Prox output scratch, swapped with `z_cache` after publish.
    z_new: Vec<f32>,
    /// Full rounds completed on this block.
    rounds: usize,
}

pub struct ServerShard {
    pub id: usize,
    /// Owned global block ids.
    blocks: Vec<usize>,
    /// local index of each global block (dense map).
    local_of_block: Vec<Option<usize>>,
    /// Per local block: the write lease over all of its mutable state.
    state: Vec<Mutex<BlockState>>,
    /// γ + Σ_{i∈𝒩(j)} ρ_i per local block.
    denom: Vec<f32>,
    /// worker id -> slot in w_tilde[local] (per local block).
    worker_slot: Vec<Vec<usize>>,
    gamma: f32,
    problem: Problem,
    store: Arc<BlockStore>,
    // -- stats (atomic: any server thread may apply to this shard) ------
    pushes: AtomicUsize,
    max_staleness: AtomicU64,
    /// f64 bit pattern of the max queueing delay in seconds (fetch_max
    /// on the bits is order-preserving for non-negative floats).
    max_queue_s_bits: AtomicU64,
}

impl ServerShard {
    pub fn new(
        id: usize,
        topo: &Topology,
        store: Arc<BlockStore>,
        problem: Problem,
        rho: f32,
        gamma: f32,
    ) -> Self {
        let blocks = topo.blocks_of_server[id].clone();
        let db = topo.block_size;
        let mut local_of_block = vec![None; topo.n_blocks];
        let mut state = Vec::with_capacity(blocks.len());
        let mut denom = Vec::with_capacity(blocks.len());
        let mut worker_slot = Vec::with_capacity(blocks.len());
        for (l, &j) in blocks.iter().enumerate() {
            local_of_block[j] = Some(l);
            let degree = topo.workers_of_block[j].len();
            denom.push(gamma + rho * degree as f32);
            let mut slots = vec![usize::MAX; topo.n_workers];
            for (s, &w) in topo.workers_of_block[j].iter().enumerate() {
                slots[w] = s;
            }
            worker_slot.push(slots);
            // One-time pull so a non-zero store initialization is honored.
            let mut z0 = vec![0.0f32; db];
            store.read_into(j, &mut z0);
            state.push(Mutex::new(BlockState {
                // Initial w̃_{i,j} = ρ x⁰ + y⁰ = 0 for z⁰ = 0 (Algorithm 1
                // worker lines 1-2), so the running sum starts at zero.
                w_tilde: vec![vec![0.0f32; db]; degree],
                w_sum: vec![0.0f32; db],
                contributed: vec![false; degree],
                z_cache: z0,
                z_new: vec![0.0; db],
                rounds: 0,
            }));
        }
        ServerShard {
            id,
            blocks,
            local_of_block,
            state,
            denom,
            worker_slot,
            gamma,
            problem,
            store,
            pushes: AtomicUsize::new(0),
            max_staleness: AtomicU64::new(0),
            max_queue_s_bits: AtomicU64::new(0),
        }
    }

    /// Apply one push (Eq. 13 incremental form). O(db).  `&self`: any
    /// server thread holding this block's lane claim may call it; the
    /// per-block lease serializes concurrent appliers.
    pub fn handle_push(&self, msg: &PushMsg, prox: &ProxBackend) -> Result<()> {
        let l = self.local_of_block[msg.block]
            .unwrap_or_else(|| panic!("server {} got push for foreign block {}", self.id, msg.block));
        let slot = self.worker_slot[l][msg.worker];
        debug_assert_ne!(slot, usize::MAX, "worker {} not in N({})", msg.worker, msg.block);

        {
            // Take the block write lease for the whole read-modify-write
            // + publish: this is the explicit writer-role handoff that
            // makes work-stealing safe (module docs).
            let mut st = self.state[l].lock().unwrap();
            let st = &mut *st;

            // w_sum += w_new - w̃_old; w̃ := w_new (4-wide unrolled).
            add_assign_diff(&mut st.w_sum, &msg.w, &st.w_tilde[slot]);
            st.w_tilde[slot].copy_from_slice(&msg.w);

            // z̃_j update + publish.  The cached z̃ is authoritative
            // (lease-holder is the sole writer), so only the version is
            // read from the store — no block copy that the prox would
            // overwrite anyway.
            let cur_version = self.store.version(msg.block);
            prox.apply(
                &st.z_cache,
                &st.w_sum,
                self.gamma,
                self.denom[l],
                self.problem.lambda,
                self.problem.clip,
                &mut st.z_new,
            )?;
            self.store.write(msg.block, &st.z_new);
            std::mem::swap(&mut st.z_cache, &mut st.z_new);

            // Round accounting (inside the lease: `contributed` is
            // per-block mutable state).
            st.contributed[slot] = true;
            if st.contributed.iter().all(|&c| c) {
                st.contributed.iter_mut().for_each(|c| *c = false);
                st.rounds += 1;
            }

            self.max_staleness
                .fetch_max(cur_version.saturating_sub(msg.z_version_used), Ordering::Relaxed);
        }

        // Shard-level stats: plain atomics, no lease needed.
        self.pushes.fetch_add(1, Ordering::Relaxed);
        let queue_s = msg.sent_at.elapsed().as_secs_f64();
        self.max_queue_s_bits.fetch_max(queue_s.to_bits(), Ordering::Relaxed);
        Ok(())
    }

    /// Snapshot of this shard's counters (pushes/staleness/queue delay
    /// are atomics; rounds are summed over the per-block leases).
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            pushes: self.pushes.load(Ordering::Relaxed),
            max_staleness: self.max_staleness.load(Ordering::Relaxed),
            max_queue_s: f64::from_bits(self.max_queue_s_bits.load(Ordering::Relaxed)),
            rounds: self.state.iter().map(|st| st.lock().unwrap().rounds).sum(),
        }
    }

    /// Blocking single-endpoint server loop (the `drain=owned` fast
    /// path and the test harness): drains the transport endpoint until
    /// it reports shutdown, then returns stats.  Pooled push buffers
    /// are returned to their owning worker after each update.  The
    /// work-stealing loop lives in `coordinator/sched.rs`.
    pub fn run(&self, mut rx: Box<dyn PushReceiver>, prox: ProxBackend) -> Result<ServerStats> {
        while let Some(mut p) = rx.recv() {
            let applied = self.handle_push(&p, &prox);
            // Send the buffer home before propagating any error; any
            // message destroyed elsewhere (transport teardown, error
            // unwinding) recycles via `PushMsg::drop`, so pooled
            // buffers can never be stranded.
            p.recycle_now();
            applied?;
        }
        Ok(self.stats())
    }

    pub fn owned_blocks(&self) -> &[usize] {
        &self.blocks
    }

    /// Test/bench hook: current z̃ cache of global block `j`.
    #[cfg(test)]
    pub(crate) fn z_cache_of(&self, j: usize) -> Vec<f32> {
        let l = self.local_of_block[j].expect("foreign block");
        self.state[l].lock().unwrap().z_cache.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};

    fn setup() -> (Topology, Arc<BlockStore>, Problem) {
        let spec = SynthSpec {
            samples: 32,
            geometry: BlockGeometry::new(4, 4),
            nnz_per_row: 3,
            blocks_per_worker: 2,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 3);
        let topo = Topology::build(&shards, 4, 2);
        let store = Arc::new(BlockStore::new(4, 4));
        (topo, store, Problem::new(LossKind::Logistic, 0.0, 1e4))
    }

    fn push(worker: usize, block: usize, w: Vec<f32>) -> PushMsg {
        PushMsg {
            worker,
            block,
            w,
            worker_epoch: 0,
            z_version_used: 0,
            sent_at: std::time::Instant::now(),
            recycle: None,
        }
    }

    #[test]
    fn incremental_sum_equals_batch_formula() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let workers = topo.workers_of_block[j].clone();
        assert!(!workers.is_empty());

        // Push twice from the same worker: w_sum must hold only the last.
        let w1 = vec![1.0f32; 4];
        let w2 = vec![3.0f32; 4];
        srv.handle_push(&push(workers[0], j, w1), &ProxBackend::Native).unwrap();
        srv.handle_push(&push(workers[0], j, w2.clone()), &ProxBackend::Native).unwrap();

        // Expected z: lambda=0 => z = (gamma*z_prev + sum_w)/denom applied
        // twice; verify against a scratch recomputation.
        let denom = 0.5 + 10.0 * workers.len() as f32;
        let z_after_1 = (0.5 * 0.0 + 1.0) / denom;
        let z_expect = (0.5 * z_after_1 + 3.0) / denom;
        let mut out = vec![0.0f32; 4];
        store.read_into(j, &mut out);
        for v in out {
            assert!((v - z_expect).abs() < 1e-6, "{v} vs {z_expect}");
        }
        assert_eq!(srv.stats().pushes, 2);
    }

    #[test]
    fn z_cache_tracks_store_content() {
        // The shard's cached z̃ must stay identical to what the store
        // publishes, push after push (the write-lease invariant).
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        for k in 0..5 {
            srv.handle_push(&push(w, j, vec![k as f32; 4]), &ProxBackend::Native).unwrap();
            let mut out = vec![0.0f32; 4];
            store.read_into(j, &mut out);
            assert_eq!(out, srv.z_cache_of(j), "push {k}: cache diverged from store");
        }
        assert_eq!(store.version(j), 5);
    }

    #[test]
    fn nonzero_store_initialization_is_honored() {
        let (topo, store, p) = setup();
        let j0 = topo.blocks_of_server[0][0];
        store.write(j0, &[0.25; 4]);
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        assert_eq!(srv.z_cache_of(j0), vec![0.25; 4]);
    }

    #[test]
    fn rounds_counted_when_all_workers_contribute() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let j = *srv
            .owned_blocks()
            .iter()
            .find(|&&j| topo.workers_of_block[j].len() > 1)
            .expect("need a shared block");
        let workers = topo.workers_of_block[j].clone();
        for (k, &w) in workers.iter().enumerate() {
            srv.handle_push(&push(w, j, vec![0.1; 4]), &ProxBackend::Native).unwrap();
            let expect_rounds = usize::from(k == workers.len() - 1);
            assert_eq!(srv.stats().rounds, expect_rounds);
        }
        // next round restarts
        srv.handle_push(&push(workers[0], j, vec![0.2; 4]), &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().rounds, 1);
    }

    #[test]
    #[should_panic(expected = "foreign block")]
    fn foreign_block_panics() {
        let (topo, store, p) = setup();
        // server 0 owns the low contiguous block range by default; find
        // any block placed on shard 1 and push it at shard 0.
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let foreign = (0..4).find(|j| topo.server_of_block[*j] == 1).unwrap();
        let worker = topo.workers_of_block[foreign].first().copied().unwrap_or(0);
        let _ = srv.handle_push(&push(worker, foreign, vec![0.0; 4]), &ProxBackend::Native);
    }

    #[test]
    fn staleness_tracked() {
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.0);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        // bump version 3 times
        for _ in 0..3 {
            store.write(j, &[0.0; 4]);
        }
        let mut m = push(w, j, vec![1.0; 4]);
        m.z_version_used = 0;
        srv.handle_push(&m, &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats().max_staleness, 3);
    }

    #[test]
    fn concurrent_appliers_on_one_shard_lose_no_push() {
        // Two threads hammer the same shard (one shared block each from
        // a different worker + disjoint blocks): the write lease must
        // keep the w̃-sum exact — the final z equals a sequential replay.
        let (topo, store, p) = setup();
        let srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = *srv
            .owned_blocks()
            .iter()
            .find(|&&j| topo.workers_of_block[j].len() > 1)
            .expect("need a shared block");
        let workers = topo.workers_of_block[j].clone();
        let reps = 200usize;
        std::thread::scope(|scope| {
            for &w in workers.iter().take(2) {
                let srv = &srv;
                scope.spawn(move || {
                    for k in 0..reps {
                        let val = (w as f32) + (k % 7) as f32;
                        srv.handle_push(&push(w, j, vec![val; 4]), &ProxBackend::Native)
                            .unwrap();
                    }
                });
            }
        });
        assert_eq!(srv.stats().pushes, 2 * reps);
        assert_eq!(store.version(j), 2 * reps as u64);
        // After all pushes, w_sum must equal the sum of each worker's
        // LAST pushed w (both last values are (w + (reps-1) % 7)):
        // verify via one more deterministic push + closed-form check on
        // the cache being finite and consistent with the store.
        let mut out = vec![0.0f32; 4];
        store.read_into(j, &mut out);
        assert_eq!(out, srv.z_cache_of(j), "cache diverged from store");
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn run_loop_recycles_pooled_buffers_with_either_transport() {
        use crate::config::TransportKind;
        use crate::coordinator::transport::{make_transport, Transport};
        use std::sync::mpsc::channel;
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            for batch in [1usize, 3] {
                let (topo, store, p) = setup();
                let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
                let j = srv.owned_blocks()[0];
                let w = topo.workers_of_block[j][0];
                let transport: Box<dyn Transport> =
                    make_transport(kind, topo.n_workers, topo.n_servers, 4, batch);
                let (home, inbox) = channel::<Vec<f32>>();
                let mut msg = push(w, j, vec![0.5; 4]);
                msg.recycle = Some(home);
                let mut tx = transport.connect_worker(w);
                tx.send(0, msg).unwrap();
                drop(tx);
                transport.shutdown();
                let stats = srv.run(transport.connect_server(0), ProxBackend::Native).unwrap();
                assert_eq!(stats.pushes, 1, "{kind:?} batch={batch}");
                let returned = inbox.try_recv().expect("buffer not recycled");
                assert_eq!(returned, vec![0.5; 4], "{kind:?} batch={batch}");
            }
        }
    }
}

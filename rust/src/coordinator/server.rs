//! Server shard: owns a subset of consensus blocks and applies the
//! incremental Eq. 13 update on every received push.
//!
//! Matching the paper's Algorithm 1 (server side): upon receiving
//! w_{i,j}^t it replaces the cached w̃_{i,j}, recomputes
//! z̃_j = prox( (γ z̃_j + Σ_i w̃_{i,j}) / (γ + Σ_i ρ_i) ), and publishes
//! the dirty copy immediately — workers never wait for an epoch barrier.
//! The w̃ running sum makes each update O(db), independent of |𝒩(j)|.
//!
//! Hot-path notes: the shard is the ONLY writer of its blocks, so it
//! keeps its own authoritative copy of each owned z̃_j (`z_cache`) and
//! never reads a block back from the store — `handle_push` touches the
//! store once for the version (staleness stat) and once for the write.
//! Pushed w buffers are pooled: after the update the shard sends each
//! buffer home on the message's recycle channel instead of freeing it.

use std::sync::Arc;

use anyhow::Result;

use super::block_store::BlockStore;
use super::messages::PushMsg;
use super::topology::Topology;
use super::transport::PushReceiver;
use crate::admm::prox_l1_box;
use crate::problem::Problem;
use crate::runtime::ServerProxXla;

/// Prox execution backend for a server thread.
pub enum ProxBackend {
    Native,
    Xla(ServerProxXla),
}

impl ProxBackend {
    fn apply(
        &self,
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            ProxBackend::Native => {
                prox_l1_box(z_tilde, w_sum, gamma, denom, lambda, clip, out);
                Ok(())
            }
            ProxBackend::Xla(sp) => {
                let z = sp.prox(z_tilde, w_sum, gamma, denom, lambda, clip)?;
                out.copy_from_slice(&z);
                Ok(())
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub pushes: usize,
    /// Max observed z-version staleness across handled pushes
    /// (Assumption 3 monitor).
    pub max_staleness: u64,
    /// Max queueing delay (send → handle) in seconds.
    pub max_queue_s: f64,
    /// Full z_j rounds completed (all of 𝒩(j) contributed since last
    /// round) — the paper's server line 5 epoch counter.
    pub rounds: usize,
}

pub struct ServerShard {
    pub id: usize,
    /// Owned global block ids.
    blocks: Vec<usize>,
    /// local index of each global block (dense map).
    local_of_block: Vec<Option<usize>>,
    /// w̃_{i,j} cache: [local block][worker-slot] -> w vector.
    w_tilde: Vec<Vec<Vec<f32>>>,
    /// Per local block: Σ_i w̃_{i,j} running sum.
    w_sum: Vec<Vec<f32>>,
    /// Per local block: which workers contributed since the last full
    /// round (server line 5 of Algorithm 1).
    contributed: Vec<Vec<bool>>,
    /// γ + Σ_{i∈𝒩(j)} ρ_i per local block.
    denom: Vec<f32>,
    /// worker id -> slot in w_tilde[local] (per local block).
    worker_slot: Vec<Vec<usize>>,
    gamma: f32,
    problem: Problem,
    store: Arc<BlockStore>,
    /// Authoritative z̃_j per owned block — this shard is the sole writer
    /// of its blocks, so the cache always equals the store's content and
    /// `handle_push` never copies a block out of the store.
    z_cache: Vec<Vec<f32>>,
    z_new: Vec<f32>,
    pub stats: ServerStats,
}

impl ServerShard {
    pub fn new(
        id: usize,
        topo: &Topology,
        store: Arc<BlockStore>,
        problem: Problem,
        rho: f32,
        gamma: f32,
    ) -> Self {
        let blocks = topo.blocks_of_server[id].clone();
        let db = topo.block_size;
        let mut local_of_block = vec![None; topo.n_blocks];
        let mut w_tilde = Vec::with_capacity(blocks.len());
        let mut w_sum = Vec::with_capacity(blocks.len());
        let mut contributed = Vec::with_capacity(blocks.len());
        let mut denom = Vec::with_capacity(blocks.len());
        let mut worker_slot = Vec::with_capacity(blocks.len());
        let mut z_cache = Vec::with_capacity(blocks.len());
        for (l, &j) in blocks.iter().enumerate() {
            local_of_block[j] = Some(l);
            let degree = topo.workers_of_block[j].len();
            // Initial w̃_{i,j} = ρ x⁰ + y⁰ = 0 for z⁰ = 0 (Algorithm 1
            // worker lines 1-2), so the running sum starts at zero.
            w_tilde.push(vec![vec![0.0f32; db]; degree]);
            w_sum.push(vec![0.0f32; db]);
            contributed.push(vec![false; degree]);
            denom.push(gamma + rho * degree as f32);
            let mut slots = vec![usize::MAX; topo.n_workers];
            for (s, &w) in topo.workers_of_block[j].iter().enumerate() {
                slots[w] = s;
            }
            worker_slot.push(slots);
            // One-time pull so a non-zero store initialization is honored.
            let mut z0 = vec![0.0f32; db];
            store.read_into(j, &mut z0);
            z_cache.push(z0);
        }
        ServerShard {
            id,
            blocks,
            local_of_block,
            w_tilde,
            w_sum,
            contributed,
            denom,
            worker_slot,
            gamma,
            problem,
            store,
            z_cache,
            z_new: vec![0.0; db],
            stats: ServerStats::default(),
        }
    }

    /// Apply one push (Eq. 13 incremental form). O(db).
    pub fn handle_push(&mut self, msg: &PushMsg, prox: &ProxBackend) -> Result<()> {
        let l = self.local_of_block[msg.block]
            .unwrap_or_else(|| panic!("server {} got push for foreign block {}", self.id, msg.block));
        let slot = self.worker_slot[l][msg.worker];
        debug_assert_ne!(slot, usize::MAX, "worker {} not in N({})", msg.worker, msg.block);

        // w_sum += w_new - w̃_old; w̃ := w_new.
        let old = &mut self.w_tilde[l][slot];
        for ((s, new), old_v) in self.w_sum[l].iter_mut().zip(&msg.w).zip(old.iter()) {
            *s += new - old_v;
        }
        old.copy_from_slice(&msg.w);

        // z̃_j update + publish.  The cached z̃ is authoritative (sole
        // writer), so only the version is read from the store — no block
        // copy that the prox would overwrite anyway.
        let cur_version = self.store.version(msg.block);
        let (gamma, denom) = (self.gamma, self.denom[l]);
        let (lambda, clip) = (self.problem.lambda, self.problem.clip);
        prox.apply(
            &self.z_cache[l],
            &self.w_sum[l],
            gamma,
            denom,
            lambda,
            clip,
            &mut self.z_new,
        )?;
        self.store.write(msg.block, &self.z_new);
        std::mem::swap(&mut self.z_cache[l], &mut self.z_new);

        // Stats + round accounting.
        self.stats.pushes += 1;
        self.stats.max_staleness =
            self.stats.max_staleness.max(cur_version.saturating_sub(msg.z_version_used));
        self.stats.max_queue_s = self
            .stats
            .max_queue_s
            .max(msg.sent_at.elapsed().as_secs_f64());
        self.contributed[l][slot] = true;
        if self.contributed[l].iter().all(|&c| c) {
            self.contributed[l].iter_mut().for_each(|c| *c = false);
            self.stats.rounds += 1;
        }
        Ok(())
    }

    /// Blocking server loop; drains the transport endpoint until it
    /// reports shutdown, then returns stats.  Pooled push buffers are
    /// returned to their owning worker after each update.
    pub fn run(mut self, mut rx: Box<dyn PushReceiver>, prox: ProxBackend) -> Result<ServerStats> {
        while let Some(mut p) = rx.recv() {
            let applied = self.handle_push(&p, &prox);
            // Send the buffer home before propagating any error; any
            // message destroyed elsewhere (transport teardown, error
            // unwinding) recycles via `PushMsg::drop`, so pooled
            // buffers can never be stranded.
            p.recycle_now();
            applied?;
        }
        Ok(self.stats)
    }

    pub fn owned_blocks(&self) -> &[usize] {
        &self.blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};

    fn setup() -> (Topology, Arc<BlockStore>, Problem) {
        let spec = SynthSpec {
            samples: 32,
            geometry: BlockGeometry::new(4, 4),
            nnz_per_row: 3,
            blocks_per_worker: 2,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 3);
        let topo = Topology::build(&shards, 4, 2);
        let store = Arc::new(BlockStore::new(4, 4));
        (topo, store, Problem::new(LossKind::Logistic, 0.0, 1e4))
    }

    fn push(worker: usize, block: usize, w: Vec<f32>) -> PushMsg {
        PushMsg {
            worker,
            block,
            w,
            worker_epoch: 0,
            z_version_used: 0,
            sent_at: std::time::Instant::now(),
            recycle: None,
        }
    }

    #[test]
    fn incremental_sum_equals_batch_formula() {
        let (topo, store, p) = setup();
        let mut srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let workers = topo.workers_of_block[j].clone();
        assert!(!workers.is_empty());

        // Push twice from the same worker: w_sum must hold only the last.
        let w1 = vec![1.0f32; 4];
        let w2 = vec![3.0f32; 4];
        srv.handle_push(&push(workers[0], j, w1), &ProxBackend::Native).unwrap();
        srv.handle_push(&push(workers[0], j, w2.clone()), &ProxBackend::Native).unwrap();

        // Expected z: lambda=0 => z = (gamma*z_prev + sum_w)/denom applied
        // twice; verify against a scratch recomputation.
        let denom = 0.5 + 10.0 * workers.len() as f32;
        let z_after_1 = (0.5 * 0.0 + 1.0) / denom;
        let z_expect = (0.5 * z_after_1 + 3.0) / denom;
        let mut out = vec![0.0f32; 4];
        store.read_into(j, &mut out);
        for v in out {
            assert!((v - z_expect).abs() < 1e-6, "{v} vs {z_expect}");
        }
        assert_eq!(srv.stats.pushes, 2);
    }

    #[test]
    fn z_cache_tracks_store_content() {
        // The shard's cached z̃ must stay identical to what the store
        // publishes, push after push (sole-writer invariant).
        let (topo, store, p) = setup();
        let mut srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.5);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        for k in 0..5 {
            srv.handle_push(&push(w, j, vec![k as f32; 4]), &ProxBackend::Native).unwrap();
            let l = srv.local_of_block[j].unwrap();
            let mut out = vec![0.0f32; 4];
            store.read_into(j, &mut out);
            assert_eq!(out, srv.z_cache[l], "push {k}: cache diverged from store");
        }
        assert_eq!(store.version(j), 5);
    }

    #[test]
    fn nonzero_store_initialization_is_honored() {
        let (topo, store, p) = setup();
        let j0 = topo.blocks_of_server[0][0];
        store.write(j0, &[0.25; 4]);
        let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.5);
        let l = srv.local_of_block[j0].unwrap();
        assert_eq!(srv.z_cache[l], vec![0.25; 4]);
    }

    #[test]
    fn rounds_counted_when_all_workers_contribute() {
        let (topo, store, p) = setup();
        let mut srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let j = *srv
            .owned_blocks()
            .iter()
            .find(|&&j| topo.workers_of_block[j].len() > 1)
            .expect("need a shared block");
        let workers = topo.workers_of_block[j].clone();
        for (k, &w) in workers.iter().enumerate() {
            srv.handle_push(&push(w, j, vec![0.1; 4]), &ProxBackend::Native).unwrap();
            let expect_rounds = usize::from(k == workers.len() - 1);
            assert_eq!(srv.stats.rounds, expect_rounds);
        }
        // next round restarts
        srv.handle_push(&push(workers[0], j, vec![0.2; 4]), &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats.rounds, 1);
    }

    #[test]
    #[should_panic(expected = "foreign block")]
    fn foreign_block_panics() {
        let (topo, store, p) = setup();
        // server 0 owns blocks {0, 2} under round-robin with 2 servers.
        let mut srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
        let foreign = (0..4).find(|j| topo.server_of_block[*j] == 1).unwrap();
        let worker = topo.workers_of_block[foreign].first().copied().unwrap_or(0);
        let _ = srv.handle_push(&push(worker, foreign, vec![0.0; 4]), &ProxBackend::Native);
    }

    #[test]
    fn staleness_tracked() {
        let (topo, store, p) = setup();
        let mut srv = ServerShard::new(0, &topo, store.clone(), p, 10.0, 0.0);
        let j = srv.owned_blocks()[0];
        let w = topo.workers_of_block[j][0];
        // bump version 3 times
        for _ in 0..3 {
            store.write(j, &[0.0; 4]);
        }
        let mut m = push(w, j, vec![1.0; 4]);
        m.z_version_used = 0;
        srv.handle_push(&m, &ProxBackend::Native).unwrap();
        assert_eq!(srv.stats.max_staleness, 3);
    }

    #[test]
    fn run_loop_recycles_pooled_buffers_with_either_transport() {
        use crate::config::TransportKind;
        use crate::coordinator::transport::{make_transport, Transport};
        use std::sync::mpsc::channel;
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            let (topo, store, p) = setup();
            let srv = ServerShard::new(0, &topo, store, p, 10.0, 0.0);
            let j = srv.owned_blocks()[0];
            let w = topo.workers_of_block[j][0];
            let transport: Box<dyn Transport> =
                make_transport(kind, topo.n_workers, topo.n_servers, 4);
            let (home, inbox) = channel::<Vec<f32>>();
            let mut msg = push(w, j, vec![0.5; 4]);
            msg.recycle = Some(home);
            let mut tx = transport.connect_worker(w);
            tx.send(0, msg).unwrap();
            drop(tx);
            transport.shutdown();
            let stats = srv.run(transport.connect_server(0), ProxBackend::Native).unwrap();
            assert_eq!(stats.pushes, 1, "{kind:?}");
            let returned = inbox.try_recv().expect("buffer not recycled");
            assert_eq!(returned, vec![0.5; 4], "{kind:?}");
        }
    }
}

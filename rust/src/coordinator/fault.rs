//! Deterministic fault injection for the threaded runtime and the DES.
//!
//! A [`FaultPlan`] is parsed from `--set faults=SPEC` and consulted from
//! hooks compiled into the worker loop (`coordinator/worker.rs`), the
//! server apply path (`coordinator/server.rs`) and the DES
//! (`crate::sim`).  The plan is *deterministic*: every fault names its
//! victim and its trigger point (a local epoch or an applied-push
//! count), so a chaos run replays exactly — the property the chaos
//! proptests and the DES/threaded differential tests rely on.
//!
//! ## Spec grammar
//!
//! `--set` splits its argument list on commas, so fault entries are
//! separated by `;`:
//!
//! ```text
//! faults=crash:w1@5;stall:s0@100+25ms;sendfail:w2@4x3
//! ```
//!
//! - `crash:w<W>@<E>` — worker `W` panics at the end of its local epoch
//!   `E` (after that epoch's push was handed to the transport, so the
//!   seq stream has no gap for recovery to bridge).
//! - `stall:s<S>@<P>+<MS>ms` — server shard `S` sleeps `MS`
//!   milliseconds, once, when its applied-push counter reaches `P`
//!   (a deterministic straggler for the watchdog tests).
//! - `sendfail:w<W>@<E>x<N>` — worker `W`'s push at epoch `E` suffers
//!   `N` transient send failures before succeeding (modelled as bounded
//!   retries; counted in `WorkerStats::send_retries`).
//!
//! Every hook is gated on [`FaultPlan::is_empty`] — a single branch on
//! a pre-computed bool — so the default (no faults) hot path pays
//! nothing measurable; `benches/fault_recovery.rs` keeps that honest.
//!
//! What a fault *did* is recorded as a [`FaultEvent`] in the plan's
//! internal log; the session monitor drains the log each wakeup,
//! forwards the events to observers ([`super::session::Observer::on_fault`])
//! and accumulates them into `TrainReport::faults`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Something that went wrong (or was injected) during a run, with
/// enough identity to correlate against the `FaultPlan` that caused it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// A worker thread panicked (injected or organic) at `epoch`
    /// completed epochs.
    WorkerCrashed { worker: usize, epoch: usize },
    /// Policy `degrade`: the crashed worker was retired, its parked
    /// (gap-blocked) pushes dropped, and the run continued on the
    /// survivors.
    WorkerDegraded { worker: usize, epoch: usize, parked_dropped: usize },
    /// Policy `restart`: a replacement worker took over at `epoch`
    /// after the dead worker's in-flight tail drained.
    WorkerRestarted { worker: usize, epoch: usize, attempt: usize },
    /// A server shard slept `ms` after `after_pushes` applied pushes.
    ServerStalled { server: usize, after_pushes: usize, ms: u64 },
    /// Watchdog: no worker published progress for `waited_ms` while the
    /// slowest live worker sat at `min_epoch` (`--set stall_warn_ms`).
    Stalled { min_epoch: usize, waited_ms: u64 },
}

impl FaultEvent {
    /// One human-readable line per event — the `/stats` endpoint's
    /// fault ledger entries and the monitor's log lines.
    pub fn describe(&self) -> String {
        match self {
            FaultEvent::WorkerCrashed { worker, epoch } => {
                format!("worker {worker} crashed at epoch {epoch}")
            }
            FaultEvent::WorkerDegraded { worker, epoch, parked_dropped } => format!(
                "worker {worker} degraded at epoch {epoch} ({parked_dropped} parked pushes dropped)"
            ),
            FaultEvent::WorkerRestarted { worker, epoch, attempt } => {
                format!("worker {worker} restarted at epoch {epoch} (attempt {attempt})")
            }
            FaultEvent::ServerStalled { server, after_pushes, ms } => {
                format!("server {server} stalled {ms}ms after {after_pushes} pushes")
            }
            FaultEvent::Stalled { min_epoch, waited_ms } => {
                format!("watchdog: no progress for {waited_ms}ms (slowest worker at epoch {min_epoch})")
            }
        }
    }
}

struct CrashEntry {
    worker: usize,
    at_epoch: usize,
    fired: AtomicBool,
}

struct StallEntry {
    server: usize,
    after_pushes: usize,
    ms: u64,
    fired: AtomicBool,
}

struct SendFailEntry {
    worker: usize,
    at_epoch: usize,
    count: usize,
}

/// A deterministic, shareable (`&self` hooks, atomics inside) schedule
/// of injected faults.  See the module docs for the spec grammar.
#[derive(Default)]
pub struct FaultPlan {
    crashes: Vec<CrashEntry>,
    stalls: Vec<StallEntry>,
    sendfails: Vec<SendFailEntry>,
    log: Mutex<Vec<FaultEvent>>,
}

impl FaultPlan {
    /// The empty plan: every hook short-circuits on one branch.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Parse a `;`-separated spec (see module docs).  Whitespace around
    /// entries is tolerated; an empty spec yields the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::none();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .with_context(|| format!("fault entry {entry:?}: expected kind:target"))?;
            match kind {
                "crash" => {
                    let (w, e) = parse_at(rest, 'w')
                        .with_context(|| format!("fault entry {entry:?} (crash:w<W>@<E>)"))?;
                    plan.crashes.push(CrashEntry {
                        worker: w,
                        at_epoch: e,
                        fired: AtomicBool::new(false),
                    });
                }
                "stall" => {
                    let (s, trigger) = parse_at_raw(rest, 's')
                        .with_context(|| format!("fault entry {entry:?} (stall:s<S>@<P>+<MS>ms)"))?;
                    let (pushes, ms) = trigger
                        .split_once('+')
                        .with_context(|| format!("fault entry {entry:?}: expected <P>+<MS>ms"))?;
                    let ms = ms
                        .strip_suffix("ms")
                        .with_context(|| format!("fault entry {entry:?}: duration must end in ms"))?;
                    plan.stalls.push(StallEntry {
                        server: s,
                        after_pushes: pushes
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad push count"))?,
                        ms: ms
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad duration"))?,
                        fired: AtomicBool::new(false),
                    });
                }
                "sendfail" => {
                    let (w, trigger) = parse_at_raw(rest, 'w')
                        .with_context(|| format!("fault entry {entry:?} (sendfail:w<W>@<E>x<N>)"))?;
                    let (epoch, count) = trigger
                        .split_once('x')
                        .with_context(|| format!("fault entry {entry:?}: expected <E>x<N>"))?;
                    plan.sendfails.push(SendFailEntry {
                        worker: w,
                        at_epoch: epoch
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad epoch"))?,
                        count: count
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad count"))?,
                    });
                }
                other => bail!(
                    "fault entry {entry:?}: unknown kind {other:?} (crash|stall|sendfail)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when no faults are scheduled — the hot-path gate.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.stalls.is_empty() && self.sendfails.is_empty()
    }

    /// Worker hook: should `worker` crash now, having just completed
    /// `epoch` epochs?  Fires each matching entry at most once, so a
    /// restarted worker re-running the same epoch does not re-crash.
    #[inline]
    pub fn should_crash(&self, worker: usize, epoch: usize) -> bool {
        if self.crashes.is_empty() {
            return false;
        }
        for c in &self.crashes {
            if c.worker == worker
                && c.at_epoch == epoch
                && !c.fired.swap(true, Ordering::AcqRel)
            {
                return true;
            }
        }
        false
    }

    /// Worker hook: transient send failures to simulate for `worker`'s
    /// push at local epoch `epoch` (0 almost always).
    #[inline]
    pub fn send_failures(&self, worker: usize, epoch: usize) -> usize {
        if self.sendfails.is_empty() {
            return 0;
        }
        self.sendfails
            .iter()
            .filter(|f| f.worker == worker && f.at_epoch == epoch)
            .map(|f| f.count)
            .sum()
    }

    /// Server hook: milliseconds shard `server` should sleep given its
    /// applied-push count.  Fires each entry once and records the
    /// [`FaultEvent::ServerStalled`] itself (the apply path has no
    /// other channel to the monitor).
    #[inline]
    pub fn stall_ms(&self, server: usize, pushes: usize) -> Option<u64> {
        if self.stalls.is_empty() {
            return None;
        }
        for st in &self.stalls {
            if st.server == server
                && pushes >= st.after_pushes
                && !st.fired.swap(true, Ordering::AcqRel)
            {
                self.record(FaultEvent::ServerStalled {
                    server,
                    after_pushes: st.after_pushes,
                    ms: st.ms,
                });
                return Some(st.ms);
            }
        }
        None
    }

    /// Append an event to the plan's log (drained by the monitor).
    pub fn record(&self, ev: FaultEvent) {
        self.log.lock().unwrap().push(ev);
    }

    /// Drain and return all events logged since the last call.
    pub fn take_events(&self) -> Vec<FaultEvent> {
        std::mem::take(&mut *self.log.lock().unwrap())
    }
}

/// Parse `"<prefix><N>@<M>"` into `(N, M)`.
fn parse_at(s: &str, prefix: char) -> Result<(usize, usize)> {
    let (id, rest) = parse_at_raw(s, prefix)?;
    Ok((id, rest.parse().context("bad trigger number")?))
}

/// Parse `"<prefix><N>@<rest>"` into `(N, rest)`.
fn parse_at_raw(s: &str, prefix: char) -> Result<(usize, &str)> {
    let s = s
        .strip_prefix(prefix)
        .with_context(|| format!("target must start with {prefix:?}"))?;
    let (id, rest) = s.split_once('@').context("expected <id>@<trigger>")?;
    Ok((id.parse().context("bad target id")?, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_yield_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn parses_all_three_kinds() {
        let p = FaultPlan::parse("crash:w1@5; stall:s0@100+25ms ;sendfail:w2@4x3").unwrap();
        assert!(!p.is_empty());
        assert!(!p.should_crash(1, 4));
        assert!(!p.should_crash(0, 5));
        assert!(p.should_crash(1, 5));
        assert!(!p.should_crash(1, 5), "crash entry refired");
        assert_eq!(p.send_failures(2, 4), 3);
        assert_eq!(p.send_failures(2, 5), 0);
        assert_eq!(p.stall_ms(0, 99), None);
        assert_eq!(p.stall_ms(1, 200), None);
        assert_eq!(p.stall_ms(0, 100), Some(25));
        assert_eq!(p.stall_ms(0, 200), None, "stall entry refired");
        // The stall recorded its own event.
        let evs = p.take_events();
        assert_eq!(
            evs,
            vec![FaultEvent::ServerStalled { server: 0, after_pushes: 100, ms: 25 }]
        );
        assert!(p.take_events().is_empty(), "take_events did not drain");
    }

    #[test]
    fn rejects_malformed_specs_with_context() {
        for bad in [
            "crash",
            "crash:x1@5",
            "crash:w1",
            "crash:w1@x",
            "stall:s0@100",
            "stall:s0@100+25",
            "sendfail:w2@4",
            "explode:w0@1",
        ] {
            let err = FaultPlan::parse(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("fault entry"),
                "error for {bad:?} lacks context: {msg}"
            );
        }
    }

    #[test]
    fn hooks_on_the_empty_plan_are_inert() {
        let p = FaultPlan::none();
        assert!(!p.should_crash(0, 0));
        assert_eq!(p.send_failures(0, 0), 0);
        assert_eq!(p.stall_ms(0, usize::MAX), None);
    }

    #[test]
    fn describe_names_the_victim_and_trigger() {
        let cases = [
            (FaultEvent::WorkerCrashed { worker: 3, epoch: 7 }, vec!["worker 3", "epoch 7"]),
            (
                FaultEvent::WorkerDegraded { worker: 1, epoch: 2, parked_dropped: 4 },
                vec!["worker 1", "degraded", "4 parked"],
            ),
            (
                FaultEvent::WorkerRestarted { worker: 0, epoch: 9, attempt: 2 },
                vec!["worker 0", "restarted", "attempt 2"],
            ),
            (
                FaultEvent::ServerStalled { server: 2, after_pushes: 100, ms: 25 },
                vec!["server 2", "25ms", "100 pushes"],
            ),
            (
                FaultEvent::Stalled { min_epoch: 5, waited_ms: 750 },
                vec!["watchdog", "750ms", "epoch 5"],
            ),
        ];
        for (ev, needles) in cases {
            let line = ev.describe();
            for needle in needles {
                assert!(line.contains(needle), "{line:?} missing {needle:?}");
            }
        }
    }

    #[test]
    fn record_and_drain_are_fifo() {
        let p = FaultPlan::none();
        p.record(FaultEvent::WorkerCrashed { worker: 3, epoch: 7 });
        p.record(FaultEvent::WorkerRestarted { worker: 3, epoch: 7, attempt: 1 });
        let evs = p.take_events();
        assert_eq!(evs.len(), 2);
        assert!(matches!(evs[0], FaultEvent::WorkerCrashed { worker: 3, epoch: 7 }));
    }
}

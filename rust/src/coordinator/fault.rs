//! Deterministic fault injection for the threaded runtime and the DES.
//!
//! A [`FaultPlan`] is parsed from `--set faults=SPEC` and consulted from
//! hooks compiled into the worker loop (`coordinator/worker.rs`), the
//! server apply path (`coordinator/server.rs`) and the DES
//! (`crate::sim`).  The plan is *deterministic*: every fault names its
//! victim and its trigger point (a local epoch or an applied-push
//! count), so a chaos run replays exactly — the property the chaos
//! proptests and the DES/threaded differential tests rely on.
//!
//! ## Spec grammar
//!
//! `--set` splits its argument list on commas, so fault entries are
//! separated by `;`:
//!
//! ```text
//! faults=crash:w1@5;stall:s0@100+25ms;sendfail:w2@4x3
//! ```
//!
//! - `crash:w<W>@<E>` — worker `W` panics at the end of its local epoch
//!   `E` (after that epoch's push was handed to the transport, so the
//!   seq stream has no gap for recovery to bridge).
//! - `stall:s<S>@<P>+<MS>ms` — server shard `S` sleeps `MS`
//!   milliseconds, once, when its applied-push counter reaches `P`
//!   (a deterministic straggler for the watchdog tests).
//! - `sendfail:w<W>@<E>x<N>` — worker `W`'s push at epoch `E` suffers
//!   `N` transient send failures before succeeding (modelled as bounded
//!   retries; counted in `WorkerStats::send_retries`).
//!
//! The networked runtime (`coordinator/net/`) adds three wire-level
//! kinds, hooked at the tcp/proc read-write seams behind the same
//! [`FaultPlan::is_empty`] gate:
//!
//! - `netdrop:w<W>@<E>` — worker `W`'s push sockets are severed just
//!   before its epoch-`E` push, simulating a network partition or a
//!   peer reset.  Fires in the *worker* process.
//! - `netstall:w<W>@<P>+<MS>ms` — worker `W`'s push stream freezes for
//!   `MS` milliseconds, once, when its sent-frame counter reaches `P`
//!   (a socket-level straggler; with `net_liveness_ms` shorter than
//!   `MS` the coordinator will treat the silence as death).  Fires in
//!   the *worker* process.
//! - `corrupt:s<S>@<N>` — the coordinator flips bytes in the `N`-th
//!   frame it sends on rank `S`'s pull-sync stream.  The receiver must
//!   surface a named decode error (never a panic) and tear down that
//!   stream cleanly.  Fires in the *serve* process.
//!
//! Every hook is gated on [`FaultPlan::is_empty`] — a single branch on
//! a pre-computed bool — so the default (no faults) hot path pays
//! nothing measurable; `benches/fault_recovery.rs` keeps that honest.
//!
//! What a fault *did* is recorded as a [`FaultEvent`] in the plan's
//! internal log; the session monitor drains the log each wakeup,
//! forwards the events to observers ([`super::session::Observer::on_fault`])
//! and accumulates them into `TrainReport::faults`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Something that went wrong (or was injected) during a run, with
/// enough identity to correlate against the `FaultPlan` that caused it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// A worker thread panicked (injected or organic) at `epoch`
    /// completed epochs.
    WorkerCrashed { worker: usize, epoch: usize },
    /// Policy `degrade`: the crashed worker was retired, its parked
    /// (gap-blocked) pushes dropped, and the run continued on the
    /// survivors.
    WorkerDegraded { worker: usize, epoch: usize, parked_dropped: usize },
    /// Policy `restart`: a replacement worker took over at `epoch`
    /// after the dead worker's in-flight tail drained.
    WorkerRestarted { worker: usize, epoch: usize, attempt: usize },
    /// A server shard slept `ms` after `after_pushes` applied pushes.
    ServerStalled { server: usize, after_pushes: usize, ms: u64 },
    /// Watchdog: no worker published progress for `waited_ms` while the
    /// slowest live worker sat at `min_epoch` (`--set stall_warn_ms`).
    Stalled { min_epoch: usize, waited_ms: u64 },
    /// `netdrop`: worker `worker`'s push sockets were severed before
    /// its epoch-`epoch` push.
    NetDropped { worker: usize, epoch: usize },
    /// `netstall`: worker `worker`'s push stream froze `ms` after
    /// `after_frames` sent frames.
    NetStalled { worker: usize, after_frames: usize, ms: u64 },
    /// `corrupt`: frame `frame` on rank `stream`'s pull stream had its
    /// bytes flipped in flight.
    FrameCorrupted { stream: usize, frame: usize },
    /// Networked runtime: rank `rank` was evicted (liveness deadline or
    /// socket reset under `failure=degrade`) and the run continued on
    /// the survivors.  `parked_dropped` counts purged early-arrivals
    /// across the rank's workers.
    RankEvicted { rank: usize, parked_dropped: usize },
    /// Networked runtime: rank `rank` rejoined via the Rejoin handshake
    /// (`failure=restart`) and resumed its seq streams exactly.
    RankRejoined { rank: usize, attempt: usize },
}

impl FaultEvent {
    /// One human-readable line per event — the `/stats` endpoint's
    /// fault ledger entries and the monitor's log lines.
    pub fn describe(&self) -> String {
        match self {
            FaultEvent::WorkerCrashed { worker, epoch } => {
                format!("worker {worker} crashed at epoch {epoch}")
            }
            FaultEvent::WorkerDegraded { worker, epoch, parked_dropped } => format!(
                "worker {worker} degraded at epoch {epoch} ({parked_dropped} parked pushes dropped)"
            ),
            FaultEvent::WorkerRestarted { worker, epoch, attempt } => {
                format!("worker {worker} restarted at epoch {epoch} (attempt {attempt})")
            }
            FaultEvent::ServerStalled { server, after_pushes, ms } => {
                format!("server {server} stalled {ms}ms after {after_pushes} pushes")
            }
            FaultEvent::Stalled { min_epoch, waited_ms } => {
                format!("watchdog: no progress for {waited_ms}ms (slowest worker at epoch {min_epoch})")
            }
            FaultEvent::NetDropped { worker, epoch } => {
                format!("worker {worker} push sockets severed at epoch {epoch} (netdrop)")
            }
            FaultEvent::NetStalled { worker, after_frames, ms } => {
                format!("worker {worker} push stream froze {ms}ms after {after_frames} frames")
            }
            FaultEvent::FrameCorrupted { stream, frame } => {
                format!("pull stream {stream}: frame {frame} corrupted in flight")
            }
            FaultEvent::RankEvicted { rank, parked_dropped } => format!(
                "rank {rank} evicted ({parked_dropped} parked pushes dropped); completing on survivors"
            ),
            FaultEvent::RankRejoined { rank, attempt } => {
                format!("rank {rank} rejoined (attempt {attempt})")
            }
        }
    }
}

struct CrashEntry {
    worker: usize,
    at_epoch: usize,
    fired: AtomicBool,
}

struct StallEntry {
    server: usize,
    after_pushes: usize,
    ms: u64,
    fired: AtomicBool,
}

struct SendFailEntry {
    worker: usize,
    at_epoch: usize,
    count: usize,
}

struct NetDropEntry {
    worker: usize,
    at_epoch: usize,
    fired: AtomicBool,
}

struct NetStallEntry {
    worker: usize,
    after_frames: usize,
    ms: u64,
    fired: AtomicBool,
}

struct CorruptEntry {
    stream: usize,
    at_frame: usize,
    fired: AtomicBool,
}

/// A deterministic, shareable (`&self` hooks, atomics inside) schedule
/// of injected faults.  See the module docs for the spec grammar.
#[derive(Default)]
pub struct FaultPlan {
    crashes: Vec<CrashEntry>,
    stalls: Vec<StallEntry>,
    sendfails: Vec<SendFailEntry>,
    netdrops: Vec<NetDropEntry>,
    netstalls: Vec<NetStallEntry>,
    corrupts: Vec<CorruptEntry>,
    log: Mutex<Vec<FaultEvent>>,
}

impl FaultPlan {
    /// The empty plan: every hook short-circuits on one branch.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Parse a `;`-separated spec (see module docs).  Whitespace around
    /// entries is tolerated; an empty spec yields the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::none();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .with_context(|| format!("fault entry {entry:?}: expected kind:target"))?;
            match kind {
                "crash" => {
                    let (w, e) = parse_at(rest, 'w')
                        .with_context(|| format!("fault entry {entry:?} (crash:w<W>@<E>)"))?;
                    plan.crashes.push(CrashEntry {
                        worker: w,
                        at_epoch: e,
                        fired: AtomicBool::new(false),
                    });
                }
                "stall" => {
                    let (s, trigger) = parse_at_raw(rest, 's')
                        .with_context(|| format!("fault entry {entry:?} (stall:s<S>@<P>+<MS>ms)"))?;
                    let (pushes, ms) = trigger
                        .split_once('+')
                        .with_context(|| format!("fault entry {entry:?}: expected <P>+<MS>ms"))?;
                    let ms = ms
                        .strip_suffix("ms")
                        .with_context(|| format!("fault entry {entry:?}: duration must end in ms"))?;
                    plan.stalls.push(StallEntry {
                        server: s,
                        after_pushes: pushes
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad push count"))?,
                        ms: ms
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad duration"))?,
                        fired: AtomicBool::new(false),
                    });
                }
                "sendfail" => {
                    let (w, trigger) = parse_at_raw(rest, 'w')
                        .with_context(|| format!("fault entry {entry:?} (sendfail:w<W>@<E>x<N>)"))?;
                    let (epoch, count) = trigger
                        .split_once('x')
                        .with_context(|| format!("fault entry {entry:?}: expected <E>x<N>"))?;
                    plan.sendfails.push(SendFailEntry {
                        worker: w,
                        at_epoch: epoch
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad epoch"))?,
                        count: count
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad count"))?,
                    });
                }
                "netdrop" => {
                    let (w, e) = parse_at(rest, 'w')
                        .with_context(|| format!("fault entry {entry:?} (netdrop:w<W>@<E>)"))?;
                    plan.netdrops.push(NetDropEntry {
                        worker: w,
                        at_epoch: e,
                        fired: AtomicBool::new(false),
                    });
                }
                "netstall" => {
                    let (w, trigger) = parse_at_raw(rest, 'w').with_context(|| {
                        format!("fault entry {entry:?} (netstall:w<W>@<P>+<MS>ms)")
                    })?;
                    let (frames, ms) = trigger
                        .split_once('+')
                        .with_context(|| format!("fault entry {entry:?}: expected <P>+<MS>ms"))?;
                    let ms = ms
                        .strip_suffix("ms")
                        .with_context(|| format!("fault entry {entry:?}: duration must end in ms"))?;
                    plan.netstalls.push(NetStallEntry {
                        worker: w,
                        after_frames: frames
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad frame count"))?,
                        ms: ms
                            .parse()
                            .with_context(|| format!("fault entry {entry:?}: bad duration"))?,
                        fired: AtomicBool::new(false),
                    });
                }
                "corrupt" => {
                    let (s, f) = parse_at(rest, 's')
                        .with_context(|| format!("fault entry {entry:?} (corrupt:s<S>@<N>)"))?;
                    plan.corrupts.push(CorruptEntry {
                        stream: s,
                        at_frame: f,
                        fired: AtomicBool::new(false),
                    });
                }
                other => bail!(
                    "fault entry {entry:?}: unknown kind {other:?} \
                     (crash|stall|sendfail|netdrop|netstall|corrupt)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when no faults are scheduled — the hot-path gate.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.stalls.is_empty()
            && self.sendfails.is_empty()
            && self.netdrops.is_empty()
            && self.netstalls.is_empty()
            && self.corrupts.is_empty()
    }

    /// Filter a spec down to the entries that fire in the *worker*
    /// process on the networked runtime (`netdrop`, `netstall`) — the
    /// subset the Welcome frame deliberately re-plumbs to `asybadmm
    /// work` (everything else would double-fire or has no seam there).
    /// Textual, so it composes with an already-validated spec.
    pub fn worker_net_spec(spec: &str) -> String {
        spec.split(';')
            .map(str::trim)
            .filter(|e| {
                matches!(e.split_once(':').map(|(k, _)| k), Some("netdrop" | "netstall"))
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Worker hook: should `worker` crash now, having just completed
    /// `epoch` epochs?  Fires each matching entry at most once, so a
    /// restarted worker re-running the same epoch does not re-crash.
    #[inline]
    pub fn should_crash(&self, worker: usize, epoch: usize) -> bool {
        if self.crashes.is_empty() {
            return false;
        }
        for c in &self.crashes {
            if c.worker == worker
                && c.at_epoch == epoch
                && !c.fired.swap(true, Ordering::AcqRel)
            {
                return true;
            }
        }
        false
    }

    /// Worker hook: transient send failures to simulate for `worker`'s
    /// push at local epoch `epoch` (0 almost always).
    #[inline]
    pub fn send_failures(&self, worker: usize, epoch: usize) -> usize {
        if self.sendfails.is_empty() {
            return 0;
        }
        self.sendfails
            .iter()
            .filter(|f| f.worker == worker && f.at_epoch == epoch)
            .map(|f| f.count)
            .sum()
    }

    /// Server hook: milliseconds shard `server` should sleep given its
    /// applied-push count.  Fires each entry once and records the
    /// [`FaultEvent::ServerStalled`] itself (the apply path has no
    /// other channel to the monitor).
    #[inline]
    pub fn stall_ms(&self, server: usize, pushes: usize) -> Option<u64> {
        if self.stalls.is_empty() {
            return None;
        }
        for st in &self.stalls {
            if st.server == server
                && pushes >= st.after_pushes
                && !st.fired.swap(true, Ordering::AcqRel)
            {
                self.record(FaultEvent::ServerStalled {
                    server,
                    after_pushes: st.after_pushes,
                    ms: st.ms,
                });
                return Some(st.ms);
            }
        }
        None
    }

    /// Push-sender hook (networked runtime): should `worker`'s sockets
    /// be severed before its epoch-`epoch` push?  Fires each matching
    /// entry at most once and records the [`FaultEvent::NetDropped`].
    #[inline]
    pub fn net_drop(&self, worker: usize, epoch: usize) -> bool {
        if self.netdrops.is_empty() {
            return false;
        }
        for d in &self.netdrops {
            if d.worker == worker
                && epoch >= d.at_epoch
                && !d.fired.swap(true, Ordering::AcqRel)
            {
                self.record(FaultEvent::NetDropped { worker, epoch });
                return true;
            }
        }
        false
    }

    /// Push-sender hook (networked runtime): milliseconds `worker`'s
    /// push stream should freeze given its sent-frame count.  Fires
    /// each entry once and records the [`FaultEvent::NetStalled`].
    #[inline]
    pub fn net_stall_ms(&self, worker: usize, frames: usize) -> Option<u64> {
        if self.netstalls.is_empty() {
            return None;
        }
        for st in &self.netstalls {
            if st.worker == worker
                && frames >= st.after_frames
                && !st.fired.swap(true, Ordering::AcqRel)
            {
                self.record(FaultEvent::NetStalled {
                    worker,
                    after_frames: st.after_frames,
                    ms: st.ms,
                });
                return Some(st.ms);
            }
        }
        None
    }

    /// Serve-side hook (networked runtime): should the `frame`-th frame
    /// on rank `stream`'s pull stream have its bytes flipped?  Fires
    /// each entry once and records the [`FaultEvent::FrameCorrupted`].
    #[inline]
    pub fn corrupt_frame(&self, stream: usize, frame: usize) -> bool {
        if self.corrupts.is_empty() {
            return false;
        }
        for c in &self.corrupts {
            if c.stream == stream
                && frame >= c.at_frame
                && !c.fired.swap(true, Ordering::AcqRel)
            {
                self.record(FaultEvent::FrameCorrupted { stream, frame });
                return true;
            }
        }
        false
    }

    /// Append an event to the plan's log (drained by the monitor).
    pub fn record(&self, ev: FaultEvent) {
        self.log.lock().unwrap().push(ev);
    }

    /// Drain and return all events logged since the last call.
    pub fn take_events(&self) -> Vec<FaultEvent> {
        std::mem::take(&mut *self.log.lock().unwrap())
    }
}

/// Parse `"<prefix><N>@<M>"` into `(N, M)`.
fn parse_at(s: &str, prefix: char) -> Result<(usize, usize)> {
    let (id, rest) = parse_at_raw(s, prefix)?;
    Ok((id, rest.parse().context("bad trigger number")?))
}

/// Parse `"<prefix><N>@<rest>"` into `(N, rest)`.
fn parse_at_raw(s: &str, prefix: char) -> Result<(usize, &str)> {
    let s = s
        .strip_prefix(prefix)
        .with_context(|| format!("target must start with {prefix:?}"))?;
    let (id, rest) = s.split_once('@').context("expected <id>@<trigger>")?;
    Ok((id.parse().context("bad target id")?, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_yield_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn parses_all_three_kinds() {
        let p = FaultPlan::parse("crash:w1@5; stall:s0@100+25ms ;sendfail:w2@4x3").unwrap();
        assert!(!p.is_empty());
        assert!(!p.should_crash(1, 4));
        assert!(!p.should_crash(0, 5));
        assert!(p.should_crash(1, 5));
        assert!(!p.should_crash(1, 5), "crash entry refired");
        assert_eq!(p.send_failures(2, 4), 3);
        assert_eq!(p.send_failures(2, 5), 0);
        assert_eq!(p.stall_ms(0, 99), None);
        assert_eq!(p.stall_ms(1, 200), None);
        assert_eq!(p.stall_ms(0, 100), Some(25));
        assert_eq!(p.stall_ms(0, 200), None, "stall entry refired");
        // The stall recorded its own event.
        let evs = p.take_events();
        assert_eq!(
            evs,
            vec![FaultEvent::ServerStalled { server: 0, after_pushes: 100, ms: 25 }]
        );
        assert!(p.take_events().is_empty(), "take_events did not drain");
    }

    #[test]
    fn parses_the_net_kinds_and_hooks_fire_once() {
        let p =
            FaultPlan::parse("netdrop:w1@5; netstall:w0@100+25ms; corrupt:s2@3").unwrap();
        assert!(!p.is_empty());
        assert!(!p.net_drop(1, 4));
        assert!(!p.net_drop(0, 5));
        assert!(p.net_drop(1, 5));
        assert!(!p.net_drop(1, 6), "netdrop entry refired");
        assert_eq!(p.net_stall_ms(0, 99), None);
        assert_eq!(p.net_stall_ms(1, 200), None);
        assert_eq!(p.net_stall_ms(0, 100), Some(25));
        assert_eq!(p.net_stall_ms(0, 200), None, "netstall entry refired");
        assert!(!p.corrupt_frame(2, 2));
        assert!(!p.corrupt_frame(0, 3));
        assert!(p.corrupt_frame(2, 3));
        assert!(!p.corrupt_frame(2, 4), "corrupt entry refired");
        // Each hook recorded its own event, in firing order.
        let evs = p.take_events();
        assert_eq!(
            evs,
            vec![
                FaultEvent::NetDropped { worker: 1, epoch: 5 },
                FaultEvent::NetStalled { worker: 0, after_frames: 100, ms: 25 },
                FaultEvent::FrameCorrupted { stream: 2, frame: 3 },
            ]
        );
    }

    #[test]
    fn worker_net_spec_keeps_only_worker_side_net_entries() {
        let spec = "crash:w1@5;netdrop:w1@5; stall:s0@9+1ms ;netstall:w0@10+5ms;corrupt:s0@3";
        assert_eq!(
            FaultPlan::worker_net_spec(spec),
            "netdrop:w1@5;netstall:w0@10+5ms"
        );
        assert_eq!(FaultPlan::worker_net_spec("crash:w0@1"), "");
        assert_eq!(FaultPlan::worker_net_spec(""), "");
    }

    #[test]
    fn rejects_malformed_specs_with_context() {
        for bad in [
            "crash",
            "crash:x1@5",
            "crash:w1",
            "crash:w1@x",
            "stall:s0@100",
            "stall:s0@100+25",
            "sendfail:w2@4",
            "explode:w0@1",
            "netdrop:s1@5",
            "netdrop:w1@",
            "netstall:w0@100",
            "netstall:w0@100+25",
            "corrupt:w0@3",
            "corrupt:s0@x",
        ] {
            let err = FaultPlan::parse(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("fault entry"),
                "error for {bad:?} lacks context: {msg}"
            );
        }
    }

    #[test]
    fn hooks_on_the_empty_plan_are_inert() {
        let p = FaultPlan::none();
        assert!(!p.should_crash(0, 0));
        assert_eq!(p.send_failures(0, 0), 0);
        assert_eq!(p.stall_ms(0, usize::MAX), None);
        assert!(!p.net_drop(0, usize::MAX));
        assert_eq!(p.net_stall_ms(0, usize::MAX), None);
        assert!(!p.corrupt_frame(0, usize::MAX));
    }

    #[test]
    fn describe_names_the_victim_and_trigger() {
        let cases = [
            (FaultEvent::WorkerCrashed { worker: 3, epoch: 7 }, vec!["worker 3", "epoch 7"]),
            (
                FaultEvent::WorkerDegraded { worker: 1, epoch: 2, parked_dropped: 4 },
                vec!["worker 1", "degraded", "4 parked"],
            ),
            (
                FaultEvent::WorkerRestarted { worker: 0, epoch: 9, attempt: 2 },
                vec!["worker 0", "restarted", "attempt 2"],
            ),
            (
                FaultEvent::ServerStalled { server: 2, after_pushes: 100, ms: 25 },
                vec!["server 2", "25ms", "100 pushes"],
            ),
            (
                FaultEvent::Stalled { min_epoch: 5, waited_ms: 750 },
                vec!["watchdog", "750ms", "epoch 5"],
            ),
            (
                FaultEvent::NetDropped { worker: 2, epoch: 6 },
                vec!["worker 2", "severed", "epoch 6"],
            ),
            (
                FaultEvent::NetStalled { worker: 1, after_frames: 40, ms: 30 },
                vec!["worker 1", "froze 30ms", "40 frames"],
            ),
            (
                FaultEvent::FrameCorrupted { stream: 0, frame: 3 },
                vec!["stream 0", "frame 3", "corrupted"],
            ),
            (
                FaultEvent::RankEvicted { rank: 1, parked_dropped: 2 },
                vec!["rank 1", "evicted", "2 parked"],
            ),
            (
                FaultEvent::RankRejoined { rank: 1, attempt: 1 },
                vec!["rank 1", "rejoined", "attempt 1"],
            ),
        ];
        for (ev, needles) in cases {
            let line = ev.describe();
            for needle in needles {
                assert!(line.contains(needle), "{line:?} missing {needle:?}");
            }
        }
    }

    #[test]
    fn record_and_drain_are_fifo() {
        let p = FaultPlan::none();
        p.record(FaultEvent::WorkerCrashed { worker: 3, epoch: 7 });
        p.record(FaultEvent::WorkerRestarted { worker: 3, epoch: 7, attempt: 1 });
        let evs = p.take_events();
        assert_eq!(evs.len(), 2);
        assert!(matches!(evs[0], FaultEvent::WorkerCrashed { worker: 3, epoch: 7 }));
    }
}

//! Training telemetry records (the rows Fig. 2 is drawn from).

/// One objective sample taken by the monitor thread.
#[derive(Clone, Debug)]
pub struct ObjSample {
    /// Wall-clock (or virtual, in the simulator) seconds since start.
    pub time_s: f64,
    /// Minimum local epoch across workers when the sample was taken
    /// ("iterations k" on the paper's x-axis).
    pub epoch: usize,
    /// F(z) = Σ_i f_i(z) + h(z).
    pub objective: f64,
    pub data_loss: f64,
    /// max_{(i,j)} ‖x_ij − z_j‖ (0 if x not sampled at this point).
    pub consensus_max: f64,
}

impl ObjSample {
    pub fn csv_header() -> &'static str {
        "time_s,epoch,objective,data_loss,consensus_max"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{:.6},{},{:.8},{:.8},{:.3e}",
            self.time_s, self.epoch, self.objective, self.data_loss, self.consensus_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_fields() {
        let s = ObjSample {
            time_s: 1.5,
            epoch: 20,
            objective: 0.69,
            data_loss: 0.68,
            consensus_max: 1e-3,
        };
        let line = s.to_csv();
        assert_eq!(line.split(',').count(), ObjSample::csv_header().split(',').count());
        assert!(line.starts_with("1.500000,20,"));
    }
}

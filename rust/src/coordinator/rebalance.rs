//! Dynamic block re-placement: migrate hot blocks between server
//! shards at runtime from observed push rates.
//!
//! The static placements (`coordinator/placement.rs`) fix the
//! block→shard map at `Topology::build` time; `degree` packs by the
//! static proxy |𝒩(j)|.  Under a Zipf-hot head whose *realized* push
//! rates drift from that prior, shard load imbalance serializes exactly
//! the updates the paper parallelizes.  `--set placement=dynamic`
//! starts from the naive contiguous map and adapts: a [`Rebalancer`]
//! (driven from the session monitor thread) samples per-block
//! applied-push counters and service-time EWMAs from the shared
//! [`super::server::BlockTable`], computes a greedy LPT re-map from the
//! observed *cost* (`rate × service time` — a slow block at the same
//! rate is a heavier block), and publishes the hottest diffs into the
//! shared [`BlockMap`] that workers read on the push path.
//!
//! ## Why migration preserves the paper's assumptions
//!
//! Adaptive Consensus ADMM (Xu et al., 2017) shows runtime adaptation
//! of ADMM internals is sound as long as per-block atomicity and
//! bounded staleness survive; Chang et al.'s async analysis
//! (arXiv:1509.02597) frames the staleness budget.  Three mechanisms
//! carry those invariants across a migration, with **zero added locks
//! on the steady-state hot path**:
//!
//! 1. **Routing** is one `Release`-written, `Acquire`-read atomic per
//!    block ([`BlockMap::owner`]): workers re-read the owner on every
//!    push — a single atomic load replacing the old static `Vec`
//!    index.  No epoch of the map needs to be consistent across
//!    blocks, so there is nothing to lock.
//! 2. **State** never moves: all per-block server state lives in the
//!    shared `BlockTable` behind per-block write leases, so the "new
//!    owner" takes the same lease the old owner used — the handoff is
//!    the mutex the apply path already holds.
//! 3. **Order** is seq-gated: the in-flight tail of the old lane can
//!    race the head of the new lane, so applies are gated on the
//!    per-(worker, block) `block_seq` (`coordinator/server.rs`) —
//!    early arrivals park until their predecessors land, preserving
//!    per-edge FIFO (Assumption 3's accounting) exactly.
//!
//! The rebalancer itself runs on the monitor thread (no extra thread,
//! no worker-visible synchronization): scan → delta counts → greedy
//! LPT → hysteresis gate → bounded migration burst.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::placement::load_imbalance;
use super::server::BlockTable;

/// Default minimum applied pushes per window before a scan acts (the
/// rate-noise floor).  Shared with the DES migration model so virtual
/// and threaded runs react on the same signal.
pub const REBALANCE_MIN_DELTA: usize = 32;
/// Default improvement factor a target map must beat the current one
/// by before migrating (churn damping).
pub const REBALANCE_HYSTERESIS: f64 = 0.95;
/// Default max blocks migrated per scan (bounded burst).
pub const REBALANCE_MAX_MOVES: usize = 8;

/// The live block→shard routing map: one atomic owner per block plus a
/// version/migration ledger.  Readers (workers, every push) pay one
/// `Acquire` load; the writer (the rebalancer) publishes owner changes
/// with `Release` stores.  Per-block independence means no cross-entry
/// consistency is needed — this is the lock-free "versioned map" of
/// the migration protocol (module docs).
pub struct BlockMap {
    owner: Vec<AtomicUsize>,
    version: AtomicU64,
    migrations: AtomicUsize,
}

impl BlockMap {
    /// A map seeded from a static placement's `server_of_block`.
    pub fn new(owners: &[usize]) -> Self {
        BlockMap {
            owner: owners.iter().map(|&s| AtomicUsize::new(s)).collect(),
            version: AtomicU64::new(0),
            migrations: AtomicUsize::new(0),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.owner.len()
    }

    /// Current owner of block `j` — the worker push-path read.
    #[inline]
    pub fn owner(&self, j: usize) -> usize {
        self.owner[j].load(Ordering::Acquire)
    }

    /// Publish a new owner for block `j`.  Returns whether the owner
    /// actually changed (and was counted as a migration).
    pub fn set_owner(&self, j: usize, s: usize) -> bool {
        let old = self.owner[j].swap(s, Ordering::Release);
        if old != s {
            self.version.fetch_add(1, Ordering::Release);
            self.migrations.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Monotone map version (bumped once per owner change).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Total owner changes published so far.
    pub fn migrations(&self) -> usize {
        self.migrations.load(Ordering::Relaxed)
    }

    /// A point-in-time copy of the owner map.
    pub fn snapshot(&self) -> Vec<usize> {
        self.owner.iter().map(|a| a.load(Ordering::Acquire)).collect()
    }

    /// `(block, new_owner)` for every block whose current owner differs
    /// from `prev` (a snapshot the caller took earlier).  The networked
    /// runtime's owner-republish step: the coordinator diffs the map
    /// after each rebalance scan and ships only the changed entries to
    /// worker processes as `OwnerUpdate` frames.
    pub fn diff(&self, prev: &[usize]) -> Vec<(usize, usize)> {
        assert_eq!(prev.len(), self.owner.len(), "owner map geometry mismatch");
        self.owner
            .iter()
            .enumerate()
            .filter_map(|(j, a)| {
                let s = a.load(Ordering::Acquire);
                (s != prev[j]).then_some((j, s))
            })
            .collect()
    }

    /// Restore owners wholesale from a checkpoint snapshot *without*
    /// counting migrations or bumping the version: a resumed run starts
    /// from the saved placement as if it had been the initial one.
    pub fn reset_owners(&self, owners: &[usize]) {
        assert_eq!(owners.len(), self.owner.len(), "owner map geometry mismatch");
        for (a, &s) in self.owner.iter().zip(owners) {
            a.store(s, Ordering::Release);
        }
    }
}

/// Greedy LPT (longest-processing-time) packing of `weight` into
/// `n_servers` bins: heaviest blocks first, each to the lightest bin.
/// Deterministic: ties break by block id, then block count, then shard
/// id — the same discipline as the static `degree` placement, so a
/// stationary workload converges to a stable map.  Shared by the
/// threaded [`Rebalancer`] and the DES migration model (`crate::sim`).
pub fn lpt_map(weight: &[usize], n_servers: usize) -> Vec<usize> {
    let n = weight.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| weight[b].cmp(&weight[a]).then(a.cmp(&b)));
    let mut load = vec![0usize; n_servers];
    let mut count = vec![0usize; n_servers];
    let mut map = vec![0usize; n];
    for j in order {
        let s = (0..n_servers)
            .min_by_key(|&s| (load[s], count[s], s))
            .expect("n_servers > 0");
        map[j] = s;
        load[s] += weight[j];
        count[s] += 1;
    }
    map
}

/// Pure migration planning, shared verbatim by the threaded
/// [`Rebalancer`] and the DES migration model (`crate::sim`) so both
/// react identically to the same observation window: greedy-LPT re-pack
/// of `weight` (per-block *cost* for the window — applied-push delta ×
/// sampled service-time EWMA on the threaded path; a rate-only caller
/// just passes raw deltas), gated on beating the current imbalance by
/// `hysteresis`, returning at most `max_moves` `(block, new_owner)`
/// moves sorted heaviest-first.  `tiebreak` breaks equal-weight move
/// ordering (the threaded path passes per-block pending-queue depth:
/// between two equally costly blocks, migrate the one whose queue is
/// deeper first); pass `&[]` for the plain block-id tiebreak.  Empty
/// result = keep the current map.  (The noise-floor / window
/// bookkeeping stays with the callers, which own the counters.)
pub fn plan_rebalance(
    current: &[usize],
    weight: &[usize],
    tiebreak: &[usize],
    n_servers: usize,
    hysteresis: f64,
    max_moves: usize,
) -> Vec<(usize, usize)> {
    if n_servers < 2 || current.is_empty() {
        return Vec::new();
    }
    let cur_imb = load_imbalance(current, weight, n_servers);
    let target = lpt_map(weight, n_servers);
    let tgt_imb = load_imbalance(&target, weight, n_servers);
    if tgt_imb >= cur_imb * hysteresis {
        return Vec::new();
    }
    // Heaviest mismatched blocks first (deepest queue on ties), bounded
    // per scan so one pass never floods the in-flight reorder window.
    let depth = |j: usize| tiebreak.get(j).copied().unwrap_or(0);
    let mut diffs: Vec<usize> =
        (0..current.len()).filter(|&j| target[j] != current[j]).collect();
    diffs.sort_by(|&a, &b| {
        weight[b].cmp(&weight[a]).then(depth(b).cmp(&depth(a))).then(a.cmp(&b))
    });
    diffs.truncate(max_moves);
    diffs.into_iter().map(|j| (j, target[j])).collect()
}

/// Samples per-block applied-push rates and migrates hot blocks toward
/// a balanced map.  Owned and driven by one thread (the session
/// monitor); everything it shares with workers/servers is the atomic
/// [`BlockMap`] and the `BlockTable` counters it reads.
pub struct Rebalancer {
    map: Arc<BlockMap>,
    table: Arc<BlockTable>,
    n_servers: usize,
    /// Counter snapshot at the last completed scan (rate window start).
    last: Vec<usize>,
    /// Minimum applied pushes per window before acting (noise floor).
    pub min_delta: usize,
    /// Act only if the LPT target beats the current imbalance by this
    /// factor (churn damping; 0.95 = require a 5% improvement).
    pub hysteresis: f64,
    /// Max blocks migrated per scan (bounded burst; hottest first).
    pub max_moves: usize,
}

impl Rebalancer {
    pub fn new(map: Arc<BlockMap>, table: Arc<BlockTable>, n_servers: usize) -> Self {
        // Baseline the first rate window on the table's CURRENT
        // counters (0 on a fresh run): a checkpoint-resumed table
        // arrives with its counters pre-seeded, and treating that
        // history as one window's delta would trigger a spurious
        // migration burst at the first scan.
        let n = map.n_blocks();
        let last = (0..n).map(|j| table.push_count(j)).collect();
        Rebalancer {
            map,
            table,
            n_servers,
            last,
            min_delta: REBALANCE_MIN_DELTA,
            hysteresis: REBALANCE_HYSTERESIS,
            max_moves: REBALANCE_MAX_MOVES,
        }
    }

    /// One sampling + migration pass; returns blocks migrated.  The
    /// window accumulates across calls until `min_delta` pushes were
    /// observed, so a fast caller cadence only sharpens reaction time.
    ///
    /// The LPT weight is the window's *cost*, not its raw rate:
    /// `delta × service-time EWMA` (nanos, sampled by the apply path).
    /// Two blocks with identical push rates but a 5× prox-cost skew —
    /// higher degree |𝒩(j)|, colder cache, an XLA round-trip — stop
    /// looking interchangeable to the packer.  Blocks with no sample
    /// yet weigh `delta × 1`, which preserves the old rate-only
    /// ordering among themselves.
    pub fn scan(&mut self) -> usize {
        let n = self.map.n_blocks();
        if self.n_servers < 2 || n == 0 {
            return 0;
        }
        let counts: Vec<usize> = (0..n).map(|j| self.table.push_count(j)).collect();
        let delta: Vec<usize> =
            counts.iter().zip(&self.last).map(|(c, l)| c.saturating_sub(*l)).collect();
        let total: usize = delta.iter().sum();
        if total < self.min_delta {
            // Window too small to be signal; keep accumulating.
            return 0;
        }
        self.last = counts;

        let cost: Vec<usize> = delta
            .iter()
            .enumerate()
            .map(|(j, &d)| d.saturating_mul(self.table.service_ewma_ns(j).max(1) as usize))
            .collect();
        // Pending (seq-parked) depth: the equal-cost tiebreak — a block
        // already backed up behind a migration tail moves first.
        let pending: Vec<usize> = (0..n).map(|j| self.table.pending_len(j)).collect();

        let current = self.map.snapshot();
        let mut moved = 0usize;
        for (j, s) in plan_rebalance(
            &current,
            &cost,
            &pending,
            self.n_servers,
            self.hysteresis,
            self.max_moves,
        ) {
            if self.map.set_owner(j, s) {
                moved += 1;
            }
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::block_store::BlockStore;
    use crate::coordinator::messages::PushMsg;
    use crate::coordinator::server::ProxBackend;
    use crate::coordinator::topology::Topology;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
    use crate::problem::Problem;

    #[test]
    fn block_map_tracks_versions_and_migrations() {
        let m = BlockMap::new(&[0, 0, 1, 1]);
        assert_eq!(m.n_blocks(), 4);
        assert_eq!(m.owner(2), 1);
        assert_eq!(m.version(), 0);
        assert!(m.set_owner(0, 1));
        assert!(!m.set_owner(0, 1), "no-op move counted");
        assert!(m.set_owner(0, 0));
        assert_eq!(m.version(), 2);
        assert_eq!(m.migrations(), 2);
        assert_eq!(m.snapshot(), vec![0, 0, 1, 1]);
    }

    #[test]
    fn reset_owners_restores_a_snapshot_without_counting_migrations() {
        let m = BlockMap::new(&[0, 0, 1, 1]);
        m.set_owner(0, 1);
        let (v, mig) = (m.version(), m.migrations());
        m.reset_owners(&[1, 1, 0, 0]);
        assert_eq!(m.snapshot(), vec![1, 1, 0, 0]);
        assert_eq!(m.version(), v, "resume must not look like churn");
        assert_eq!(m.migrations(), mig);
    }

    #[test]
    fn diff_reports_exactly_the_changed_owners() {
        let m = BlockMap::new(&[0, 0, 1, 1]);
        let before = m.snapshot();
        assert!(m.diff(&before).is_empty());
        m.set_owner(0, 1);
        m.set_owner(3, 0);
        m.set_owner(1, 0); // no-op: already 0
        let mut d = m.diff(&before);
        d.sort_unstable();
        assert_eq!(d, vec![(0, 1), (3, 0)]);
        // Diffing against the fresh snapshot is empty again.
        assert!(m.diff(&m.snapshot()).is_empty());
    }

    #[test]
    fn lpt_map_balances_and_is_deterministic() {
        // One hot block + uniform tail over 2 bins: the hot block gets
        // its own-ish bin and the tail fills around it.
        let w = vec![10usize, 1, 1, 1, 1, 1, 1, 1];
        let a = lpt_map(&w, 2);
        let b = lpt_map(&w, 2);
        assert_eq!(a, b);
        let imb = load_imbalance(&a, &w, 2);
        assert!(imb <= 1.2, "LPT left imbalance {imb}");
        // Hot block alone on a shard is the LPT signature here.
        let hot = a[0];
        let hot_load: usize =
            (0..8).filter(|&j| a[j] == hot).map(|j| w[j]).sum();
        assert!(hot_load <= 11, "hot shard overloaded: {hot_load}");
    }

    #[test]
    fn cost_weight_moves_what_rate_only_calls_balanced() {
        // Two shards, two blocks each, every block at the SAME push
        // rate — rate-only load is perfectly balanced and the planner
        // must hold still.  Fold in a 9× service-time skew on block 0
        // (the cost weighting the threaded scan and the DES both use)
        // and shard 0 is suddenly carrying 100 of 120 cost units: the
        // planner must move block 1 off it.
        let current = vec![0usize, 0, 1, 1];
        let rate = vec![10usize, 10, 10, 10];
        assert!(
            plan_rebalance(&current, &rate, &[], 2, 0.95, 8).is_empty(),
            "rate-only view is balanced; nothing should move"
        );
        let ewma_ns = [9usize, 1, 1, 1];
        let cost: Vec<usize> = rate.iter().zip(ewma_ns).map(|(&r, e)| r * e).collect();
        let moves = plan_rebalance(&current, &cost, &[], 2, 0.95, 8);
        assert_eq!(moves, vec![(1, 1)], "slow-block skew not rebalanced");
    }

    #[test]
    fn plan_rebalance_breaks_weight_ties_by_queue_depth() {
        // All four equal-weight blocks sit on shard 0; LPT wants blocks
        // 1 and 3 on shard 1.  With max_moves=1 the pending-depth
        // tiebreak decides which migrates first.
        let current = vec![0usize, 0, 0, 0];
        let weight = vec![10usize, 10, 10, 10];
        let deep_at_3 = vec![0usize, 0, 0, 5];
        let moves = plan_rebalance(&current, &weight, &deep_at_3, 2, 0.95, 1);
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].0, 3, "deepest queue should move first: {moves:?}");
        // No depth info: lowest mismatched block id wins, as before.
        let moves = plan_rebalance(&current, &weight, &[], 2, 0.95, 1);
        assert_eq!(moves[0].0, 1, "{moves:?}");
    }

    #[test]
    fn rebalancer_migrates_a_contiguous_hot_head_toward_balance() {
        // Every worker touches every block; the synthetic Zipf pushes
        // below hammer the low-index head, all of which contiguous
        // placement parks on shard 0.
        let n_blocks = 8usize;
        let spec = SynthSpec {
            samples: 24,
            geometry: BlockGeometry::new(n_blocks, 4),
            nnz_per_row: 3,
            blocks_per_worker: n_blocks,
            shared_blocks: n_blocks,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 3);
        let topo = Topology::build(&shards, n_blocks, 2);
        let store = std::sync::Arc::new(BlockStore::new(n_blocks, 4));
        let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
        let table =
            std::sync::Arc::new(BlockTable::new(&topo, store, problem, 2.0, 0.1));
        let map = std::sync::Arc::new(BlockMap::new(&topo.server_of_block));
        // Contiguous default: blocks 0..4 on shard 0.
        assert_eq!(map.owner(0), 0);
        assert_eq!(map.owner(1), 0);

        let mut rb = Rebalancer::new(map.clone(), table.clone(), 2);
        // Below the noise floor nothing moves.
        assert_eq!(rb.scan(), 0);

        // Zipf-ish traffic: block 0 ≫ block 1 ≫ tail, straight into the
        // shared table (what the server drain loops do).
        let mut seqs = vec![0u64; n_blocks];
        let mut feed = |j: usize, times: usize| {
            for _ in 0..times {
                seqs[j] += 1;
                let msg = PushMsg {
                    worker: topo.workers_of_block[j][0],
                    block: j,
                    w: vec![0.1; 4].into(),
                    worker_epoch: 0,
                    z_version_used: 0,
                    block_seq: seqs[j],
                    sent_at: None,
                    recycle: None,
                };
                table.ingest(&msg, &ProxBackend::Native).unwrap();
            }
        };
        feed(0, 60);
        feed(1, 30);
        for j in 2..n_blocks {
            feed(j, 4);
        }
        let moved = rb.scan();
        assert!(moved > 0, "rebalancer ignored a hot contiguous head");
        assert!(map.migrations() >= moved);
        // The two hottest blocks must no longer share a shard.
        assert_ne!(map.owner(0), map.owner(1), "hot head not split: {:?}", map.snapshot());

        // Stationary traffic: the map settles (hysteresis) instead of
        // churning.
        feed(0, 60);
        feed(1, 30);
        for j in 2..n_blocks {
            feed(j, 4);
        }
        let before = map.snapshot();
        rb.scan();
        let after = map.snapshot();
        assert_eq!(before, after, "map churned under a stationary load");
    }
}

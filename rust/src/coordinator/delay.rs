//! Delay injection (E5: γ-vs-delay ablation; Assumption-3 stress).
//!
//! Two mechanisms, composable:
//! * **network latency** — each push sleeps a random duration before the
//!   server sees it (exponential with a configured mean, truncated at
//!   4× mean so Assumption 3's *bounded* delay holds);
//! * **stale pulls** — a worker refreshes its cached z̃ blocks only every
//!   `hold` iterations, giving a deterministic iteration-count staleness
//!   (the knob the γ-ablation sweeps).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelayPolicy {
    /// Mean injected network delay in milliseconds (0 = none).
    pub net_mean_ms: f64,
    /// Refresh local z̃ every `hold` iterations (1 = every iteration).
    pub pull_hold: usize,
}

impl Default for DelayPolicy {
    fn default() -> Self {
        DelayPolicy { net_mean_ms: 0.0, pull_hold: 1 }
    }
}

impl DelayPolicy {
    pub fn none() -> Self {
        Self::default()
    }

    /// Sample one network delay (milliseconds, bounded by 4× mean).
    pub fn sample_net_ms(&self, rng: &mut Rng) -> f64 {
        if self.net_mean_ms <= 0.0 {
            return 0.0;
        }
        rng.exponential(1.0 / self.net_mean_ms).min(4.0 * self.net_mean_ms)
    }

    /// Should the worker refresh its z̃ cache at local epoch `t`?
    pub fn should_pull(&self, t: usize) -> bool {
        self.pull_hold <= 1 || t % self.pull_hold == 0
    }

    pub fn sleep_net(&self, rng: &mut Rng) {
        let ms = self.sample_net_ms(rng);
        if ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mean_is_no_delay() {
        let mut rng = Rng::new(1);
        let p = DelayPolicy::none();
        for _ in 0..10 {
            assert_eq!(p.sample_net_ms(&mut rng), 0.0);
        }
    }

    #[test]
    fn delays_bounded_by_4x_mean() {
        let mut rng = Rng::new(2);
        let p = DelayPolicy { net_mean_ms: 5.0, pull_hold: 1 };
        let mut total = 0.0;
        for _ in 0..5000 {
            let d = p.sample_net_ms(&mut rng);
            assert!((0.0..=20.0).contains(&d));
            total += d;
        }
        let mean = total / 5000.0;
        assert!((mean - 5.0).abs() < 0.8, "mean {mean}"); // truncation pulls it slightly below 5
    }

    #[test]
    fn pull_hold_schedule() {
        let p = DelayPolicy { net_mean_ms: 0.0, pull_hold: 4 };
        let pulls: Vec<bool> = (0..8).map(|t| p.should_pull(t)).collect();
        assert_eq!(pulls, vec![true, false, false, false, true, false, false, false]);
        let every = DelayPolicy::none();
        assert!((0..5).all(|t| every.should_pull(t)));
    }
}

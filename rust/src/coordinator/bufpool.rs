//! Reusable push-buffer pool — the allocation-free worker→server path.
//!
//! Before this existed every worker epoch heap-allocated a fresh
//! `Vec<f32>` for the pushed w block (`self.w.clone()`), and the server
//! dropped it after `handle_push` — one malloc + one free per epoch on
//! the hottest path in the system.  The pool closes the loop:
//!
//! 1. the worker [`PushPool::acquire`]s a buffer (reuse → new-up-to-cap
//!    → block),
//! 2. the compute backend writes w into it and it rides inside the
//!    [`super::messages::PushMsg`],
//! 3. after `handle_push` the server shard sends the buffer home on the
//!    message's recycle channel instead of dropping it.
//!
//! The pool cap is sized from the transport's in-flight push budget
//! (`transport::push_inflight`, see session.rs), so the number of live
//! buffers — and the pool's high-water mark — is bounded by the queue
//! depth, not by the number of epochs.  `acquire` blocking at the cap is
//! the same backpressure the bounded transport already provides.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::util::AlignedBuf;

/// Worker-owned pool of `db`-sized, 64-byte-aligned push buffers with a
/// recycle channel.  Cache-line alignment means two buffers acquired
/// back-to-back never straddle one line — the server-side readers of
/// adjacent in-flight pushes cannot false-share.
pub struct PushPool {
    /// Recycle inbox: buffers the server shards have finished with.
    inbox: Receiver<AlignedBuf>,
    /// Kept alive so `inbox.recv()` can never observe a closed channel;
    /// cloned into every [`PushMsg`] as the return address.
    home: Sender<AlignedBuf>,
    db: usize,
    cap: usize,
    allocated: usize,
}

impl PushPool {
    /// Pool for `db`-float buffers; at most `cap` are ever allocated.
    pub fn new(db: usize, cap: usize) -> Self {
        let (home, inbox) = channel();
        PushPool { inbox, home, db, cap: cap.max(1), allocated: 0 }
    }

    /// The sender a consumer uses to return a buffer to this pool.
    pub fn recycler(&self) -> Sender<AlignedBuf> {
        self.home.clone()
    }

    /// Get a buffer: reuse a recycled one if available, allocate while
    /// under the cap, otherwise block until a consumer returns one
    /// (backpressure mirroring the bounded push channel).
    pub fn acquire(&mut self) -> AlignedBuf {
        if let Ok(buf) = self.inbox.try_recv() {
            debug_assert_eq!(buf.len(), self.db);
            return buf;
        }
        if self.allocated < self.cap {
            self.allocated += 1;
            return AlignedBuf::zeroed(self.db);
        }
        // Cannot fail: `self.home` keeps a sender alive.
        self.inbox.recv().expect("push pool recycle channel closed")
    }

    /// Buffers ever allocated — the no-allocation-per-epoch invariant is
    /// `high_water() ≤ cap` regardless of how many epochs ran.
    pub fn high_water(&self) -> usize {
        self.allocated
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// Receiver-side free list for wire-decoded push buffers
/// (`coordinator/net`).  Unlike [`PushPool`], `acquire` **never
/// blocks**: the receive path cannot wait on its own downstream (the
/// server apply loop recycles into this pool *after* handling the
/// message this pool is allocating for — blocking here would deadlock
/// the lane).  Backpressure is the transport's credit window, not the
/// pool; steady state still allocates nothing because every applied
/// message sends its buffer straight back.
pub struct LeasePool {
    inbox: Receiver<AlignedBuf>,
    home: Sender<AlignedBuf>,
    /// Buffers ever allocated fresh (diagnostics; bounded by the credit
    /// window in steady state, not by message count).
    allocated: usize,
}

impl Default for LeasePool {
    fn default() -> Self {
        Self::new()
    }
}

impl LeasePool {
    pub fn new() -> Self {
        let (home, inbox) = channel();
        LeasePool { inbox, home, allocated: 0 }
    }

    /// The return address decoded messages carry as their `recycle`.
    pub fn recycler(&self) -> Sender<AlignedBuf> {
        self.home.clone()
    }

    /// A buffer of exactly `n` floats: reuse a returned one if the size
    /// matches, else allocate.  Off-size returns (a worker with a
    /// different block size on the same lane cannot happen today, but a
    /// resized config across a reconnect could) are dropped rather than
    /// hoarded.
    pub fn acquire(&mut self, n: usize) -> AlignedBuf {
        while let Ok(buf) = self.inbox.try_recv() {
            if buf.len() == n {
                return buf;
            }
        }
        self.allocated += 1;
        AlignedBuf::zeroed(n)
    }

    pub fn high_water(&self) -> usize {
        self.allocated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_pool_reuses_matching_returns_without_blocking() {
        let mut pool = LeasePool::new();
        let a = pool.acquire(4);
        assert_eq!(pool.high_water(), 1);
        pool.recycler().send(a).unwrap();
        let b = pool.acquire(4);
        assert_eq!(b.len(), 4);
        assert_eq!(pool.high_water(), 1, "matching return not reused");
        // A size change allocates fresh and drops the stale return.
        pool.recycler().send(b).unwrap();
        let c = pool.acquire(8);
        assert_eq!(c.len(), 8);
        assert_eq!(pool.high_water(), 2);
    }

    #[test]
    fn acquire_allocates_up_to_cap_then_reuses() {
        let mut pool = PushPool::new(4, 2);
        let a = pool.acquire();
        let b = pool.acquire();
        assert_eq!(pool.high_water(), 2);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // Return one; the next acquire must reuse it, not allocate.
        pool.recycler().send(a).unwrap();
        let c = pool.acquire();
        assert_eq!(c.len(), 4);
        assert_eq!(pool.high_water(), 2);
    }

    #[test]
    fn acquire_blocks_at_cap_until_a_buffer_returns() {
        let mut pool = PushPool::new(8, 1);
        let buf = pool.acquire();
        assert_eq!(pool.high_water(), 1);
        // Return from another thread after a delay; acquire must wake.
        let tx = pool.recycler();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(buf).unwrap();
        });
        let got = pool.acquire(); // would deadlock if the cap leaked
        assert_eq!(got.len(), 8);
        assert_eq!(pool.high_water(), 1);
        handle.join().unwrap();
    }

    #[test]
    fn high_water_is_bounded_by_cap_not_iterations() {
        let mut pool = PushPool::new(2, 3);
        let ret = pool.recycler();
        for _ in 0..1000 {
            let buf = pool.acquire();
            ret.send(buf).unwrap(); // immediate "server" turnaround
        }
        assert!(pool.high_water() <= 3, "pool grew: {}", pool.high_water());
    }
}

//! Server-thread drain scheduling: who services which inbound queue.
//!
//! The transport exposes each server shard's inbound stream as one or
//! more independently drainable **lanes**
//! ([`Transport::connect_server_lanes`]): per-(worker, shard) SPSC
//! rings for the ring transport, one lane total for mpsc.  This module
//! decides which server *thread* drains which lane:
//!
//! * [`DrainKind::Owned`] — each thread drains only its own shard's
//!   lanes (the pre-PR-4 behavior, round-robin over lanes).
//! * [`DrainKind::Steal`] — a thread whose own lanes run dry CAS-claims
//!   pending lanes of busier shards and drains those.  Stealing moves
//!   **whole lanes, never single messages**: a lane is a per-worker
//!   FIFO sub-stream, and exclusive sequential access to it (the
//!   claim) preserves per-(worker, block) delivery order no matter
//!   which thread drains — the invariant Algorithm 1's staleness
//!   accounting needs.
//!
//! ## Why stealing is safe (the ownership handoff)
//!
//! Two layers cooperate:
//!
//! 1. **Lane claim** (`AtomicBool` CAS, here): at most one thread
//!    drains a lane at any time, so the SPSC ring's single-consumer
//!    discipline holds even as the consumer *role* migrates between
//!    threads.  The claim's release(store)/acquire(CAS) pair carries
//!    the receiver's internal cursor across threads.
//! 2. **Block write lease** (`server.rs`): applying a push takes the
//!    target block's mutex for the whole read-modify-write + store
//!    publish, so a thief and the owner draining two different lanes
//!    into the same hot block never interleave an update.
//!
//! Budgeted drains (at most [`DRAIN_BUDGET`] messages per claim) bound
//! how long a thief holds someone else's lane, so the owner coming
//! back never starves behind its own queue.
//!
//! ## Elastic thread pool (`--set server_threads=N`)
//!
//! [`run_pool`] decouples thread count from shard count: when the
//! session runs `N != n_servers` threads, every thread services every
//! shard's lanes (own-first affinity at `tid % n_servers`), so
//! oversubscribed shards borrow CPU from idle threads and a single
//! thread can drain any number of shards.  The same lane-claim + block-
//! lease machinery makes this safe — a pool thread is just a permanent
//! "thief" with no shard of its own to favor beyond affinity.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::server::{ProxBackend, ServerShard};
use super::transport::{Backoff, PushReceiver, Transport, TryRecv};
use crate::config::DrainKind;

/// Messages drained per successful lane claim before the claim is
/// released (fairness bound; see module docs).
const DRAIN_BUDGET: usize = 64;

/// One independently drainable inbound lane of a server shard.
struct Lane {
    /// Exclusive drain claim; CAS-acquired, store-released.
    claim: AtomicBool,
    /// Terminal: the lane reported end-of-stream (shutdown + drained).
    done: AtomicBool,
    /// The receiving endpoint; `None` once [`ShardRt::close_lanes`]
    /// force-closed it.  The claim already serializes access; the
    /// mutex exists because `Box<dyn PushReceiver>` is `Send` but not
    /// `Sync`, and its (uncontended) lock doubles as a second
    /// happens-before edge for the receiver's cursor state.
    rx: Mutex<Option<Box<dyn PushReceiver>>>,
}

impl Lane {
    fn new(rx: Box<dyn PushReceiver>) -> Self {
        Lane {
            claim: AtomicBool::new(false),
            done: AtomicBool::new(false),
            rx: Mutex::new(Some(rx)),
        }
    }

    fn try_claim(&self) -> bool {
        self.claim
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    fn release(&self) {
        self.claim.store(false, Ordering::Release);
    }

    fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }
}

/// A server shard plus its claimable inbound lanes — everything a
/// server thread (its own, or a stealing neighbor) needs to service it.
pub struct ShardRt {
    pub shard: ServerShard,
    lanes: Vec<Lane>,
}

impl ShardRt {
    /// Take shard `shard.id`'s receiver lanes from the transport.
    /// Single-take, like `connect_server`.
    pub fn new(shard: ServerShard, transport: &dyn Transport) -> Self {
        let lanes =
            transport.connect_server_lanes(shard.id).into_iter().map(Lane::new).collect();
        ShardRt { shard, lanes }
    }

    fn all_done(&self) -> bool {
        self.lanes.iter().all(Lane::is_done)
    }

    /// Force-close every lane: drop the receivers — disconnecting
    /// their channels/rings so senders blocked on this shard fail
    /// loudly — and mark the lanes terminal so steal-mode peers stop
    /// waiting on them.  The session monitor calls this for a shard
    /// whose thread died, restoring the pre-sched behavior where a
    /// panicking server thread dropped its receiver on unwind (the
    /// receivers now live here, outliving the thread).  Poison-
    /// tolerant: the dead thread may have panicked holding a lane.
    pub fn close_lanes(&self) {
        for lane in &self.lanes {
            let mut rx =
                lane.rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            drop(rx.take());
            lane.done.store(true, Ordering::Release);
        }
    }
}

/// Drain up to `budget` messages from a claimed lane into `shard`.
/// Returns how many were applied.  The caller holds the claim.
fn drain_claimed(
    shard: &ServerShard,
    lane: &Lane,
    prox: &ProxBackend,
    budget: usize,
) -> Result<usize> {
    let mut rx = lane.rx.lock().unwrap();
    let Some(rx) = rx.as_mut() else {
        // Force-closed (dead-shard teardown): terminal.
        lane.done.store(true, Ordering::Release);
        return Ok(0);
    };
    let mut applied = 0usize;
    while applied < budget {
        match rx.try_recv() {
            TryRecv::Msg(mut msg) => {
                let r = shard.handle_push(&msg, prox);
                // Buffer goes home before any error propagates
                // (`PushMsg::drop` would also recycle, but do it
                // eagerly on the happy path).
                msg.recycle_now();
                r?;
                applied += 1;
            }
            TryRecv::Empty => break,
            TryRecv::Done => {
                lane.done.store(true, Ordering::Release);
                break;
            }
        }
    }
    Ok(applied)
}

/// Sweep `rt`'s lanes once, claiming and draining each available lane.
/// Returns messages applied.
fn sweep(rt: &ShardRt, prox: &ProxBackend) -> Result<usize> {
    let mut applied = 0usize;
    for lane in &rt.lanes {
        if lane.is_done() || !lane.try_claim() {
            continue;
        }
        // Release the claim before propagating any error so other
        // threads are not wedged out of a lane nobody holds.
        let r = drain_claimed(&rt.shard, lane, prox, DRAIN_BUDGET);
        lane.release();
        applied += r?;
    }
    Ok(applied)
}

/// The server-thread main loop for shard `sid` under drain policy
/// `drain`.  Returns once this thread's exit condition holds: all own
/// lanes terminal for [`DrainKind::Owned`]; all lanes of *every* shard
/// terminal for [`DrainKind::Steal`] (a thief keeps helping busier
/// shards after its own queues close).
///
/// Call with the same `rts` slice from every server thread; `sid`
/// indexes this thread's own shard.
pub fn run_server(
    rts: &[ShardRt],
    sid: usize,
    drain: DrainKind,
    prox: &ProxBackend,
) -> Result<()> {
    let own = &rts[sid];
    // Fast path: `owned` with a single lane (the mpsc shape) is the
    // plain blocking server loop — no polling, no idle wakeups, same
    // CPU profile as the pre-sched design.
    if matches!(drain, DrainKind::Owned) && own.lanes.len() == 1 {
        let lane = &own.lanes[0];
        if lane.try_claim() {
            let mut guard = lane.rx.lock().unwrap();
            if let Some(rx) = guard.as_mut() {
                while let Some(mut msg) = rx.recv() {
                    let r = own.shard.handle_push(&msg, prox);
                    msg.recycle_now();
                    r?;
                }
            }
            lane.done.store(true, Ordering::Release);
            // The claim is deliberately not released: the lane is
            // terminal and nobody else should ever drain it.
        }
        return Ok(());
    }
    let mut backoff = Backoff::new();
    loop {
        // Own lanes first — the owner is the common case and keeps
        // locality (its shard's z̃ caches are warm in this core).
        let mut applied = sweep(own, prox)?;

        match drain {
            DrainKind::Owned => {
                if own.all_done() {
                    return Ok(());
                }
            }
            DrainKind::Steal => {
                if applied == 0 {
                    // Own lanes dry: steal pending lanes of busier
                    // shards, whole lanes at a time, starting after our
                    // own index so thieves fan out over victims.
                    for k in 1..rts.len() {
                        applied += sweep(&rts[(sid + k) % rts.len()], prox)?;
                    }
                }
                if rts.iter().all(ShardRt::all_done) {
                    return Ok(());
                }
            }
        }

        if applied == 0 {
            backoff.snooze();
        } else {
            backoff.reset();
        }
    }
}

/// Elastic-pool thread main loop: thread `tid` of a pool whose size is
/// decoupled from the shard count services EVERY shard's lanes,
/// sweeping its affinity shard (`tid % n_servers`) first for locality.
/// Returns once every lane of every shard is terminal (all producers
/// flushed + shutdown observed, or lanes force-closed).
///
/// Call with the same `rts` slice from every pool thread; any `tid`
/// works (only the sweep starting point depends on it).
pub fn run_pool(rts: &[ShardRt], tid: usize, prox: &ProxBackend) -> Result<()> {
    let n = rts.len();
    let mut backoff = Backoff::new();
    loop {
        let mut applied = 0usize;
        for k in 0..n {
            applied += sweep(&rts[(tid + k) % n], prox)?;
        }
        if rts.iter().all(ShardRt::all_done) {
            return Ok(());
        }
        if applied == 0 {
            backoff.snooze();
        } else {
            backoff.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::block_store::BlockStore;
    use super::super::messages::PushMsg;
    use super::super::topology::Topology;
    use super::super::transport::make_transport;
    use crate::config::TransportKind;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
    use crate::problem::Problem;
    use std::sync::Arc;
    use std::time::Duration;

    fn setup(n_blocks: usize, n_servers: usize, workers: usize) -> (Topology, Arc<BlockStore>, Problem) {
        let spec = SynthSpec {
            samples: 8 * workers,
            geometry: BlockGeometry::new(n_blocks, 4),
            nnz_per_row: 3,
            blocks_per_worker: n_blocks, // every worker touches every block
            shared_blocks: n_blocks,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, workers);
        let topo = Topology::build(&shards, n_blocks, n_servers);
        let store = Arc::new(BlockStore::new(n_blocks, 4));
        (topo, store, Problem::new(LossKind::Logistic, 0.0, 1e4))
    }

    fn push(worker: usize, block: usize, epoch: usize) -> PushMsg {
        PushMsg {
            worker,
            block,
            w: vec![0.1; 4].into(),
            worker_epoch: epoch,
            z_version_used: 0,
            block_seq: 0,
            sent_at: None,
            recycle: None,
        }
    }

    /// Send `per_worker` pushes per worker (routed by the topology),
    /// run `n_servers` threads under `drain`, and return per-shard
    /// push counts.
    fn run_matrix(kind: TransportKind, drain: DrainKind, batch: usize) -> Vec<usize> {
        let (n_blocks, n_servers, workers, per_worker) = (6usize, 2usize, 3usize, 40usize);
        let (topo, store, problem) = setup(n_blocks, n_servers, workers);
        let transport =
            make_transport(kind, workers, n_servers, 8, batch);
        let rts: Vec<ShardRt> = (0..n_servers)
            .map(|sid| {
                let shard = ServerShard::new(sid, &topo, store.clone(), problem, 2.0, 0.1);
                ShardRt::new(shard, transport.as_ref())
            })
            .collect();
        std::thread::scope(|scope| {
            let mut producers = Vec::new();
            for w in 0..workers {
                let mut tx = transport.connect_worker(w);
                let topo = &topo;
                producers.push(scope.spawn(move || {
                    for i in 0..per_worker {
                        let j = topo.blocks_of_worker[w][i % topo.blocks_of_worker[w].len()];
                        tx.send(topo.server_of_block[j], push(w, j, i)).unwrap();
                    }
                    tx.flush().unwrap();
                }));
            }
            let rts_ref = &rts;
            let mut servers = Vec::new();
            for sid in 0..n_servers {
                servers.push(scope.spawn(move || {
                    run_server(rts_ref, sid, drain, &ProxBackend::Native).unwrap();
                }));
            }
            for p in producers {
                p.join().unwrap();
            }
            transport.shutdown();
            for s in servers {
                s.join().unwrap();
            }
        });
        rts.iter().map(|rt| rt.shard.stats().pushes).collect()
    }

    #[test]
    fn owned_and_steal_drain_everything_under_both_transports() {
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            for drain in [DrainKind::Owned, DrainKind::Steal] {
                for batch in [1usize, 4] {
                    let per_shard = run_matrix(kind, drain, batch);
                    let total: usize = per_shard.iter().sum();
                    // 3 workers x 40 pushes, none lost, none duplicated.
                    assert_eq!(
                        total, 120,
                        "{kind:?}/{drain:?}/batch={batch}: {per_shard:?}"
                    );
                    // Per-shard counts are placement-determined (every
                    // push for a block lands on its owning shard, no
                    // matter which thread drained it).
                    assert!(
                        per_shard.iter().all(|&c| c > 0),
                        "{kind:?}/{drain:?}/batch={batch}: a shard applied nothing: {per_shard:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn elastic_pool_drains_everything_with_any_thread_count() {
        // server_threads decoupled from shard count: 1 thread for 2
        // shards (scarcity) and 3 threads for 2 shards (oversubscribed
        // shards borrow CPU) must both drain every lane.
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            for n_threads in [1usize, 3] {
                let (n_blocks, n_servers, workers, per_worker) = (6usize, 2usize, 3usize, 40usize);
                let (topo, store, problem) = setup(n_blocks, n_servers, workers);
                let transport = make_transport(kind, workers, n_servers, 8, 1);
                let rts: Vec<ShardRt> = (0..n_servers)
                    .map(|sid| {
                        let shard =
                            ServerShard::new(sid, &topo, store.clone(), problem, 2.0, 0.1);
                        ShardRt::new(shard, transport.as_ref())
                    })
                    .collect();
                std::thread::scope(|scope| {
                    let mut producers = Vec::new();
                    for w in 0..workers {
                        let mut tx = transport.connect_worker(w);
                        let topo = &topo;
                        producers.push(scope.spawn(move || {
                            for i in 0..per_worker {
                                let j = topo.blocks_of_worker[w]
                                    [i % topo.blocks_of_worker[w].len()];
                                tx.send(topo.server_of_block[j], push(w, j, i)).unwrap();
                            }
                            tx.flush().unwrap();
                        }));
                    }
                    let rts_ref = &rts;
                    let mut pool = Vec::new();
                    for tid in 0..n_threads {
                        pool.push(scope.spawn(move || {
                            run_pool(rts_ref, tid, &ProxBackend::Native).unwrap();
                        }));
                    }
                    for p in producers {
                        p.join().unwrap();
                    }
                    transport.shutdown();
                    for t in pool {
                        t.join().unwrap();
                    }
                });
                let per_shard: Vec<usize> =
                    rts.iter().map(|rt| rt.shard.stats().pushes).collect();
                let total: usize = per_shard.iter().sum();
                assert_eq!(
                    total,
                    workers * per_worker,
                    "{kind:?}/threads={n_threads}: {per_shard:?}"
                );
                assert!(
                    per_shard.iter().all(|&c| c > 0),
                    "{kind:?}/threads={n_threads}: a shard applied nothing: {per_shard:?}"
                );
            }
        }
    }

    #[test]
    fn steal_services_a_dead_owners_backlog() {
        // Traffic is queued for BOTH shards but only shard 1's thread
        // ever runs: under `steal` it must drain shard 0's backlog too
        // (whole lanes, never splitting a per-worker stream) — any
        // shard-0 push applied proves the writer role was stolen.
        let (n_servers, workers, per_worker) = (2usize, 2usize, 30usize);
        let spec = SynthSpec {
            samples: 16,
            geometry: BlockGeometry::new(4, 4),
            nnz_per_row: 3,
            blocks_per_worker: 4,
            shared_blocks: 4,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, workers);
        let topo = Topology::build(&shards, 4, n_servers);
        let store = Arc::new(BlockStore::new(4, 4));
        let problem = Problem::new(LossKind::Logistic, 0.0, 1e4);
        // Producers pre-fill the rings before any consumer runs: size
        // the per-lane capacity to hold the whole backlog (inflight is
        // split across workers' rings).
        let transport =
            make_transport(TransportKind::SpscRing, workers, n_servers, workers * per_worker, 1);
        let rts: Vec<ShardRt> = (0..n_servers)
            .map(|sid| {
                let shard = ServerShard::new(sid, &topo, store.clone(), problem, 2.0, 0.1);
                ShardRt::new(shard, transport.as_ref())
            })
            .collect();
        // Only thread 1 runs; it owns shard 1 (whose lanes go Done
        // immediately after shutdown) and must steal shard 0's backlog.
        std::thread::scope(|scope| {
            let mut producers = Vec::new();
            for w in 0..workers {
                let mut tx = transport.connect_worker(w);
                let topo = &topo;
                producers.push(scope.spawn(move || {
                    for i in 0..per_worker {
                        let j = i % 4;
                        tx.send(topo.server_of_block[j], push(w, j, i)).unwrap();
                    }
                }));
            }
            for p in producers {
                p.join().unwrap();
            }
            transport.shutdown();
            let rts_ref = &rts;
            scope
                .spawn(move || run_server(rts_ref, 1, DrainKind::Steal, &ProxBackend::Native).unwrap())
                .join()
                .unwrap();
        });
        let shard0_pushes = rts[0].shard.stats().pushes;
        let shard1_pushes = rts[1].shard.stats().pushes;
        assert_eq!(
            shard0_pushes + shard1_pushes,
            workers * per_worker,
            "stolen drain lost messages"
        );
        assert!(shard0_pushes > 0, "thief never drained the victim shard");
    }

    #[test]
    fn close_lanes_unblocks_a_sender_to_a_dead_shard() {
        // The dead-server teardown path: receivers live in ShardRt (not
        // in the server thread), so when a shard's thread dies without
        // draining, the monitor force-closes its lanes — and a worker
        // blocked in send() on the full queue must fail loudly instead
        // of hanging the join forever.
        let (topo, store, problem) = setup(4, 1, 1);
        let transport = make_transport(TransportKind::Mpsc, 1, 1, 2, 1); // tiny queue
        let rts: Vec<ShardRt> = vec![ShardRt::new(
            ServerShard::new(0, &topo, store, problem, 2.0, 0.1),
            transport.as_ref(),
        )];
        std::thread::scope(|scope| {
            let mut tx = transport.connect_worker(0);
            let topo = &topo;
            let h = scope.spawn(move || {
                // Nobody drains shard 0: fill the queue, block, and
                // count how many sends completed before the error.
                let mut sent = 0usize;
                loop {
                    let j = topo.blocks_of_worker[0][sent % 4];
                    if tx.send(0, push(0, j, sent)).is_err() {
                        return sent;
                    }
                    sent += 1;
                }
            });
            std::thread::sleep(Duration::from_millis(50)); // let it block
            rts[0].close_lanes();
            let sent = h.join().unwrap();
            assert!(sent >= 2, "sender errored before filling the queue: {sent}");
        });
        // The closed lane reads as terminal to any drain loop.
        run_server(&rts, 0, DrainKind::Owned, &ProxBackend::Native).unwrap();
        assert_eq!(rts[0].shard.stats().pushes, 0);
    }

    #[test]
    fn owned_thread_exits_without_touching_other_shards() {
        // Under `owned`, a thread returns once ITS lanes are done even
        // if another shard still has queued messages.
        let (topo, store, problem) = setup(4, 2, 2);
        let transport = make_transport(TransportKind::SpscRing, 2, 2, 8, 1);
        let rts: Vec<ShardRt> = (0..2)
            .map(|sid| {
                let shard = ServerShard::new(sid, &topo, store.clone(), problem, 2.0, 0.1);
                ShardRt::new(shard, transport.as_ref())
            })
            .collect();
        let mut tx = transport.connect_worker(0);
        // Queue traffic only for shard 1's blocks.
        let j = topo.blocks_of_server[1][0];
        tx.send(1, push(0, j, 0)).unwrap();
        tx.flush().unwrap();
        drop(tx);
        drop(transport.connect_worker(1));
        transport.shutdown();
        run_server(&rts, 0, DrainKind::Owned, &ProxBackend::Native).unwrap();
        assert_eq!(rts[0].shard.stats().pushes, 0);
        // Shard 1's message is still queued, untouched by thread 0.
        run_server(&rts, 1, DrainKind::Owned, &ProxBackend::Native).unwrap();
        assert_eq!(rts[1].shard.stats().pushes, 1);
    }
}

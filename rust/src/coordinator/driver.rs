//! Launcher/driver: wires store, topology, server shards, workers, and a
//! monitor thread into one training run and returns a [`TrainReport`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::block_store::BlockStore;
use super::compute::make_compute;
use super::delay::DelayPolicy;
use super::events::ObjSample;
use super::messages::ServerMsg;
use super::server::{ProxBackend, ServerShard, ServerStats};
use super::topology::Topology;
use super::worker::{WorkerCtx, WorkerStats};
use crate::admm::{
    check_theorem1, consensus_gap, objective_at_z, stationarity_residual, Objective,
};
use crate::config::{Backend, Config};
use crate::data::{Dataset, WorkerShard};
use crate::info;
use crate::problem::Problem;
use crate::runtime::{Manifest, ServerProxXla};

#[derive(Debug)]
pub struct TrainReport {
    pub samples: Vec<ObjSample>,
    pub final_objective: Objective,
    pub z_final: Vec<f32>,
    pub elapsed_s: f64,
    pub epochs: usize,
    pub worker_stats: Vec<WorkerStats>,
    pub server_stats: Vec<ServerStats>,
    /// Paper Eq. 14 residual at the final iterate.
    pub stationarity: f64,
    pub consensus_max: f64,
    /// Strict Theorem-1 feasibility of the hyper-parameters used.
    pub theorem1_feasible: bool,
}

impl TrainReport {
    pub fn total_pushes(&self) -> usize {
        self.server_stats.iter().map(|s| s.pushes).sum()
    }

    pub fn max_staleness(&self) -> u64 {
        self.worker_stats
            .iter()
            .map(|w| w.max_staleness)
            .chain(self.server_stats.iter().map(|s| s.max_staleness))
            .max()
            .unwrap_or(0)
    }
}

/// Capacity of each server shard's bounded push channel for `n_workers`
/// workers.  Public so tests can assert the push-buffer pools' high-water
/// marks against the actual in-flight bound.
pub fn push_inflight(n_workers: usize) -> usize {
    (2 * n_workers).max(8)
}

/// Run block-wise asynchronous ADMM (Algorithm 1) with the threaded
/// parameter-server runtime.
pub fn run_async(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> Result<TrainReport> {
    cfg.validate()?;
    anyhow::ensure!(shards.len() == cfg.n_workers, "shards/workers mismatch");
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    // Reported objective: paper Eq. 22's global mean (weight 1/m);
    // each worker's f_i is its LOCAL mean (weight 1/m_i), which keeps
    // per-iteration progress p-independent (DESIGN.md "objective
    // scaling").
    let weight = 1.0 / ds.samples() as f32;
    let topo = Topology::build(shards, cfg.n_blocks, cfg.n_servers);
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    let policy = DelayPolicy { net_mean_ms: cfg.net_delay_mean_ms, pull_hold: cfg.pull_hold.max(1) };

    // Theorem-1 feasibility report (logged; the paper itself runs with
    // infeasible-but-working γ=0.01, as do the defaults here).
    let shard_refs: Vec<&WorkerShard> = shards.iter().collect();
    let t1 = check_theorem1(
        &shard_refs,
        &problem,
        cfg.n_blocks,
        cfg.rho as f64,
        cfg.gamma as f64,
        cfg.max_delay,
    );
    info!(
        "driver",
        "theorem1: min_alpha={:.3e} min_beta={:.3e} feasible={} (strict bound; paper runs gamma=0.01 anyway)",
        t1.min_alpha,
        t1.min_beta,
        t1.feasible
    );

    let manifest = match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    };

    // Bounded channels provide backpressure (ps-lite style bounded
    // in-flight pushes): without it a fast worker can run all its epochs
    // against a starved server queue, i.e. unbounded effective delay,
    // violating Assumption 3 and stalling convergence.
    let inflight = push_inflight(cfg.n_workers);
    // The push-buffer pool never needs more buffers than can be in
    // flight at once: the channel depth, one in service, one in the
    // worker's hands, plus slack for recycle-channel latency.
    let pool_cap = inflight + 4;
    let mut server_txs = Vec::new();
    let mut server_rxs = Vec::new();
    for _ in 0..cfg.n_servers {
        let (tx, rx) = mpsc::sync_channel::<ServerMsg>(inflight);
        server_txs.push(tx);
        server_rxs.push(rx);
    }
    let progress: Vec<AtomicUsize> = (0..cfg.n_workers).map(|_| AtomicUsize::new(0)).collect();
    let worker_results: Mutex<Vec<Option<(WorkerStats, Vec<f32>, Vec<f32>)>>> =
        Mutex::new((0..cfg.n_workers).map(|_| None).collect());
    let server_results: Mutex<Vec<Option<ServerStats>>> =
        Mutex::new((0..cfg.n_servers).map(|_| None).collect());

    let start = Instant::now();
    let mut samples: Vec<ObjSample> = Vec::new();

    std::thread::scope(|scope| -> Result<()> {
        // -- server shards -------------------------------------------------
        for (sid, rx) in server_rxs.drain(..).enumerate() {
            let topo = &topo;
            let store = store.clone();
            let manifest = manifest.as_ref();
            let server_results = &server_results;
            scope.spawn(move || {
                let prox = match manifest {
                    None => ProxBackend::Native,
                    Some(m) => match ServerProxXla::load(m, cfg.block_size) {
                        Ok(p) => ProxBackend::Xla(p),
                        Err(e) => {
                            eprintln!("server {sid}: XLA prox unavailable ({e:#}); native fallback");
                            ProxBackend::Native
                        }
                    },
                };
                let shard = ServerShard::new(sid, topo, store, problem, cfg.rho, cfg.gamma);
                let stats = shard.run(rx, prox).expect("server loop failed");
                server_results.lock().unwrap()[sid] = Some(stats);
            });
        }

        // -- workers ---------------------------------------------------------
        for shard in shards {
            let wid = shard.worker_id;
            let topo = &topo;
            let store = &store;
            let txs = &server_txs;
            let progress = &progress[wid];
            let manifest = manifest.as_ref();
            let worker_results = &worker_results;
            let seed = cfg.seed ^ (0x9E37 + wid as u64 * 0x1000_0000_01B3);
            let local_weight = 1.0 / shard.samples().max(1) as f32;
            scope.spawn(move || {
                let mut compute = make_compute(
                    cfg.backend,
                    shard,
                    problem,
                    local_weight,
                    manifest,
                    cfg.m_chunk,
                    cfg.d_pad,
                )
                .expect("construct worker compute backend");
                let mut ctx = WorkerCtx::new(
                    shard,
                    topo,
                    store,
                    txs,
                    policy,
                    cfg.selection,
                    cfg.rho,
                    cfg.epochs,
                    cfg.max_delay,
                    cfg.enforce_delay_bound,
                    seed,
                    progress,
                    pool_cap,
                );
                let stats = ctx.run(compute.as_mut()).expect("worker loop failed");
                let (x, y) = ctx.into_state();
                worker_results.lock().unwrap()[wid] = Some((stats, x, y));
            });
        }

        // -- monitor (this thread) --------------------------------------------
        let log_every = cfg.log_every.max(1);
        let mut next_epoch = 0usize;
        loop {
            let min_epoch =
                progress.iter().map(|p| p.load(Ordering::Acquire)).min().unwrap_or(0);
            if min_epoch >= next_epoch {
                let z = store.snapshot();
                let obj = objective_at_z(shards, &problem, weight, &z);
                samples.push(ObjSample {
                    time_s: start.elapsed().as_secs_f64(),
                    epoch: min_epoch,
                    objective: obj.total(),
                    data_loss: obj.data_loss,
                    consensus_max: 0.0,
                });
                next_epoch = next_epoch.max(min_epoch) + log_every;
            }
            if min_epoch >= cfg.epochs {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        // workers are done (or finishing); ask servers to drain & exit.
        // The scope joins everything on exit.
        for tx in &server_txs {
            let _ = tx.send(ServerMsg::Shutdown);
        }
        Ok(())
    })?;
    let elapsed_s = start.elapsed().as_secs_f64();

    // -- final metrics ---------------------------------------------------
    let z_final = store.snapshot();
    let final_objective = objective_at_z(shards, &problem, weight, &z_final);
    let collected = worker_results.into_inner().unwrap();
    let mut worker_stats = Vec::with_capacity(cfg.n_workers);
    let mut xs = Vec::with_capacity(cfg.n_workers);
    let mut ys = Vec::with_capacity(cfg.n_workers);
    for r in collected {
        let (stats, x, y) = r.context("worker did not report")?;
        worker_stats.push(stats);
        xs.push(x);
        ys.push(y);
    }
    let server_stats: Vec<ServerStats> = server_results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.unwrap_or_default())
        .collect();
    let stationarity = stationarity_residual(shards, &problem, cfg.rho, &xs, &ys, &z_final);
    let (consensus_max, _) = consensus_gap(shards, &xs, &z_final);

    // Ensure the last sample reflects the final state.
    samples.push(ObjSample {
        time_s: elapsed_s,
        epoch: cfg.epochs,
        objective: final_objective.total(),
        data_loss: final_objective.data_loss,
        consensus_max,
    });

    Ok(TrainReport {
        samples,
        final_objective,
        z_final,
        elapsed_s,
        epochs: cfg.epochs,
        worker_stats,
        server_stats,
        stationarity,
        consensus_max,
        theorem1_feasible: t1.feasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_partitioned;

    #[test]
    fn async_native_training_decreases_objective() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 240; // one random block per epoch => ~60 full passes
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = run_async(&cfg, &ds, &shards).unwrap();

        let first = report.samples.first().unwrap().objective;
        let last = report.final_objective.total();
        assert!(
            last < first * 0.9,
            "objective should drop: {first} -> {last}"
        );
        assert!(report.total_pushes() >= cfg.epochs * cfg.n_workers);
        assert!(report.consensus_max.is_finite());
        assert_eq!(report.worker_stats.len(), cfg.n_workers);
    }

    #[test]
    fn push_pool_high_water_bounded_by_channel_capacity_not_epochs() {
        // The no-allocation-per-epoch invariant: buffers allocated on the
        // push path are bounded by the in-flight channel capacity, not by
        // the number of epochs run.
        let mut cfg = Config::tiny_test();
        cfg.epochs = 400;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = run_async(&cfg, &ds, &shards).unwrap();
        let bound = push_inflight(cfg.n_workers) + 4;
        for w in &report.worker_stats {
            assert!(w.pool_high_water >= 1, "pool never used");
            assert!(
                w.pool_high_water <= bound,
                "pool allocated {} buffers (bound {bound}, epochs {})",
                w.pool_high_water,
                cfg.epochs
            );
            assert!(w.pool_high_water < cfg.epochs / 8, "allocation scaled with epochs");
        }
    }

    #[test]
    fn delay_enforcement_caps_staleness() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 40;
        cfg.max_delay = 2;
        cfg.enforce_delay_bound = true;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = run_async(&cfg, &ds, &shards).unwrap();
        for w in &report.worker_stats {
            assert!(
                w.max_staleness <= 2 + 1, // one concurrent write can land mid-step
                "staleness {} exceeds bound",
                w.max_staleness
            );
        }
    }
}

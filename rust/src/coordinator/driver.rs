//! Deprecated launcher shim.
//!
//! The 270-line monolith that used to live here — channel wiring,
//! thread spawning, the busy-wait monitor loop, stats collection — was
//! decomposed into [`super::session`] (the `Session` builder +
//! `Observer` hooks) and [`super::transport`] (the pluggable push
//! queueing).  `run_async` survives for one PR as a thin shim so
//! out-of-tree callers get a deprecation pointer instead of a break.

use anyhow::Result;

use super::session::{Session, TrainReport};
use crate::config::Config;
use crate::data::{Dataset, WorkerShard};

/// Run block-wise asynchronous ADMM (Algorithm 1) with the threaded
/// parameter-server runtime.
#[deprecated(
    note = "use Session::builder(&cfg).dataset(&ds, &shards).run() — \
            it also selects transports, observers and baseline algos"
)]
pub fn run_async(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> Result<TrainReport> {
    Session::builder(cfg).dataset(ds, shards).run()
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]
    use super::*;
    use crate::data::gen_partitioned;

    #[test]
    fn deprecated_shim_still_trains() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 120;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = run_async(&cfg, &ds, &shards).unwrap();
        let first = report.samples.first().unwrap().objective;
        assert!(report.final_objective.total() < first);
        assert_eq!(report.worker_stats.len(), cfg.n_workers);
    }
}

//! Hand-rolled HTTP/1.1 stats endpoint (`--set stats_addr=HOST:PORT`).
//!
//! One thread, one non-blocking listener, zero dependencies: enough
//! HTTP to let `curl`/a browser/a test's bare `TcpStream` watch a run.
//!
//! * `GET /stats`   → `200 application/json` — a live snapshot built by
//!   the closure the runtime registers (per-shard load, applied-push
//!   counters, placement map, migration ledger, fault events, and the
//!   nested `"wire"`/`"pull"` data-plane counter objects the serve role
//!   publishes — see DESIGN.md §2.0.6).
//! * `GET /healthz` → `200 application/json` when the runtime registers
//!   a liveness closure (serve mode: per-rank heartbeat ages,
//!   connection state, evicted flags, `"degraded"` overall status —
//!   DESIGN.md §2.0.7); `200 text/plain` `ok` otherwise.
//! * `POST /config` → hot-reload: the body is `key=value` lines; the
//!   registered apply closure validates against the reloadable
//!   whitelist and applies atomically.  `200` with the applied set, or
//!   `400` with the validation error (which lists the reloadable
//!   keys).  `404` when no apply closure is registered.
//! * anything else  → `404` (unknown path) or `405` (bad method).
//!
//! Requests are served sequentially — this is an observability tap for
//! a handful of human/test clients, not a web server.  Each connection
//! is read with a short timeout and closed after one response
//! (`Connection: close`), so a stuck client cannot wedge the thread for
//! long and teardown is prompt.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Builds the `/stats` JSON on demand; registered by the runtime that
/// owns the counters.
pub type StatsFn = Arc<dyn Fn() -> Json + Send + Sync>;

/// Builds the `/healthz` JSON on demand (serve mode: per-rank liveness
/// detail).  Without one the endpoint answers plain `ok`.
pub type HealthFn = Arc<dyn Fn() -> Json + Send + Sync>;

/// Applies a `POST /config` body (`key=value` lines).  Returns the
/// human-readable confirmation for a `200`, or an error (surfaced as a
/// `400` whose body lists the reloadable keys).
pub type ConfigFn = Arc<dyn Fn(&str) -> Result<String> + Send + Sync>;

/// The closures one endpoint serves; only `stats` is mandatory.
#[derive(Clone)]
struct Hooks {
    stats: StatsFn,
    health: Option<HealthFn>,
    config: Option<ConfigFn>,
}

/// A running stats endpoint; dropping it (or calling [`StatsServer::stop`])
/// shuts the thread down.
pub struct StatsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatsServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, or `:0` for an ephemeral
    /// port) and serve `stats` until stopped.
    pub fn spawn(addr: &str, stats: StatsFn) -> Result<StatsServer> {
        Self::spawn_with(addr, stats, None, None)
    }

    /// [`StatsServer::spawn`] plus the optional serve-mode closures: a
    /// `/healthz` liveness-detail builder and a `POST /config`
    /// hot-reload handler.
    pub fn spawn_with(
        addr: &str,
        stats: StatsFn,
        health: Option<HealthFn>,
        config: Option<ConfigFn>,
    ) -> Result<StatsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("stats_addr {addr:?} (expected host:port)"))?;
        let local = listener.local_addr().context("stats listener local_addr")?;
        listener.set_nonblocking(true).context("nonblocking stats listener")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let hooks = Hooks { stats, health, config };
        let thread = std::thread::Builder::new()
            .name("stats-http".into())
            .spawn(move || serve_loop(listener, hooks, stop2))
            .context("spawn stats thread")?;
        Ok(StatsServer { addr: local, stop, thread: Some(thread) })
    }

    /// The bound address (resolves a `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop serving and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(listener: TcpListener, hooks: Hooks, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((conn, _)) => {
                let _ = serve_one(conn, &hooks);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {}
        }
    }
}

/// `Content-Length` from a raw header block (case-insensitive key).
fn content_length(head: &str) -> usize {
    head.lines()
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Read one request (head + body for POST), write one response, close.
fn serve_one(mut conn: TcpStream, hooks: &Hooks) -> Result<()> {
    conn.set_read_timeout(Some(Duration::from_millis(500))).ok();
    conn.set_nodelay(true).ok();
    let mut raw = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // Read until the blank line ends the header block (the only header
    // that matters is Content-Length — method + path decide the rest).
    while !raw.windows(4).any(|w| w == b"\r\n\r\n") && raw.len() < 8192 {
        match conn.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => raw.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break, // timeout or reset: respond to what we have
        }
    }
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(raw.len());
    let head = String::from_utf8_lossy(&raw[..head_end]).into_owned();
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    // Body: whatever followed the blank line, topped up to
    // Content-Length (bounded — config bodies are a few lines).
    let want = content_length(&head).min(64 * 1024);
    let mut body_bytes = raw[head_end..].to_vec();
    while body_bytes.len() < want {
        match conn.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => body_bytes.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    let (status, content_type, body): (&str, &str, String) = match (method.as_str(), path.as_str())
    {
        ("GET", "/healthz") => match &hooks.health {
            Some(h) => ("200 OK", "application/json", {
                let mut s = h().to_string_pretty();
                s.push('\n');
                s
            }),
            None => ("200 OK", "text/plain", "ok\n".into()),
        },
        ("GET", "/stats") => ("200 OK", "application/json", {
            let mut s = (hooks.stats)().to_string_pretty();
            s.push('\n');
            s
        }),
        ("POST", "/config") => match &hooks.config {
            Some(apply) => {
                let text = String::from_utf8_lossy(&body_bytes);
                match apply(&text) {
                    Ok(msg) => ("200 OK", "text/plain", format!("{msg}\n")),
                    Err(e) => ("400 Bad Request", "text/plain", format!("{e:#}\n")),
                }
            }
            None => ("404 Not Found", "text/plain", "config reload not enabled\n".into()),
        },
        ("GET", _) => {
            ("404 Not Found", "text/plain", "unknown path (try /stats or /healthz)\n".into())
        }
        _ => ("405 Method Not Allowed", "text/plain", "GET (or POST /config) only\n".into()),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(response.as_bytes()).context("write response")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    /// Bare-TcpStream client: the same curl-free probe the netproc CI
    /// job uses.
    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.lines().next().unwrap_or("").to_string(), body.to_string())
    }

    #[test]
    fn serves_stats_healthz_and_errors() {
        let server = StatsServer::spawn(
            "127.0.0.1:0",
            Arc::new(|| obj(vec![("pushes_total", num(42.0))])),
        )
        .unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/healthz");
        assert!(status.contains("200"), "healthz: {status}");
        assert_eq!(body, "ok\n");

        let (status, body) = get(addr, "/stats");
        assert!(status.contains("200"), "stats: {status}");
        let parsed = Json::parse(&body).expect("stats body is JSON");
        assert_eq!(parsed.get("pushes_total"), Some(&Json::Num(42.0)));

        let (status, _) = get(addr, "/nope");
        assert!(status.contains("404"), "unknown path: {status}");
    }

    /// The serve role nests its data-plane counters under `"wire"` and
    /// `"pull"`; the endpoint must ship nested objects intact (a flat
    /// serializer would silently drop them from dashboards).
    #[test]
    fn serves_nested_counter_objects_intact() {
        let server = StatsServer::spawn(
            "127.0.0.1:0",
            Arc::new(|| {
                obj(vec![
                    ("pushes_total", num(3.0)),
                    ("wire", obj(vec![("push_frames_in", num(17.0)), ("credits_out", num(34.0))])),
                    ("pull", obj(vec![("sparse_blocks", num(5.0))])),
                ])
            }),
        )
        .unwrap();

        let (status, body) = get(server.addr(), "/stats");
        assert!(status.contains("200"), "stats: {status}");
        let parsed = Json::parse(&body).expect("stats body is JSON");
        let wire = parsed.get("wire").expect("nested wire object");
        assert_eq!(wire.get("push_frames_in"), Some(&Json::Num(17.0)));
        assert_eq!(wire.get("credits_out"), Some(&Json::Num(34.0)));
        let pull = parsed.get("pull").expect("nested pull object");
        assert_eq!(pull.get("sparse_blocks"), Some(&Json::Num(5.0)));
    }

    #[test]
    fn malformed_stats_addr_error_names_the_expected_form() {
        let err = StatsServer::spawn("not-an-addr", Arc::new(|| Json::Null)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("host:port"), "error should show the form: {msg}");
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> (String, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.lines().next().unwrap_or("").to_string(), body.to_string())
    }

    /// The serve role registers a liveness closure and a config-apply
    /// closure; `/healthz` then answers JSON and `POST /config` routes
    /// the body through the apply hook (200 on success, 400 with the
    /// hook's error otherwise).
    #[test]
    fn healthz_detail_and_config_reload_round_trip() {
        use std::sync::Mutex;
        let applied: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let applied2 = applied.clone();
        let server = StatsServer::spawn_with(
            "127.0.0.1:0",
            Arc::new(|| obj(vec![("pushes_total", num(0.0))])),
            Some(Arc::new(|| {
                obj(vec![("status", Json::Str("degraded".into())), ("evicted", num(1.0))])
            })),
            Some(Arc::new(move |body: &str| {
                if body.contains("bogus") {
                    anyhow::bail!("config key \"bogus\" is not hot-reloadable");
                }
                applied2.lock().unwrap().push(body.to_string());
                Ok(format!("applied {} line(s)", body.lines().count()))
            })),
        )
        .unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/healthz");
        assert!(status.contains("200"), "healthz: {status}");
        let parsed = Json::parse(&body).expect("healthz body is JSON");
        assert_eq!(parsed.get("status"), Some(&Json::Str("degraded".into())));
        assert_eq!(parsed.get("evicted"), Some(&Json::Num(1.0)));

        let (status, body) = post(addr, "/config", "rebalance_ms=50\nstall_warn_ms=100\n");
        assert!(status.contains("200"), "config apply: {status} {body}");
        assert!(body.contains("applied 2"), "confirmation: {body}");
        assert_eq!(applied.lock().unwrap().len(), 1, "hook ran once");

        let (status, body) = post(addr, "/config", "bogus=1\n");
        assert!(status.contains("400"), "bad key must 400: {status}");
        assert!(body.contains("not hot-reloadable"), "names the failure: {body}");

        let (status, _) = post(addr, "/stats", "");
        assert!(status.contains("405"), "POST on a GET path: {status}");
    }

    /// Without an apply hook, POST /config is a 404 (feature off), and
    /// bare spawn keeps the plain-text healthz contract.
    #[test]
    fn config_endpoint_is_404_without_a_hook() {
        let server = StatsServer::spawn("127.0.0.1:0", Arc::new(|| Json::Null)).unwrap();
        let (status, body) = post(server.addr(), "/config", "rebalance_ms=50\n");
        assert!(status.contains("404"), "no hook: {status}");
        assert!(body.contains("not enabled"), "says why: {body}");
        let (status, body) = get(server.addr(), "/healthz");
        assert!(status.contains("200"), "healthz: {status}");
        assert_eq!(body, "ok\n");
    }
}

//! Hand-rolled HTTP/1.1 stats endpoint (`--set stats_addr=HOST:PORT`).
//!
//! One thread, one non-blocking listener, zero dependencies: enough
//! HTTP to let `curl`/a browser/a test's bare `TcpStream` watch a run.
//!
//! * `GET /stats`   → `200 application/json` — a live snapshot built by
//!   the closure the runtime registers (per-shard load, applied-push
//!   counters, placement map, migration ledger, fault events, and the
//!   nested `"wire"`/`"pull"` data-plane counter objects the serve role
//!   publishes — see DESIGN.md §2.0.6).
//! * `GET /healthz` → `200 text/plain` `ok` — liveness only.
//! * anything else  → `404` (unknown path) or `405` (non-GET).
//!
//! Requests are served sequentially — this is an observability tap for
//! a handful of human/test clients, not a web server.  Each connection
//! is read with a short timeout and closed after one response
//! (`Connection: close`), so a stuck client cannot wedge the thread for
//! long and teardown is prompt.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Builds the `/stats` JSON on demand; registered by the runtime that
/// owns the counters.
pub type StatsFn = Arc<dyn Fn() -> Json + Send + Sync>;

/// A running stats endpoint; dropping it (or calling [`StatsServer::stop`])
/// shuts the thread down.
pub struct StatsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatsServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, or `:0` for an ephemeral
    /// port) and serve `stats` until stopped.
    pub fn spawn(addr: &str, stats: StatsFn) -> Result<StatsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("stats_addr {addr:?} (expected host:port)"))?;
        let local = listener.local_addr().context("stats listener local_addr")?;
        listener.set_nonblocking(true).context("nonblocking stats listener")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("stats-http".into())
            .spawn(move || serve_loop(listener, stats, stop2))
            .context("spawn stats thread")?;
        Ok(StatsServer { addr: local, stop, thread: Some(thread) })
    }

    /// The bound address (resolves a `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop serving and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(listener: TcpListener, stats: StatsFn, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((conn, _)) => {
                let _ = serve_one(conn, &stats);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {}
        }
    }
}

/// Read one request head, write one response, close.
fn serve_one(mut conn: TcpStream, stats: &StatsFn) -> Result<()> {
    conn.set_read_timeout(Some(Duration::from_millis(500))).ok();
    conn.set_nodelay(true).ok();
    let mut head = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // Read until the blank line ends the header block (we ignore the
    // headers themselves — method + path decide everything).
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match conn.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break, // timeout or reset: respond to what we have
        }
    }
    let request = String::from_utf8_lossy(&head);
    let mut parts = request.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body): (&str, &str, String) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "GET only\n".into())
    } else {
        match path {
            "/healthz" => ("200 OK", "text/plain", "ok\n".into()),
            "/stats" => ("200 OK", "application/json", {
                let mut s = stats().to_string_pretty();
                s.push('\n');
                s
            }),
            _ => ("404 Not Found", "text/plain", "unknown path (try /stats or /healthz)\n".into()),
        }
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(response.as_bytes()).context("write response")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    /// Bare-TcpStream client: the same curl-free probe the netproc CI
    /// job uses.
    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.lines().next().unwrap_or("").to_string(), body.to_string())
    }

    #[test]
    fn serves_stats_healthz_and_errors() {
        let server = StatsServer::spawn(
            "127.0.0.1:0",
            Arc::new(|| obj(vec![("pushes_total", num(42.0))])),
        )
        .unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/healthz");
        assert!(status.contains("200"), "healthz: {status}");
        assert_eq!(body, "ok\n");

        let (status, body) = get(addr, "/stats");
        assert!(status.contains("200"), "stats: {status}");
        let parsed = Json::parse(&body).expect("stats body is JSON");
        assert_eq!(parsed.get("pushes_total"), Some(&Json::Num(42.0)));

        let (status, _) = get(addr, "/nope");
        assert!(status.contains("404"), "unknown path: {status}");
    }

    /// The serve role nests its data-plane counters under `"wire"` and
    /// `"pull"`; the endpoint must ship nested objects intact (a flat
    /// serializer would silently drop them from dashboards).
    #[test]
    fn serves_nested_counter_objects_intact() {
        let server = StatsServer::spawn(
            "127.0.0.1:0",
            Arc::new(|| {
                obj(vec![
                    ("pushes_total", num(3.0)),
                    ("wire", obj(vec![("push_frames_in", num(17.0)), ("credits_out", num(34.0))])),
                    ("pull", obj(vec![("sparse_blocks", num(5.0))])),
                ])
            }),
        )
        .unwrap();

        let (status, body) = get(server.addr(), "/stats");
        assert!(status.contains("200"), "stats: {status}");
        let parsed = Json::parse(&body).expect("stats body is JSON");
        let wire = parsed.get("wire").expect("nested wire object");
        assert_eq!(wire.get("push_frames_in"), Some(&Json::Num(17.0)));
        assert_eq!(wire.get("credits_out"), Some(&Json::Num(34.0)));
        let pull = parsed.get("pull").expect("nested pull object");
        assert_eq!(pull.get("sparse_blocks"), Some(&Json::Num(5.0)));
    }

    #[test]
    fn malformed_stats_addr_error_names_the_expected_form() {
        let err = StatsServer::spawn("not-an-addr", Arc::new(|| Json::Null)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("host:port"), "error should show the form: {msg}");
    }
}

//! Multi-process roles: `asybadmm serve` / `asybadmm work`.
//!
//! Splits the threaded runtime across OS processes with **zero new
//! dependencies**: the coordinator process owns the server shards, the
//! authoritative [`BlockStore`], the [`BlockTable`], the rebalancer and
//! the `/stats` control plane; each worker process owns a slice of the
//! worker ranks and talks to the coordinator over the
//! [`super::tcp::TcpTransport`] wire format.
//!
//! ## Protocol (all frames from `wire.rs`)
//!
//! 1. **Join**: a worker process dials the coordinator and sends
//!    `JoinCtl{rank, n_ranks}`.  The coordinator replies
//!    `Welcome{config kv text, n_blocks, owner map, map_version}` on the
//!    same stream; the worker rebuilds the [`Config`] from defaults +
//!    the shipped `key=value` lines, so both sides run byte-identical
//!    hyper-parameters and (for synthetic data) regenerate the same
//!    dataset from the same seed.
//! 2. **Push lanes**: each worker rank dials `n_servers` sockets via
//!    [`TcpPushSender::connect_remote`] — the exact credit-window
//!    backpressure documented in `tcp.rs`, identical to the in-process
//!    `transport=tcp` path.  Delivery acks return as coalesced
//!    `Credit{frames, hint}` frames; the hint is the coordinator's
//!    publish counter and feeds the pull cadence below.
//! 3. **Mirror sync**: one extra stream per worker process
//!    (`HelloPull`) runs a poll loop: `PullReq` ships the mirror's
//!    per-block versions, `PullResp` returns every block whose
//!    authoritative version is newer — dense, or as a sparse
//!    (index,value) delta against the worker's acked copy when that is
//!    cheaper (v2 encoding, `wire.rs`) — and the mirror adopts them
//!    with [`BlockStore::write_versioned`] — workers see coordinator
//!    version numbers, so staleness accounting matches the in-process
//!    run.  The poll cadence is adaptive ([`PullCadence`]): 500µs while
//!    responses carry data, exponential backoff to 8ms on an idle
//!    stream, snapped back to the floor by the Credit-borne publish
//!    hint.
//! 4. **Owner republish**: when `placement=dynamic` migrates a block,
//!    the coordinator writes `OwnerUpdate{block, owner, map_version}`
//!    frames down every rank's control stream; a reader thread applies
//!    them to the process-local [`BlockMap`] mirror.  Pushes routed to
//!    the old owner mid-flight still apply — every shard shares one
//!    [`BlockTable`], exactly like the in-process handoff.
//! 5. **Done**: a rank that finished its epochs sends
//!    `WorkerDone{rank, pushes, pull_rounds, pull_empty}`; once every
//!    rank reported, the coordinator shuts the transport down, drains,
//!    and prints the same `# done …` summary line as `asybadmm train`
//!    (extended with the aggregated pull round-trip accounting).
//!
//! ## Deliberate simplifications
//!
//! * Fault injection (`--set faults=…`) and `failure=degrade|restart`
//!   stay with the in-process runtime: a worker process clears the
//!   shipped fault plan (a remote crash is a process exit, reported as
//!   a hard error by the coordinator when the control stream drops).
//! * `--set data=FILE` requires the file to be readable by every
//!   process; the default synthetic dataset needs nothing shared.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::super::block_store::BlockStore;
use super::super::compute::make_compute;
use super::super::delay::DelayPolicy;
use super::super::fault::FaultPlan;
use super::super::placement::make_placement;
use super::super::rebalance::{BlockMap, Rebalancer};
use super::super::sched::{run_pool, run_server, ShardRt};
use super::super::server::{BlockTable, ProxBackend, ServerShard};
use super::super::session::MonitorGate;
use super::super::topology::Topology;
use super::super::transport::{push_inflight, PushSender, Transport};
use super::super::worker::WorkerCtx;
use super::http::StatsServer;
use super::tcp::{CtlConn, TcpPushSender, TcpTransport};
use super::wire::{self, kind};
use crate::admm::objective_at_z;
use crate::config::{Backend, Config, PlacementKind, TransportKind};
use crate::data::{gen_partitioned, load_libsvm, partition_even, Dataset, WorkerShard};
use crate::info;
use crate::problem::Problem;
use crate::runtime::{Manifest, ServerProxXla};
use crate::sparse::Kernels;
use crate::util::cli::{Args, Parsed};
use crate::util::json::{num, obj, Json};

/// Mirror-refresh poll floor (worker side).  Each round is one
/// request/response; 500µs keeps mirror staleness far below an epoch
/// while z̃ is churning.
const PULL_POLL_MIN: Duration = Duration::from_micros(500);

/// Idle poll ceiling: bounds how stale the mirror can go once z̃
/// quiesces (and how long a rank naps before noticing new versions if
/// the publish hint is somehow lost).
const PULL_POLL_MAX: Duration = Duration::from_millis(8);

/// Exponential idle backoff for the mirror poll loop: sleeps start at
/// [`PULL_POLL_MIN`], double after every empty round (a `PullResp`
/// carrying no blocks), cap at [`PULL_POLL_MAX`], and snap back to the
/// floor on any productive response or publish-hint advance.
struct PullCadence {
    cur: Duration,
}

impl PullCadence {
    fn new() -> Self {
        PullCadence { cur: PULL_POLL_MIN }
    }

    /// Sleep to take after a round; `productive` means the response
    /// carried at least one newer block.
    fn after_round(&mut self, productive: bool) -> Duration {
        if productive {
            self.cur = PULL_POLL_MIN;
            return self.cur;
        }
        let d = self.cur;
        self.cur = (self.cur * 2).min(PULL_POLL_MAX);
        d
    }

    /// The coordinator's publish hint advanced: poll at the floor again.
    fn reset(&mut self) {
        self.cur = PULL_POLL_MIN;
    }
}

/// Coordinator-side pull-plane counters, shared by every pull-serve
/// thread and the `/stats` closure.  `resp_bytes` vs
/// `dense_equiv_bytes` is the live form of the `delta_pull_bytes`
/// bench gate: encoded block bytes actually sent vs what the same
/// blocks would have cost fully dense.
#[derive(Default)]
struct PullServeStats {
    /// `PullReq` frames answered.
    rounds: AtomicU64,
    /// Rounds whose response carried no blocks (idle polls).
    empty: AtomicU64,
    /// Blocks shipped dense / as sparse deltas.
    dense_blocks: AtomicU64,
    sparse_blocks: AtomicU64,
    /// Encoded `PullResp` block bytes, and their all-dense equivalent.
    resp_bytes: AtomicU64,
    dense_equiv_bytes: AtomicU64,
}

/// How long `serve` waits between join events before giving up on the
/// barrier (a worker process that died pre-join must not wedge the
/// coordinator forever).
const JOIN_TIMEOUT: Duration = Duration::from_secs(60);

/// Per-lane in-flight cap for the multi-process transport: the global
/// budget [`push_inflight`] split per worker, floored so a lane can
/// always hold a frame plus a partial batch.  Serve and work compute
/// this independently from the same config — the two sides' credit
/// windows must agree.
fn lane_cap(cfg: &Config) -> usize {
    push_inflight(cfg.n_workers).div_ceil(cfg.n_workers.max(1)).max(2)
}

/// Generate or load the dataset + shards for a config (the `main.rs`
/// helper, duplicated here because the binary crate's items are not
/// visible to the library).  Deterministic for synthetic specs: every
/// process regenerates identical shards from the config seed.
fn load_data(cfg: &Config) -> Result<(Dataset, Vec<WorkerShard>)> {
    match &cfg.data_path {
        Some(path) => {
            let ds = load_libsvm(path, cfg.loss, cfg.block_size)?;
            let shards = partition_even(&ds, cfg.n_workers);
            Ok((ds, shards))
        }
        None => Ok(gen_partitioned(&cfg.synth_spec(), cfg.n_workers)),
    }
}

fn build_config(p: &Parsed) -> Result<Config> {
    let mut cfg = Config::default();
    let file = p.get("config");
    if !file.is_empty() {
        cfg.apply_file(std::path::Path::new(file))?;
    }
    for kv in p.get("set").split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got {kv:?}"))?;
        cfg.apply_kv(k, v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

// ---------------------------------------------------------------------
// Handshake payloads
// ---------------------------------------------------------------------

/// The `Welcome` config body: non-default keys as `key=value` lines.
fn config_kv_text(cfg: &Config) -> String {
    cfg.to_kv().iter().map(|(k, v)| format!("{k}={v}\n")).collect()
}

fn encode_welcome(cfg: &Config, owners: &[usize], map_version: u64) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_str(&mut p, &config_kv_text(cfg));
    wire::put_u32(&mut p, owners.len() as u32);
    for &s in owners {
        wire::put_u32(&mut p, s as u32);
    }
    wire::put_u64(&mut p, map_version);
    p
}

fn decode_welcome(payload: &[u8]) -> Result<(Config, Vec<usize>, u64)> {
    let mut cur = wire::Cursor::new(kind::WELCOME, payload)?;
    let kv = cur.str("config")?.to_string();
    let n_blocks = cur.u32("n_blocks")? as usize;
    let mut owners = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        owners.push(cur.u32("owner")? as usize);
    }
    let map_version = cur.u64("map_version")?;
    cur.finish()?;
    let mut cfg = Config::default();
    for line in kv.lines().filter(|l| !l.trim().is_empty()) {
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("Welcome config line {line:?}"))?;
        cfg.apply_kv(k, v)?;
    }
    // The coordinator owns the observability endpoint and the fault
    // plan; a worker process re-binding the same stats address or
    // re-injecting the same faults would double them up.
    cfg.stats_addr.clear();
    cfg.faults.clear();
    cfg.validate()?;
    anyhow::ensure!(
        cfg.n_blocks == n_blocks,
        "Welcome owner map covers {n_blocks} blocks, config says {}",
        cfg.n_blocks
    );
    anyhow::ensure!(
        owners.iter().all(|&s| s < cfg.n_servers),
        "Welcome owner map references a server shard >= {}",
        cfg.n_servers
    );
    Ok((cfg, owners, map_version))
}

fn parse_rank(s: &str) -> Result<(usize, usize)> {
    let (r, n) = s
        .split_once('/')
        .with_context(|| format!("--rank {s:?}: expected R/N (e.g. 0/2)"))?;
    let r: usize = r.trim().parse().with_context(|| format!("--rank {s:?}: bad rank"))?;
    let n: usize =
        n.trim().parse().with_context(|| format!("--rank {s:?}: bad rank count"))?;
    anyhow::ensure!(n >= 1 && r < n, "--rank {s}: rank must be in 0..{n}");
    Ok((r, n))
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

/// `asybadmm serve` entry point.
pub fn serve_main(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "coordinator process: server shards + BlockTable + rebalancer; \
         worker processes join over TCP (`asybadmm work`)",
    )
    .opt("listen", "127.0.0.1:0", "listen address (host:port; port 0 picks one)")
    .opt("config", "", "config file (TOML-subset key = value)")
    .opt(
        "set",
        "",
        "comma-separated key=value config overrides (same keys as `asybadmm \
         train`, e.g. stats_addr=HOST:PORT, placement=dynamic, batch=N; an \
         unknown key lists all valid keys)",
    )
    .parse_from(argv);
    let mut cfg = build_config(&p)?;
    // The multi-process runtime IS the tcp transport; pin the canonical
    // value so the shipped kv text says what actually runs.
    cfg.transport = TransportKind::Tcp;
    serve(&cfg, p.get("listen"))
}

fn serve(cfg: &Config, listen: &str) -> Result<()> {
    let (ds, shards) = load_data(cfg)?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    let placement = make_placement(cfg.placement);
    let topo = Topology::build_with(&shards, cfg.n_blocks, cfg.n_servers, placement.as_ref());
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    let kernels = Kernels::select(cfg.kernel);
    let dynamic = cfg.placement == PlacementKind::Dynamic;
    let table = Arc::new(BlockTable::with_kernels(
        &topo,
        store.clone(),
        problem,
        cfg.rho,
        cfg.gamma,
        kernels,
    ));
    let map = Arc::new(BlockMap::new(&topo.server_of_block));
    let manifest: Arc<Option<Manifest>> = Arc::new(match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    });

    let transport =
        TcpTransport::bind(listen, cfg.n_workers, cfg.n_servers, lane_cap(cfg), cfg.batch)?;
    let (ctl_tx, ctl_rx) = channel::<CtlConn>();
    transport.set_ctl_hook(ctl_tx);
    // Every z̃ publish bumps this counter; receivers piggyback it on
    // Credit frames so idle workers snap their pull cadence back down.
    transport.set_version_hint(store.publish_counter());
    let pull_stats = Arc::new(PullServeStats::default());
    println!("# {}", cfg.summary());
    println!("# dataset {}: m={} d={} nnz={}", ds.name, ds.samples(), ds.dim(), ds.a.nnz());
    // Parsed by `asybadmm work` launchers and tests/netproc.rs; Rust
    // stdout is line-buffered even when piped, so these appear live.
    println!("# listening on {}", transport.local_addr());

    let _stats_server = if cfg.stats_addr.is_empty() {
        None
    } else {
        let table = table.clone();
        let map = map.clone();
        let n_servers = cfg.n_servers;
        let wire_ctr = transport.wire_counters();
        let pull_stats = pull_stats.clone();
        let server = StatsServer::spawn(
            &cfg.stats_addr,
            Arc::new(move || {
                let counts = table.push_counts();
                let owners = map.snapshot();
                let mut shard_load = vec![0usize; n_servers];
                for (j, &c) in counts.iter().enumerate() {
                    shard_load[owners[j]] += c;
                }
                let w = wire_ctr.snapshot();
                let p = &pull_stats;
                obj(vec![
                    ("pushes_total", num(counts.iter().sum::<usize>() as f64)),
                    ("push_counts", Json::Arr(counts.iter().map(|&c| num(c as f64)).collect())),
                    ("placement", Json::Arr(owners.iter().map(|&o| num(o as f64)).collect())),
                    (
                        "shard_load",
                        Json::Arr(shard_load.iter().map(|&l| num(l as f64)).collect()),
                    ),
                    ("map_version", num(map.version() as f64)),
                    ("migrations", num(map.migrations() as f64)),
                    (
                        "wire",
                        obj(vec![
                            ("push_frames_in", num(w.push_frames_in as f64)),
                            ("push_bytes_in", num(w.push_bytes_in as f64)),
                            ("msgs_in", num(w.msgs_in as f64)),
                            ("credit_frames_out", num(w.credit_frames_out as f64)),
                            ("credits_out", num(w.credits_out as f64)),
                        ]),
                    ),
                    (
                        "pull",
                        obj(vec![
                            ("rounds", num(p.rounds.load(Ordering::Relaxed) as f64)),
                            ("empty_rounds", num(p.empty.load(Ordering::Relaxed) as f64)),
                            ("dense_blocks", num(p.dense_blocks.load(Ordering::Relaxed) as f64)),
                            (
                                "sparse_blocks",
                                num(p.sparse_blocks.load(Ordering::Relaxed) as f64),
                            ),
                            ("resp_bytes", num(p.resp_bytes.load(Ordering::Relaxed) as f64)),
                            (
                                "dense_equiv_bytes",
                                num(p.dense_equiv_bytes.load(Ordering::Relaxed) as f64),
                            ),
                        ]),
                    ),
                    // Serve mode runs fault-free (module docs); the key
                    // stays so /stats consumers see one schema.
                    ("faults", Json::Arr(Vec::new())),
                ])
            }),
        )?;
        println!("# stats on {}", server.addr());
        Some(server)
    };

    // -- server threads (plain spawns, not a scope: any error below
    //    must be able to exit the process without first waiting out a
    //    drain loop that only a clean shutdown unblocks) --------------
    let shard_rts: Arc<Vec<ShardRt>> = Arc::new(
        (0..cfg.n_servers)
            .map(|sid| {
                let shard = ServerShard::with_table(sid, &topo, table.clone(), !dynamic);
                ShardRt::new(shard, &transport)
            })
            .collect(),
    );
    let n_threads = if cfg.server_threads == 0 { cfg.n_servers } else { cfg.server_threads };
    let mut server_handles = Vec::with_capacity(n_threads);
    for tid in 0..n_threads {
        let rts = shard_rts.clone();
        let manifest = manifest.clone();
        let (drain, n_servers, block_size) = (cfg.drain, cfg.n_servers, cfg.block_size);
        server_handles.push(
            std::thread::Builder::new()
                .name(format!("server-{tid}"))
                .spawn(move || {
                    let prox = match &*manifest {
                        None => ProxBackend::Native,
                        Some(m) => match ServerProxXla::load(m, block_size) {
                            Ok(p) => ProxBackend::Xla(p),
                            Err(e) => {
                                eprintln!(
                                    "server thread {tid}: XLA prox unavailable ({e:#}); native fallback"
                                );
                                ProxBackend::Native
                            }
                        },
                    };
                    if n_threads == n_servers {
                        run_server(&rts, tid, drain, &prox).expect("server loop failed");
                    } else {
                        run_pool(&rts, tid, &prox).expect("server pool loop failed");
                    }
                })
                .context("spawn server thread")?,
        );
    }

    // -- join barrier: every rank sends JoinCtl, gets Welcome ----------
    let mut n_ranks: Option<usize> = None;
    let mut joined: Vec<Option<TcpStream>> = Vec::new();
    let mut joined_count = 0usize;
    while n_ranks.map_or(true, |n| joined_count < n) {
        let conn = match ctl_rx.recv_timeout(JOIN_TIMEOUT) {
            Ok(conn) => conn,
            Err(RecvTimeoutError::Timeout) => bail!(
                "no worker joined within {}s ({joined_count} rank(s) connected so far); \
                 start `asybadmm work --connect {} --rank R/N`",
                JOIN_TIMEOUT.as_secs(),
                transport.local_addr()
            ),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("control channel closed before all ranks joined")
            }
        };
        match conn.kind {
            kind::JOIN_CTL => {
                let mut cur = wire::Cursor::new(kind::JOIN_CTL, &conn.payload)?;
                let rank = cur.u32("rank")? as usize;
                let ranks = cur.u32("n_ranks")? as usize;
                cur.finish()?;
                anyhow::ensure!(
                    ranks >= 1 && ranks <= cfg.n_workers,
                    "JoinCtl: n_ranks {ranks} outside 1..={} (every rank needs a worker)",
                    cfg.n_workers
                );
                anyhow::ensure!(rank < ranks, "JoinCtl: rank {rank} out of range 0..{ranks}");
                match n_ranks {
                    None => {
                        n_ranks = Some(ranks);
                        joined.resize_with(ranks, || None);
                    }
                    Some(n) => anyhow::ensure!(
                        n == ranks,
                        "JoinCtl: rank {rank} claims {ranks} ranks, first join said {n}"
                    ),
                }
                anyhow::ensure!(joined[rank].is_none(), "rank {rank} joined twice");
                let mut stream = conn.stream;
                wire::write_frame(
                    &mut stream,
                    kind::WELCOME,
                    &encode_welcome(cfg, &map.snapshot(), map.version()),
                )
                .with_context(|| format!("sending Welcome to rank {rank}"))?;
                info!("serve", "rank {rank}/{ranks} joined");
                joined[rank] = Some(stream);
                joined_count += 1;
            }
            // A rank's mirror-sync stream may open before the last rank
            // joins; serve it right away.
            kind::HELLO_PULL => spawn_pull_thread(conn.stream, store.clone(), pull_stats.clone()),
            other => bail!("unexpected {} frame on the control plane", wire::kind_name(other)),
        }
    }
    let n_ranks = n_ranks.expect("join barrier complete");

    // Late control connections (a pull stream opening after the
    // barrier) drain on their own thread for the rest of the run.
    let stop_ctl = Arc::new(AtomicBool::new(false));
    let ctl_drain = {
        let store = store.clone();
        let stats = pull_stats.clone();
        let stop = stop_ctl.clone();
        std::thread::Builder::new()
            .name("ctl-drain".into())
            .spawn(move || loop {
                match ctl_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(conn) if conn.kind == kind::HELLO_PULL => {
                        spawn_pull_thread(conn.stream, store.clone(), stats.clone())
                    }
                    Ok(conn) => {
                        eprintln!("late {} connection refused", wire::kind_name(conn.kind))
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            })
            .context("spawn control drain thread")?
    };

    // Split each rank's control stream: the read half waits for
    // WorkerDone, the write half carries OwnerUpdate republishes.
    let mut ctl_writers = Vec::with_capacity(n_ranks);
    let (done_tx, done_rx) = channel::<(usize, u64, u64, u64)>();
    for (rank, slot) in joined.into_iter().enumerate() {
        let stream = slot.expect("join barrier complete");
        ctl_writers.push(stream.try_clone().context("clone control stream")?);
        let done_tx = done_tx.clone();
        std::thread::Builder::new()
            .name(format!("ctl-rank-{rank}"))
            .spawn(move || ctl_read_loop(rank, stream, done_tx))
            .context("spawn control reader")?;
    }
    drop(done_tx);

    // -- monitor: collect WorkerDone, drive the rebalancer, republish -
    let start = Instant::now();
    let mut rebalancer = (dynamic && cfg.n_servers > 1)
        .then(|| Rebalancer::new(map.clone(), table.clone(), cfg.n_servers));
    let rebalance_every = Duration::from_millis(cfg.rebalance_ms.max(1));
    let mut last_scan = Instant::now();
    let mut owners_prev = map.snapshot();
    let tick = Duration::from_millis(cfg.rebalance_ms.clamp(5, 100));
    let mut done_ranks = 0usize;
    let mut sent_total = 0u64;
    let (mut pull_rounds_total, mut pull_empty_total) = (0u64, 0u64);
    while done_ranks < n_ranks {
        match done_rx.recv_timeout(tick) {
            Ok((rank, pushes, rounds, empty)) => {
                done_ranks += 1;
                sent_total += pushes;
                pull_rounds_total += rounds;
                pull_empty_total += empty;
                info!(
                    "serve",
                    "rank {rank} done ({pushes} pushes, {rounds} pull rounds ({empty} empty); \
                     {done_ranks}/{n_ranks} ranks)"
                );
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => bail!(
                "a worker process exited without finishing ({done_ranks}/{n_ranks} ranks done)"
            ),
        }
        if let Some(rb) = rebalancer.as_mut() {
            if last_scan.elapsed() >= rebalance_every {
                rb.scan();
                last_scan = Instant::now();
                let changed = map.diff(&owners_prev);
                if !changed.is_empty() {
                    let version = map.version();
                    for &(j, s) in &changed {
                        owners_prev[j] = s;
                        let mut p = Vec::with_capacity(16);
                        wire::put_u32(&mut p, j as u32);
                        wire::put_u32(&mut p, s as u32);
                        wire::put_u64(&mut p, version);
                        // A rank that already finished may have closed
                        // its stream; EPIPE here is not an error.
                        for w in ctl_writers.iter_mut() {
                            let _ = wire::write_frame(w, kind::OWNER_UPDATE, &p);
                        }
                    }
                }
            }
        }
    }

    // -- drain + summary ----------------------------------------------
    transport.shutdown();
    for h in server_handles {
        h.join().map_err(|_| anyhow::anyhow!("server thread panicked"))?;
    }
    stop_ctl.store(true, Ordering::Release);
    let _ = ctl_drain.join();
    let applied: usize = shard_rts.iter().map(|rt| rt.shard.stats().pushes).sum();
    let final_obj = objective_at_z(&shards, &problem, weight, &store.snapshot());
    println!(
        "# done in {:.3}s: objective {:.6} (data {:.6} + reg {:.6}); pushes={} sent={} \
         migrations={} pull_rounds={} pull_empty={}",
        start.elapsed().as_secs_f64(),
        final_obj.total(),
        final_obj.data_loss,
        final_obj.reg,
        applied,
        sent_total,
        map.migrations(),
        pull_rounds_total,
        pull_empty_total
    );
    Ok(())
}

fn spawn_pull_thread(stream: TcpStream, store: Arc<BlockStore>, stats: Arc<PullServeStats>) {
    // Detached: exits on its worker's EOF, reaped at process exit
    // otherwise.
    let _ = std::thread::Builder::new()
        .name("pull-serve".into())
        .spawn(move || pull_serve_loop(stream, store, stats));
}

/// Answer one worker process's `PullReq` stream until it hangs up.
///
/// Delta encoding: the loop mirrors exactly what it last sent for each
/// block.  TCP is reliable and ordered, so whenever a request's
/// `have_version` equals the mirrored version the worker's copy is
/// byte-identical to the mirror, and the block can ship as a sparse
/// (index,value) patch against it when that is smaller
/// ([`wire::sparse_saves_bytes`]).  Any base mismatch — first send on
/// this connection, a reconnect, a worker that skipped a version —
/// falls back to dense, so reconstruction is always exact.
fn pull_serve_loop(mut stream: TcpStream, store: Arc<BlockStore>, stats: Arc<PullServeStats>) {
    let n = store.n_blocks();
    let db = store.block_size();
    let mut block = vec![0.0f32; db];
    let mut resp = Vec::new();
    let mut sent: Vec<Vec<f32>> = vec![Vec::new(); n];
    let mut sent_v = vec![0u64; n];
    let (mut idx, mut vals) = (Vec::new(), Vec::new());
    loop {
        let payload = match wire::read_frame(&mut stream) {
            Ok(Some((kind::PULL_REQ, p))) => p,
            Ok(Some((k, _))) => {
                eprintln!("pull stream: unexpected {} frame", wire::kind_name(k));
                return;
            }
            Ok(None) | Err(_) => return,
        };
        let built = (|| -> Result<()> {
            let mut cur = wire::Cursor::new(kind::PULL_REQ, &payload)?;
            let req_n = cur.u32("n_blocks")? as usize;
            anyhow::ensure!(req_n == n, "PullReq covers {req_n} blocks, store has {n}");
            resp.clear();
            wire::put_u32(&mut resp, 0); // changed-block count, patched below
            let mut count = 0u32;
            for j in 0..n {
                let have = cur.u64("have_version")?;
                let v = store.read_into(j, &mut block);
                if v <= have {
                    continue;
                }
                let before = resp.len();
                if have > 0 && sent_v[j] == have {
                    wire::diff_block(&sent[j], &block, &mut idx, &mut vals);
                    if wire::sparse_saves_bytes(idx.len(), db) {
                        wire::put_pull_block_sparse(&mut resp, j as u32, v, have, &idx, &vals);
                        stats.sparse_blocks.fetch_add(1, Ordering::Relaxed);
                    } else {
                        wire::put_pull_block_dense(&mut resp, j as u32, v, &block);
                        stats.dense_blocks.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    wire::put_pull_block_dense(&mut resp, j as u32, v, &block);
                    stats.dense_blocks.fetch_add(1, Ordering::Relaxed);
                }
                stats.resp_bytes.fetch_add((resp.len() - before) as u64, Ordering::Relaxed);
                stats.dense_equiv_bytes.fetch_add((17 + 4 * db) as u64, Ordering::Relaxed);
                if sent[j].is_empty() {
                    sent[j].resize(db, 0.0);
                }
                sent[j].copy_from_slice(&block);
                sent_v[j] = v;
                count += 1;
            }
            cur.finish()?;
            resp[0..4].copy_from_slice(&count.to_le_bytes());
            stats.rounds.fetch_add(1, Ordering::Relaxed);
            if count == 0 {
                stats.empty.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        })();
        if let Err(e) = built {
            eprintln!("pull stream: bad PullReq: {e:#}");
            return;
        }
        if wire::write_frame(&mut stream, kind::PULL_RESP, &resp).is_err() {
            return;
        }
    }
}

/// Wait for one rank's `WorkerDone` (or its death) on the control
/// stream's read half.
fn ctl_read_loop(rank: usize, mut stream: TcpStream, done: Sender<(usize, u64, u64, u64)>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some((kind::WORKER_DONE, payload))) => {
                let parsed = (|| -> Result<(usize, u64, u64, u64)> {
                    let mut cur = wire::Cursor::new(kind::WORKER_DONE, &payload)?;
                    let r = cur.u32("rank")? as usize;
                    let pushes = cur.u64("pushes")?;
                    let pull_rounds = cur.u64("pull_rounds")?;
                    let pull_empty = cur.u64("pull_empty")?;
                    cur.finish()?;
                    Ok((r, pushes, pull_rounds, pull_empty))
                })();
                match parsed {
                    Ok(tuple) => {
                        let _ = done.send(tuple);
                    }
                    Err(e) => eprintln!("rank {rank}: bad WorkerDone: {e:#}"),
                }
                return;
            }
            Ok(Some((k, _))) => {
                eprintln!("rank {rank}: unexpected {} on control stream", wire::kind_name(k))
            }
            // EOF without WorkerDone: the rank died.  Dropping `done`
            // is the signal — once every reader exits, the monitor's
            // channel disconnects and serve reports the failure.
            Ok(None) => return,
            Err(e) => {
                eprintln!("rank {rank}: control stream error: {e:#}");
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------
// work
// ---------------------------------------------------------------------

/// `asybadmm work` entry point.
pub fn work_main(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "worker process: joins an `asybadmm serve` coordinator and runs \
         the worker ranks w where w mod N == R",
    )
    .req("connect", "coordinator address (host:port, printed by `asybadmm serve`)")
    .req("rank", "this process's share as R/N (e.g. 0/2)")
    .parse_from(argv);
    let (rank, n_ranks) = parse_rank(p.get("rank"))?;
    work(p.get("connect"), rank, n_ranks)
}

fn work(connect: &str, rank: usize, n_ranks: usize) -> Result<()> {
    let addr: SocketAddr = connect
        .to_socket_addrs()
        .with_context(|| format!("connect address {connect:?} (expected host:port)"))?
        .next()
        .with_context(|| format!("connect address {connect:?} resolved to nothing"))?;

    // -- join ----------------------------------------------------------
    let mut ctl = TcpStream::connect(addr)
        .with_context(|| format!("connecting to coordinator at {addr}"))?;
    ctl.set_nodelay(true).ok();
    let mut join = Vec::with_capacity(8);
    wire::put_u32(&mut join, rank as u32);
    wire::put_u32(&mut join, n_ranks as u32);
    wire::write_frame(&mut ctl, kind::JOIN_CTL, &join).context("sending JoinCtl")?;
    let (k, payload) = wire::read_frame(&mut ctl)
        .context("waiting for Welcome")?
        .context("coordinator closed the connection before Welcome")?;
    anyhow::ensure!(k == kind::WELCOME, "expected Welcome, got {}", wire::kind_name(k));
    let (cfg, owners, _map_version) = decode_welcome(&payload)?;
    anyhow::ensure!(
        n_ranks <= cfg.n_workers,
        "rank {rank}/{n_ranks}: only {} workers configured",
        cfg.n_workers
    );
    info!("work", "rank {rank}/{n_ranks} joined {addr}: {}", cfg.summary());

    let (_ds, shards) = load_data(&cfg)?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let kernels = Kernels::select(cfg.kernel);
    let manifest = match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    };
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    let map = Arc::new(BlockMap::new(&owners));
    let policy =
        DelayPolicy { net_mean_ms: cfg.net_delay_mean_ms, pull_hold: cfg.pull_hold.max(1) };
    let fault_plan = FaultPlan::none();
    let pool_cap =
        push_inflight(cfg.n_workers) + 4 + cfg.n_servers * cfg.batch.saturating_sub(1);

    // -- mirror-sync thread -------------------------------------------
    let stop_sync = Arc::new(AtomicBool::new(false));
    // Publish hint: every push sender's Credit frames max-merge the
    // coordinator's publish counter in here; the pull loop reads it to
    // cut idle backoff short the moment z̃ moves.
    let publish_hint = Arc::new(AtomicU64::new(0));
    let pull_rounds = Arc::new(AtomicU64::new(0));
    let pull_empty = Arc::new(AtomicU64::new(0));
    let sync_handle = {
        let mut stream = TcpStream::connect(addr).context("connecting the mirror-sync stream")?;
        stream.set_nodelay(true).ok();
        let mut hello = Vec::with_capacity(4);
        wire::put_u32(&mut hello, rank as u32);
        wire::write_frame(&mut stream, kind::HELLO_PULL, &hello).context("sending HelloPull")?;
        let store = store.clone();
        let stop = stop_sync.clone();
        let hint = publish_hint.clone();
        let (rounds, empty) = (pull_rounds.clone(), pull_empty.clone());
        std::thread::Builder::new()
            .name("pull-sync".into())
            .spawn(move || pull_sync_loop(stream, store, stop, hint, rounds, empty))
            .context("spawn mirror-sync thread")?
    };

    // -- owner-update reader (detached; exits on the coordinator's EOF)
    {
        let map = map.clone();
        let stream = ctl.try_clone().context("clone control stream")?;
        std::thread::Builder::new()
            .name("ctl-owner".into())
            .spawn(move || owner_update_loop(stream, map))
            .context("spawn owner-update thread")?;
    }

    // -- this rank's workers ------------------------------------------
    let local: Vec<&WorkerShard> =
        shards.iter().filter(|s| s.worker_id % n_ranks == rank).collect();
    anyhow::ensure!(!local.is_empty(), "rank {rank}/{n_ranks}: no workers to run");
    let progress: Vec<AtomicUsize> = (0..cfg.n_workers).map(|_| AtomicUsize::new(0)).collect();
    let gate = MonitorGate::new();
    let ledgers: Vec<Vec<AtomicU64>> = shards
        .iter()
        .map(|s| (0..s.n_slots()).map(|_| AtomicU64::new(0)).collect())
        .collect();

    // Dial every lane before spawning anything: a refused connection
    // fails the rank instead of stranding half-started workers.
    let mut senders = Vec::with_capacity(local.len());
    for shard in &local {
        let mut tx = TcpPushSender::connect_remote(
            &addr,
            shard.worker_id,
            cfg.n_servers,
            lane_cap(&cfg),
            cfg.batch,
        )
        .with_context(|| format!("worker {}: dialing push lanes", shard.worker_id))?;
        tx.set_hint_sink(publish_hint.clone());
        senders.push(tx);
    }

    let start = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(local.len());
        for (shard, tx) in local.iter().zip(senders) {
            let wid = shard.worker_id;
            let shard: &WorkerShard = shard;
            let store = &store;
            let router: &BlockMap = &map;
            let progress = &progress[wid];
            let gate = &gate;
            let manifest = manifest.as_ref();
            let fault_plan = &fault_plan;
            let ledger: &[AtomicU64] = &ledgers[wid];
            let cfg = &cfg;
            let seed = cfg.seed ^ (0x9E37 + wid as u64 * 0x1000_0000_01B3);
            let local_weight = 1.0 / shard.samples().max(1) as f32;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut compute = make_compute(
                    cfg.backend,
                    shard,
                    problem,
                    local_weight,
                    manifest,
                    cfg.m_chunk,
                    cfg.d_pad,
                    kernels,
                )
                .context("construct worker compute backend")?;
                let tx: Box<dyn PushSender> = Box::new(tx);
                let mut ctx = WorkerCtx::new(
                    shard,
                    store,
                    router,
                    tx,
                    policy,
                    cfg.selection,
                    cfg.rho,
                    cfg.epochs,
                    cfg.max_delay,
                    cfg.enforce_delay_bound,
                    seed,
                    progress,
                    gate,
                    pool_cap,
                    fault_plan,
                    ledger,
                );
                ctx.run(compute.as_mut()).with_context(|| format!("worker {wid} loop"))?;
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
        }
        Ok(())
    })?;

    // -- report + teardown --------------------------------------------
    // Senders dropped with the scope: their FIN is behind the last
    // flushed push frame, so the coordinator's drain sees every message
    // before the EOF.
    stop_sync.store(true, Ordering::Release);
    let _ = sync_handle.join();
    let sent: u64 = local
        .iter()
        .map(|s| ledgers[s.worker_id].iter().map(|a| a.load(Ordering::Acquire)).sum::<u64>())
        .sum();
    // Counters are final: the sync thread joined above.
    let rounds = pull_rounds.load(Ordering::Acquire);
    let empty = pull_empty.load(Ordering::Acquire);
    let mut done = Vec::with_capacity(28);
    wire::put_u32(&mut done, rank as u32);
    wire::put_u64(&mut done, sent);
    wire::put_u64(&mut done, rounds);
    wire::put_u64(&mut done, empty);
    wire::write_frame(&mut ctl, kind::WORKER_DONE, &done).context("sending WorkerDone")?;
    // Parsed by tests/netproc.rs (`pull_rounds=` / `pull_empty=`).
    println!(
        "# rank {rank}/{n_ranks} done in {:.3}s: {} workers, {sent} pushes sent, \
         pull_rounds={rounds} pull_empty={empty}",
        start.elapsed().as_secs_f64(),
        local.len()
    );
    Ok(())
}

/// Worker-side mirror refresh: poll the coordinator for blocks newer
/// than the local replica and adopt them via
/// [`BlockStore::write_versioned`].
///
/// Keeps shadow copies of the exact bytes last adopted per block — the
/// base sparse deltas patch against.  The shadow's versions go out as
/// `have_version`, so the coordinator's per-connection mirror and this
/// shadow stay in lockstep and reconstruction is bit-identical (SET
/// semantics).  Pacing is [`PullCadence`]; `hint` is the coordinator's
/// publish counter delivered via Credit frames, sampled mid-sleep so an
/// idle 8ms nap ends the moment z̃ moves.
fn pull_sync_loop(
    mut stream: TcpStream,
    store: Arc<BlockStore>,
    stop: Arc<AtomicBool>,
    hint: Arc<AtomicU64>,
    rounds_out: Arc<AtomicU64>,
    empty_out: Arc<AtomicU64>,
) {
    let n = store.n_blocks();
    let db = store.block_size();
    let mut req = Vec::new();
    let mut shadow: Vec<Vec<f32>> = vec![vec![0.0f32; db]; n];
    let mut shadow_v = vec![0u64; n];
    let mut cadence = PullCadence::new();
    while !stop.load(Ordering::Acquire) {
        req.clear();
        wire::put_u32(&mut req, n as u32);
        for &v in &shadow_v {
            wire::put_u64(&mut req, v);
        }
        if wire::write_frame(&mut stream, kind::PULL_REQ, &req).is_err() {
            return;
        }
        rounds_out.fetch_add(1, Ordering::Relaxed);
        let (k, payload) = match wire::read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => return,
        };
        if k != kind::PULL_RESP {
            eprintln!("pull-sync: unexpected {} frame", wire::kind_name(k));
            return;
        }
        let mut got = 0usize;
        let applied = (|| -> Result<()> {
            let mut cur = wire::Cursor::new(kind::PULL_RESP, &payload)?;
            let count = cur.u32("count")? as usize;
            for _ in 0..count {
                let b = wire::take_pull_block(&mut cur)?;
                let j = b.block;
                anyhow::ensure!(j < n, "PullResp: block {j} outside geometry {n}x{db}");
                match b.body {
                    wire::WirePullBody::Dense(data) => {
                        anyhow::ensure!(
                            data.len() == db,
                            "PullResp: block {j} length {} outside geometry {n}x{db}",
                            data.len()
                        );
                        shadow[j].copy_from_slice(&data);
                    }
                    wire::WirePullBody::Sparse { base_version, idx, vals } => {
                        anyhow::ensure!(
                            base_version == shadow_v[j],
                            "PullResp: sparse block {j} against base v{base_version}, \
                             shadow holds v{}",
                            shadow_v[j]
                        );
                        wire::apply_sparse_patch(&mut shadow[j], &idx, &vals)?;
                    }
                }
                shadow_v[j] = b.version;
                store.write_versioned(j, &shadow[j], b.version);
                got += 1;
            }
            cur.finish()
        })();
        if let Err(e) = applied {
            eprintln!("pull-sync: bad PullResp: {e:#}");
            return;
        }
        if got == 0 {
            empty_out.fetch_add(1, Ordering::Relaxed);
        }
        // Sleep in floor-sized slices so the publish hint (or stop) can
        // cut a long idle nap short.
        let target = cadence.after_round(got > 0);
        let h0 = hint.load(Ordering::Relaxed);
        let mut slept = Duration::ZERO;
        while slept < target && !stop.load(Ordering::Acquire) {
            let step = PULL_POLL_MIN.min(target - slept);
            std::thread::sleep(step);
            slept += step;
            if hint.load(Ordering::Relaxed) > h0 {
                cadence.reset();
                break;
            }
        }
    }
}

/// Apply `OwnerUpdate` republishes to the process-local routing map.
fn owner_update_loop(mut stream: TcpStream, map: Arc<BlockMap>) {
    loop {
        let payload = match wire::read_frame(&mut stream) {
            Ok(Some((kind::OWNER_UPDATE, p))) => p,
            Ok(Some((k, _))) => {
                eprintln!("owner-update: unexpected {} frame", wire::kind_name(k));
                return;
            }
            Ok(None) | Err(_) => return,
        };
        let applied = (|| -> Result<()> {
            let mut cur = wire::Cursor::new(kind::OWNER_UPDATE, &payload)?;
            let j = cur.u32("block")? as usize;
            let s = cur.u32("owner")? as usize;
            let _v = cur.u64("map_version")?;
            cur.finish()?;
            anyhow::ensure!(j < map.n_blocks(), "OwnerUpdate: block {j} out of range");
            map.set_owner(j, s);
            Ok(())
        })();
        if let Err(e) = applied {
            eprintln!("owner-update: {e:#}");
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_spec_parses_and_rejects() {
        assert_eq!(parse_rank("0/2").unwrap(), (0, 2));
        assert_eq!(parse_rank("3/4").unwrap(), (3, 4));
        assert!(parse_rank("2/2").is_err());
        assert!(parse_rank("1").is_err());
        assert!(parse_rank("a/b").is_err());
        assert!(parse_rank("0/0").is_err());
    }

    #[test]
    fn welcome_round_trips_config_and_owner_map() {
        let mut cfg = Config::default();
        cfg.apply_kv("n_workers", "3").unwrap();
        cfg.apply_kv("n_servers", "2").unwrap();
        cfg.apply_kv("epochs", "17").unwrap();
        cfg.apply_kv("placement", "dynamic").unwrap();
        cfg.apply_kv("batch", "2").unwrap();
        cfg.apply_kv("stats_addr", "127.0.0.1:0").unwrap();
        let owners: Vec<usize> = (0..cfg.n_blocks).map(|j| j % 2).collect();
        let payload = encode_welcome(&cfg, &owners, 7);
        let (got, got_owners, v) = decode_welcome(&payload).unwrap();
        assert_eq!(got.n_workers, 3);
        assert_eq!(got.n_servers, 2);
        assert_eq!(got.epochs, 17);
        assert_eq!(got.batch, 2);
        assert_eq!(got_owners, owners);
        assert_eq!(v, 7);
        // Worker-side policy: the coordinator keeps the stats endpoint.
        assert!(got.stats_addr.is_empty());
    }

    #[test]
    fn welcome_rejects_owner_map_geometry_mismatch() {
        let cfg = Config::default();
        let mut owners: Vec<usize> = vec![0; cfg.n_blocks];
        owners[0] = cfg.n_servers; // out-of-range shard
        let payload = encode_welcome(&cfg, &owners, 1);
        let err = format!("{:#}", decode_welcome(&payload).unwrap_err());
        assert!(err.contains("server shard"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_welcome_names_the_missing_field() {
        let cfg = Config::default();
        let payload = encode_welcome(&cfg, &vec![0; cfg.n_blocks], 1);
        let err = format!("{:#}", decode_welcome(&payload[..payload.len() - 4]).unwrap_err());
        assert!(err.contains("map_version"), "unexpected error: {err}");
    }

    #[test]
    fn pull_cadence_backs_off_doubling_and_resets_on_progress() {
        let mut c = PullCadence::new();
        assert_eq!(c.after_round(true), PULL_POLL_MIN);
        assert_eq!(c.after_round(false), PULL_POLL_MIN);
        let mut prev = PULL_POLL_MIN;
        for _ in 0..10 {
            let d = c.after_round(false);
            assert!(d >= prev && d <= PULL_POLL_MAX, "cadence left [{prev:?}, max]: {d:?}");
            prev = d;
        }
        assert_eq!(prev, PULL_POLL_MAX, "ten idle rounds must reach the ceiling");
        assert_eq!(c.after_round(true), PULL_POLL_MIN, "productive round resets");
        let _ = c.after_round(false);
        assert!(c.after_round(false) > PULL_POLL_MIN);
        c.reset();
        assert_eq!(c.after_round(false), PULL_POLL_MIN, "hint reset returns to the floor");
    }

    /// The serve and sync loops against each other over a real socket:
    /// dense first sends, sparse deltas once bases align, bit-identical
    /// mirrors throughout (including -0.0 and NaN payloads).
    #[test]
    fn pull_loop_pair_converges_bit_identically_via_sparse_deltas() {
        let (n, db) = (4usize, 32usize);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_store = Arc::new(BlockStore::new(n, db));
        for j in 0..n {
            let data: Vec<f32> = (0..db).map(|i| (j * db + i) as f32).collect();
            server_store.write_versioned(j, &data, 1);
        }
        let stats = Arc::new(PullServeStats::default());
        {
            let (store, stats) = (server_store.clone(), stats.clone());
            std::thread::spawn(move || {
                let (s, _) = listener.accept().unwrap();
                pull_serve_loop(s, store, stats);
            });
        }
        let worker_store = Arc::new(BlockStore::new(n, db));
        let stop = Arc::new(AtomicBool::new(false));
        let hint = Arc::new(AtomicU64::new(0));
        let rounds = Arc::new(AtomicU64::new(0));
        let empty = Arc::new(AtomicU64::new(0));
        let sync = {
            let (ws, st) = (worker_store.clone(), stop.clone());
            let (h, r, e) = (hint.clone(), rounds.clone(), empty.clone());
            let stream = TcpStream::connect(addr).unwrap();
            std::thread::spawn(move || pull_sync_loop(stream, ws, st, h, r, e))
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        let wait_version = |j: usize, v: u64| {
            while worker_store.version(j) < v {
                assert!(Instant::now() < deadline, "mirror never reached block {j} v{v}");
                std::thread::sleep(Duration::from_millis(1));
            }
        };
        for j in 0..n {
            wait_version(j, 1);
        }
        // Idle tail: with everything in sync, rounds must come back
        // empty (and the cadence backs off, not asserted on timing).
        std::thread::sleep(Duration::from_millis(40));
        assert!(empty.load(Ordering::Relaxed) > 0, "idle polls should report empty rounds");
        // Touch two lanes of block 2 with awkward bit patterns: small
        // enough for the sparse path, and only bit-exact copying keeps
        // the mirrors identical.
        let mut blk = vec![0.0f32; db];
        server_store.read_into(2, &mut blk);
        blk[3] = -0.0;
        blk[17] = f32::from_bits(0x7fc0_1234); // non-canonical NaN
        server_store.write_versioned(2, &blk, 2);
        wait_version(2, 2);
        stop.store(true, Ordering::Release);
        sync.join().unwrap();
        assert!(
            stats.sparse_blocks.load(Ordering::Relaxed) >= 1,
            "2 changed lanes of {db} must take the sparse path"
        );
        assert!(stats.dense_blocks.load(Ordering::Relaxed) >= n as u64 - 1);
        let (mut sv, mut wv) = (vec![0.0f32; db], vec![0.0f32; db]);
        for j in 0..n {
            server_store.read_into(j, &mut sv);
            worker_store.read_into(j, &mut wv);
            let sb: Vec<u32> = sv.iter().map(|f| f.to_bits()).collect();
            let wb: Vec<u32> = wv.iter().map(|f| f.to_bits()).collect();
            assert_eq!(sb, wb, "block {j} mirrors diverged");
        }
        assert!(
            stats.resp_bytes.load(Ordering::Relaxed)
                < stats.dense_equiv_bytes.load(Ordering::Relaxed),
            "delta encoding should beat all-dense on this workload"
        );
        assert_eq!(rounds.load(Ordering::Relaxed), stats.rounds.load(Ordering::Relaxed));
    }
}

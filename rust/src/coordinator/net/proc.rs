//! Multi-process roles: `asybadmm serve` / `asybadmm work`.
//!
//! Splits the threaded runtime across OS processes with **zero new
//! dependencies**: the coordinator process owns the server shards, the
//! authoritative [`BlockStore`], the [`BlockTable`], the rebalancer and
//! the `/stats` control plane; each worker process owns a slice of the
//! worker ranks and talks to the coordinator over the
//! [`super::tcp::TcpTransport`] wire format.
//!
//! ## Protocol (all frames from `wire.rs`)
//!
//! 1. **Join**: a worker process dials the coordinator and sends
//!    `JoinCtl{rank, n_ranks}`.  The coordinator replies
//!    `Welcome{config kv text, n_blocks, owner map, map_version}` on the
//!    same stream; the worker rebuilds the [`Config`] from defaults +
//!    the shipped `key=value` lines, so both sides run byte-identical
//!    hyper-parameters and (for synthetic data) regenerate the same
//!    dataset from the same seed.
//! 2. **Push lanes**: each worker rank dials `n_servers` sockets via
//!    [`TcpPushSender::connect_remote`] — the exact credit-window
//!    backpressure documented in `tcp.rs`, identical to the in-process
//!    `transport=tcp` path.  Delivery acks return as coalesced
//!    `Credit{frames, hint}` frames; the hint is the coordinator's
//!    publish counter and feeds the pull cadence below.
//! 3. **Mirror sync**: one extra stream per worker process
//!    (`HelloPull`) runs a poll loop: `PullReq` ships the mirror's
//!    per-block versions, `PullResp` returns every block whose
//!    authoritative version is newer — dense, or as a sparse
//!    (index,value) delta against the worker's acked copy when that is
//!    cheaper (v2 encoding, `wire.rs`) — and the mirror adopts them
//!    with [`BlockStore::write_versioned`] — workers see coordinator
//!    version numbers, so staleness accounting matches the in-process
//!    run.  The poll cadence is adaptive ([`PullCadence`]): 500µs while
//!    responses carry data, exponential backoff to 8ms on an idle
//!    stream, snapped back to the floor by the Credit-borne publish
//!    hint.
//! 4. **Owner republish**: when `placement=dynamic` migrates a block,
//!    the coordinator writes `OwnerUpdate{block, owner, map_version}`
//!    frames down every rank's control stream; a reader thread applies
//!    them to the process-local [`BlockMap`] mirror.  Pushes routed to
//!    the old owner mid-flight still apply — every shard shares one
//!    [`BlockTable`], exactly like the in-process handoff.
//! 5. **Done**: a rank that finished its epochs sends
//!    `WorkerDone{rank, pushes, pull_rounds, pull_empty}`; once every
//!    rank reported, the coordinator shuts the transport down, drains,
//!    and prints the same `# done …` summary line as `asybadmm train`
//!    (extended with the aggregated pull round-trip accounting and an
//!    `evicted=` count).
//!
//! ## Failure model (DESIGN.md §2.0.7)
//!
//! The in-process survivability contract extends across the process
//! boundary:
//!
//! * **Liveness**: each rank's control stream carries `Heartbeat`
//!   frames (`--set net_liveness_ms=MS`; period MS/3, floor 10ms).
//!   The coordinator tracks per-rank last-seen ages — a rank silent
//!   past the deadline, or whose control stream drops, is declared
//!   dead.  `/healthz` publishes the per-rank detail.
//! * **`failure=die`** (default): a dead rank fails the run with an
//!   error naming the rank — the pre-PR behavior, made diagnosable.
//! * **`failure=degrade`**: the coordinator *evicts* the rank — its
//!   push lanes are force-closed (late reconnects refused), parked
//!   early-arrivals are purged so no seq gap blocks the survivors, a
//!   `RankEvicted` fault event is recorded, and the run completes on
//!   the survivors.  The victim's already-applied pushes stay in the
//!   consensus, exactly like the threaded degrade path.
//! * **`failure=restart`**: a dead rank's slot waits (bounded by
//!   `join_timeout_ms`) for a replacement `asybadmm work … --rank R/N`.
//!   The rejoin handshake drains the crashed stream's tail (kernel
//!   socket buffers survive process death, so the applied prefix is
//!   contiguous), then the Welcome carries per-(worker, slot) resume
//!   state — last applied seq and warm duals y ≈ w̃ − ρ·z̃ — and the
//!   replacement resumes the exact FIFO streams mid-flight.
//! * **Wire fault injection**: `netdrop:wW@E` / `netstall:wW@P+MSms`
//!   ship to worker processes (the only fault kinds that survive the
//!   Welcome; crash/stall/sendfail remain in-process kinds) and fire
//!   in [`TcpPushSender`]; `corrupt:sS@N` fires coordinator-side on a
//!   pull stream and must surface as a *named* decode error, never a
//!   panic.  All hooks sit behind the `FaultPlan::is_empty` guard.
//! * **Config hot-reload**: `POST /config` on the stats endpoint
//!   accepts `key=value` lines for the reloadable whitelist
//!   (`Config::RELOADABLE_KEYS`), applies them atomically, and
//!   republishes via `ConfigUpdate` frames on every control stream.
//! * With `checkpoint_every=N`, the coordinator snapshots the v2
//!   checkpoint off the monitor loop; a restarted `asybadmm serve`
//!   warm-starts z̃ and the owner map from it.
//!
//! ## Deliberate simplifications
//!
//! * `--set data=FILE` requires the file to be readable by every
//!   process; the default synthetic dataset needs nothing shared.
//! * Serve-side checkpoint resume restores the model (z̃, owners) but
//!   not epoch bookkeeping: rejoined worker processes rerun their full
//!   epoch budget against the warm model.

use std::io::Write;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::super::block_store::BlockStore;
use super::super::compute::make_compute;
use super::super::delay::DelayPolicy;
use super::super::fault::{FaultEvent, FaultPlan};
use super::super::placement::make_placement;
use super::super::rebalance::{BlockMap, Rebalancer};
use super::super::sched::{run_pool, run_server, ShardRt};
use super::super::server::{BlockTable, ProxBackend, ServerShard};
use super::super::session::{approx_duals, snapshot_checkpoint, MonitorGate};
use super::super::topology::Topology;
use super::super::transport::{push_inflight, PushSender, Transport};
use super::super::worker::WorkerCtx;
use super::http::{ConfigFn, HealthFn, StatsServer};
use super::tcp::{CtlConn, TcpPushSender, TcpTransport};
use super::wire::{self, kind};
use crate::admm::objective_at_z;
use crate::config::{Backend, Config, FailurePolicy, PlacementKind, TransportKind};
use crate::data::{gen_partitioned, load_libsvm, partition_even, Dataset, WorkerShard};
use crate::info;
use crate::problem::Problem;
use crate::report::Checkpoint;
use crate::runtime::{Manifest, ServerProxXla};
use crate::sparse::Kernels;
use crate::util::cli::{Args, Parsed};
use crate::util::json::{num, obj, Json};

/// Worker-side hot-reloadable knobs, shared between the control-stream
/// reader (which applies `ConfigUpdate` frames) and the loops that
/// consume them.  Plain atomics: a torn read across two keys costs one
/// mistimed poll, nothing more.
struct PullTuning {
    /// `pull_floor_us` — mirror poll floor, microseconds.
    floor_us: AtomicU64,
    /// `pull_ceil_ms` — idle poll ceiling, milliseconds.
    ceil_ms: AtomicU64,
    /// Heartbeat period, milliseconds (derived `net_liveness_ms / 3`,
    /// floored at 10ms; 0 = heartbeats off).
    hb_period_ms: AtomicU64,
}

impl PullTuning {
    fn from_cfg(cfg: &Config) -> Self {
        PullTuning {
            floor_us: AtomicU64::new(cfg.pull_floor_us.max(1)),
            ceil_ms: AtomicU64::new(cfg.pull_ceil_ms.max(1)),
            hb_period_ms: AtomicU64::new(heartbeat_period_ms(cfg.net_liveness_ms)),
        }
    }

    fn floor(&self) -> Duration {
        Duration::from_micros(self.floor_us.load(Ordering::Relaxed).max(1))
    }

    fn ceil(&self) -> Duration {
        Duration::from_millis(self.ceil_ms.load(Ordering::Relaxed).max(1)).max(self.floor())
    }
}

/// Heartbeat cadence for a liveness deadline: three beats per deadline
/// window so one delayed frame never trips the deadline, floored at
/// 10ms.  0 (liveness off) disables the thread.
fn heartbeat_period_ms(net_liveness_ms: u64) -> u64 {
    if net_liveness_ms == 0 {
        0
    } else {
        (net_liveness_ms / 3).max(10)
    }
}

/// Exponential idle backoff for the mirror poll loop: sleeps start at
/// the floor, double after every empty round (a `PullResp` carrying no
/// blocks), cap at the ceiling, and snap back to the floor on any
/// productive response or publish-hint advance.  The bounds arrive per
/// round so a `ConfigUpdate` retunes the loop mid-run.
struct PullCadence {
    cur: Duration,
}

impl PullCadence {
    fn new(floor: Duration) -> Self {
        PullCadence { cur: floor }
    }

    /// Sleep to take after a round; `productive` means the response
    /// carried at least one newer block.
    fn after_round(&mut self, productive: bool, floor: Duration, ceil: Duration) -> Duration {
        if productive {
            self.cur = floor;
            return self.cur;
        }
        let d = self.cur.clamp(floor, ceil);
        self.cur = (d * 2).min(ceil);
        d
    }

    /// The coordinator's publish hint advanced: poll at the floor again.
    fn reset(&mut self, floor: Duration) {
        self.cur = floor;
    }
}

/// Coordinator-side pull-plane counters, shared by every pull-serve
/// thread and the `/stats` closure.  `resp_bytes` vs
/// `dense_equiv_bytes` is the live form of the `delta_pull_bytes`
/// bench gate: encoded block bytes actually sent vs what the same
/// blocks would have cost fully dense.
#[derive(Default)]
struct PullServeStats {
    /// `PullReq` frames answered.
    rounds: AtomicU64,
    /// Rounds whose response carried no blocks (idle polls).
    empty: AtomicU64,
    /// Blocks shipped dense / as sparse deltas.
    dense_blocks: AtomicU64,
    sparse_blocks: AtomicU64,
    /// Encoded `PullResp` block bytes, and their all-dense equivalent.
    resp_bytes: AtomicU64,
    dense_equiv_bytes: AtomicU64,
}

// ---------------------------------------------------------------------
// Rank liveness (serve side)
// ---------------------------------------------------------------------

/// Rank states on the coordinator's liveness board.
const RANK_ALIVE: usize = 0;
/// Control stream lost (or heartbeat deadline missed); under
/// `failure=restart` the slot waits for a rejoin.
const RANK_DEAD: usize = 1;
/// Evicted under `failure=degrade`: lanes closed, parked purged, the
/// run completes on the survivors.
const RANK_EVICTED: usize = 2;
/// `WorkerDone` received.
const RANK_DONE: usize = 3;

fn rank_state_name(state: usize) -> &'static str {
    match state {
        RANK_ALIVE => "alive",
        RANK_DEAD => "dead",
        RANK_EVICTED => "evicted",
        RANK_DONE => "done",
        _ => "unknown",
    }
}

/// Per-rank liveness slot: last frame seen on the control stream
/// (milliseconds since serve start), heartbeat count, state.
struct RankSlot {
    last_seen_ms: AtomicU64,
    beats: AtomicU64,
    state: AtomicUsize,
}

/// The coordinator's liveness board, shared by the control-stream
/// readers (writers), the monitor loop (deadline scans, transitions)
/// and the `/healthz` closure (readers).  Sized at the join barrier —
/// `/healthz` before that reports `"starting"`.
struct RankBoard {
    start: Instant,
    slots: OnceLock<Vec<RankSlot>>,
}

impl RankBoard {
    fn new() -> Self {
        RankBoard { start: Instant::now(), slots: OnceLock::new() }
    }

    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Size the board once the barrier knows `n_ranks`; every rank
    /// starts alive with a fresh last-seen stamp.
    fn init(&self, n_ranks: usize) {
        let now = self.now_ms();
        let _ = self.slots.set(
            (0..n_ranks)
                .map(|_| RankSlot {
                    last_seen_ms: AtomicU64::new(now),
                    beats: AtomicU64::new(0),
                    state: AtomicUsize::new(RANK_ALIVE),
                })
                .collect(),
        );
    }

    /// A control frame arrived from `rank`; `heartbeat` distinguishes
    /// Heartbeat frames (counted) from other traffic (stamp only).
    fn seen(&self, rank: usize, heartbeat: bool) {
        if let Some(s) = self.slots.get() {
            s[rank].last_seen_ms.store(self.now_ms(), Ordering::Release);
            if heartbeat {
                s[rank].beats.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn set_state(&self, rank: usize, state: usize) {
        if let Some(s) = self.slots.get() {
            s[rank].state.store(state, Ordering::Release);
        }
    }

    fn state(&self, rank: usize) -> usize {
        self.slots.get().map_or(RANK_ALIVE, |s| s[rank].state.load(Ordering::Acquire))
    }

    /// Milliseconds since the rank's last control frame.
    fn age_ms(&self, rank: usize) -> u64 {
        self.slots
            .get()
            .map_or(0, |s| self.now_ms().saturating_sub(s[rank].last_seen_ms.load(Ordering::Acquire)))
    }

    /// The `/healthz` body: per-rank liveness detail plus an overall
    /// status — `"degraded"` the moment any rank is dead or evicted.
    fn health_json(&self) -> Json {
        let Some(slots) = self.slots.get() else {
            return obj(vec![
                ("status", Json::Str("starting".into())),
                ("ranks", Json::Arr(Vec::new())),
                ("survivors", num(0.0)),
                ("evicted", num(0.0)),
            ]);
        };
        let mut ranks = Vec::with_capacity(slots.len());
        let (mut survivors, mut evicted) = (0usize, 0usize);
        for (rank, _) in slots.iter().enumerate() {
            let state = self.state(rank);
            match state {
                RANK_ALIVE | RANK_DONE => survivors += 1,
                RANK_EVICTED => evicted += 1,
                _ => {}
            }
            ranks.push(obj(vec![
                ("rank", num(rank as f64)),
                ("state", Json::Str(rank_state_name(state).into())),
                ("last_heartbeat_ms", num(self.age_ms(rank) as f64)),
                ("heartbeats", num(slots[rank].beats.load(Ordering::Relaxed) as f64)),
            ]));
        }
        let status = if survivors == slots.len() { "ok" } else { "degraded" };
        obj(vec![
            ("status", Json::Str(status.into())),
            ("ranks", Json::Arr(ranks)),
            ("survivors", num(survivors as f64)),
            ("evicted", num(evicted as f64)),
        ])
    }
}

/// Everything the monitor loop reacts to, from every source: control
/// readers (`Done`/`Dead`), the late-control drain (`Rejoin`), and the
/// `POST /config` hook (`Config`).
enum CtlEvent {
    Done { rank: usize, pushes: u64, rounds: u64, empty: u64 },
    Dead { rank: usize },
    Rejoin { rank: usize, stream: TcpStream },
    Config { kv: String },
}

/// Serve-side hot-reloadable knobs (the worker-side ones republish via
/// `ConfigUpdate` and live in [`PullTuning`] over there).
struct ServeTuning {
    rebalance_ms: AtomicU64,
    net_liveness_ms: AtomicU64,
}

/// The workers a rank runs: `w ≡ rank (mod n_ranks)`.
fn rank_workers(rank: usize, n_ranks: usize, n_workers: usize) -> impl Iterator<Item = usize> {
    (0..n_workers).filter(move |w| w % n_ranks == rank)
}

/// Mirror the drained fault events into the log the `/stats` and
/// `/healthz` closures read.
fn drain_faults(plan: &FaultPlan, log: &Mutex<Vec<String>>) {
    let events = plan.take_events();
    if !events.is_empty() {
        let mut log = log.lock().unwrap();
        for ev in events {
            log.push(ev.describe());
        }
    }
}

/// Per-lane in-flight cap for the multi-process transport: the global
/// budget [`push_inflight`] split per worker, floored so a lane can
/// always hold a frame plus a partial batch.  Serve and work compute
/// this independently from the same config — the two sides' credit
/// windows must agree.
fn lane_cap(cfg: &Config) -> usize {
    push_inflight(cfg.n_workers).div_ceil(cfg.n_workers.max(1)).max(2)
}

/// Generate or load the dataset + shards for a config (the `main.rs`
/// helper, duplicated here because the binary crate's items are not
/// visible to the library).  Deterministic for synthetic specs: every
/// process regenerates identical shards from the config seed.
fn load_data(cfg: &Config) -> Result<(Dataset, Vec<WorkerShard>)> {
    match &cfg.data_path {
        Some(path) => {
            let ds = load_libsvm(path, cfg.loss, cfg.block_size)?;
            let shards = partition_even(&ds, cfg.n_workers);
            Ok((ds, shards))
        }
        None => Ok(gen_partitioned(&cfg.synth_spec(), cfg.n_workers)),
    }
}

fn build_config(p: &Parsed) -> Result<Config> {
    let mut cfg = Config::default();
    let file = p.get("config");
    if !file.is_empty() {
        cfg.apply_file(std::path::Path::new(file))?;
    }
    for kv in p.get("set").split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got {kv:?}"))?;
        cfg.apply_kv(k, v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

// ---------------------------------------------------------------------
// Handshake payloads
// ---------------------------------------------------------------------

/// The `Welcome` config body: non-default keys as `key=value` lines.
fn config_kv_text(cfg: &Config) -> String {
    cfg.to_kv().iter().map(|(k, v)| format!("{k}={v}\n")).collect()
}

/// Per-worker resume state shipped in a rejoin `Welcome`
/// (`failure=restart`): the crashed worker's last applied seq per slot
/// (the gate accepts `seq + 1` next), the epochs it completed, and
/// warm duals y ≈ w̃ − ρ·z̃ derived from server state.
#[derive(Debug, PartialEq)]
struct ResumeEntry {
    worker: usize,
    start_epoch: usize,
    /// Last applied seq per slot, `shard.active_blocks` order.
    seqs: Vec<u64>,
    /// Packed warm duals, `n_slots × block_size`.
    duals: Vec<f32>,
}

fn encode_welcome(cfg: &Config, owners: &[usize], map_version: u64) -> Vec<u8> {
    encode_welcome_resume(cfg, owners, map_version, &[])
}

fn encode_welcome_resume(
    cfg: &Config,
    owners: &[usize],
    map_version: u64,
    resume: &[ResumeEntry],
) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_str(&mut p, &config_kv_text(cfg));
    wire::put_u32(&mut p, owners.len() as u32);
    for &s in owners {
        wire::put_u32(&mut p, s as u32);
    }
    wire::put_u64(&mut p, map_version);
    wire::put_u32(&mut p, resume.len() as u32);
    for e in resume {
        wire::put_u32(&mut p, e.worker as u32);
        wire::put_u64(&mut p, e.start_epoch as u64);
        wire::put_u32(&mut p, e.seqs.len() as u32);
        for &s in &e.seqs {
            wire::put_u64(&mut p, s);
        }
        wire::put_u32(&mut p, e.duals.len() as u32);
        wire::put_f32s(&mut p, &e.duals);
    }
    p
}

fn decode_welcome(payload: &[u8]) -> Result<(Config, Vec<usize>, u64, Vec<ResumeEntry>)> {
    let mut cur = wire::Cursor::new(kind::WELCOME, payload)?;
    let kv = cur.str("config")?.to_string();
    let n_blocks = cur.u32("n_blocks")? as usize;
    let mut owners = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        owners.push(cur.u32("owner")? as usize);
    }
    let map_version = cur.u64("map_version")?;
    let n_resume = cur.u32("n_resume")? as usize;
    let mut resume = Vec::with_capacity(n_resume.min(64));
    for _ in 0..n_resume {
        let worker = cur.u32("worker")? as usize;
        let start_epoch = cur.u64("start_epoch")? as usize;
        let n_slots = cur.u32("n_slots")? as usize;
        let mut seqs = Vec::with_capacity(n_slots.min(4096));
        for _ in 0..n_slots {
            seqs.push(cur.u64("next_seq")?);
        }
        let n_duals = cur.u32("n_duals")? as usize;
        anyhow::ensure!(
            n_duals <= wire::MAX_FRAME / 4,
            "Welcome resume entry for worker {worker}: absurd dual count {n_duals}"
        );
        let mut duals = vec![0.0f32; n_duals];
        cur.f32s_into(&mut duals, "duals")?;
        resume.push(ResumeEntry { worker, start_epoch, seqs, duals });
    }
    cur.finish()?;
    let mut cfg = Config::default();
    for line in kv.lines().filter(|l| !l.trim().is_empty()) {
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("Welcome config line {line:?}"))?;
        cfg.apply_kv(k, v)?;
    }
    // The coordinator owns the observability endpoint; a worker process
    // re-binding the same stats address would double it up.  Of the
    // fault plan, only the worker-side *wire* kinds survive the
    // handshake — crash/stall/sendfail are in-process kinds and the
    // coordinator keeps `corrupt:`, so re-injecting them here would
    // double-fire the plan.
    cfg.stats_addr.clear();
    cfg.faults = FaultPlan::worker_net_spec(&cfg.faults);
    cfg.validate()?;
    anyhow::ensure!(
        cfg.n_blocks == n_blocks,
        "Welcome owner map covers {n_blocks} blocks, config says {}",
        cfg.n_blocks
    );
    anyhow::ensure!(
        owners.iter().all(|&s| s < cfg.n_servers),
        "Welcome owner map references a server shard >= {}",
        cfg.n_servers
    );
    for e in &resume {
        anyhow::ensure!(
            e.worker < cfg.n_workers,
            "Welcome resume entry references worker {} of {}",
            e.worker,
            cfg.n_workers
        );
        anyhow::ensure!(
            e.duals.len() == e.seqs.len() * cfg.block_size,
            "Welcome resume entry for worker {}: {} duals for {} slots of size {}",
            e.worker,
            e.duals.len(),
            e.seqs.len(),
            cfg.block_size
        );
    }
    Ok((cfg, owners, map_version, resume))
}

fn parse_rank(s: &str) -> Result<(usize, usize)> {
    let (r, n) = s
        .split_once('/')
        .with_context(|| format!("--rank {s:?}: expected R/N (e.g. 0/2)"))?;
    let r: usize = r.trim().parse().with_context(|| format!("--rank {s:?}: bad rank"))?;
    let n: usize =
        n.trim().parse().with_context(|| format!("--rank {s:?}: bad rank count"))?;
    anyhow::ensure!(n >= 1 && r < n, "--rank {s}: rank must be in 0..{n}");
    Ok((r, n))
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

/// `asybadmm serve` entry point.
pub fn serve_main(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "coordinator process: server shards + BlockTable + rebalancer; \
         worker processes join over TCP (`asybadmm work`)",
    )
    .opt("listen", "127.0.0.1:0", "listen address (host:port; port 0 picks one)")
    .opt("config", "", "config file (TOML-subset key = value)")
    .opt(
        "set",
        "",
        "comma-separated key=value config overrides (same keys as `asybadmm \
         train`, e.g. stats_addr=HOST:PORT, placement=dynamic, batch=N; an \
         unknown key lists all valid keys)",
    )
    .parse_from(argv);
    let mut cfg = build_config(&p)?;
    // The multi-process runtime IS the tcp transport; pin the canonical
    // value so the shipped kv text says what actually runs.
    cfg.transport = TransportKind::Tcp;
    serve(&cfg, p.get("listen"))
}

fn serve(cfg: &Config, listen: &str) -> Result<()> {
    let (ds, shards) = load_data(cfg)?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    let placement = make_placement(cfg.placement);
    let topo = Topology::build_with(&shards, cfg.n_blocks, cfg.n_servers, placement.as_ref());
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    let kernels = Kernels::select(cfg.kernel);
    let dynamic = cfg.placement == PlacementKind::Dynamic;
    let table = Arc::new(BlockTable::with_kernels(
        &topo,
        store.clone(),
        problem,
        cfg.rho,
        cfg.gamma,
        kernels,
    ));
    let map = Arc::new(BlockMap::new(&topo.server_of_block));
    let manifest: Arc<Option<Manifest>> = Arc::new(match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    });

    // Warm-start from a periodic checkpoint left by a previous serve
    // run: restore the consensus z̃ and the owner map (model state; the
    // epoch budget restarts — module docs).  Geometry mismatches skip
    // the resume rather than corrupt the run.
    let mut resume_epoch = 0usize;
    if cfg.checkpoint_every > 0 && cfg.checkpoint_path.exists() {
        match Checkpoint::load(&cfg.checkpoint_path) {
            Ok(ck) if ck.n_blocks == cfg.n_blocks && ck.block_size == cfg.block_size => {
                for (j, block) in ck.z.chunks(cfg.block_size).enumerate() {
                    store.write_versioned(j, block, 1);
                }
                for (j, &owner) in ck.block_owners.iter().enumerate() {
                    if owner < cfg.n_servers && j < cfg.n_blocks {
                        map.set_owner(j, owner);
                    }
                }
                resume_epoch = ck.epoch;
                println!(
                    "# resumed from checkpoint {} (epoch {}, objective {:.6})",
                    cfg.checkpoint_path.display(),
                    ck.epoch,
                    ck.objective
                );
            }
            Ok(ck) => eprintln!(
                "checkpoint {} is {}x{}, config wants {}x{}; starting cold",
                cfg.checkpoint_path.display(),
                ck.n_blocks,
                ck.block_size,
                cfg.n_blocks,
                cfg.block_size
            ),
            Err(e) => {
                eprintln!("checkpoint {} unreadable ({e:#}); starting cold", cfg.checkpoint_path.display())
            }
        }
    }

    // Serve-side fault plan: `corrupt:` entries fire on the pull
    // streams here; the worker-side wire kinds ship via the Welcome.
    let plan = Arc::new(FaultPlan::parse(&cfg.faults)?);
    let fault_log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let board = Arc::new(RankBoard::new());
    let tuning = Arc::new(ServeTuning {
        rebalance_ms: AtomicU64::new(cfg.rebalance_ms.max(1)),
        net_liveness_ms: AtomicU64::new(cfg.net_liveness_ms),
    });

    let transport =
        TcpTransport::bind(listen, cfg.n_workers, cfg.n_servers, lane_cap(cfg), cfg.batch)?;
    let (ctl_tx, ctl_rx) = channel::<CtlConn>();
    transport.set_ctl_hook(ctl_tx);
    // Every z̃ publish bumps this counter; receivers piggyback it on
    // Credit frames so idle workers snap their pull cadence back down.
    transport.set_version_hint(store.publish_counter());
    let pull_stats = Arc::new(PullServeStats::default());
    // The monitor reacts to everything through one channel: Done/Dead
    // from control readers, Rejoin from the late-control drain, Config
    // from the POST /config hook.
    let (events_tx, events_rx) = channel::<CtlEvent>();
    println!("# {}", cfg.summary());
    println!("# dataset {}: m={} d={} nnz={}", ds.name, ds.samples(), ds.dim(), ds.a.nnz());
    // Parsed by `asybadmm work` launchers and tests/netproc.rs; Rust
    // stdout is line-buffered even when piped, so these appear live.
    println!("# listening on {}", transport.local_addr());

    let _stats_server = if cfg.stats_addr.is_empty() {
        None
    } else {
        let table = table.clone();
        let map = map.clone();
        let n_servers = cfg.n_servers;
        let wire_ctr = transport.wire_counters();
        let pull_stats = pull_stats.clone();
        let health: HealthFn = {
            let board = board.clone();
            Arc::new(move || board.health_json())
        };
        // POST /config: validate every line against the reloadable
        // whitelist on a scratch copy first (all-or-nothing), then
        // flip the serve-side atomics and hand the kv text to the
        // monitor for ConfigUpdate republish.
        let config_hook: ConfigFn = {
            let tuning = tuning.clone();
            let events = Mutex::new(events_tx.clone());
            let scratch = cfg.clone();
            Arc::new(move |body: &str| {
                let mut probe = scratch.clone();
                let mut applied = Vec::new();
                for line in body.lines().map(str::trim).filter(|l| !l.is_empty()) {
                    let (k, v) = line.split_once('=').with_context(|| {
                        format!("config line {line:?}: expected key=value")
                    })?;
                    probe.apply_reload_kv(k.trim(), v.trim())?;
                    applied.push((k.trim().to_string(), v.trim().to_string()));
                }
                anyhow::ensure!(!applied.is_empty(), "empty config body (key=value lines)");
                probe.validate()?;
                tuning.rebalance_ms.store(probe.rebalance_ms.max(1), Ordering::Relaxed);
                tuning.net_liveness_ms.store(probe.net_liveness_ms, Ordering::Relaxed);
                let kv: String =
                    applied.iter().map(|(k, v)| format!("{k}={v}\n")).collect();
                let _ = events.lock().unwrap().send(CtlEvent::Config { kv });
                Ok(format!(
                    "applied: {}",
                    applied
                        .iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                ))
            })
        };
        let fault_log = fault_log.clone();
        let server = StatsServer::spawn_with(
            &cfg.stats_addr,
            Arc::new(move || {
                let counts = table.push_counts();
                let owners = map.snapshot();
                let mut shard_load = vec![0usize; n_servers];
                for (j, &c) in counts.iter().enumerate() {
                    shard_load[owners[j]] += c;
                }
                let w = wire_ctr.snapshot();
                let p = &pull_stats;
                obj(vec![
                    ("pushes_total", num(counts.iter().sum::<usize>() as f64)),
                    ("push_counts", Json::Arr(counts.iter().map(|&c| num(c as f64)).collect())),
                    ("placement", Json::Arr(owners.iter().map(|&o| num(o as f64)).collect())),
                    (
                        "shard_load",
                        Json::Arr(shard_load.iter().map(|&l| num(l as f64)).collect()),
                    ),
                    ("map_version", num(map.version() as f64)),
                    ("migrations", num(map.migrations() as f64)),
                    (
                        "wire",
                        obj(vec![
                            ("push_frames_in", num(w.push_frames_in as f64)),
                            ("push_bytes_in", num(w.push_bytes_in as f64)),
                            ("msgs_in", num(w.msgs_in as f64)),
                            ("credit_frames_out", num(w.credit_frames_out as f64)),
                            ("credits_out", num(w.credits_out as f64)),
                        ]),
                    ),
                    (
                        "pull",
                        obj(vec![
                            ("rounds", num(p.rounds.load(Ordering::Relaxed) as f64)),
                            ("empty_rounds", num(p.empty.load(Ordering::Relaxed) as f64)),
                            ("dense_blocks", num(p.dense_blocks.load(Ordering::Relaxed) as f64)),
                            (
                                "sparse_blocks",
                                num(p.sparse_blocks.load(Ordering::Relaxed) as f64),
                            ),
                            ("resp_bytes", num(p.resp_bytes.load(Ordering::Relaxed) as f64)),
                            (
                                "dense_equiv_bytes",
                                num(p.dense_equiv_bytes.load(Ordering::Relaxed) as f64),
                            ),
                        ]),
                    ),
                    // Fault events drained by the monitor (evictions,
                    // rejoins, corrupt frames) — same schema as the
                    // in-process report.
                    (
                        "faults",
                        Json::Arr(
                            fault_log
                                .lock()
                                .unwrap()
                                .iter()
                                .map(|s| Json::Str(s.clone()))
                                .collect(),
                        ),
                    ),
                ])
            }),
            Some(health),
            Some(config_hook),
        )?;
        println!("# stats on {}", server.addr());
        Some(server)
    };

    // -- server threads (plain spawns, not a scope: any error below
    //    must be able to exit the process without first waiting out a
    //    drain loop that only a clean shutdown unblocks) --------------
    let shard_rts: Arc<Vec<ShardRt>> = Arc::new(
        (0..cfg.n_servers)
            .map(|sid| {
                let shard = ServerShard::with_table(sid, &topo, table.clone(), !dynamic);
                ShardRt::new(shard, &transport)
            })
            .collect(),
    );
    let n_threads = if cfg.server_threads == 0 { cfg.n_servers } else { cfg.server_threads };
    let mut server_handles = Vec::with_capacity(n_threads);
    for tid in 0..n_threads {
        let rts = shard_rts.clone();
        let manifest = manifest.clone();
        let (drain, n_servers, block_size) = (cfg.drain, cfg.n_servers, cfg.block_size);
        server_handles.push(
            std::thread::Builder::new()
                .name(format!("server-{tid}"))
                .spawn(move || {
                    let prox = match &*manifest {
                        None => ProxBackend::Native,
                        Some(m) => match ServerProxXla::load(m, block_size) {
                            Ok(p) => ProxBackend::Xla(p),
                            Err(e) => {
                                eprintln!(
                                    "server thread {tid}: XLA prox unavailable ({e:#}); native fallback"
                                );
                                ProxBackend::Native
                            }
                        },
                    };
                    if n_threads == n_servers {
                        run_server(&rts, tid, drain, &prox).expect("server loop failed");
                    } else {
                        run_pool(&rts, tid, &prox).expect("server pool loop failed");
                    }
                })
                .context("spawn server thread")?,
        );
    }

    // -- join barrier: every rank sends JoinCtl, gets Welcome ----------
    let join_timeout = Duration::from_millis(cfg.join_timeout_ms.max(1));
    let mut n_ranks: Option<usize> = None;
    let mut joined: Vec<Option<TcpStream>> = Vec::new();
    let mut joined_count = 0usize;
    while n_ranks.map_or(true, |n| joined_count < n) {
        let conn = match ctl_rx.recv_timeout(join_timeout) {
            Ok(conn) => conn,
            Err(RecvTimeoutError::Timeout) => {
                let missing = match n_ranks {
                    None => "every rank (none joined yet)".to_string(),
                    Some(_) => format!(
                        "rank(s) [{}]",
                        joined
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| s.is_none())
                            .map(|(r, _)| r.to_string())
                            .collect::<Vec<_>>()
                            .join(", ")
                    ),
                };
                bail!(
                    "join barrier timed out after {}ms waiting for {missing} \
                     ({joined_count} rank(s) connected so far); start \
                     `asybadmm work --connect {} --rank R/N`, or raise \
                     --set join_timeout_ms=MS",
                    cfg.join_timeout_ms,
                    transport.local_addr()
                )
            }
            Err(RecvTimeoutError::Disconnected) => {
                bail!("control channel closed before all ranks joined")
            }
        };
        match conn.kind {
            kind::JOIN_CTL => {
                let mut cur = wire::Cursor::new(kind::JOIN_CTL, &conn.payload)?;
                let rank = cur.u32("rank")? as usize;
                let ranks = cur.u32("n_ranks")? as usize;
                cur.finish()?;
                anyhow::ensure!(
                    ranks >= 1 && ranks <= cfg.n_workers,
                    "JoinCtl: n_ranks {ranks} outside 1..={} (every rank needs a worker)",
                    cfg.n_workers
                );
                anyhow::ensure!(rank < ranks, "JoinCtl: rank {rank} out of range 0..{ranks}");
                match n_ranks {
                    None => {
                        n_ranks = Some(ranks);
                        joined.resize_with(ranks, || None);
                    }
                    Some(n) => anyhow::ensure!(
                        n == ranks,
                        "JoinCtl: rank {rank} claims {ranks} ranks, first join said {n}"
                    ),
                }
                anyhow::ensure!(joined[rank].is_none(), "rank {rank} joined twice");
                let mut stream = conn.stream;
                wire::write_frame(
                    &mut stream,
                    kind::WELCOME,
                    &encode_welcome(cfg, &map.snapshot(), map.version()),
                )
                .with_context(|| format!("sending Welcome to rank {rank}"))?;
                info!("serve", "rank {rank}/{ranks} joined");
                joined[rank] = Some(stream);
                joined_count += 1;
            }
            // A rank's mirror-sync stream may open before the last rank
            // joins; serve it right away.
            kind::HELLO_PULL => spawn_pull_thread(
                conn.stream,
                &conn.payload,
                store.clone(),
                pull_stats.clone(),
                plan.clone(),
            ),
            other => bail!("unexpected {} frame on the control plane", wire::kind_name(other)),
        }
    }
    let n_ranks = match n_ranks {
        Some(n) => n,
        None => bail!("join barrier ended with no ranks joined"),
    };
    board.init(n_ranks);

    // Late control connections drain on their own thread for the rest
    // of the run: pull streams are served directly; a late JoinCtl is a
    // rejoin attempt and routes to the monitor (`failure=restart`).
    let stop_ctl = Arc::new(AtomicBool::new(false));
    let ctl_drain = {
        let store = store.clone();
        let stats = pull_stats.clone();
        let stop = stop_ctl.clone();
        let plan = plan.clone();
        let events = events_tx.clone();
        std::thread::Builder::new()
            .name("ctl-drain".into())
            .spawn(move || loop {
                match ctl_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(conn) if conn.kind == kind::HELLO_PULL => spawn_pull_thread(
                        conn.stream,
                        &conn.payload,
                        store.clone(),
                        stats.clone(),
                        plan.clone(),
                    ),
                    Ok(conn) if conn.kind == kind::JOIN_CTL => {
                        let parsed = (|| -> Result<usize> {
                            let mut cur = wire::Cursor::new(kind::JOIN_CTL, &conn.payload)?;
                            let rank = cur.u32("rank")? as usize;
                            let ranks = cur.u32("n_ranks")? as usize;
                            cur.finish()?;
                            anyhow::ensure!(
                                ranks == n_ranks && rank < n_ranks,
                                "rejoin JoinCtl: rank {rank}/{ranks} against a {n_ranks}-rank run"
                            );
                            Ok(rank)
                        })();
                        match parsed {
                            Ok(rank) => {
                                let _ = events.send(CtlEvent::Rejoin { rank, stream: conn.stream });
                            }
                            Err(e) => eprintln!("rejoin refused: {e:#}"),
                        }
                    }
                    Ok(conn) => {
                        eprintln!("late {} connection refused", wire::kind_name(conn.kind))
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            })
            .context("spawn control drain thread")?
    };

    // Split each rank's control stream: the read half waits for
    // WorkerDone (updating the liveness board on every Heartbeat), the
    // write half carries OwnerUpdate/ConfigUpdate republishes.
    let mut ctl_writers = Vec::with_capacity(n_ranks);
    for (rank, slot) in joined.into_iter().enumerate() {
        let stream = match slot {
            Some(s) => s,
            None => bail!("join barrier ended with rank {rank} missing"),
        };
        ctl_writers.push(stream.try_clone().context("clone control stream")?);
        let events = events_tx.clone();
        let board = board.clone();
        std::thread::Builder::new()
            .name(format!("ctl-rank-{rank}"))
            .spawn(move || ctl_read_loop(rank, stream, events, board))
            .context("spawn control reader")?;
    }

    // -- monitor: liveness, evictions, rejoins, rebalancer, checkpoints
    let start = Instant::now();
    let mut rebalancer = (dynamic && cfg.n_servers > 1)
        .then(|| Rebalancer::new(map.clone(), table.clone(), cfg.n_servers));
    let mut last_scan = Instant::now();
    let mut owners_prev = map.snapshot();
    let tick = Duration::from_millis(cfg.rebalance_ms.clamp(5, 100));
    // `finished` counts done AND evicted ranks — both end the wait.
    let mut finished = 0usize;
    let mut evicted = 0usize;
    let mut rejoin_attempts = vec![0usize; n_ranks];
    let mut config_version = 0u64;
    let mut sent_total = 0u64;
    let (mut pull_rounds_total, mut pull_empty_total) = (0u64, 0u64);
    let ckpt_every = if cfg.checkpoint_every > 0 { cfg.checkpoint_every } else { usize::MAX };
    let mut next_ckpt = resume_epoch.saturating_add(ckpt_every);
    while finished < n_ranks {
        match events_rx.recv_timeout(tick) {
            Ok(CtlEvent::Done { rank, pushes, rounds, empty }) => {
                // An evicted rank's stale Done (it was mid-teardown as
                // the deadline fired) must not double-count.
                if board.state(rank) == RANK_ALIVE {
                    board.set_state(rank, RANK_DONE);
                    finished += 1;
                    sent_total += pushes;
                    pull_rounds_total += rounds;
                    pull_empty_total += empty;
                    info!(
                        "serve",
                        "rank {rank} done ({pushes} pushes, {rounds} pull rounds ({empty} \
                         empty); {finished}/{n_ranks} ranks)"
                    );
                }
            }
            Ok(CtlEvent::Dead { rank }) => {
                if board.state(rank) == RANK_ALIVE {
                    match cfg.failure {
                        FailurePolicy::Die => bail!(
                            "rank {rank} died without finishing (control stream lost); rerun \
                             with --set failure=degrade|restart to survive worker loss"
                        ),
                        FailurePolicy::Degrade => {
                            evict_rank(
                                rank, "lost its control stream", cfg, n_ranks, &transport,
                                &table, &shards, &plan, &board,
                            );
                            finished += 1;
                            evicted += 1;
                        }
                        FailurePolicy::Restart => {
                            board.set_state(rank, RANK_DEAD);
                            board.seen(rank, false); // stamp death for the rejoin deadline
                            info!(
                                "serve",
                                "rank {rank} died; failure=restart — waiting for a replacement \
                                 (`asybadmm work --connect {} --rank {rank}/{n_ranks}`)",
                                transport.local_addr()
                            );
                        }
                    }
                }
            }
            Ok(CtlEvent::Rejoin { rank, stream }) => {
                if cfg.failure != FailurePolicy::Restart {
                    eprintln!(
                        "rank {rank} attempted rejoin, but rejoin needs --set failure=restart; \
                         refusing"
                    );
                } else if board.state(rank) != RANK_DEAD {
                    eprintln!(
                        "rank {rank} attempted rejoin while {}; refusing",
                        rank_state_name(board.state(rank))
                    );
                } else {
                    rejoin_attempts[rank] += 1;
                    // Tail drain: TCP kernel buffers survive process
                    // death, so the crashed streams' applied prefix is
                    // contiguous; wait for the seq gates to go quiet
                    // before reading the resume point.
                    wait_seq_quiesce(&table, &shards, rank, n_ranks);
                    let resume: Vec<ResumeEntry> = shards
                        .iter()
                        .filter(|sh| sh.worker_id % n_ranks == rank)
                        .map(|sh| {
                            let seqs: Vec<u64> = sh
                                .active_blocks
                                .iter()
                                .map(|&j| table.next_seq(j, sh.worker_id).saturating_sub(1))
                                .collect();
                            let ledger: Vec<AtomicU64> =
                                seqs.iter().map(|&s| AtomicU64::new(s)).collect();
                            let duals = approx_duals(&table, &store, sh, &ledger, cfg.rho);
                            let start_epoch = seqs.iter().sum::<u64>() as usize;
                            ResumeEntry { worker: sh.worker_id, start_epoch, seqs, duals }
                        })
                        .collect();
                    let mut stream = stream;
                    let welcome =
                        encode_welcome_resume(cfg, &map.snapshot(), map.version(), &resume);
                    if let Err(e) = wire::write_frame(&mut stream, kind::WELCOME, &welcome) {
                        eprintln!("rank {rank} rejoin: Welcome failed ({e:#}); still waiting");
                    } else {
                        match stream.try_clone() {
                            Ok(writer) => {
                                ctl_writers[rank] = writer;
                                let events = events_tx.clone();
                                let board2 = board.clone();
                                std::thread::Builder::new()
                                    .name(format!("ctl-rank-{rank}"))
                                    .spawn(move || ctl_read_loop(rank, stream, events, board2))
                                    .context("spawn rejoin control reader")?;
                                board.seen(rank, false);
                                board.set_state(rank, RANK_ALIVE);
                                plan.record(FaultEvent::RankRejoined {
                                    rank,
                                    attempt: rejoin_attempts[rank],
                                });
                                let resumed: u64 =
                                    resume.iter().flat_map(|e| e.seqs.iter()).sum();
                                info!(
                                    "serve",
                                    "rank {rank} rejoined (attempt {}): resuming past {} \
                                     applied pushes",
                                    rejoin_attempts[rank],
                                    resumed
                                );
                            }
                            Err(e) => eprintln!(
                                "rank {rank} rejoin: clone control stream failed ({e}); \
                                 still waiting"
                            ),
                        }
                    }
                }
            }
            Ok(CtlEvent::Config { kv }) => {
                config_version += 1;
                let mut p = Vec::with_capacity(kv.len() + 12);
                wire::put_u64(&mut p, config_version);
                wire::put_str(&mut p, &kv);
                // A rank that already finished may have closed its
                // stream; EPIPE here is not an error.
                for (rank, w) in ctl_writers.iter_mut().enumerate() {
                    if board.state(rank) == RANK_ALIVE {
                        let _ = wire::write_frame(w, kind::CONFIG_UPDATE, &p);
                    }
                }
                info!(
                    "serve",
                    "config v{config_version} applied and republished: {}",
                    kv.replace('\n', " ")
                );
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                bail!("monitor event channel closed unexpectedly")
            }
        }

        // Heartbeat deadline scan: a rank silent past net_liveness_ms
        // is dead even if its socket is technically open (SIGSTOP, a
        // wedged peer, a one-way partition).  Dead ranks under
        // failure=restart get join_timeout_ms to produce a rejoin.
        let liveness = tuning.net_liveness_ms.load(Ordering::Relaxed);
        for rank in 0..n_ranks {
            let state = board.state(rank);
            if state == RANK_ALIVE && liveness > 0 && board.age_ms(rank) > liveness {
                match cfg.failure {
                    FailurePolicy::Die => bail!(
                        "rank {rank} missed its liveness deadline ({}ms silent > \
                         net_liveness_ms={liveness}); rerun with --set \
                         failure=degrade|restart to survive worker loss",
                        board.age_ms(rank)
                    ),
                    FailurePolicy::Degrade => {
                        evict_rank(
                            rank, "missed its liveness deadline", cfg, n_ranks, &transport,
                            &table, &shards, &plan, &board,
                        );
                        finished += 1;
                        evicted += 1;
                    }
                    FailurePolicy::Restart => {
                        board.set_state(rank, RANK_DEAD);
                        board.seen(rank, false);
                        info!(
                            "serve",
                            "rank {rank} missed its liveness deadline; failure=restart — \
                             waiting for a replacement"
                        );
                    }
                }
            } else if state == RANK_DEAD && board.age_ms(rank) > cfg.join_timeout_ms.max(1) {
                bail!(
                    "rank {rank} died and no replacement rejoined within \
                     join_timeout_ms={}; start `asybadmm work --connect {} \
                     --rank {rank}/{n_ranks}` sooner or raise the timeout",
                    cfg.join_timeout_ms,
                    transport.local_addr()
                );
            }
        }

        if let Some(rb) = rebalancer.as_mut() {
            // Cadence is hot-reloadable (POST /config rebalance_ms=…).
            let rebalance_every =
                Duration::from_millis(tuning.rebalance_ms.load(Ordering::Relaxed).max(1));
            if last_scan.elapsed() >= rebalance_every {
                rb.scan();
                last_scan = Instant::now();
                let changed = map.diff(&owners_prev);
                if !changed.is_empty() {
                    let version = map.version();
                    for &(j, s) in &changed {
                        owners_prev[j] = s;
                        let mut p = Vec::with_capacity(16);
                        wire::put_u32(&mut p, j as u32);
                        wire::put_u32(&mut p, s as u32);
                        wire::put_u64(&mut p, version);
                        // A rank that already finished may have closed
                        // its stream; EPIPE here is not an error.
                        for w in ctl_writers.iter_mut() {
                            let _ = wire::write_frame(w, kind::OWNER_UPDATE, &p);
                        }
                    }
                }
            }
        }

        // Periodic v2 checkpoint off the monitor loop: the epoch
        // estimate is total applied pushes over n_workers (each worker
        // pushes once per epoch).
        if ckpt_every != usize::MAX {
            let applied: usize = table.push_counts().iter().sum();
            let epoch_est = resume_epoch + applied / cfg.n_workers.max(1);
            if epoch_est >= next_ckpt {
                let ledgers = pseudo_ledgers(&shards, &table);
                let ck = snapshot_checkpoint(
                    cfg, &shards, &store, &table, &map, &ledgers, &problem, weight, epoch_est,
                );
                match ck.save(&cfg.checkpoint_path) {
                    Ok(()) => info!(
                        "serve",
                        "checkpoint at epoch ~{epoch_est} -> {}",
                        cfg.checkpoint_path.display()
                    ),
                    Err(e) => eprintln!("checkpoint write failed: {e:#} (continuing)"),
                }
                while next_ckpt <= epoch_est {
                    next_ckpt = next_ckpt.saturating_add(ckpt_every);
                }
            }
        }

        drain_faults(&plan, &fault_log);
    }

    // -- drain + summary ----------------------------------------------
    transport.shutdown();
    for h in server_handles {
        h.join().map_err(|_| anyhow::anyhow!("server thread panicked"))?;
    }
    stop_ctl.store(true, Ordering::Release);
    let _ = ctl_drain.join();
    drain_faults(&plan, &fault_log);
    for line in fault_log.lock().unwrap().iter() {
        println!("# fault: {line}");
    }
    let applied: usize = shard_rts.iter().map(|rt| rt.shard.stats().pushes).sum();
    let final_obj = objective_at_z(&shards, &problem, weight, &store.snapshot());
    println!(
        "# done in {:.3}s: objective {:.6} (data {:.6} + reg {:.6}); pushes={} sent={} \
         migrations={} pull_rounds={} pull_empty={} evicted={}",
        start.elapsed().as_secs_f64(),
        final_obj.total(),
        final_obj.data_loss,
        final_obj.reg,
        applied,
        sent_total,
        map.migrations(),
        pull_rounds_total,
        pull_empty_total,
        evicted
    );
    Ok(())
}

/// Degrade-path eviction: force-close the rank's push lanes (late
/// reconnects refused), let the in-flight tail settle, purge parked
/// early-arrivals so no seq gap blocks the survivors, and record the
/// fault event.  The victim's already-applied pushes stay in the
/// consensus.
#[allow(clippy::too_many_arguments)]
fn evict_rank(
    rank: usize,
    reason: &str,
    cfg: &Config,
    n_ranks: usize,
    transport: &TcpTransport,
    table: &BlockTable,
    shards: &[WorkerShard],
    plan: &FaultPlan,
    board: &RankBoard,
) {
    for w in rank_workers(rank, n_ranks, cfg.n_workers) {
        transport.close_worker_lanes(w);
    }
    // Quiesce before purging: frames already decoded from the dead
    // sockets' kernel buffers keep applying for a moment, and a purge
    // racing them could leave a fresh parked message behind.
    wait_seq_quiesce(table, shards, rank, n_ranks);
    let mut parked = 0usize;
    for w in rank_workers(rank, n_ranks, cfg.n_workers) {
        parked += table.purge_worker_pending(w);
    }
    plan.record(FaultEvent::RankEvicted { rank, parked_dropped: parked });
    board.set_state(rank, RANK_EVICTED);
    eprintln!(
        "rank {rank} {reason}; evicted ({parked} parked pushes dropped), completing on survivors"
    );
}

/// Wait until the seq gates of `rank`'s workers stop advancing (200ms
/// quiet window, 2s bound): the crashed streams' kernel-buffered tail
/// has then been applied and `next_seq` is the exact resume point.
fn wait_seq_quiesce(table: &BlockTable, shards: &[WorkerShard], rank: usize, n_ranks: usize) {
    let snap = || -> Vec<u64> {
        shards
            .iter()
            .filter(|sh| sh.worker_id % n_ranks == rank)
            .flat_map(|sh| {
                sh.active_blocks.iter().map(move |&j| table.next_seq(j, sh.worker_id))
            })
            .collect()
    };
    let deadline = Instant::now() + Duration::from_secs(2);
    let mut prev = snap();
    let mut quiet_since = Instant::now();
    while Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
        let cur = snap();
        if cur != prev {
            prev = cur;
            quiet_since = Instant::now();
        } else if quiet_since.elapsed() >= Duration::from_millis(200) {
            return;
        }
    }
}

/// Server-side stand-in for the worker ledgers (which live in worker
/// processes): per (worker, slot), the last applied seq — what the
/// ledger would read after a clean drain.  Feeds the checkpoint and
/// dual-approximation helpers shared with the in-process monitor.
fn pseudo_ledgers(shards: &[WorkerShard], table: &BlockTable) -> Vec<Vec<AtomicU64>> {
    shards
        .iter()
        .map(|sh| {
            sh.active_blocks
                .iter()
                .map(|&j| AtomicU64::new(table.next_seq(j, sh.worker_id).saturating_sub(1)))
                .collect()
        })
        .collect()
}

fn spawn_pull_thread(
    stream: TcpStream,
    payload: &[u8],
    store: Arc<BlockStore>,
    stats: Arc<PullServeStats>,
    plan: Arc<FaultPlan>,
) {
    // The HelloPull payload carries the requesting rank — only needed
    // to address `corrupt:sS@N` injections, so a malformed hello just
    // disables injection for this stream instead of failing it.
    let rank = (|| -> Result<usize> {
        let mut cur = wire::Cursor::new(kind::HELLO_PULL, payload)?;
        let r = cur.u32("rank")? as usize;
        cur.finish()?;
        Ok(r)
    })()
    .unwrap_or(usize::MAX);
    // Detached: exits on its worker's EOF, reaped at process exit
    // otherwise.
    let _ = std::thread::Builder::new()
        .name("pull-serve".into())
        .spawn(move || pull_serve_loop(stream, store, stats, plan, rank));
}

/// Answer one worker process's `PullReq` stream until it hangs up.
///
/// Delta encoding: the loop mirrors exactly what it last sent for each
/// block.  TCP is reliable and ordered, so whenever a request's
/// `have_version` equals the mirrored version the worker's copy is
/// byte-identical to the mirror, and the block can ship as a sparse
/// (index,value) patch against it when that is smaller
/// ([`wire::sparse_saves_bytes`]).  Any base mismatch — first send on
/// this connection, a reconnect, a worker that skipped a version —
/// falls back to dense, so reconstruction is always exact.
fn pull_serve_loop(
    mut stream: TcpStream,
    store: Arc<BlockStore>,
    stats: Arc<PullServeStats>,
    plan: Arc<FaultPlan>,
    rank: usize,
) {
    let n = store.n_blocks();
    let db = store.block_size();
    let mut block = vec![0.0f32; db];
    let mut resp = Vec::new();
    let mut sent: Vec<Vec<f32>> = vec![Vec::new(); n];
    let mut sent_v = vec![0u64; n];
    let (mut idx, mut vals) = (Vec::new(), Vec::new());
    let mut frames = 0usize;
    loop {
        let payload = match wire::read_frame(&mut stream) {
            Ok(Some((kind::PULL_REQ, p))) => p,
            Ok(Some((k, _))) => {
                eprintln!("pull stream: unexpected {} frame", wire::kind_name(k));
                return;
            }
            Ok(None) | Err(_) => return,
        };
        let built = (|| -> Result<()> {
            let mut cur = wire::Cursor::new(kind::PULL_REQ, &payload)?;
            let req_n = cur.u32("n_blocks")? as usize;
            anyhow::ensure!(req_n == n, "PullReq covers {req_n} blocks, store has {n}");
            resp.clear();
            wire::put_u32(&mut resp, 0); // changed-block count, patched below
            let mut count = 0u32;
            for j in 0..n {
                let have = cur.u64("have_version")?;
                let v = store.read_into(j, &mut block);
                if v <= have {
                    continue;
                }
                let before = resp.len();
                if have > 0 && sent_v[j] == have {
                    wire::diff_block(&sent[j], &block, &mut idx, &mut vals);
                    if wire::sparse_saves_bytes(idx.len(), db) {
                        wire::put_pull_block_sparse(&mut resp, j as u32, v, have, &idx, &vals);
                        stats.sparse_blocks.fetch_add(1, Ordering::Relaxed);
                    } else {
                        wire::put_pull_block_dense(&mut resp, j as u32, v, &block);
                        stats.dense_blocks.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    wire::put_pull_block_dense(&mut resp, j as u32, v, &block);
                    stats.dense_blocks.fetch_add(1, Ordering::Relaxed);
                }
                stats.resp_bytes.fetch_add((resp.len() - before) as u64, Ordering::Relaxed);
                stats.dense_equiv_bytes.fetch_add((17 + 4 * db) as u64, Ordering::Relaxed);
                if sent[j].is_empty() {
                    sent[j].resize(db, 0.0);
                }
                sent[j].copy_from_slice(&block);
                sent_v[j] = v;
                count += 1;
            }
            cur.finish()?;
            resp[0..4].copy_from_slice(&count.to_le_bytes());
            stats.rounds.fetch_add(1, Ordering::Relaxed);
            if count == 0 {
                stats.empty.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        })();
        if let Err(e) = built {
            eprintln!("pull stream: bad PullReq: {e:#}");
            return;
        }
        frames += 1;
        // `corrupt:sS@N` (DESIGN.md §2.0.7): flip the count field of
        // this stream's Nth response.  The peer must surface a named
        // decode error — never a panic — so this bypasses the encoder
        // and mangles finished payload bytes.
        if !plan.is_empty() && rank != usize::MAX && plan.corrupt_frame(rank, frames) {
            for b in resp.iter_mut().take(4) {
                *b ^= 0xFF;
            }
        }
        if wire::write_frame(&mut stream, kind::PULL_RESP, &resp).is_err() {
            return;
        }
    }
}

/// Read one rank's control stream until `WorkerDone` or its death,
/// stamping the liveness board on every frame (heartbeats included).
/// EOF or a stream error without a prior `WorkerDone` reports
/// [`CtlEvent::Dead`] — the monitor's failure policy decides what that
/// means.
fn ctl_read_loop(
    rank: usize,
    mut stream: TcpStream,
    events: Sender<CtlEvent>,
    board: Arc<RankBoard>,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some((kind::HEARTBEAT, payload))) => {
                let parsed = (|| -> Result<wire::WireHeartbeat> {
                    let mut cur = wire::Cursor::new(kind::HEARTBEAT, &payload)?;
                    let hb = wire::take_heartbeat(&mut cur)?;
                    cur.finish()?;
                    Ok(hb)
                })();
                match parsed {
                    Ok(hb) if hb.rank as usize == rank => board.seen(rank, true),
                    Ok(hb) => {
                        eprintln!("rank {rank}: heartbeat claims rank {}; ignoring", hb.rank)
                    }
                    Err(e) => eprintln!("rank {rank}: bad Heartbeat: {e:#}"),
                }
            }
            Ok(Some((kind::WORKER_DONE, payload))) => {
                board.seen(rank, false);
                let parsed = (|| -> Result<CtlEvent> {
                    let mut cur = wire::Cursor::new(kind::WORKER_DONE, &payload)?;
                    let r = cur.u32("rank")? as usize;
                    let pushes = cur.u64("pushes")?;
                    let rounds = cur.u64("pull_rounds")?;
                    let empty = cur.u64("pull_empty")?;
                    cur.finish()?;
                    Ok(CtlEvent::Done { rank: r, pushes, rounds, empty })
                })();
                match parsed {
                    Ok(ev) => {
                        let _ = events.send(ev);
                    }
                    Err(e) => {
                        eprintln!("rank {rank}: bad WorkerDone: {e:#}");
                        let _ = events.send(CtlEvent::Dead { rank });
                    }
                }
                return;
            }
            Ok(Some((k, _))) => {
                board.seen(rank, false);
                eprintln!("rank {rank}: unexpected {} on control stream", wire::kind_name(k))
            }
            Ok(None) => {
                let _ = events.send(CtlEvent::Dead { rank });
                return;
            }
            Err(e) => {
                eprintln!("rank {rank}: control stream error: {e:#}");
                let _ = events.send(CtlEvent::Dead { rank });
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------
// work
// ---------------------------------------------------------------------

/// `asybadmm work` entry point.
pub fn work_main(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "worker process: joins an `asybadmm serve` coordinator and runs \
         the worker ranks w where w mod N == R",
    )
    .req("connect", "coordinator address (host:port, printed by `asybadmm serve`)")
    .req("rank", "this process's share as R/N (e.g. 0/2)")
    .parse_from(argv);
    let (rank, n_ranks) = parse_rank(p.get("rank"))?;
    work(p.get("connect"), rank, n_ranks)
}

/// Retry a fallible dial with jittered exponential backoff: 8 attempts,
/// 50ms doubling to a 2s cap, ±25% deterministic jitter keyed off the
/// process id so racing replacement ranks don't dial in lockstep.
/// This is what makes `asybadmm work` a viable *replacement* process
/// under `failure=restart`: it can be started before the coordinator
/// notices the death it is replacing.
fn with_backoff<T>(what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
    const ATTEMPTS: u32 = 8;
    let mut rng = 0x9E37_79B9_7F4A_7C15u64 ^ (u64::from(std::process::id()) << 17);
    let mut wait = 50u64;
    let mut last = None;
    for attempt in 1..=ATTEMPTS {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                if attempt < ATTEMPTS {
                    rng ^= rng << 13;
                    rng ^= rng >> 7;
                    rng ^= rng << 17;
                    let jitter = wait / 4;
                    let ms = wait - jitter + rng % (2 * jitter + 1);
                    eprintln!(
                        "{what}: attempt {attempt}/{ATTEMPTS} failed ({e:#}); \
                         retrying in {ms}ms"
                    );
                    std::thread::sleep(Duration::from_millis(ms));
                    wait = (wait * 2).min(2000);
                }
                last = Some(e);
            }
        }
    }
    Err(last.expect("at least one attempt ran")
        .context(format!("{what}: gave up after {ATTEMPTS} attempts")))
}

fn work(connect: &str, rank: usize, n_ranks: usize) -> Result<()> {
    let addr: SocketAddr = connect
        .to_socket_addrs()
        .with_context(|| format!("connect address {connect:?} (expected host:port)"))?
        .next()
        .with_context(|| format!("connect address {connect:?} resolved to nothing"))?;

    // -- join (reconnect-with-backoff) --------------------------------
    // The whole exchange retries, not just the connect: a replacement
    // rank's JoinCtl can race the coordinator's death detection, whose
    // refusal shows up here as EOF-before-Welcome.
    let (mut ctl, cfg, owners, resume) =
        with_backoff(&format!("rank {rank}/{n_ranks}: joining {addr}"), || {
            let mut ctl = TcpStream::connect(addr).context("connect")?;
            ctl.set_nodelay(true).ok();
            let mut join = Vec::with_capacity(8);
            wire::put_u32(&mut join, rank as u32);
            wire::put_u32(&mut join, n_ranks as u32);
            wire::write_frame(&mut ctl, kind::JOIN_CTL, &join).context("sending JoinCtl")?;
            let (k, payload) = wire::read_frame(&mut ctl)
                .context("waiting for Welcome")?
                .context("coordinator closed the connection before Welcome")?;
            anyhow::ensure!(k == kind::WELCOME, "expected Welcome, got {}", wire::kind_name(k));
            let (cfg, owners, _map_version, resume) = decode_welcome(&payload)?;
            Ok((ctl, cfg, owners, resume))
        })?;
    anyhow::ensure!(
        n_ranks <= cfg.n_workers,
        "rank {rank}/{n_ranks}: only {} workers configured",
        cfg.n_workers
    );
    info!("work", "rank {rank}/{n_ranks} joined {addr}: {}", cfg.summary());

    let (_ds, shards) = load_data(&cfg)?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let kernels = Kernels::select(cfg.kernel);
    let manifest = match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    };
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    let map = Arc::new(BlockMap::new(&owners));
    let policy =
        DelayPolicy { net_mean_ms: cfg.net_delay_mean_ms, pull_hold: cfg.pull_hold.max(1) };
    // In-process fault kinds don't re-plumb across the Welcome (they
    // would double-fire); the worker-side *net* kinds arrive filtered
    // through `worker_net_spec` and hook the push senders below.
    let fault_plan = FaultPlan::none();
    let net_plan =
        Arc::new(FaultPlan::parse(&cfg.faults).context("fault spec from Welcome")?);
    let tuning = Arc::new(PullTuning::from_cfg(&cfg));
    let pool_cap =
        push_inflight(cfg.n_workers) + 4 + cfg.n_servers * cfg.batch.saturating_sub(1);

    // -- mirror-sync thread -------------------------------------------
    let stop_sync = Arc::new(AtomicBool::new(false));
    // Publish hint: every push sender's Credit frames max-merge the
    // coordinator's publish counter in here; the pull loop reads it to
    // cut idle backoff short the moment z̃ moves.
    let publish_hint = Arc::new(AtomicU64::new(0));
    let pull_rounds = Arc::new(AtomicU64::new(0));
    let pull_empty = Arc::new(AtomicU64::new(0));
    let sync_handle = {
        let mut stream = with_backoff("dialing the mirror-sync stream", || {
            let s = TcpStream::connect(addr).context("connect")?;
            s.set_nodelay(true).ok();
            Ok(s)
        })?;
        let mut hello = Vec::with_capacity(4);
        wire::put_u32(&mut hello, rank as u32);
        wire::write_frame(&mut stream, kind::HELLO_PULL, &hello).context("sending HelloPull")?;
        let store = store.clone();
        let stop = stop_sync.clone();
        let hint = publish_hint.clone();
        let tuning = tuning.clone();
        let (rounds, empty) = (pull_rounds.clone(), pull_empty.clone());
        std::thread::Builder::new()
            .name("pull-sync".into())
            .spawn(move || pull_sync_loop(stream, store, stop, hint, tuning, rounds, empty))
            .context("spawn mirror-sync thread")?
    };

    // -- control-update reader (detached; exits on the coordinator's
    // EOF).  Applies OwnerUpdate republishes and ConfigUpdate reloads.
    {
        let map = map.clone();
        let tuning = tuning.clone();
        let stream = ctl.try_clone().context("clone control stream")?;
        std::thread::Builder::new()
            .name("ctl-update".into())
            .spawn(move || ctl_update_loop(stream, map, tuning))
            .context("spawn control-update thread")?;
    }

    // -- heartbeat thread (liveness; DESIGN.md §2.0.7) ----------------
    let stop_hb = Arc::new(AtomicBool::new(false));
    let hb_handle = {
        let writer = ctl.try_clone().context("clone control stream for heartbeats")?;
        let stop = stop_hb.clone();
        let tuning = tuning.clone();
        std::thread::Builder::new()
            .name("heartbeat".into())
            .spawn(move || heartbeat_loop(writer, rank, stop, tuning))
            .context("spawn heartbeat thread")?
    };

    // -- this rank's workers ------------------------------------------
    let local: Vec<&WorkerShard> =
        shards.iter().filter(|s| s.worker_id % n_ranks == rank).collect();
    anyhow::ensure!(!local.is_empty(), "rank {rank}/{n_ranks}: no workers to run");
    let progress: Vec<AtomicUsize> = (0..cfg.n_workers).map(|_| AtomicUsize::new(0)).collect();
    let gate = MonitorGate::new();
    let ledgers: Vec<Vec<AtomicU64>> = shards
        .iter()
        .map(|s| (0..s.n_slots()).map(|_| AtomicU64::new(0)).collect())
        .collect();

    // Seed the ledgers from the rejoin resume state: the seq gates
    // server-side already sit past these, so the next push on slot s
    // must carry seqs[s] + 1 — exactly what a ledger holding seqs[s]
    // produces.
    let mut resume_base = 0u64;
    for e in &resume {
        anyhow::ensure!(
            e.worker % n_ranks == rank,
            "Welcome resume entry for worker {} outside rank {rank}/{n_ranks}",
            e.worker
        );
        let ledger = &ledgers[e.worker];
        anyhow::ensure!(
            e.seqs.len() == ledger.len(),
            "Welcome resume entry for worker {}: {} slots, shard has {}",
            e.worker,
            e.seqs.len(),
            ledger.len()
        );
        for (slot, &s) in e.seqs.iter().enumerate() {
            ledger[slot].store(s, Ordering::Release);
            resume_base += s;
        }
    }
    if !resume.is_empty() {
        info!(
            "work",
            "rank {rank}/{n_ranks} resuming past {resume_base} applied pushes across {} workers",
            resume.len()
        );
    }

    // Dial every lane before spawning anything: a refused connection
    // fails the rank instead of stranding half-started workers.
    let mut senders = Vec::with_capacity(local.len());
    for shard in &local {
        let mut tx = with_backoff(
            &format!("worker {}: dialing push lanes", shard.worker_id),
            || {
                TcpPushSender::connect_remote(
                    &addr,
                    shard.worker_id,
                    cfg.n_servers,
                    lane_cap(&cfg),
                    cfg.batch,
                )
            },
        )?;
        tx.set_hint_sink(publish_hint.clone());
        if !net_plan.is_empty() {
            tx.set_fault_plan(net_plan.clone());
        }
        senders.push(tx);
    }

    let start = Instant::now();
    let run_result = std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(local.len());
        for (shard, tx) in local.iter().zip(senders) {
            let wid = shard.worker_id;
            let shard: &WorkerShard = shard;
            let store = &store;
            let router: &BlockMap = &map;
            let progress = &progress[wid];
            let gate = &gate;
            let manifest = manifest.as_ref();
            let fault_plan = &fault_plan;
            let ledger: &[AtomicU64] = &ledgers[wid];
            let cfg = &cfg;
            let resume_entry = resume.iter().find(|e| e.worker == wid);
            let seed = cfg.seed ^ (0x9E37 + wid as u64 * 0x1000_0000_01B3);
            let local_weight = 1.0 / shard.samples().max(1) as f32;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut compute = make_compute(
                    cfg.backend,
                    shard,
                    problem,
                    local_weight,
                    manifest,
                    cfg.m_chunk,
                    cfg.d_pad,
                    kernels,
                )
                .context("construct worker compute backend")?;
                let tx: Box<dyn PushSender> = Box::new(tx);
                let mut ctx = WorkerCtx::new(
                    shard,
                    store,
                    router,
                    tx,
                    policy,
                    cfg.selection,
                    cfg.rho,
                    cfg.epochs,
                    cfg.max_delay,
                    cfg.enforce_delay_bound,
                    seed,
                    progress,
                    gate,
                    pool_cap,
                    fault_plan,
                    ledger,
                );
                if let Some(e) = resume_entry {
                    // Rejoin: pick up the epoch count and per-slot seq
                    // continuity where the crashed incarnation stopped,
                    // with warm duals from the coordinator's state.
                    ctx.resume_at(e.start_epoch, &e.seqs);
                    ctx.warm_duals(&e.duals);
                }
                ctx.run(compute.as_mut()).with_context(|| format!("worker {wid} loop"))?;
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
        }
        Ok(())
    });

    // -- report + teardown --------------------------------------------
    // Senders dropped with the scope: their FIN is behind the last
    // flushed push frame, so the coordinator's drain sees every message
    // before the EOF.
    stop_sync.store(true, Ordering::Release);
    let _ = sync_handle.join();
    stop_hb.store(true, Ordering::Release);
    let _ = hb_handle.join();
    // Surface injected-fault events before deciding the exit: a rank
    // that `netdrop` severed must still say what hit it.
    for ev in net_plan.take_events() {
        println!("# fault: {}", ev.describe());
    }
    run_result?;
    let applied: u64 = local
        .iter()
        .map(|s| ledgers[s.worker_id].iter().map(|a| a.load(Ordering::Acquire)).sum::<u64>())
        .sum();
    // A resumed rank's ledgers were seeded with the crashed
    // incarnation's pushes; report only this process's own.
    let sent = applied.saturating_sub(resume_base);
    // Counters are final: the sync thread joined above.
    let rounds = pull_rounds.load(Ordering::Acquire);
    let empty = pull_empty.load(Ordering::Acquire);
    let mut done = Vec::with_capacity(28);
    wire::put_u32(&mut done, rank as u32);
    wire::put_u64(&mut done, sent);
    wire::put_u64(&mut done, rounds);
    wire::put_u64(&mut done, empty);
    wire::write_frame(&mut ctl, kind::WORKER_DONE, &done).context("sending WorkerDone")?;
    // Parsed by tests/netproc.rs (`pull_rounds=` / `pull_empty=`).
    println!(
        "# rank {rank}/{n_ranks} done in {:.3}s: {} workers, {sent} pushes sent, \
         pull_rounds={rounds} pull_empty={empty}",
        start.elapsed().as_secs_f64(),
        local.len()
    );
    Ok(())
}

/// Beacon the coordinator's liveness board: one `Heartbeat` frame per
/// period on the control stream's write half (own fd clone — the main
/// thread only writes `WorkerDone`, after this thread joins).  The
/// period is re-read every beat so a `ConfigUpdate` retuning
/// `net_liveness_ms` takes effect mid-run; sleeps run in ≤25ms slices
/// so stop requests never wait out a long period.
fn heartbeat_loop(
    mut writer: TcpStream,
    rank: usize,
    stop: Arc<AtomicBool>,
    tuning: Arc<PullTuning>,
) {
    let mut seq = 0u64;
    let mut buf = Vec::with_capacity(32);
    while !stop.load(Ordering::Acquire) {
        let period = tuning.hb_period_ms.load(Ordering::Relaxed);
        if period == 0 {
            // Liveness off (possibly retuned off); nap and re-check.
            std::thread::sleep(Duration::from_millis(25));
            continue;
        }
        let mut slept = 0u64;
        while slept < period && !stop.load(Ordering::Acquire) {
            let step = (period - slept).min(25);
            std::thread::sleep(Duration::from_millis(step));
            slept += step;
        }
        if stop.load(Ordering::Acquire) {
            return;
        }
        seq += 1;
        buf.clear();
        wire::put_heartbeat_frame(&mut buf, rank as u32, seq);
        if writer.write_all(&buf).is_err() {
            // Coordinator gone; the control reader owns reporting that.
            return;
        }
    }
}

/// Worker-side mirror refresh: poll the coordinator for blocks newer
/// than the local replica and adopt them via
/// [`BlockStore::write_versioned`].
///
/// Keeps shadow copies of the exact bytes last adopted per block — the
/// base sparse deltas patch against.  The shadow's versions go out as
/// `have_version`, so the coordinator's per-connection mirror and this
/// shadow stay in lockstep and reconstruction is bit-identical (SET
/// semantics).  Pacing is [`PullCadence`]; `hint` is the coordinator's
/// publish counter delivered via Credit frames, sampled mid-sleep so an
/// idle 8ms nap ends the moment z̃ moves.
fn pull_sync_loop(
    mut stream: TcpStream,
    store: Arc<BlockStore>,
    stop: Arc<AtomicBool>,
    hint: Arc<AtomicU64>,
    tuning: Arc<PullTuning>,
    rounds_out: Arc<AtomicU64>,
    empty_out: Arc<AtomicU64>,
) {
    let n = store.n_blocks();
    let db = store.block_size();
    let mut req = Vec::new();
    let mut shadow: Vec<Vec<f32>> = vec![vec![0.0f32; db]; n];
    let mut shadow_v = vec![0u64; n];
    let mut cadence = PullCadence::new(tuning.floor());
    while !stop.load(Ordering::Acquire) {
        req.clear();
        wire::put_u32(&mut req, n as u32);
        for &v in &shadow_v {
            wire::put_u64(&mut req, v);
        }
        if wire::write_frame(&mut stream, kind::PULL_REQ, &req).is_err() {
            return;
        }
        rounds_out.fetch_add(1, Ordering::Relaxed);
        let (k, payload) = match wire::read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => return,
        };
        if k != kind::PULL_RESP {
            eprintln!("pull-sync: unexpected {} frame", wire::kind_name(k));
            return;
        }
        let mut got = 0usize;
        let applied = (|| -> Result<()> {
            let mut cur = wire::Cursor::new(kind::PULL_RESP, &payload)?;
            let count = cur.u32("count")? as usize;
            for _ in 0..count {
                let b = wire::take_pull_block(&mut cur)?;
                let j = b.block;
                anyhow::ensure!(j < n, "PullResp: block {j} outside geometry {n}x{db}");
                match b.body {
                    wire::WirePullBody::Dense(data) => {
                        anyhow::ensure!(
                            data.len() == db,
                            "PullResp: block {j} length {} outside geometry {n}x{db}",
                            data.len()
                        );
                        shadow[j].copy_from_slice(&data);
                    }
                    wire::WirePullBody::Sparse { base_version, idx, vals } => {
                        anyhow::ensure!(
                            base_version == shadow_v[j],
                            "PullResp: sparse block {j} against base v{base_version}, \
                             shadow holds v{}",
                            shadow_v[j]
                        );
                        wire::apply_sparse_patch(&mut shadow[j], &idx, &vals)?;
                    }
                }
                shadow_v[j] = b.version;
                store.write_versioned(j, &shadow[j], b.version);
                got += 1;
            }
            cur.finish()
        })();
        if let Err(e) = applied {
            eprintln!("pull-sync: bad PullResp: {e:#}");
            return;
        }
        if got == 0 {
            empty_out.fetch_add(1, Ordering::Relaxed);
        }
        // Sleep in floor-sized slices so the publish hint (or stop) can
        // cut a long idle nap short.  Bounds are re-read per round so a
        // `ConfigUpdate` retunes the cadence mid-run.
        let (floor, ceil) = (tuning.floor(), tuning.ceil());
        let target = cadence.after_round(got > 0, floor, ceil);
        let h0 = hint.load(Ordering::Relaxed);
        let mut slept = Duration::ZERO;
        while slept < target && !stop.load(Ordering::Acquire) {
            let step = floor.min(target - slept);
            std::thread::sleep(step);
            slept += step;
            if hint.load(Ordering::Relaxed) > h0 {
                cadence.reset(floor);
                break;
            }
        }
    }
}

/// Apply control-stream republishes to process-local state:
/// `OwnerUpdate` frames move blocks in the routing map, `ConfigUpdate`
/// frames retune the worker-side hot-reloadable knobs ([`PullTuning`]).
/// Keys the worker doesn't consume (e.g. `rebalance_ms`) are ignored —
/// the coordinator already applied them on its side.
fn ctl_update_loop(mut stream: TcpStream, map: Arc<BlockMap>, tuning: Arc<PullTuning>) {
    loop {
        let (k, payload) = match wire::read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => return,
        };
        let applied = (|| -> Result<()> {
            match k {
                kind::OWNER_UPDATE => {
                    let mut cur = wire::Cursor::new(kind::OWNER_UPDATE, &payload)?;
                    let j = cur.u32("block")? as usize;
                    let s = cur.u32("owner")? as usize;
                    let _v = cur.u64("map_version")?;
                    cur.finish()?;
                    anyhow::ensure!(j < map.n_blocks(), "OwnerUpdate: block {j} out of range");
                    map.set_owner(j, s);
                }
                kind::CONFIG_UPDATE => {
                    let mut cur = wire::Cursor::new(kind::CONFIG_UPDATE, &payload)?;
                    let (version, kv) = wire::take_config_update(&mut cur)?;
                    for line in kv.lines() {
                        let Some((key, value)) = line.split_once('=') else { continue };
                        match (key.trim(), value.trim().parse::<u64>()) {
                            ("pull_floor_us", Ok(v)) => {
                                tuning.floor_us.store(v.max(1), Ordering::Relaxed)
                            }
                            ("pull_ceil_ms", Ok(v)) => {
                                tuning.ceil_ms.store(v.max(1), Ordering::Relaxed)
                            }
                            ("net_liveness_ms", Ok(v)) => tuning
                                .hb_period_ms
                                .store(heartbeat_period_ms(v), Ordering::Relaxed),
                            _ => {}
                        }
                    }
                    let kv = kv.replace('\n', " ");
                    cur.finish()?;
                    info!("work", "config v{version} applied: {kv}");
                }
                other => anyhow::bail!("unexpected {} frame", wire::kind_name(other)),
            }
            Ok(())
        })();
        if let Err(e) = applied {
            eprintln!("ctl-update: {e:#}");
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_spec_parses_and_rejects() {
        assert_eq!(parse_rank("0/2").unwrap(), (0, 2));
        assert_eq!(parse_rank("3/4").unwrap(), (3, 4));
        assert!(parse_rank("2/2").is_err());
        assert!(parse_rank("1").is_err());
        assert!(parse_rank("a/b").is_err());
        assert!(parse_rank("0/0").is_err());
    }

    #[test]
    fn welcome_round_trips_config_and_owner_map() {
        let mut cfg = Config::default();
        cfg.apply_kv("n_workers", "3").unwrap();
        cfg.apply_kv("n_servers", "2").unwrap();
        cfg.apply_kv("epochs", "17").unwrap();
        cfg.apply_kv("placement", "dynamic").unwrap();
        cfg.apply_kv("batch", "2").unwrap();
        cfg.apply_kv("stats_addr", "127.0.0.1:0").unwrap();
        cfg.apply_kv("faults", "crash:w0@1;netdrop:w1@5;netstall:w0@10+25ms").unwrap();
        let owners: Vec<usize> = (0..cfg.n_blocks).map(|j| j % 2).collect();
        let payload = encode_welcome(&cfg, &owners, 7);
        let (got, got_owners, v, resume) = decode_welcome(&payload).unwrap();
        assert_eq!(got.n_workers, 3);
        assert_eq!(got.n_servers, 2);
        assert_eq!(got.epochs, 17);
        assert_eq!(got.batch, 2);
        assert_eq!(got_owners, owners);
        assert_eq!(v, 7);
        assert!(resume.is_empty(), "cold-start Welcome must carry no resume state");
        // Worker-side policy: the coordinator keeps the stats endpoint,
        // and only the worker-side net fault kinds cross the wire.
        assert!(got.stats_addr.is_empty());
        assert_eq!(got.faults, "netdrop:w1@5;netstall:w0@10+25ms");
    }

    #[test]
    fn welcome_resume_entries_round_trip() {
        let mut cfg = Config::default();
        cfg.apply_kv("n_workers", "3").unwrap();
        let owners: Vec<usize> = vec![0; cfg.n_blocks];
        let db = cfg.block_size;
        let resume = vec![
            ResumeEntry {
                worker: 1,
                start_epoch: 9,
                seqs: vec![4, 5],
                duals: (0..2 * db).map(|i| i as f32 * 0.25).collect(),
            },
            ResumeEntry { worker: 2, start_epoch: 0, seqs: vec![0], duals: vec![0.5; db] },
        ];
        let payload = encode_welcome_resume(&cfg, &owners, 3, &resume);
        let (_, _, v, got) = decode_welcome(&payload).unwrap();
        assert_eq!(v, 3);
        assert_eq!(got, resume);
    }

    #[test]
    fn welcome_resume_rejects_bad_geometry() {
        let mut cfg = Config::default();
        cfg.apply_kv("n_workers", "2").unwrap();
        let owners: Vec<usize> = vec![0; cfg.n_blocks];
        // Worker id outside the config.
        let bad_worker = vec![ResumeEntry {
            worker: 5,
            start_epoch: 0,
            seqs: vec![1],
            duals: vec![0.0; cfg.block_size],
        }];
        let payload = encode_welcome_resume(&cfg, &owners, 1, &bad_worker);
        let err = format!("{:#}", decode_welcome(&payload).unwrap_err());
        assert!(err.contains("worker"), "unexpected error: {err}");
        // Dual vector inconsistent with the slot count.
        let bad_duals = vec![ResumeEntry {
            worker: 1,
            start_epoch: 0,
            seqs: vec![1, 2],
            duals: vec![0.0; cfg.block_size],
        }];
        let payload = encode_welcome_resume(&cfg, &owners, 1, &bad_duals);
        let err = format!("{:#}", decode_welcome(&payload).unwrap_err());
        assert!(err.contains("dual"), "unexpected error: {err}");
    }

    #[test]
    fn welcome_rejects_owner_map_geometry_mismatch() {
        let cfg = Config::default();
        let mut owners: Vec<usize> = vec![0; cfg.n_blocks];
        owners[0] = cfg.n_servers; // out-of-range shard
        let payload = encode_welcome(&cfg, &owners, 1);
        let err = format!("{:#}", decode_welcome(&payload).unwrap_err());
        assert!(err.contains("server shard"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_welcome_names_the_missing_field() {
        let cfg = Config::default();
        let payload = encode_welcome(&cfg, &vec![0; cfg.n_blocks], 1);
        let err = format!("{:#}", decode_welcome(&payload[..payload.len() - 4]).unwrap_err());
        assert!(err.contains("n_resume"), "unexpected error: {err}");
        let err =
            format!("{:#}", decode_welcome(&payload[..payload.len() - 12]).unwrap_err());
        assert!(err.contains("map_version"), "unexpected error: {err}");
    }

    #[test]
    fn pull_cadence_backs_off_doubling_and_resets_on_progress() {
        // The `pull_floor_us` / `pull_ceil_ms` config defaults.
        let (floor, ceil) = (Duration::from_micros(500), Duration::from_millis(8));
        let mut c = PullCadence::new(floor);
        assert_eq!(c.after_round(true, floor, ceil), floor);
        assert_eq!(c.after_round(false, floor, ceil), floor);
        let mut prev = floor;
        for _ in 0..10 {
            let d = c.after_round(false, floor, ceil);
            assert!(d >= prev && d <= ceil, "cadence left [{prev:?}, max]: {d:?}");
            prev = d;
        }
        assert_eq!(prev, ceil, "ten idle rounds must reach the ceiling");
        assert_eq!(c.after_round(true, floor, ceil), floor, "productive round resets");
        let _ = c.after_round(false, floor, ceil);
        assert!(c.after_round(false, floor, ceil) > floor);
        c.reset(floor);
        assert_eq!(c.after_round(false, floor, ceil), floor, "hint reset returns to the floor");
        // A ConfigUpdate shrinking the ceiling clamps the very next round.
        let _ = c.after_round(false, floor, ceil);
        let _ = c.after_round(false, floor, ceil);
        assert!(c.after_round(false, floor, floor) == floor, "new bounds clamp in-flight state");
    }

    /// The serve and sync loops against each other over a real socket:
    /// dense first sends, sparse deltas once bases align, bit-identical
    /// mirrors throughout (including -0.0 and NaN payloads).
    #[test]
    fn pull_loop_pair_converges_bit_identically_via_sparse_deltas() {
        let (n, db) = (4usize, 32usize);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_store = Arc::new(BlockStore::new(n, db));
        for j in 0..n {
            let data: Vec<f32> = (0..db).map(|i| (j * db + i) as f32).collect();
            server_store.write_versioned(j, &data, 1);
        }
        let stats = Arc::new(PullServeStats::default());
        {
            let (store, stats) = (server_store.clone(), stats.clone());
            std::thread::spawn(move || {
                let (s, _) = listener.accept().unwrap();
                pull_serve_loop(s, store, stats, Arc::new(FaultPlan::none()), usize::MAX);
            });
        }
        let worker_store = Arc::new(BlockStore::new(n, db));
        let stop = Arc::new(AtomicBool::new(false));
        let hint = Arc::new(AtomicU64::new(0));
        let rounds = Arc::new(AtomicU64::new(0));
        let empty = Arc::new(AtomicU64::new(0));
        let sync = {
            let (ws, st) = (worker_store.clone(), stop.clone());
            let (h, r, e) = (hint.clone(), rounds.clone(), empty.clone());
            let tuning = Arc::new(PullTuning::from_cfg(&Config::default()));
            let stream = TcpStream::connect(addr).unwrap();
            std::thread::spawn(move || pull_sync_loop(stream, ws, st, h, tuning, r, e))
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        let wait_version = |j: usize, v: u64| {
            while worker_store.version(j) < v {
                assert!(Instant::now() < deadline, "mirror never reached block {j} v{v}");
                std::thread::sleep(Duration::from_millis(1));
            }
        };
        for j in 0..n {
            wait_version(j, 1);
        }
        // Idle tail: with everything in sync, rounds must come back
        // empty (and the cadence backs off, not asserted on timing).
        std::thread::sleep(Duration::from_millis(40));
        assert!(empty.load(Ordering::Relaxed) > 0, "idle polls should report empty rounds");
        // Touch two lanes of block 2 with awkward bit patterns: small
        // enough for the sparse path, and only bit-exact copying keeps
        // the mirrors identical.
        let mut blk = vec![0.0f32; db];
        server_store.read_into(2, &mut blk);
        blk[3] = -0.0;
        blk[17] = f32::from_bits(0x7fc0_1234); // non-canonical NaN
        server_store.write_versioned(2, &blk, 2);
        wait_version(2, 2);
        stop.store(true, Ordering::Release);
        sync.join().unwrap();
        assert!(
            stats.sparse_blocks.load(Ordering::Relaxed) >= 1,
            "2 changed lanes of {db} must take the sparse path"
        );
        assert!(stats.dense_blocks.load(Ordering::Relaxed) >= n as u64 - 1);
        let (mut sv, mut wv) = (vec![0.0f32; db], vec![0.0f32; db]);
        for j in 0..n {
            server_store.read_into(j, &mut sv);
            worker_store.read_into(j, &mut wv);
            let sb: Vec<u32> = sv.iter().map(|f| f.to_bits()).collect();
            let wb: Vec<u32> = wv.iter().map(|f| f.to_bits()).collect();
            assert_eq!(sb, wb, "block {j} mirrors diverged");
        }
        assert!(
            stats.resp_bytes.load(Ordering::Relaxed)
                < stats.dense_equiv_bytes.load(Ordering::Relaxed),
            "delta encoding should beat all-dense on this workload"
        );
        assert_eq!(rounds.load(Ordering::Relaxed), stats.rounds.load(Ordering::Relaxed));
    }
}

//! Networked runtime: the multi-process face of the coordinator.
//!
//! Everything in here is std-only (`std::net` + threads): a
//! length-prefixed little-endian wire format ([`wire`]), a TCP
//! implementation of the [`super::transport::Transport`] contract
//! ([`tcp`]), the `asybadmm serve` / `asybadmm work` process roles and
//! their join/handshake + owner-republish control protocol ([`proc`]),
//! and a hand-rolled HTTP/1.1 stats endpoint ([`http`]).
//!
//! The layering rule: nothing above the transport knows whether a push
//! crossed a channel or a socket.  `net` adds *reach*, not semantics —
//! FIFO per (worker, server) lane, exact in-flight bounds, drain-then-
//! `None` shutdown, and reconnect all mean the same thing here as in
//! `coordinator/transport.rs`, which is what lets the seq-gated apply,
//! work stealing, dynamic re-placement and fault handling run unchanged
//! across machines.

pub mod http;
pub mod proc;
pub mod tcp;
pub mod wire;

pub use http::StatsServer;
pub use proc::{serve_main, work_main};
pub use tcp::{TcpPushSender, TcpTransport};

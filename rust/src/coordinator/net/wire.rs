//! Length-prefixed little-endian wire format for the networked runtime.
//!
//! Every frame on every socket — push lanes, the control plane, the
//! pull-sync stream — has the same envelope:
//!
//! ```text
//! [len: u32 LE][kind: u8][payload: len bytes]
//! ```
//!
//! `len` counts payload bytes only, so a whole frame is `HEADER + len`
//! bytes.  Integers are little-endian; f32 data is raw LE bit patterns
//! (the same floats on both ends — no text round-trip).  A `len` above
//! [`MAX_FRAME`] or an unknown `kind` is rejected before any payload is
//! trusted, and every decode error names the frame kind and the length
//! it expected (mirroring the checkpoint sidecar validation), so a
//! truncated or corrupted stream produces a contextual `Err`, never a
//! panic.
//!
//! The push hot path preserves the pooled-buffer discipline end to end:
//! the **sender** serializes `w` straight out of the pooled
//! [`AlignedBuf`] and recycles it at encode time (the buffer never
//! crosses the wire, only its bytes do), and the **receiver**
//! re-materializes the block into a lane-local [`super::super::bufpool`]
//! free list — steady state allocates nothing per message on either
//! side.  [`FrameReader`] likewise accumulates into one reused buffer.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::util::AlignedBuf;

/// Envelope bytes before the payload: u32 length + u8 kind.
pub const HEADER: usize = 5;
/// Upper bound on one frame's payload — rejects corrupted lengths
/// before any allocation (64 MiB is orders of magnitude above the
/// largest legal batch of w blocks).
pub const MAX_FRAME: usize = 64 << 20;

/// Frame kinds.  Values are wire-stable: changing one breaks mixed
/// coordinator/worker versions.
pub mod kind {
    /// Worker → server lane greeting: `worker u32, server u32, local u8`.
    pub const HELLO_PUSH: u8 = 1;
    /// One push body (see [`super::put_push_body`]).
    pub const PUSH: u8 = 2;
    /// `count u32` followed by `count` push bodies (a coalesced batch).
    pub const PUSH_BATCH: u8 = 3;
    /// Receiver → sender credit return: `frames u32`.
    pub const ACK: u8 = 4;
    /// Worker process join: `rank u32, n_ranks u32`.
    pub const JOIN_CTL: u8 = 5;
    /// Coordinator reply to a join: config + owner map (text kv + u32s).
    pub const WELCOME: u8 = 6;
    /// Rebalancer republish: `block u32, owner u32, map_version u64`.
    pub const OWNER_UPDATE: u8 = 7;
    /// Pull-sync stream greeting: `rank u32`.
    pub const HELLO_PULL: u8 = 8;
    /// Mirror sync request: `n_blocks u32, have_version u64 × n_blocks`.
    pub const PULL_REQ: u8 = 9;
    /// Sync reply (v2): `count u32`, then per changed block
    /// `block u32, version u64, enc u8` followed by a dense body
    /// (`n u32, f32 × n`) or a sparse delta against the receiver's
    /// acked copy (`base_version u64, k u32, idx u32 × k, f32 × k`) —
    /// see [`super::take_pull_block`].
    pub const PULL_RESP: u8 = 10;
    /// Worker process completion:
    /// `rank u32, pushes u64, pull_rounds u64, pull_empty u64`.
    pub const WORKER_DONE: u8 = 11;
    /// Coalesced receiver → sender credit return:
    /// `frames u32, hint u64`.  Replaces N per-frame [`ACK`]s with one
    /// cumulative grant; `hint` piggybacks the server's monotonically
    /// increasing z̃ publish counter so an idle pull stream learns that
    /// new versions exist without a round-trip (0 = no hint source).
    pub const CREDIT: u8 = 12;
    /// Liveness beacon on an otherwise-idle control stream:
    /// `rank u32, seq u64`.  `seq` increments per beacon so a receiver
    /// can tell a fresh beacon from a replayed buffer on reconnect.
    pub const HEARTBEAT: u8 = 13;
    /// Coordinator → worker runtime-config republish:
    /// `version u64, kv str` — the same `key=value` line format the
    /// Welcome frame ships, restricted to `Config::RELOADABLE_KEYS`.
    pub const CONFIG_UPDATE: u8 = 14;
}

/// Human name for a frame kind (error context).
pub fn kind_name(k: u8) -> &'static str {
    match k {
        kind::HELLO_PUSH => "HelloPush",
        kind::PUSH => "Push",
        kind::PUSH_BATCH => "PushBatch",
        kind::ACK => "Ack",
        kind::JOIN_CTL => "JoinCtl",
        kind::WELCOME => "Welcome",
        kind::OWNER_UPDATE => "OwnerUpdate",
        kind::HELLO_PULL => "HelloPull",
        kind::PULL_REQ => "PullReq",
        kind::PULL_RESP => "PullResp",
        kind::WORKER_DONE => "WorkerDone",
        kind::CREDIT => "Credit",
        kind::HEARTBEAT => "Heartbeat",
        kind::CONFIG_UPDATE => "ConfigUpdate",
        _ => "unknown",
    }
}

fn known_kind(k: u8) -> bool {
    (kind::HELLO_PUSH..=kind::CONFIG_UPDATE).contains(&k)
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

/// Start a frame in `buf`: pushes a length placeholder + the kind byte
/// and returns the frame's start offset for [`end_frame`].
pub fn begin_frame(buf: &mut Vec<u8>, kind: u8) -> usize {
    let start = buf.len();
    buf.extend_from_slice(&[0, 0, 0, 0, kind]);
    start
}

/// Patch the length placeholder of the frame opened at `start`.
pub fn end_frame(buf: &mut Vec<u8>, start: usize) {
    let len = buf.len() - start - HEADER;
    debug_assert!(len <= MAX_FRAME, "oversized frame: {len}");
    buf[start..start + 4].copy_from_slice(&(len as u32).to_le_bytes());
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32s(buf: &mut Vec<u8>, data: &[f32]) {
    // One reserve + per-element extend: f32::to_le_bytes compiles to a
    // plain 4-byte store, so this is a straight memcpy on LE targets.
    buf.reserve(4 * data.len());
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize one push body (no envelope):
/// `worker u32, block u32, worker_epoch u64, z_version_used u64,
/// block_seq u64, n u32, f32 × n`.  `sent_at`/`recycle` are process-
/// local and never cross the wire — the caller recycles the pooled
/// buffer right after this returns.
pub fn put_push_body(buf: &mut Vec<u8>, msg: &super::super::messages::PushMsg) {
    put_u32(buf, msg.worker as u32);
    put_u32(buf, msg.block as u32);
    put_u64(buf, msg.worker_epoch as u64);
    put_u64(buf, msg.z_version_used);
    put_u64(buf, msg.block_seq);
    put_u32(buf, msg.w.len() as u32);
    put_f32s(buf, &msg.w);
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// A decoded push body, not yet bound to a recycle home (the lane
/// receiver attaches its pool when it re-materializes the `PushMsg`).
#[derive(Debug)]
pub struct WirePush {
    pub worker: usize,
    pub block: usize,
    pub worker_epoch: usize,
    pub z_version_used: u64,
    pub block_seq: u64,
    pub w: AlignedBuf,
}

/// Bounds-checked payload reader with frame-kind context in every
/// error: truncation/corruption yields `Err`, never a panic or an
/// out-of-bounds read.
pub struct Cursor<'a> {
    kind: &'static str,
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(kind: u8, payload: &'a [u8]) -> Result<Self> {
        if !known_kind(kind) {
            bail!("unknown frame kind {kind} ({} payload bytes)", payload.len());
        }
        Ok(Cursor { kind: kind_name(kind), b: payload, i: 0 })
    }

    fn need(&self, n: usize, field: &str) -> Result<usize> {
        let at = self.i;
        if at + n > self.b.len() {
            bail!(
                "{} frame truncated: field {field:?} needs {n} bytes at \
                 offset {at}, payload is {} bytes",
                self.kind,
                self.b.len()
            );
        }
        Ok(at)
    }

    pub fn u32(&mut self, field: &str) -> Result<u32> {
        let at = self.need(4, field)?;
        self.i = at + 4;
        Ok(u32::from_le_bytes(self.b[at..at + 4].try_into().unwrap()))
    }

    pub fn u64(&mut self, field: &str) -> Result<u64> {
        let at = self.need(8, field)?;
        self.i = at + 8;
        Ok(u64::from_le_bytes(self.b[at..at + 8].try_into().unwrap()))
    }

    pub fn u8(&mut self, field: &str) -> Result<u8> {
        let at = self.need(1, field)?;
        self.i = at + 1;
        Ok(self.b[at])
    }

    /// Copy `out.len()` f32s out of the payload.
    pub fn f32s_into(&mut self, out: &mut [f32], field: &str) -> Result<()> {
        let at = self.need(4 * out.len(), field)?;
        for (k, o) in out.iter_mut().enumerate() {
            let p = at + 4 * k;
            *o = f32::from_le_bytes(self.b[p..p + 4].try_into().unwrap());
        }
        self.i = at + 4 * out.len();
        Ok(())
    }

    /// A length-prefixed UTF-8 string (`len u32, bytes`).
    pub fn str(&mut self, field: &str) -> Result<&'a str> {
        let n = self.u32(field)? as usize;
        let at = self.need(n, field)?;
        self.i = at + n;
        std::str::from_utf8(&self.b[at..at + n])
            .with_context(|| format!("{} frame: field {field:?} is not UTF-8", self.kind))
    }

    /// Reject trailing garbage (a wrong-length but parseable frame).
    pub fn finish(&self) -> Result<()> {
        let left = self.b.len() - self.i;
        if left != 0 {
            bail!(
                "{} frame corrupted: {left} trailing bytes after a \
                 {}-byte body (payload is {} bytes)",
                self.kind,
                self.i,
                self.b.len()
            );
        }
        Ok(())
    }
}

/// Emit a length-prefixed string for [`Cursor::str`].
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Decode one push body at the cursor; `alloc` supplies the receiving
/// buffer (the lane pool's free list on the hot path).
pub fn take_push_body(
    cur: &mut Cursor<'_>,
    alloc: &mut dyn FnMut(usize) -> AlignedBuf,
) -> Result<WirePush> {
    let worker = cur.u32("worker")? as usize;
    let block = cur.u32("block")? as usize;
    let worker_epoch = cur.u64("worker_epoch")? as usize;
    let z_version_used = cur.u64("z_version_used")?;
    let block_seq = cur.u64("block_seq")?;
    let n = cur.u32("n")? as usize;
    if n > MAX_FRAME / 4 {
        bail!("Push frame corrupted: block length {n} exceeds the frame bound");
    }
    let mut w = alloc(n);
    debug_assert_eq!(w.len(), n);
    cur.f32s_into(&mut w, "w")?;
    Ok(WirePush { worker, block, worker_epoch, z_version_used, block_seq, w })
}

// ---------------------------------------------------------------------
// Credit frames (coalesced reverse-path flow control)
// ---------------------------------------------------------------------

/// A decoded [`kind::CREDIT`] body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireCredit {
    /// Cumulative frame credits granted since the last credit frame.
    pub frames: u32,
    /// Server z̃ publish counter at grant time (monotone; 0 = no hint
    /// source wired up, e.g. the in-process `transport=tcp` path).
    pub hint: u64,
}

/// Append one whole `Credit` frame (envelope included) to `buf`.
pub fn put_credit_frame(buf: &mut Vec<u8>, frames: u32, hint: u64) {
    let at = begin_frame(buf, kind::CREDIT);
    put_u32(buf, frames);
    put_u64(buf, hint);
    end_frame(buf, at);
}

/// Decode a `Credit` body at the cursor.
pub fn take_credit(cur: &mut Cursor<'_>) -> Result<WireCredit> {
    let frames = cur.u32("frames")?;
    let hint = cur.u64("hint")?;
    Ok(WireCredit { frames, hint })
}

// ---------------------------------------------------------------------
// Liveness + runtime-config frames (control plane)
// ---------------------------------------------------------------------

/// A decoded [`kind::HEARTBEAT`] body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireHeartbeat {
    /// Sending process's rank.
    pub rank: u32,
    /// Monotone per-connection beacon counter.
    pub seq: u64,
}

/// Append one whole `Heartbeat` frame (envelope included) to `buf`.
pub fn put_heartbeat_frame(buf: &mut Vec<u8>, rank: u32, seq: u64) {
    let at = begin_frame(buf, kind::HEARTBEAT);
    put_u32(buf, rank);
    put_u64(buf, seq);
    end_frame(buf, at);
}

/// Decode a `Heartbeat` body at the cursor.
pub fn take_heartbeat(cur: &mut Cursor<'_>) -> Result<WireHeartbeat> {
    let rank = cur.u32("rank")?;
    let seq = cur.u64("seq")?;
    Ok(WireHeartbeat { rank, seq })
}

/// Append one whole `ConfigUpdate` frame (envelope included) to `buf`.
/// `kv` is `key=value` lines restricted to the reloadable subset.
pub fn put_config_update_frame(buf: &mut Vec<u8>, version: u64, kv: &str) {
    let at = begin_frame(buf, kind::CONFIG_UPDATE);
    put_u64(buf, version);
    put_str(buf, kv);
    end_frame(buf, at);
}

/// Decode a `ConfigUpdate` body at the cursor: `(version, kv text)`.
pub fn take_config_update<'a>(cur: &mut Cursor<'a>) -> Result<(u64, &'a str)> {
    let version = cur.u64("version")?;
    let kv = cur.str("kv")?;
    Ok((version, kv))
}

// ---------------------------------------------------------------------
// PullResp v2 blocks (dense or sparse delta vs the worker's copy)
// ---------------------------------------------------------------------

/// Per-block encoding tag inside a `PullResp` payload.
pub mod pull_enc {
    /// `n u32, f32 × n` — the whole block.
    pub const DENSE: u8 = 0;
    /// `base_version u64, k u32, idx u32 × k, f32 × k` — SET-semantics
    /// patch over the worker's copy at `base_version` (changed entries
    /// overwrite; untouched entries are bit-identical by construction).
    pub const SPARSE: u8 = 1;
}

/// Body of one decoded v2 pull block.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePullBody {
    Dense(Vec<f32>),
    Sparse { base_version: u64, idx: Vec<u32>, vals: Vec<f32> },
}

/// One decoded v2 pull block.
#[derive(Debug, Clone, PartialEq)]
pub struct WirePullBlock {
    pub block: usize,
    pub version: u64,
    pub body: WirePullBody,
}

/// Collect the entries of `new` that differ bit-wise from `base` into
/// `(idx, vals)`.  Bit-level comparison (`to_bits`), so `-0.0` vs `0.0`
/// and NaN payload changes are treated as changes — the sparse patch
/// reconstructs the dense block bit-identically, never "close enough".
pub fn diff_block(base: &[f32], new: &[f32], idx: &mut Vec<u32>, vals: &mut Vec<f32>) {
    debug_assert_eq!(base.len(), new.len());
    idx.clear();
    vals.clear();
    for (i, (&b, &n)) in base.iter().zip(new.iter()).enumerate() {
        if b.to_bits() != n.to_bits() {
            idx.push(i as u32);
            vals.push(n);
        }
    }
}

/// Does a sparse patch of `changed` entries beat shipping all `db`
/// entries dense?  Compares exact encoded body bytes: sparse costs
/// `1 (tag) + 8 (base) + 4 (k) + 8·k`, dense `1 (tag) + 4 (n) + 4·db`.
pub fn sparse_saves_bytes(changed: usize, db: usize) -> bool {
    13 + 8 * changed < 5 + 4 * db
}

/// Append one dense v2 block (no envelope — the caller owns the
/// `PullResp` frame and its leading count).
pub fn put_pull_block_dense(buf: &mut Vec<u8>, block: u32, version: u64, data: &[f32]) {
    put_u32(buf, block);
    put_u64(buf, version);
    buf.push(pull_enc::DENSE);
    put_u32(buf, data.len() as u32);
    put_f32s(buf, data);
}

/// Append one sparse v2 block (no envelope).  `idx`/`vals` come from
/// [`diff_block`] against the copy the worker holds at `base_version`.
pub fn put_pull_block_sparse(
    buf: &mut Vec<u8>,
    block: u32,
    version: u64,
    base_version: u64,
    idx: &[u32],
    vals: &[f32],
) {
    debug_assert_eq!(idx.len(), vals.len());
    put_u32(buf, block);
    put_u64(buf, version);
    buf.push(pull_enc::SPARSE);
    put_u64(buf, base_version);
    put_u32(buf, idx.len() as u32);
    for &i in idx {
        put_u32(buf, i);
    }
    put_f32s(buf, vals);
}

/// Decode one v2 pull block at the cursor.
pub fn take_pull_block(cur: &mut Cursor<'_>) -> Result<WirePullBlock> {
    let block = cur.u32("block")? as usize;
    let version = cur.u64("version")?;
    let enc = cur.u8("enc")?;
    let body = match enc {
        pull_enc::DENSE => {
            let n = cur.u32("n")? as usize;
            if n > MAX_FRAME / 4 {
                bail!("PullResp frame corrupted: block length {n} exceeds the frame bound");
            }
            let mut data = vec![0.0f32; n];
            cur.f32s_into(&mut data, "data")?;
            WirePullBody::Dense(data)
        }
        pull_enc::SPARSE => {
            let base_version = cur.u64("base_version")?;
            let k = cur.u32("k")? as usize;
            if k > MAX_FRAME / 8 {
                bail!("PullResp frame corrupted: patch length {k} exceeds the frame bound");
            }
            let mut idx = Vec::with_capacity(k);
            for _ in 0..k {
                idx.push(cur.u32("idx")?);
            }
            let mut vals = vec![0.0f32; k];
            cur.f32s_into(&mut vals, "vals")?;
            WirePullBody::Sparse { base_version, idx, vals }
        }
        other => bail!("PullResp frame corrupted: unknown block encoding tag {other}"),
    };
    Ok(WirePullBlock { block, version, body })
}

/// Apply a SET-semantics sparse patch onto `dst` (the worker's copy at
/// the patch's `base_version`).  Out-of-range indices are corruption.
pub fn apply_sparse_patch(dst: &mut [f32], idx: &[u32], vals: &[f32]) -> Result<()> {
    for (&i, &v) in idx.iter().zip(vals.iter()) {
        let i = i as usize;
        if i >= dst.len() {
            bail!(
                "PullResp frame corrupted: patch index {i} out of range for a \
                 {}-entry block",
                dst.len()
            );
        }
        dst[i] = v;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Blocking frame I/O (control plane, pull sync — not the push path)
// ---------------------------------------------------------------------

/// Write one whole frame (envelope + payload) on a blocking stream.
pub fn write_frame(w: &mut dyn Write, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut head = [0u8; HEADER];
    head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[4] = kind;
    w.write_all(&head)?;
    w.write_all(payload)
}

/// Read one whole frame on a blocking stream.  `Ok(None)` = clean EOF
/// at a frame boundary; EOF mid-frame is a contextual error.
pub fn read_frame(r: &mut dyn Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; HEADER];
    let mut got = 0usize;
    while got < HEADER {
        match r.read(&mut head[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid-header: got {got} of {HEADER} bytes"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    let k = head[4];
    if !known_kind(k) {
        bail!("unknown frame kind {k} (claimed length {len})");
    }
    if len > MAX_FRAME {
        bail!("{} frame length {len} exceeds the {MAX_FRAME}-byte bound", kind_name(k));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).with_context(|| {
        format!("{} frame truncated: expected {len} payload bytes", kind_name(k))
    })?;
    Ok(Some((k, payload)))
}

// ---------------------------------------------------------------------
// Non-blocking frame accumulation (the push-lane receive path)
// ---------------------------------------------------------------------

/// What [`FrameReader::poll`] found.
#[derive(Debug, PartialEq, Eq)]
pub enum Poll {
    /// A complete frame is ready: [`FrameReader::frame_kind`] /
    /// [`FrameReader::payload`] are valid until `consume`.
    Frame,
    /// No complete frame buffered and the socket has nothing more now.
    Pending,
    /// Peer closed cleanly at a frame boundary (all frames consumed).
    Eof,
}

/// Accumulates bytes from a non-blocking socket into one reused buffer
/// and yields complete frames zero-copy (`payload` borrows the buffer),
/// so the steady-state receive path allocates nothing per message.
pub struct FrameReader {
    buf: Vec<u8>,
    /// Start of un-consumed bytes in `buf`.
    start: usize,
    eof: bool,
}

const READ_CHUNK: usize = 64 * 1024;

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameReader {
    pub fn new() -> Self {
        FrameReader { buf: Vec::with_capacity(READ_CHUNK), start: 0, eof: false }
    }

    /// Header of the buffered-but-unconsumed region, if complete.
    fn buffered_header(&self) -> Option<(u8, usize)> {
        let b = &self.buf[self.start..];
        if b.len() < HEADER {
            return None;
        }
        let len = u32::from_le_bytes(b[..4].try_into().unwrap()) as usize;
        Some((b[4], len))
    }

    fn has_frame(&self) -> Result<bool> {
        match self.buffered_header() {
            None => Ok(false),
            Some((k, len)) => {
                if !known_kind(k) {
                    bail!("unknown frame kind {k} on lane (claimed length {len})");
                }
                if len > MAX_FRAME {
                    bail!(
                        "{} frame length {len} exceeds the {MAX_FRAME}-byte bound",
                        kind_name(k)
                    );
                }
                Ok(self.buf.len() - self.start >= HEADER + len)
            }
        }
    }

    /// Pull whatever the socket has ready and report the state.  Never
    /// blocks (the stream must be in non-blocking mode).  After
    /// [`Poll::Frame`], call [`FrameReader::consume`] before polling
    /// again.
    pub fn poll(&mut self, conn: &mut TcpStream) -> Result<Poll> {
        loop {
            if self.has_frame()? {
                return Ok(Poll::Frame);
            }
            if self.eof {
                if self.buf.len() > self.start {
                    bail!(
                        "connection closed mid-frame: {} bytes of an incomplete \
                         frame buffered",
                        self.buf.len() - self.start
                    );
                }
                return Ok(Poll::Eof);
            }
            // Compact before growing: consumed frames' bytes are dead.
            if self.start > 0 {
                self.buf.drain(..self.start);
                self.start = 0;
            }
            let filled = self.buf.len();
            self.buf.resize(filled + READ_CHUNK, 0);
            match conn.read(&mut self.buf[filled..]) {
                Ok(0) => {
                    self.buf.truncate(filled);
                    self.eof = true;
                }
                Ok(n) => {
                    self.buf.truncate(filled + n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.buf.truncate(filled);
                    return Ok(Poll::Pending);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {
                    self.buf.truncate(filled);
                }
                // A reset from a closing peer after its last frame is a
                // teardown artifact, not corruption: everything sent
                // before the close was already buffered here.
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::ConnectionReset | ErrorKind::BrokenPipe
                    ) =>
                {
                    self.buf.truncate(filled);
                    self.eof = true;
                }
                Err(e) => {
                    self.buf.truncate(filled);
                    return Err(e).context("reading push lane");
                }
            }
        }
    }

    /// Kind of the frame reported by the last [`Poll::Frame`].
    pub fn frame_kind(&self) -> u8 {
        self.buf[self.start + 4]
    }

    /// Payload of the frame reported by the last [`Poll::Frame`].
    pub fn payload(&self) -> &[u8] {
        let (_, len) = self.buffered_header().expect("no buffered frame");
        &self.buf[self.start + HEADER..self.start + HEADER + len]
    }

    /// Advance past the frame reported by the last [`Poll::Frame`].
    pub fn consume(&mut self) {
        let (_, len) = self.buffered_header().expect("no buffered frame");
        self.start += HEADER + len;
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::messages::PushMsg;
    use super::*;

    fn msg(worker: usize, block: usize, seq: u64, data: &[f32]) -> PushMsg {
        PushMsg {
            worker,
            block,
            w: data.into(),
            worker_epoch: 7,
            z_version_used: 42,
            block_seq: seq,
            sent_at: None,
            recycle: None,
        }
    }

    #[test]
    fn push_body_round_trips() {
        let m = msg(3, 11, 9, &[1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let mut buf = Vec::new();
        let at = begin_frame(&mut buf, kind::PUSH);
        put_push_body(&mut buf, &m);
        end_frame(&mut buf, at);
        assert_eq!(buf.len(), HEADER + 4 + 4 + 8 + 8 + 8 + 4 + 16);

        let mut cur = Cursor::new(buf[4], &buf[HEADER..]).unwrap();
        let p = take_push_body(&mut cur, &mut |n| AlignedBuf::zeroed(n)).unwrap();
        cur.finish().unwrap();
        assert_eq!(p.worker, 3);
        assert_eq!(p.block, 11);
        assert_eq!(p.worker_epoch, 7);
        assert_eq!(p.z_version_used, 42);
        assert_eq!(p.block_seq, 9);
        assert_eq!(&p.w[..], &[1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
    }

    #[test]
    fn truncated_push_names_kind_and_need() {
        let m = msg(0, 0, 1, &[1.0, 2.0]);
        let mut buf = Vec::new();
        let at = begin_frame(&mut buf, kind::PUSH);
        put_push_body(&mut buf, &m);
        end_frame(&mut buf, at);
        // Cut the payload short of the w data.
        let cut = &buf[HEADER..buf.len() - 5];
        let mut cur = Cursor::new(kind::PUSH, cut).unwrap();
        let err = take_push_body(&mut cur, &mut |n| AlignedBuf::zeroed(n)).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("Push frame truncated"), "{text}");
        assert!(text.contains("needs"), "{text}");
    }

    #[test]
    fn unknown_kind_and_oversize_are_rejected() {
        assert!(Cursor::new(0, &[]).is_err());
        assert!(Cursor::new(99, &[]).is_err());
        let mut head = [0u8; HEADER];
        head[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        head[4] = kind::PUSH;
        let err = read_frame(&mut &head[..]).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
    }

    #[test]
    fn trailing_bytes_are_corruption() {
        let mut payload = Vec::new();
        put_u32(&mut payload, 5);
        put_u32(&mut payload, 0); // stray extra field
        let mut cur = Cursor::new(kind::ACK, &payload).unwrap();
        assert_eq!(cur.u32("frames").unwrap(), 5);
        let err = cur.finish().unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn blocking_read_frame_round_trips_and_reports_clean_eof() {
        let mut bytes = Vec::new();
        write_frame(&mut bytes, kind::ACK, &3u32.to_le_bytes()).unwrap();
        let mut r = &bytes[..];
        let (k, payload) = read_frame(&mut r).unwrap().expect("one frame");
        assert_eq!(k, kind::ACK);
        assert_eq!(payload, 3u32.to_le_bytes());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF expected");
        // Mid-header EOF is an error, not None.
        let mut cut = &bytes[..3];
        assert!(read_frame(&mut cut).is_err());
    }

    #[test]
    fn strings_round_trip() {
        let mut payload = Vec::new();
        put_str(&mut payload, "rho=2.5\nseed=7");
        let mut cur = Cursor::new(kind::WELCOME, &payload).unwrap();
        assert_eq!(cur.str("config").unwrap(), "rho=2.5\nseed=7");
        cur.finish().unwrap();
    }

    #[test]
    fn credit_frame_round_trips() {
        let mut buf = Vec::new();
        put_credit_frame(&mut buf, 7, 123_456_789);
        assert_eq!(buf.len(), HEADER + 4 + 8);
        assert_eq!(buf[4], kind::CREDIT);
        let mut cur = Cursor::new(buf[4], &buf[HEADER..]).unwrap();
        let c = take_credit(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(c, WireCredit { frames: 7, hint: 123_456_789 });
    }

    #[test]
    fn truncated_credit_names_kind_and_field() {
        let mut buf = Vec::new();
        put_credit_frame(&mut buf, 1, 9);
        let mut cur = Cursor::new(kind::CREDIT, &buf[HEADER..buf.len() - 3]).unwrap();
        let err = take_credit(&mut cur).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("Credit frame truncated"), "{text}");
        assert!(text.contains("\"hint\""), "{text}");
    }

    #[test]
    fn heartbeat_frame_round_trips() {
        let mut buf = Vec::new();
        put_heartbeat_frame(&mut buf, 3, 77);
        assert_eq!(buf.len(), HEADER + 4 + 8);
        assert_eq!(buf[4], kind::HEARTBEAT);
        let mut cur = Cursor::new(buf[4], &buf[HEADER..]).unwrap();
        let hb = take_heartbeat(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(hb, WireHeartbeat { rank: 3, seq: 77 });
    }

    #[test]
    fn truncated_heartbeat_names_kind_and_field() {
        let mut buf = Vec::new();
        put_heartbeat_frame(&mut buf, 1, 9);
        let mut cur = Cursor::new(kind::HEARTBEAT, &buf[HEADER..buf.len() - 3]).unwrap();
        let err = take_heartbeat(&mut cur).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("Heartbeat frame truncated"), "{text}");
        assert!(text.contains("\"seq\""), "{text}");
    }

    #[test]
    fn config_update_frame_round_trips() {
        let mut buf = Vec::new();
        put_config_update_frame(&mut buf, 5, "rebalance_ms=20\nstall_warn_ms=0");
        assert_eq!(buf[4], kind::CONFIG_UPDATE);
        let mut cur = Cursor::new(buf[4], &buf[HEADER..]).unwrap();
        let (v, kv) = take_config_update(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(v, 5);
        assert_eq!(kv, "rebalance_ms=20\nstall_warn_ms=0");
    }

    #[test]
    fn truncated_config_update_names_kind_and_field() {
        let mut buf = Vec::new();
        put_config_update_frame(&mut buf, 1, "rebalance_ms=5");
        let mut cur = Cursor::new(kind::CONFIG_UPDATE, &buf[HEADER..buf.len() - 4]).unwrap();
        let err = take_config_update(&mut cur).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("ConfigUpdate frame truncated"), "{text}");
        assert!(text.contains("\"kv\""), "{text}");
    }

    #[test]
    fn sparse_pull_block_reconstructs_bit_identically() {
        let base = [1.0f32, -0.0, 2.5, f32::NAN, 0.0, 7.0];
        let mut new = base;
        new[1] = 0.0; // -0.0 -> 0.0 is a bit-level change
        new[3] = 4.0;
        new[5] = -7.0;
        let (mut idx, mut vals) = (Vec::new(), Vec::new());
        diff_block(&base, &new, &mut idx, &mut vals);
        assert_eq!(idx, [1, 3, 5]);

        let mut payload = Vec::new();
        put_pull_block_sparse(&mut payload, 3, 11, 10, &idx, &vals);
        let mut cur = Cursor::new(kind::PULL_RESP, &payload).unwrap();
        let blk = take_pull_block(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(blk.block, 3);
        assert_eq!(blk.version, 11);
        let WirePullBody::Sparse { base_version, idx: di, vals: dv } = blk.body else {
            panic!("expected sparse body");
        };
        assert_eq!(base_version, 10);
        let mut got = base;
        apply_sparse_patch(&mut got, &di, &dv).unwrap();
        for (g, n) in got.iter().zip(new.iter()) {
            assert_eq!(g.to_bits(), n.to_bits());
        }
    }

    #[test]
    fn dense_pull_block_round_trips() {
        let data = [0.5f32, -1.5, 3.25];
        let mut payload = Vec::new();
        put_pull_block_dense(&mut payload, 9, 42, &data);
        let mut cur = Cursor::new(kind::PULL_RESP, &payload).unwrap();
        let blk = take_pull_block(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!((blk.block, blk.version), (9, 42));
        assert_eq!(blk.body, WirePullBody::Dense(data.to_vec()));
    }

    #[test]
    fn pull_block_rejects_unknown_tag_and_bad_patch_index() {
        let mut payload = Vec::new();
        put_u32(&mut payload, 0);
        put_u64(&mut payload, 1);
        payload.push(7); // unknown encoding tag
        let mut cur = Cursor::new(kind::PULL_RESP, &payload).unwrap();
        let err = take_pull_block(&mut cur).unwrap_err();
        assert!(format!("{err:#}").contains("unknown block encoding tag"), "{err:#}");

        let mut dst = [0.0f32; 4];
        let err = apply_sparse_patch(&mut dst, &[4], &[1.0]).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn sparse_chooser_matches_encoded_bytes() {
        for db in [1usize, 4, 16, 256] {
            for changed in 0..=db {
                let idx: Vec<u32> = (0..changed as u32).collect();
                let vals = vec![1.0f32; changed];
                let data = vec![1.0f32; db];
                let mut sparse = Vec::new();
                put_pull_block_sparse(&mut sparse, 0, 2, 1, &idx, &vals);
                let mut dense = Vec::new();
                put_pull_block_dense(&mut dense, 0, 2, &data);
                assert_eq!(
                    sparse_saves_bytes(changed, db),
                    sparse.len() < dense.len(),
                    "db={db} changed={changed}: sparse {} vs dense {}",
                    sparse.len(),
                    dense.len()
                );
            }
        }
    }
}

//! [`TcpTransport`] — the [`Transport`] contract over real sockets.
//!
//! Same observable semantics as the in-process mpsc/ring transports —
//! per-(worker, server) FIFO lanes, an **exact** `inflight_bound`, a
//! drain-then-`None` shutdown, per-lane hang-up errors, reconnect that
//! resumes the same FIFO stream — so every layer above (seq-gated
//! apply, work stealing, dynamic re-placement, `failure=degrade|
//! restart`) runs unchanged whether the peer is a thread or a process.
//!
//! ## Shape
//!
//! One listener (ephemeral loopback for `--set transport=tcp` inside a
//! process; the `--listen` address for `asybadmm serve`), one
//! **sequential acceptor thread** that reads each connection's hello
//! frame and parks push sockets into their (worker, server) lane queue
//! — sequential accept + park preserves socket arrival order, which is
//! what makes reconnect gap-free: the replacement socket can only be
//! parked after the dead one.  Non-push hellos (`JoinCtl`,
//! `HelloPull`) are handed to the serve-mode control plane
//! (`coordinator/net/proc.rs`).
//!
//! ## Exact backpressure over TCP
//!
//! Kernel socket buffers are invisible and huge, so the in-flight
//! bound is enforced with application-level **credits counted in
//! frames**: a lane starts with `cap_b = ceil(cap / batch)` credits,
//! every push frame (full or partial batch) spends one, and the lane
//! receiver grants credits back the moment it *decodes* frames.  With
//! no receiver decoding, a sender therefore stalls after exactly
//! `cap_b × batch` queued messages plus `batch − 1` buffered in its
//! partial batch — `inflight_bound = cap_b·batch + batch − 1`, the
//! same accounting the SPSC ring reports.  Outstanding wire bytes are
//! bounded by `cap_b` frames, so a blocked receiver never balloons
//! kernel memory either.
//!
//! Credits are **coalesced**: instead of one `Ack` frame per decoded
//! push frame, the receiver accumulates owed credits and returns one
//! cumulative `Credit{frames, hint}` frame when the debt reaches half
//! the window (`max(1, cap_b/2)`) or when its socket goes idle
//! (`Poll::Pending`) — so a drain pass over a burst costs O(1) reverse
//! frames instead of O(frames), while the idle flush guarantees the
//! sender can never be left waiting on withheld credits (liveness
//! holds even at `cap_b = 1`, where the threshold degenerates to the
//! per-frame behavior).  Coalescing only *delays* credit return within
//! a drain pass, never changes the total granted, so the inflight
//! bound above stays exact (conformance-gated).  The `hint` field
//! piggybacks the server's z̃ publish counter for the adaptive pull
//! cadence (`coordinator/net/proc.rs`).
//!
//! ## Pooled buffers
//!
//! The sender serializes `w` out of the pooled buffer and recycles it
//! at encode time; the receiver re-materializes into a lane-local
//! [`LeasePool`] free list.  Buffer conservation holds independently on
//! each side; nothing allocates per message in steady state.

use std::collections::VecDeque;
use std::io::{ErrorKind, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender as MpscSender;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::super::bufpool::LeasePool;
use super::super::fault::FaultPlan;
use super::super::messages::PushMsg;
use super::super::transport::{Backoff, PushReceiver, PushSender, Transport, TryRecv};
use super::wire::{self, kind, FrameReader, Poll};

/// How long the acceptor waits for a connection's hello frame before
/// dropping it (a stuck dialer must not wedge the accept loop).
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);
/// Bounded best-effort flush window for a dropped sender's partial
/// batch (the explicit-flush paths wait on credits indefinitely).
const DROP_FLUSH_DEADLINE: Duration = Duration::from_millis(250);

/// A non-push connection routed off the acceptor to the serve-mode
/// control plane: the hello frame that identified it plus the stream,
/// back in blocking mode.
pub struct CtlConn {
    pub kind: u8,
    pub payload: Vec<u8>,
    pub stream: TcpStream,
}

/// Per-(worker, server) lane state shared between the acceptor, the
/// sender (in-process fast-path close detection) and the lane receiver.
struct LaneShared {
    /// Replacement sockets parked by the acceptor, oldest first.
    incoming: Mutex<VecDeque<TcpStream>>,
    /// The receiving endpoint was dropped: senders fail fast with
    /// "server S hung up" instead of waiting for a socket error.
    closed: AtomicBool,
    /// Sockets ever dialed at this lane (local dials count at dial
    /// time, remote ones when their hello is parked).  The lane is
    /// drained only once it has consumed EOF on this many sockets.
    dialed: AtomicUsize,
}

/// Listener-side wire counters, shared by every lane receiver (one
/// `fetch_add` per *frame*, not per message, so they cost nothing the
/// hot path can feel).  Surfaced through `/stats` in serve mode and
/// read directly by the `credit_coalescing_frames` bench gate.
#[derive(Default)]
pub struct WireCounters {
    /// Push / PushBatch frames decoded.
    pub push_frames_in: AtomicU64,
    /// Envelope + payload bytes of those frames.
    pub push_bytes_in: AtomicU64,
    /// Push messages decoded out of those frames.
    pub msgs_in: AtomicU64,
    /// Credit frames written back to senders.
    pub credit_frames_out: AtomicU64,
    /// Frame credits granted inside those Credit frames.
    pub credits_out: AtomicU64,
}

/// A point-in-time copy of [`WireCounters`].
#[derive(Debug, Clone, Copy, Default)]
pub struct WireSnapshot {
    pub push_frames_in: u64,
    pub push_bytes_in: u64,
    pub msgs_in: u64,
    pub credit_frames_out: u64,
    pub credits_out: u64,
}

impl WireCounters {
    /// Relaxed point-in-time copy (monitoring only).
    pub fn snapshot(&self) -> WireSnapshot {
        WireSnapshot {
            push_frames_in: self.push_frames_in.load(Ordering::Relaxed),
            push_bytes_in: self.push_bytes_in.load(Ordering::Relaxed),
            msgs_in: self.msgs_in.load(Ordering::Relaxed),
            credit_frames_out: self.credit_frames_out.load(Ordering::Relaxed),
            credits_out: self.credits_out.load(Ordering::Relaxed),
        }
    }
}

struct Shared {
    addr: SocketAddr,
    n_workers: usize,
    n_servers: usize,
    /// Credit window per lane, in frames.
    cap_b: usize,
    batch: usize,
    shutdown: AtomicBool,
    stop_accept: AtomicBool,
    /// `lanes[server][worker]`.
    lanes: Vec<Vec<LaneShared>>,
    worker_connected: Vec<AtomicBool>,
    server_taken: Vec<AtomicBool>,
    /// Serve-mode hook: where the acceptor routes non-push hellos.
    ctl: Mutex<Option<MpscSender<CtlConn>>>,
    /// Listener-side wire counters (all lanes).
    wire: Arc<WireCounters>,
    /// z̃ publish counter piggybacked on Credit frames (serve mode sets
    /// it to the coordinator store's counter; unset = hint 0).
    hint: OnceLock<Arc<AtomicU64>>,
}

impl Shared {
    fn lane(&self, server: usize, worker: usize) -> &LaneShared {
        &self.lanes[server][worker]
    }
}

/// TCP implementation of [`Transport`] (see module docs).
pub struct TcpTransport {
    shared: Arc<Shared>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
}

impl TcpTransport {
    /// In-process loopback transport (`--set transport=tcp`): binds an
    /// ephemeral 127.0.0.1 port.  `cap` is the per-lane in-flight
    /// message budget (the ring's `ring_cap` analogue); the credit
    /// window is `ceil(cap / batch)` frames.
    pub fn new(n_workers: usize, n_servers: usize, cap: usize, batch: usize) -> Self {
        Self::bind("127.0.0.1:0", n_workers, n_servers, cap, batch)
            .expect("bind ephemeral loopback listener")
    }

    /// Bind `listen` and start the acceptor (the `asybadmm serve`
    /// entry; malformed addresses error with the `host:port` form).
    pub fn bind(
        listen: &str,
        n_workers: usize,
        n_servers: usize,
        cap: usize,
        batch: usize,
    ) -> Result<Self> {
        assert!(batch >= 1, "batch must be >= 1");
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("listen address {listen:?} (expected host:port)"))?;
        let addr = listener.local_addr().context("listener local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let cap_b = cap.div_ceil(batch).max(1);
        let shared = Arc::new(Shared {
            addr,
            n_workers,
            n_servers,
            cap_b,
            batch,
            shutdown: AtomicBool::new(false),
            stop_accept: AtomicBool::new(false),
            lanes: (0..n_servers)
                .map(|_| {
                    (0..n_workers)
                        .map(|_| LaneShared {
                            incoming: Mutex::new(VecDeque::new()),
                            closed: AtomicBool::new(false),
                            dialed: AtomicUsize::new(0),
                        })
                        .collect()
                })
                .collect(),
            worker_connected: (0..n_workers).map(|_| AtomicBool::new(false)).collect(),
            server_taken: (0..n_servers).map(|_| AtomicBool::new(false)).collect(),
            ctl: Mutex::new(None),
            wire: Arc::new(WireCounters::default()),
            hint: OnceLock::new(),
        });
        let accept_shared = shared.clone();
        let acceptor = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .context("spawn acceptor")?;
        Ok(TcpTransport { shared, acceptor: Mutex::new(Some(acceptor)) })
    }

    /// The bound address (resolves a `:0` listen port).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serve mode: route `JoinCtl`/`HelloPull` connections to `hook`
    /// instead of dropping them.
    pub fn set_ctl_hook(&self, hook: MpscSender<CtlConn>) {
        *self.shared.ctl.lock().unwrap() = Some(hook);
    }

    /// Serve mode: piggyback this monotone publish counter as the
    /// `hint` field of every Credit frame (the [`crate::coordinator::
    /// BlockStore`] publish counter), letting workers' pull streams
    /// learn about new z̃ versions without a poll round-trip.  Set once
    /// before workers join; later calls are ignored.
    pub fn set_version_hint(&self, counter: Arc<AtomicU64>) {
        let _ = self.shared.hint.set(counter);
    }

    /// Copy of the listener-side wire counters.
    pub fn wire_snapshot(&self) -> WireSnapshot {
        self.shared.wire.snapshot()
    }

    /// Shared handle on the live listener-side counters (the `/stats`
    /// closure outlives this struct's borrow).
    pub fn wire_counters(&self) -> Arc<WireCounters> {
        self.shared.wire.clone()
    }

    /// Serve-mode eviction (`failure=degrade`): force-close every lane
    /// of `worker` — the `Transport::close_and_drain` semantics over
    /// sockets.  Local senders fail fast on the `closed` flag, parked
    /// replacement sockets are orphaned, and the acceptor refuses any
    /// later `HelloPush` for these lanes, so an evicted (possibly
    /// zombie) process can never re-enter the seq streams after its
    /// parked early-arrivals were purged.
    pub fn close_worker_lanes(&self, worker: usize) {
        assert!(worker < self.shared.n_workers, "worker {worker} out of range");
        for server in 0..self.shared.n_servers {
            let lane = self.shared.lane(server, worker);
            lane.closed.store(true, Ordering::Release);
            lane.incoming.lock().unwrap().clear();
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shared.stop_accept.store(true, Ordering::Release);
        if let Some(h) = self.acceptor.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    while !shared.stop_accept.load(Ordering::Acquire) {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(500));
                continue;
            }
            Err(_) => continue,
        };
        // Sequential hello read: parking order == connection order,
        // the property reconnect's gap-free FIFO relies on.
        let _ = admit(stream, &shared);
    }
}

/// Read one hello frame (blocking, bounded) and route the connection.
fn admit(stream: TcpStream, shared: &Shared) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(HELLO_TIMEOUT)).ok();
    let mut s = stream;
    let Some((k, payload)) = wire::read_frame(&mut s)? else {
        return Ok(()); // dialed and closed without a hello
    };
    match k {
        kind::HELLO_PUSH => {
            let mut cur = wire::Cursor::new(k, &payload)?;
            let worker = cur.u32("worker")? as usize;
            let server = cur.u32("server")? as usize;
            let local = cur.u8("local")?;
            cur.finish()?;
            if worker >= shared.n_workers || server >= shared.n_servers {
                bail!("hello for unknown lane (worker {worker}, server {server})");
            }
            if shared.lane(server, worker).closed.load(Ordering::Acquire) {
                // Evicted worker (failure=degrade): its streams were
                // purged; a late reconnect must not re-enter them.
                bail!("lane (worker {worker}, server {server}) is closed (worker evicted)");
            }
            s.set_read_timeout(None).ok();
            s.set_nonblocking(true).context("nonblocking lane socket")?;
            let lane = shared.lane(server, worker);
            if local == 0 {
                // Remote dials are counted when they arrive; local ones
                // were counted at dial time (see connect_lanes).
                lane.dialed.fetch_add(1, Ordering::Release);
                shared.worker_connected[worker].store(true, Ordering::Release);
            }
            lane.incoming.lock().unwrap().push_back(s);
            Ok(())
        }
        kind::JOIN_CTL | kind::HELLO_PULL => {
            s.set_read_timeout(None).ok();
            let hook = shared.ctl.lock().unwrap().clone();
            match hook {
                Some(tx) => {
                    let _ = tx.send(CtlConn { kind: k, payload, stream: s });
                    Ok(())
                }
                None => bail!("{} connection without a control plane", wire::kind_name(k)),
            }
        }
        other => bail!("unexpected {} hello frame", wire::kind_name(other)),
    }
}

// ---------------------------------------------------------------------
// Sender
// ---------------------------------------------------------------------

enum Link {
    /// Same process as the listener: lane close and shutdown are
    /// observable through the shared flags, no socket error needed.
    Local(Arc<Shared>),
    /// A worker process: hang-up is discovered via EPIPE/EOF.
    Remote,
}

struct SendConn {
    stream: TcpStream,
    /// Credit stream accumulator.
    reader: FrameReader,
    credits: usize,
    eof: bool,
    /// Per-connection wire counters (this process's side of the lane).
    frames_out: u64,
    bytes_out: u64,
    credit_frames_in: u64,
}

/// Per-worker sending endpoint: one socket + credit window per server,
/// batching up to `batch` messages per frame.
pub struct TcpPushSender {
    link: Link,
    worker: usize,
    batch: usize,
    conns: Vec<SendConn>,
    pending: Vec<Vec<PushMsg>>,
    /// Reused frame-encode buffer.
    wire_buf: Vec<u8>,
    /// Where Credit-frame version hints land (max-merged): the worker
    /// process's pull cadence resets when this advances.
    hint_sink: Option<Arc<AtomicU64>>,
    /// Wire-level fault injection (`netdrop:`/`netstall:` entries);
    /// `None` or an empty plan costs one branch per send.
    faults: Option<Arc<FaultPlan>>,
}

/// Dial one lane socket and say hello.
fn dial_lane(
    addr: &SocketAddr,
    worker: usize,
    server: usize,
    local: bool,
    cap_b: usize,
) -> Result<SendConn> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect to coordinator at {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut hello = Vec::with_capacity(16);
    wire::put_u32(&mut hello, worker as u32);
    wire::put_u32(&mut hello, server as u32);
    hello.push(u8::from(local));
    wire::write_frame(&mut stream, kind::HELLO_PUSH, &hello)
        .with_context(|| format!("hello to server {server}"))?;
    stream.set_nonblocking(true).context("nonblocking lane socket")?;
    Ok(SendConn {
        stream,
        reader: FrameReader::new(),
        credits: cap_b,
        eof: false,
        frames_out: 0,
        bytes_out: 0,
        credit_frames_in: 0,
    })
}

fn connect_lanes(shared: &Arc<Shared>, worker: usize) -> TcpPushSender {
    let mut conns = Vec::with_capacity(shared.n_servers);
    for server in 0..shared.n_servers {
        // Count the dial BEFORE the hello goes out so a lane's drain
        // check (`consumed == dialed`) can never run ahead of a socket
        // the acceptor has yet to park.
        shared.lane(server, worker).dialed.fetch_add(1, Ordering::Release);
        conns.push(
            dial_lane(&shared.addr, worker, server, true, shared.cap_b)
                .expect("dial in-process lane"),
        );
    }
    shared.worker_connected[worker].store(true, Ordering::Release);
    TcpPushSender {
        link: Link::Local(shared.clone()),
        worker,
        batch: shared.batch,
        conns,
        pending: (0..shared.n_servers).map(|_| Vec::new()).collect(),
        wire_buf: Vec::new(),
        hint_sink: None,
        faults: None,
    }
}

impl TcpPushSender {
    /// Worker-process endpoint: dial `n_servers` lanes of the
    /// coordinator at `addr`.  `cap` and `batch` must match the
    /// coordinator's config (the handshake ships them).
    pub fn connect_remote(
        addr: &SocketAddr,
        worker: usize,
        n_servers: usize,
        cap: usize,
        batch: usize,
    ) -> Result<Self> {
        let cap_b = cap.div_ceil(batch).max(1);
        let mut conns = Vec::with_capacity(n_servers);
        for server in 0..n_servers {
            conns.push(dial_lane(addr, worker, server, false, cap_b)?);
        }
        Ok(TcpPushSender {
            link: Link::Remote,
            worker,
            batch,
            conns,
            pending: (0..n_servers).map(|_| Vec::new()).collect(),
            wire_buf: Vec::new(),
            hint_sink: None,
            faults: None,
        })
    }

    /// Arm wire-level fault injection on this sender.  `netdrop:wW@E`
    /// severs every lane socket at the first push of epoch `E`
    /// (simulating a network partition — the next flush surfaces the
    /// same "server hung up" error a real reset would); `netstall:wW@P+MSms`
    /// freezes the push stream for `MS` ms once `P` frames have gone
    /// out.  An empty plan is a single `is_empty` branch per call.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Sever every lane socket in both directions: in-flight kernel
    /// bytes are discarded where possible and every subsequent flush
    /// fails like a peer reset.
    fn sever_all(&mut self) {
        for conn in &mut self.conns {
            let _ = conn.stream.shutdown(Shutdown::Both);
            conn.eof = true;
        }
    }

    /// Publish Credit-frame version hints into `sink` (max-merged —
    /// hints are monotone counters, so a stale frame can never move the
    /// sink backwards).  The worker process shares one sink across all
    /// its senders and its pull-sync thread.
    pub fn set_hint_sink(&mut self, sink: Arc<AtomicU64>) {
        self.hint_sink = Some(sink);
    }

    /// Totals of the per-connection wire counters:
    /// `(push frames out, bytes out, credit frames in)`.
    pub fn wire_totals(&self) -> (u64, u64, u64) {
        self.conns.iter().fold((0, 0, 0), |(f, b, c), conn| {
            (f + conn.frames_out, b + conn.bytes_out, c + conn.credit_frames_in)
        })
    }

    fn lane_closed(&self, server: usize) -> bool {
        match &self.link {
            Link::Local(sh) => sh.lane(server, self.worker).closed.load(Ordering::Acquire),
            Link::Remote => false,
        }
    }

    fn is_shutdown(&self) -> bool {
        match &self.link {
            Link::Local(sh) => sh.shutdown.load(Ordering::Acquire),
            Link::Remote => false,
        }
    }

    /// Drain any credits the receiver has returned — coalesced
    /// `Credit{frames, hint}` frames, plus the legacy per-frame `Ack`
    /// for continuity — and flip `eof` when the peer is gone.  Version
    /// hints are max-merged into `hint_sink` (monotone, so out-of-order
    /// frames across lanes can never move it backwards).
    fn poll_acks(conn: &mut SendConn, hint_sink: Option<&AtomicU64>) -> Result<()> {
        if conn.eof {
            return Ok(());
        }
        loop {
            match conn.reader.poll(&mut conn.stream) {
                Ok(Poll::Frame) => {
                    let k = conn.reader.frame_kind();
                    let payload = conn.reader.payload();
                    let mut cur = wire::Cursor::new(k, payload)?;
                    let (frames, hint) = match k {
                        kind::CREDIT => {
                            let c = wire::take_credit(&mut cur)?;
                            (c.frames as usize, c.hint)
                        }
                        kind::ACK => (cur.u32("frames")? as usize, 0),
                        other => {
                            bail!("unexpected {} frame on credit stream", wire::kind_name(other))
                        }
                    };
                    cur.finish()?;
                    conn.reader.consume();
                    conn.credits += frames;
                    conn.credit_frames_in += 1;
                    if hint > 0 {
                        if let Some(sink) = hint_sink {
                            sink.fetch_max(hint, Ordering::Relaxed);
                        }
                    }
                }
                Ok(Poll::Pending) => return Ok(()),
                Ok(Poll::Eof) | Err(_) => {
                    conn.eof = true;
                    return Ok(());
                }
            }
        }
    }

    /// Encode + write the pending batch for `server`, spending one
    /// credit (waiting for one if the window is exhausted).
    fn flush_server(&mut self, server: usize) -> Result<()> {
        if self.pending[server].is_empty() {
            return Ok(());
        }
        if let Some(plan) = &self.faults {
            if !plan.is_empty() {
                let frames = self.conns.iter().map(|c| c.frames_out).sum::<u64>() as usize;
                if let Some(ms) = plan.net_stall_ms(self.worker, frames) {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
        }
        let mut backoff = Backoff::new();
        loop {
            if self.lane_closed(server) {
                self.pending[server].clear(); // Drop recycles the buffers
                bail!("server {server} hung up");
            }
            Self::poll_acks(&mut self.conns[server], self.hint_sink.as_deref())?;
            let conn = &mut self.conns[server];
            if conn.eof {
                self.pending[server].clear();
                bail!("server {server} hung up");
            }
            if conn.credits > 0 {
                conn.credits -= 1;
                break;
            }
            if self.is_shutdown() {
                self.pending[server].clear();
                bail!("transport shut down with pushes still in flight to server {server}");
            }
            backoff.snooze();
        }
        // Serialize, recycling each pooled buffer at encode time: the
        // bytes travel, the buffer goes straight home.
        self.wire_buf.clear();
        let n = self.pending[server].len();
        let start = if n == 1 {
            wire::begin_frame(&mut self.wire_buf, kind::PUSH)
        } else {
            let s = wire::begin_frame(&mut self.wire_buf, kind::PUSH_BATCH);
            wire::put_u32(&mut self.wire_buf, n as u32);
            s
        };
        for mut m in self.pending[server].drain(..) {
            wire::put_push_body(&mut self.wire_buf, &m);
            m.recycle_now();
        }
        wire::end_frame(&mut self.wire_buf, start);
        let conn = &mut self.conns[server];
        if let Err(e) = write_all_nb(&mut conn.stream, &self.wire_buf) {
            conn.eof = true;
            bail!("server {server} hung up ({e})");
        }
        conn.frames_out += 1;
        conn.bytes_out += self.wire_buf.len() as u64;
        Ok(())
    }
}

/// `write_all` on a non-blocking socket: spin through `WouldBlock`
/// (bounded by the credit window — at most `cap_b` small frames are
/// ever outstanding, so the kernel buffer drains without the peer's
/// application reading).
fn write_all_nb(stream: &mut TcpStream, mut buf: &[u8]) -> std::io::Result<()> {
    let mut backoff = Backoff::new();
    while !buf.is_empty() {
        match stream.write(buf) {
            Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
            Ok(n) => {
                buf = &buf[n..];
                backoff.reset();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => backoff.snooze(),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

impl PushSender for TcpPushSender {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        if let Some(plan) = self.faults.clone() {
            if !plan.is_empty() && plan.net_drop(self.worker, msg.worker_epoch) {
                self.sever_all();
            }
        }
        if self.lane_closed(server) || self.conns[server].eof {
            drop(msg); // recycles the pooled buffer
            bail!("server {server} hung up");
        }
        self.pending[server].push(msg);
        if self.pending[server].len() >= self.batch {
            self.flush_server(server)?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        for server in 0..self.conns.len() {
            self.flush_server(server)?;
        }
        Ok(())
    }
}

impl Drop for TcpPushSender {
    /// Best-effort bounded flush of partial batches, mirroring the
    /// in-process senders' drop-flush: a crashed worker's buffered tail
    /// still reaches the wire when credits allow, and gives up (the
    /// messages' own `Drop` recycles their buffers) rather than hang.
    fn drop(&mut self) {
        let deadline = Instant::now() + DROP_FLUSH_DEADLINE;
        for server in 0..self.conns.len() {
            while !self.pending[server].is_empty()
                && !self.lane_closed(server)
                && !self.conns[server].eof
            {
                let _ = Self::poll_acks(&mut self.conns[server], self.hint_sink.as_deref());
                if self.conns[server].credits > 0 {
                    let _ = self.flush_server(server);
                    break;
                }
                if Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        // Dropping the streams sends FIN: receivers see EOF after the
        // last written frame, never before it.
    }
}

// ---------------------------------------------------------------------
// Receiver
// ---------------------------------------------------------------------

/// One (worker, server) lane: current socket + parked replacements +
/// decoded-but-unconsumed messages.
pub struct TcpLaneReceiver {
    shared: Arc<Shared>,
    server: usize,
    worker: usize,
    conn: Option<TcpStream>,
    reader: FrameReader,
    queue: VecDeque<PushMsg>,
    pool: LeasePool,
    /// Sockets consumed through EOF (drain accounting vs `dialed`).
    consumed: usize,
    done: bool,
    /// Frame credits owed to the current socket's sender but not yet
    /// written — coalesced into one Credit frame at the flush threshold
    /// or on idle.  Credits are a per-socket window, so this resets to
    /// 0 whenever the socket is retired (a reconnecting sender starts
    /// with a fresh window; stale debt must not leak into it).
    owed: u32,
    /// Reused Credit-frame encode buffer.
    credit_buf: Vec<u8>,
}

impl TcpLaneReceiver {
    fn new(shared: Arc<Shared>, server: usize, worker: usize) -> Self {
        TcpLaneReceiver {
            shared,
            server,
            worker,
            conn: None,
            reader: FrameReader::new(),
            queue: VecDeque::new(),
            pool: LeasePool::new(),
            consumed: 0,
            done: false,
            owed: 0,
            credit_buf: Vec::with_capacity(wire::HEADER + 12),
        }
    }

    /// Credits owed at or past which a Credit frame is written without
    /// waiting for idle: half the window, so the sender never sees the
    /// window run dry mid-burst.  At `cap_b = 1` this is 1 — the
    /// per-frame behavior, the only live option with a window of one.
    fn credit_flush_threshold(&self) -> u32 {
        ((self.shared.cap_b / 2).max(1)) as u32
    }

    /// Write one coalesced `Credit{frames, hint}` frame returning all
    /// owed credits on the current socket.  A vanished sender is not an
    /// error here (its replacement gets a fresh window).
    fn flush_credits(&mut self) {
        if self.owed == 0 {
            return;
        }
        let Some(conn) = self.conn.as_mut() else { return };
        let hint = self
            .shared
            .hint
            .get()
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0);
        self.credit_buf.clear();
        wire::put_credit_frame(&mut self.credit_buf, self.owed, hint);
        let _ = write_all_nb(conn, &self.credit_buf);
        self.shared.wire.credit_frames_out.fetch_add(1, Ordering::Relaxed);
        self.shared.wire.credits_out.fetch_add(self.owed as u64, Ordering::Relaxed);
        self.owed = 0;
    }

    /// Retire the current socket (EOF or corruption).  Owed credits die
    /// with it: the window is per-socket, and a reconnecting sender
    /// starts with a fresh `cap_b`.
    fn retire_socket(&mut self) {
        self.conn = None;
        self.reader = FrameReader::new();
        self.consumed += 1;
        self.owed = 0;
    }

    /// Decode the frame currently buffered in `self.reader` into
    /// `self.queue` and account one owed credit (returned coalesced —
    /// see [`Self::flush_credits`]).
    fn decode_frame(&mut self) -> Result<()> {
        let k = self.reader.frame_kind();
        let payload = self.reader.payload();
        let frame_bytes = (wire::HEADER + payload.len()) as u64;
        let mut cur = wire::Cursor::new(k, payload)?;
        let count = match k {
            kind::PUSH => 1,
            kind::PUSH_BATCH => cur.u32("count")? as usize,
            other => bail!("unexpected {} frame on push lane", wire::kind_name(other)),
        };
        let pool = &mut self.pool;
        let recycle = pool.recycler();
        let mut decoded = Vec::with_capacity(count);
        {
            let mut alloc = |n: usize| pool.acquire(n);
            for _ in 0..count {
                let p = wire::take_push_body(&mut cur, &mut alloc)?;
                decoded.push(p);
            }
        }
        cur.finish()?;
        self.reader.consume();
        for p in decoded {
            self.queue.push_back(PushMsg::from_wire(
                p.worker,
                p.block,
                p.w,
                p.worker_epoch,
                p.z_version_used,
                p.block_seq,
                Some(recycle.clone()),
            ));
        }
        let wire_stats = &self.shared.wire;
        wire_stats.push_frames_in.fetch_add(1, Ordering::Relaxed);
        wire_stats.push_bytes_in.fetch_add(frame_bytes, Ordering::Relaxed);
        wire_stats.msgs_in.fetch_add(count as u64, Ordering::Relaxed);
        // Credit return: one frame decoded = one credit owed, written
        // coalesced on the same socket once the debt reaches the flush
        // threshold (or at idle, in `try_recv`).
        self.owed += 1;
        if self.owed >= self.credit_flush_threshold() {
            self.flush_credits();
        }
        Ok(())
    }
}

impl PushReceiver for TcpLaneReceiver {
    fn try_recv(&mut self) -> TryRecv {
        loop {
            if let Some(m) = self.queue.pop_front() {
                return TryRecv::Msg(m);
            }
            if self.done {
                return TryRecv::Done;
            }
            if self.conn.is_some()
                && self.shared.lane(self.server, self.worker).closed.load(Ordering::Acquire)
            {
                // Evicted mid-run (`close_worker_lanes`): drop the live
                // socket too, so a stopped-but-undead peer cannot keep
                // feeding frames after its pending pushes were purged.
                self.retire_socket();
            }
            if self.conn.is_none() {
                let next =
                    self.shared.lane(self.server, self.worker).incoming.lock().unwrap().pop_front();
                match next {
                    Some(s) => {
                        self.conn = Some(s);
                        self.reader = FrameReader::new();
                    }
                    None => {
                        // Nothing connected right now: drained only if
                        // shut down AND every dialed socket was fully
                        // consumed (a dial is counted before its socket
                        // can be parked, so this cannot run ahead).  A
                        // closed lane waives the socket accounting: its
                        // parked replacements were discarded unread by
                        // the eviction, not consumed.
                        let lane = self.shared.lane(self.server, self.worker);
                        if self.shared.shutdown.load(Ordering::Acquire)
                            && (lane.closed.load(Ordering::Acquire)
                                || self.consumed >= lane.dialed.load(Ordering::Acquire))
                            && lane.incoming.lock().unwrap().is_empty()
                        {
                            self.done = true;
                            return TryRecv::Done;
                        }
                        return TryRecv::Empty;
                    }
                }
            }
            let conn = self.conn.as_mut().expect("conn set above");
            match self.reader.poll(conn) {
                Ok(Poll::Frame) => {
                    if let Err(e) = self.decode_frame() {
                        // A corrupted lane cannot be resynchronized;
                        // surface loudly and retire the socket.
                        eprintln!(
                            "tcp lane (worker {}, server {}): {e:#}",
                            self.worker, self.server
                        );
                        self.retire_socket();
                    }
                }
                Ok(Poll::Pending) => {
                    // Idle flush: the socket has nothing more right
                    // now, so return every owed credit before going
                    // quiet — a sender blocked on the window always
                    // unblocks within one drain pass (liveness, even
                    // at cap_b = 1).
                    self.flush_credits();
                    return TryRecv::Empty;
                }
                Ok(Poll::Eof) => {
                    self.retire_socket();
                }
                Err(e) => {
                    eprintln!(
                        "tcp lane (worker {}, server {}): {e:#}",
                        self.worker, self.server
                    );
                    self.retire_socket();
                }
            }
        }
    }

    fn recv(&mut self) -> Option<PushMsg> {
        let mut backoff = Backoff::new();
        loop {
            match self.try_recv() {
                TryRecv::Msg(m) => return Some(m),
                TryRecv::Done => return None,
                TryRecv::Empty => backoff.snooze(),
            }
        }
    }
}

impl Drop for TcpLaneReceiver {
    fn drop(&mut self) {
        let lane = self.shared.lane(self.server, self.worker);
        lane.closed.store(true, Ordering::Release);
        // Orphan any parked replacements too: with the endpoint gone
        // their senders get EPIPE (remote) or the closed flag (local).
        lane.incoming.lock().unwrap().clear();
        // Queued messages drop here; their buffers recycle into the
        // lane pool, which drops with them — nothing is stranded.
    }
}

/// The single-endpoint view: all of one server's lanes behind one
/// receiver, drained round-robin (fair across workers, FIFO within
/// each).
pub struct TcpServerReceiver {
    lanes: Vec<TcpLaneReceiver>,
    next: usize,
}

impl PushReceiver for TcpServerReceiver {
    fn try_recv(&mut self) -> TryRecv {
        let n = self.lanes.len();
        if n == 0 {
            return TryRecv::Done;
        }
        let mut done = 0;
        for i in 0..n {
            let idx = (self.next + i) % n;
            match self.lanes[idx].try_recv() {
                TryRecv::Msg(m) => {
                    self.next = (idx + 1) % n;
                    return TryRecv::Msg(m);
                }
                TryRecv::Done => done += 1,
                TryRecv::Empty => {}
            }
        }
        if done == n {
            TryRecv::Done
        } else {
            TryRecv::Empty
        }
    }

    fn recv(&mut self) -> Option<PushMsg> {
        let mut backoff = Backoff::new();
        loop {
            match self.try_recv() {
                TryRecv::Msg(m) => return Some(m),
                TryRecv::Done => return None,
                TryRecv::Empty => backoff.snooze(),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Transport impl
// ---------------------------------------------------------------------

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        assert!(worker < self.shared.n_workers, "worker {worker} out of range");
        Box::new(connect_lanes(&self.shared, worker))
    }

    fn reconnect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        assert!(
            self.shared.worker_connected[worker].load(Ordering::Acquire),
            "reconnect_worker({worker}): worker never connected"
        );
        // Fresh sockets, parked behind the dead ones: the acceptor's
        // sequential ordering + per-socket FIFO resume the stream
        // gap-free once the old tail is consumed.
        Box::new(connect_lanes(&self.shared, worker))
    }

    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver> {
        if self.shared.server_taken[server].swap(true, Ordering::AcqRel) {
            panic!("server {server} endpoint already taken");
        }
        let lanes = (0..self.shared.n_workers)
            .map(|w| TcpLaneReceiver::new(self.shared.clone(), server, w))
            .collect();
        Box::new(TcpServerReceiver { lanes, next: 0 })
    }

    fn connect_server_lanes(&self, server: usize) -> Vec<Box<dyn PushReceiver>> {
        if self.shared.server_taken[server].swap(true, Ordering::AcqRel) {
            panic!("server {server} endpoint already taken");
        }
        (0..self.shared.n_workers)
            .map(|w| {
                Box::new(TcpLaneReceiver::new(self.shared.clone(), server, w))
                    as Box<dyn PushReceiver>
            })
            .collect()
    }

    fn inflight_bound(&self) -> usize {
        self.shared.cap_b * self.shared.batch + (self.shared.batch - 1)
    }

    fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    /// `write_all_nb` through a saturated socket: std can't shrink
    /// SO_SNDBUF, so saturate the default kernel buffers instead — a
    /// payload far larger than any default send+receive window, with
    /// the reader deliberately asleep so the writer *must* ride
    /// `WouldBlock` via the shared `Backoff` (not a hot spin) until the
    /// reader drains.  Asserts completion and byte-exact integrity.
    #[test]
    fn write_all_nb_survives_a_full_send_buffer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        // 16 MiB of a rolling pattern (compressible by nothing in the
        // kernel path; position-dependent so reordering would show).
        let payload: Vec<u8> = (0..16usize << 20).map(|i| (i % 251) as u8).collect();
        let expect = payload.clone();

        let writer = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.set_nonblocking(true).unwrap();
            write_all_nb(&mut stream, &payload).unwrap();
            // Keep the socket open until the reader is done (FIN after
            // the last byte, never before).
            stream
        });

        let (mut conn, _) = listener.accept().unwrap();
        // Let the writer hit the kernel buffer wall before draining.
        std::thread::sleep(Duration::from_millis(50));
        let mut got = Vec::with_capacity(expect.len());
        let mut chunk = [0u8; 64 * 1024];
        while got.len() < expect.len() {
            let n = conn.read(&mut chunk).unwrap();
            assert!(n > 0, "EOF before the full payload arrived ({} bytes)", got.len());
            got.extend_from_slice(&chunk[..n]);
        }
        let _ = writer.join().unwrap();
        assert_eq!(got.len(), expect.len());
        assert!(got == expect, "payload corrupted in flight");
    }

    /// The coalesced credit path returns every credit: push a burst
    /// through a loopback lane, drain it, and check the listener-side
    /// counters — all credits granted, in strictly fewer Credit frames
    /// than push frames once the window is wide enough to coalesce.
    #[test]
    fn coalesced_credits_balance_and_save_frames() {
        let t = TcpTransport::new(1, 1, 16, 2); // cap_b = 8, threshold 4
        let mut rx = t.connect_server(0);
        let mut tx = t.connect_worker(0);
        // Exactly the credit window: 16 messages = 8 full batch frames,
        // so every send completes without waiting on a drain, and the
        // whole burst sits in the receive buffer before the first poll.
        let total = 16usize;
        for i in 0..total {
            tx.send(
                0,
                PushMsg {
                    worker: 0,
                    block: 0,
                    w: [i as f32].as_slice().into(),
                    worker_epoch: i,
                    z_version_used: 0,
                    block_seq: 0,
                    sent_at: None,
                    recycle: None,
                },
            )
            .unwrap();
        }
        tx.flush().unwrap();
        let mut got = 0;
        let deadline = Instant::now() + Duration::from_secs(5);
        while got < total {
            match rx.try_recv() {
                TryRecv::Msg(_) => got += 1,
                TryRecv::Empty => {
                    assert!(Instant::now() < deadline, "drained {got}/{total} then stalled");
                    std::thread::yield_now();
                }
                TryRecv::Done => panic!("premature Done at {got}/{total}"),
            }
        }
        let w = t.wire_snapshot();
        assert_eq!(w.msgs_in, total as u64);
        assert_eq!(w.push_frames_in, (total / 2) as u64); // batch = 2
        assert_eq!(w.credits_out, w.push_frames_in, "every decoded frame re-credited");
        assert!(
            w.credit_frames_out < w.push_frames_in,
            "coalescing saved nothing: {} credit frames for {} push frames",
            w.credit_frames_out,
            w.push_frames_in
        );
        // The sender can keep going: the returned credits are spendable
        // (a full second window flows without a stall).
        for i in 0..total {
            tx.send(
                0,
                PushMsg {
                    worker: 0,
                    block: 0,
                    w: [i as f32].as_slice().into(),
                    worker_epoch: i,
                    z_version_used: 0,
                    block_seq: 0,
                    sent_at: None,
                    recycle: None,
                },
            )
            .unwrap();
        }
        tx.flush().unwrap();
    }
}

//! L3 coordinator (S5) — the paper's system contribution.
//!
//! A Parameter-Server runtime in the shape of Fig. 1 of the paper:
//! multiple *server shards*, each owning a subset of the consensus
//! blocks z_j; multiple *workers*, each owning a data shard and running
//! Algorithm 1 asynchronously; and a shared [`BlockStore`] whose locking
//! granularity is a single block — the paper's "lock-free" property: no
//! operation ever locks more than one z_j, so updates to different
//! blocks proceed fully in parallel (contrast `baselines::locked_admm`,
//! which serializes through one global model lock as all prior
//! asynchronous ADMMs required).

mod block_store;
mod compute;
mod delay;
mod driver;
mod events;
mod messages;
mod server;
mod topology;
mod worker;

pub use block_store::BlockStore;
pub use compute::{make_compute, NativeCompute, WorkerCompute, XlaCompute};
pub use delay::DelayPolicy;
pub use driver::{run_async, TrainReport};
pub use events::ObjSample;
pub use messages::{PushMsg, ServerMsg};
pub use server::{ProxBackend, ServerShard, ServerStats};
pub use topology::Topology;
pub use worker::{WorkerCtx, WorkerStats};

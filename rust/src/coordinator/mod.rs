//! L3 coordinator (S5) — the paper's system contribution.
//!
//! A Parameter-Server runtime in the shape of Fig. 1 of the paper:
//! multiple *server shards*, each owning a subset of the consensus
//! blocks z_j; multiple *workers*, each owning a data shard and running
//! Algorithm 1 asynchronously; and a shared [`BlockStore`] of per-block
//! seqlock-style double buffers — the paper's "lock-free" property made
//! literal: reads never block writes, writes never block reads, and no
//! operation touches more than one z_j, so updates to different blocks
//! proceed fully in parallel (contrast `baselines::locked_admm`, which
//! serializes through one global model lock as all prior asynchronous
//! ADMMs required).  Worker pushes ride pooled buffers ([`PushPool`])
//! that server shards recycle, so the steady-state push path performs no
//! heap allocation.
//!
//! The public surface is the [`Session`] builder (`session.rs`):
//! dataset + algorithm + [`Transport`] + [`Observer`]s in, unified
//! [`TrainReport`] out.  Three server-side policies are pluggable:
//!
//! * **queueing** behind [`Transport`] (`transport.rs`): the bounded
//!   mpsc original and the lock-free per-worker SPSC ring, both with
//!   batched slots (`--set transport=mpsc|ring batch=N`);
//! * **block placement** behind [`Placement`] (`placement.rs`): which
//!   shard owns each z_j
//!   (`--set placement=contiguous|hash|degree|dynamic`) — `dynamic`
//!   adds a runtime [`Rebalancer`] (`rebalance.rs`) that migrates hot
//!   blocks between shards from observed push rates through a
//!   lock-free [`BlockMap`] workers re-read on every push;
//! * **queue draining** behind [`crate::config::DrainKind`]
//!   (`sched.rs`): each server thread services only its own shard's
//!   lanes, or CAS-claims and steals whole pending lanes of busier
//!   shards (`--set drain=owned|steal`); `--set server_threads=N`
//!   decouples the thread count from the shard count entirely (an
//!   elastic pool over all shards' lanes).

mod block_store;
mod bufpool;
mod compute;
mod delay;
mod events;
mod fault;
mod messages;
pub(crate) mod net;
mod placement;
mod rebalance;
mod sched;
mod server;
mod session;
mod topology;
pub(crate) mod transport;
mod worker;

pub use block_store::{BlockStore, RwBlockStore};
pub use bufpool::PushPool;
pub use compute::{make_compute, NativeCompute, WorkerCompute, XlaCompute};
pub use delay::DelayPolicy;
pub use events::ObjSample;
pub use fault::{FaultEvent, FaultPlan};
pub use messages::PushMsg;
pub use net::wire;
pub use net::{serve_main, work_main, StatsServer, TcpPushSender, TcpTransport};
pub use placement::{
    load_imbalance, make_placement, ContiguousPlacement, DegreePlacement, DynamicPlacement,
    HashPlacement, Placement, RoundRobinPlacement,
};
pub use rebalance::{
    lpt_map, plan_rebalance, BlockMap, Rebalancer, REBALANCE_HYSTERESIS,
    REBALANCE_MAX_MOVES, REBALANCE_MIN_DELTA,
};
pub use sched::{run_pool, run_server, ShardRt};
pub use server::{BlockTable, ProxBackend, ServerShard, ServerStats};
pub use session::{
    Algo, MonitorGate, Observer, Progress, Session, SessionBuilder, SimExtras, TrainReport,
};
pub use topology::Topology;
pub use transport::{
    make_transport, push_inflight, MpscTransport, PushReceiver, PushSender, SpscRingTransport,
    Transport, TryRecv,
};
pub use worker::{WorkerCtx, WorkerStats};

//! L3 coordinator (S5) — the paper's system contribution.
//!
//! A Parameter-Server runtime in the shape of Fig. 1 of the paper:
//! multiple *server shards*, each owning a subset of the consensus
//! blocks z_j; multiple *workers*, each owning a data shard and running
//! Algorithm 1 asynchronously; and a shared [`BlockStore`] of per-block
//! seqlock-style double buffers — the paper's "lock-free" property made
//! literal: reads never block writes, writes never block reads, and no
//! operation touches more than one z_j, so updates to different blocks
//! proceed fully in parallel (contrast `baselines::locked_admm`, which
//! serializes through one global model lock as all prior asynchronous
//! ADMMs required).  Worker pushes ride pooled buffers ([`PushPool`])
//! that server shards recycle, so the steady-state push path performs no
//! heap allocation.

mod block_store;
mod bufpool;
mod compute;
mod delay;
mod driver;
mod events;
mod messages;
mod server;
mod topology;
mod worker;

pub use block_store::{BlockStore, RwBlockStore};
pub use bufpool::PushPool;
pub use compute::{make_compute, NativeCompute, WorkerCompute, XlaCompute};
pub use delay::DelayPolicy;
pub use driver::{push_inflight, run_async, TrainReport};
pub use events::ObjSample;
pub use messages::{PushMsg, ServerMsg};
pub use server::{ProxBackend, ServerShard, ServerStats};
pub use topology::Topology;
pub use worker::{WorkerCtx, WorkerStats};

//! The public training API: a [`Session`] builder over every execution
//! path, a pluggable [`super::transport::Transport`], and an
//! [`Observer`] hook replacing the old hardwired monitor loop.
//!
//! One surface for every way this repo can run Algorithm 1 (or a
//! baseline against it):
//!
//! ```text
//! Session::builder(&cfg)
//!     .dataset(&ds, &shards)
//!     .transport(make_transport(TransportKind::SpscRing, ...))  // optional
//!     .observer(MyObserver)                                     // optional
//!     .algo(Algo::AsyncAdmm)                                    // default
//!     .run()? -> TrainReport
//! ```
//!
//! * **Algo::AsyncAdmm** — the threaded parameter-server runtime
//!   (paper Fig. 1 / Algorithm 1), with the push path behind the
//!   chosen transport.
//! * **Algo::SyncAdmm / LockedAdmm / HogwildSgd** — the §3.1 barrier
//!   baseline and the two prior-art asynchronous designs, unified into
//!   the same [`TrainReport`] (their extra fields are empty/NaN).
//! * **Algo::Sim** — the discrete-event cluster simulation of the
//!   async runtime under a calibrated [`CostModel`]; DES-only results
//!   land in [`TrainReport::sim`].
//!
//! The monitor is no longer a busy-wait poll: the session's own thread
//! parks ([`MonitorGate`]) and workers unpark it when the minimum
//! epoch crosses the next sampling watermark.  Objective sampling is
//! itself just the built-in observer; user observers see the exact
//! same [`Progress`] views (threaded and DES paths alike).
//!
//! ## Failure model (DESIGN.md §2.0.3)
//!
//! A worker thread that panics mid-run — injected via `--set
//! faults=crash:w0@50` or a genuine bug — is contained by a
//! `catch_unwind` loop inside its own thread, and `--set
//! failure=die|degrade|restart` decides what happens next: re-raise
//! (the pre-fault-model behavior, default), retire the worker and
//! complete on the survivors, or spawn a warm-started replacement that
//! resumes the dead worker's seq stream.  The monitor doubles as the
//! recovery plane: it drains [`FaultEvent`]s to observers, runs the
//! no-progress stall watchdog (`--set stall_warn_ms=N`) and writes
//! periodic v2 checkpoints (`--set checkpoint_every=N`) off the hot
//! path; [`SessionBuilder::resume_from`] warm-starts a new run from
//! one.

use std::cell::OnceCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::block_store::BlockStore;
use super::compute::make_compute;
use super::delay::DelayPolicy;
use super::events::ObjSample;
use super::fault::{FaultEvent, FaultPlan};
use super::placement::make_placement;
use super::rebalance::{BlockMap, Rebalancer};
use super::sched::{run_pool, run_server, ShardRt};
use super::server::{BlockTable, ProxBackend, ServerShard, ServerStats};
use super::topology::Topology;
use super::transport::{make_transport, push_inflight, Transport};
use super::worker::{WorkerCtx, WorkerStats};
use crate::admm::{
    check_theorem1, consensus_gap, objective_at_z, stationarity_residual, Objective,
};
use crate::baselines::BaselineReport;
use crate::config::{Backend, Config, FailurePolicy, PlacementKind};
use crate::data::{Dataset, WorkerShard};
use crate::info;
use crate::problem::Problem;
use crate::report::Checkpoint;
use crate::runtime::{Manifest, ServerProxXla};
use crate::sim::CostModel;
use crate::sparse::Kernels;

/// Which algorithm a [`Session`] executes.
#[derive(Clone, Copy, Debug)]
pub enum Algo {
    /// Block-wise asynchronous ADMM (Algorithm 1) on the threaded
    /// parameter-server runtime.  The default.
    AsyncAdmm,
    /// Synchronous block-wise ADMM (paper §3.1): the epoch-barrier
    /// correctness anchor.
    SyncAdmm,
    /// Prior-art asynchronous full-vector ADMM behind one global lock
    /// (Zhang-Kwok '14 / Hong '17 style; the E4 ablation baseline).
    LockedAdmm,
    /// HOGWILD!-style asynchronous proximal SGD with this step size.
    HogwildSgd { step_size: f32 },
    /// Discrete-event simulation of `AsyncAdmm` under a cost model
    /// (virtual time; real numerics).  Fills [`TrainReport::sim`].
    Sim(CostModel),
}

/// DES-only results (virtual-time scaling study outputs).
#[derive(Clone, Debug)]
pub struct SimExtras {
    /// Total virtual seconds simulated.
    pub virtual_time_s: f64,
    /// Virtual time when the min worker epoch first reached k, for
    /// every k ≤ epochs.
    pub time_to_epoch: Vec<f64>,
    /// Max server queue length observed (contention indicator).
    pub max_queue: usize,
}

/// Unified result of any [`Session`] run.
#[derive(Debug)]
pub struct TrainReport {
    pub samples: Vec<ObjSample>,
    pub final_objective: Objective,
    pub z_final: Vec<f32>,
    /// Wall-clock seconds (virtual seconds for [`Algo::Sim`]).
    pub elapsed_s: f64,
    pub epochs: usize,
    /// Per-worker stats (threaded async path; empty for baselines/DES).
    pub worker_stats: Vec<WorkerStats>,
    /// Per-server stats (threaded async path; the DES reports one
    /// synthetic entry carrying its total push count).
    pub server_stats: Vec<ServerStats>,
    /// Paper Eq. 14 residual at the final iterate (NaN where the local
    /// x/y iterates are not collected — baselines and the DES).
    pub stationarity: f64,
    /// max ‖x_ij − z_j‖ at the end (NaN where unavailable, see above).
    pub consensus_max: f64,
    /// Strict Theorem-1 feasibility of the hyper-parameters used
    /// (threaded async path only; false elsewhere).
    pub theorem1_feasible: bool,
    /// Blocks migrated between shards at runtime (`placement=dynamic`
    /// on the threaded and DES paths; 0 for static placements and
    /// baselines).
    pub migrations: usize,
    /// Fault-model events (injected faults firing, degrade/restart
    /// transitions, the stall watchdog) in recording order.  Empty on
    /// fault-free runs and for the baselines.
    pub faults: Vec<FaultEvent>,
    /// Mirror-sync round-trips issued over the pull stream.  Only the
    /// networked runtime (`asybadmm serve`/`work`) has a pull stream:
    /// its coordinator aggregates these from `WorkerDone` accounting;
    /// in-process runs read the shared [`BlockStore`] directly and
    /// report 0.
    pub pull_rounds: u64,
    /// Of [`Self::pull_rounds`], how many came back with no newer
    /// blocks (idle polls the adaptive cadence exists to suppress).
    pub pull_empty: u64,
    /// Present iff the run was [`Algo::Sim`].
    pub sim: Option<SimExtras>,
}

impl TrainReport {
    pub fn total_pushes(&self) -> usize {
        self.server_stats.iter().map(|s| s.pushes).sum()
    }

    pub fn max_staleness(&self) -> u64 {
        self.worker_stats
            .iter()
            .map(|w| w.max_staleness)
            .chain(self.server_stats.iter().map(|s| s.max_staleness))
            .max()
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Observer
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum ZSource<'a> {
    Store(&'a BlockStore),
    Dense(&'a [f32]),
}

/// A point-in-time view of a run, handed to [`Observer::on_sample`].
/// Snapshot and objective are computed lazily and cached, so a sampler
/// plus N user observers cost one objective evaluation, not N + 1.
pub struct Progress<'a> {
    /// Minimum local epoch across workers at this sample.
    pub epoch: usize,
    /// Wall-clock (threaded) or virtual (DES) seconds since start.
    pub time_s: f64,
    z: ZSource<'a>,
    shards: &'a [WorkerShard],
    problem: &'a Problem,
    weight: f32,
    cached_z: OnceCell<Vec<f32>>,
    cached_obj: OnceCell<Objective>,
}

impl<'a> Progress<'a> {
    pub(crate) fn new_store(
        epoch: usize,
        time_s: f64,
        store: &'a BlockStore,
        shards: &'a [WorkerShard],
        problem: &'a Problem,
        weight: f32,
    ) -> Self {
        Progress {
            epoch,
            time_s,
            z: ZSource::Store(store),
            shards,
            problem,
            weight,
            cached_z: OnceCell::new(),
            cached_obj: OnceCell::new(),
        }
    }

    pub(crate) fn new_dense(
        epoch: usize,
        time_s: f64,
        z: &'a [f32],
        shards: &'a [WorkerShard],
        problem: &'a Problem,
        weight: f32,
    ) -> Self {
        Progress {
            epoch,
            time_s,
            z: ZSource::Dense(z),
            shards,
            problem,
            weight,
            cached_z: OnceCell::new(),
            cached_obj: OnceCell::new(),
        }
    }

    /// The consensus iterate z at this sample (snapshotted once).
    pub fn z(&self) -> &[f32] {
        match self.z {
            ZSource::Dense(z) => z,
            ZSource::Store(store) => self.cached_z.get_or_init(|| store.snapshot()),
        }
    }

    /// Paper Eq. 22 objective at [`Progress::z`] (computed once).
    pub fn objective(&self) -> Objective {
        *self
            .cached_obj
            .get_or_init(|| objective_at_z(self.shards, self.problem, self.weight, self.z()))
    }

    /// This progress point as a telemetry row.
    pub fn sample(&self) -> ObjSample {
        let obj = self.objective();
        ObjSample {
            time_s: self.time_s,
            epoch: self.epoch,
            objective: obj.total(),
            data_loss: obj.data_loss,
            consensus_max: 0.0,
        }
    }
}

/// Run telemetry hook.  Registered via [`SessionBuilder::observer`];
/// the built-in objective sampler is one of these too.
pub trait Observer: Send {
    /// Called at every sampling point — when the minimum worker epoch
    /// crosses a `cfg.log_every` watermark (including epoch 0) — on
    /// the threaded async and DES paths.  Baseline algos sample
    /// internally and only fire [`Observer::on_complete`].
    fn on_sample(&mut self, progress: &Progress<'_>);

    /// Called once with the final report, after all threads joined.
    fn on_complete(&mut self, _report: &TrainReport) {}

    /// Called from the monitor thread, in recording order, for every
    /// fault-model event: injected faults firing, worker degrade /
    /// restart transitions, and the no-progress stall watchdog
    /// (`--set stall_warn_ms=N`).  Default: ignore.
    fn on_fault(&mut self, _event: &FaultEvent) {}
}

/// The built-in observer: objective sampling into
/// [`TrainReport::samples`] (formerly hardwired into the monitor loop).
#[derive(Default)]
struct ObjectiveSampler {
    samples: Vec<ObjSample>,
}

impl Observer for ObjectiveSampler {
    fn on_sample(&mut self, progress: &Progress<'_>) {
        self.samples.push(progress.sample());
    }
}

// ---------------------------------------------------------------------------
// Monitor wakeup
// ---------------------------------------------------------------------------

/// Park/unpark coordination between workers and the monitor thread.
///
/// The monitor parks instead of busy-polling; it publishes the next
/// min-epoch it cares about (`wake_at`, monotone non-decreasing) and
/// every worker at or beyond that watermark unparks it after finishing
/// an epoch.  `unpark` on an already-running thread just sets the park
/// token, so notifications coalesce; a park timeout bounds the damage
/// of any missed edge.
pub struct MonitorGate {
    wake_at: AtomicUsize,
    monitor: std::thread::Thread,
}

impl MonitorGate {
    /// A gate whose monitor is the CURRENT thread (the session monitor
    /// loop, or the serve/work process driver).
    pub(crate) fn new() -> Self {
        MonitorGate { wake_at: AtomicUsize::new(0), monitor: std::thread::current() }
    }

    /// Worker side: epoch `completed` just finished.
    pub fn notify_epoch(&self, completed: usize) {
        if completed >= self.wake_at.load(Ordering::Relaxed) {
            self.monitor.unpark();
        }
    }

    /// Monitor side: sleep until progress may have crossed `epoch`.
    fn park_until(&self, epoch: usize) {
        self.wake_at.store(epoch, Ordering::Release);
        std::thread::park_timeout(Duration::from_millis(5));
    }

    /// Wake the monitor immediately, regardless of the epoch watermark
    /// (fault events, worker death — anything it should notice now).
    pub fn wake(&self) {
        self.monitor.unpark();
    }
}

// ---------------------------------------------------------------------------
// Session builder
// ---------------------------------------------------------------------------

/// Entry point for every training run; see the module docs.
pub struct Session;

impl Session {
    pub fn builder(cfg: &Config) -> SessionBuilder<'_> {
        SessionBuilder {
            cfg,
            data: None,
            transport: None,
            observers: Vec::new(),
            algo: Algo::AsyncAdmm,
            resume: None,
        }
    }
}

pub struct SessionBuilder<'a> {
    cfg: &'a Config,
    data: Option<(&'a Dataset, &'a [WorkerShard])>,
    transport: Option<Box<dyn Transport>>,
    observers: Vec<Box<dyn Observer + 'a>>,
    algo: Algo,
    resume: Option<&'a Checkpoint>,
}

impl<'a> SessionBuilder<'a> {
    /// The dataset and its per-worker shards (required).
    pub fn dataset(mut self, ds: &'a Dataset, shards: &'a [WorkerShard]) -> Self {
        self.data = Some((ds, shards));
        self
    }

    /// Override the push transport (default: built from
    /// `cfg.transport` — `--set transport=mpsc|ring|tcp`).  Only the
    /// threaded [`Algo::AsyncAdmm`] path moves real messages.
    pub fn transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Register a telemetry observer (repeatable).
    pub fn observer(mut self, obs: impl Observer + 'a) -> Self {
        self.observers.push(Box::new(obs));
        self
    }

    /// Select the algorithm (default [`Algo::AsyncAdmm`]).
    pub fn algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    /// Warm-start from a saved [`Checkpoint`]: its consensus z seeds
    /// the block store (so workers start from x⁰ = z̃⁰), a dynamic
    /// placement restores the saved owner map and per-block push
    /// counters, and v2 per-worker duals (when present and matching
    /// this run's geometry) warm-start each worker's y.  The run still
    /// executes `cfg.epochs` fresh epochs — resume restores *state*,
    /// not the remaining epoch budget.  Threaded [`Algo::AsyncAdmm`]
    /// only; other algos ignore it.
    pub fn resume_from(mut self, ck: &'a Checkpoint) -> Self {
        self.resume = Some(ck);
        self
    }

    pub fn run(mut self) -> Result<TrainReport> {
        let (ds, shards) = self
            .data
            .context("Session has no dataset: call .dataset(&ds, &shards)")?;
        let cfg = self.cfg;
        let report = match self.algo {
            Algo::AsyncAdmm => {
                let transport = self.transport.take().unwrap_or_else(|| {
                    make_transport(
                        cfg.transport,
                        cfg.n_workers,
                        cfg.n_servers,
                        push_inflight(cfg.n_workers),
                        cfg.batch,
                    )
                });
                run_threaded(cfg, ds, shards, transport, &mut self.observers, self.resume)?
            }
            Algo::SyncAdmm => {
                from_baseline(crate::baselines::run_sync_admm(cfg, ds, shards)?)
            }
            Algo::LockedAdmm => {
                from_baseline(crate::baselines::run_locked_admm(cfg, ds, shards)?)
            }
            Algo::HogwildSgd { step_size } => {
                from_baseline(crate::baselines::run_hogwild_sgd(cfg, ds, shards, step_size)?)
            }
            Algo::Sim(cost) => {
                let r = crate::sim::run_sim_observed(cfg, ds, shards, &cost, &mut self.observers)?;
                TrainReport {
                    samples: r.samples,
                    final_objective: r.final_objective,
                    z_final: r.z_final,
                    elapsed_s: r.virtual_time_s,
                    epochs: r.epochs,
                    worker_stats: Vec::new(),
                    // One synthetic entry so `total_pushes()` is uniform
                    // across execution paths.
                    server_stats: vec![ServerStats { pushes: r.pushes, ..Default::default() }],
                    stationarity: f64::NAN,
                    consensus_max: f64::NAN,
                    theorem1_feasible: false,
                    migrations: r.migrations,
                    faults: r.faults,
                    pull_rounds: 0,
                    pull_empty: 0,
                    sim: Some(SimExtras {
                        virtual_time_s: r.virtual_time_s,
                        time_to_epoch: r.time_to_epoch,
                        max_queue: r.max_queue,
                    }),
                }
            }
        };
        for obs in self.observers.iter_mut() {
            obs.on_complete(&report);
        }
        Ok(report)
    }
}

/// Baselines collect their own samples; lift them into the unified
/// report shape (no per-thread stats, no stationarity collection).
fn from_baseline(r: BaselineReport) -> TrainReport {
    TrainReport {
        samples: r.samples,
        final_objective: r.final_objective,
        z_final: r.z_final,
        elapsed_s: r.elapsed_s,
        epochs: r.epochs,
        worker_stats: Vec::new(),
        server_stats: Vec::new(),
        stationarity: f64::NAN,
        consensus_max: f64::NAN,
        theorem1_feasible: false,
        migrations: 0,
        faults: Vec::new(),
        pull_rounds: 0,
        pull_empty: 0,
        sim: None,
    }
}

// ---------------------------------------------------------------------------
// Threaded async runtime (Algorithm 1)
// ---------------------------------------------------------------------------

fn run_threaded<'o>(
    cfg: &Config,
    ds: &Dataset,
    shards: &[WorkerShard],
    transport: Box<dyn Transport>,
    observers: &mut [Box<dyn Observer + 'o>],
    resume: Option<&Checkpoint>,
) -> Result<TrainReport> {
    cfg.validate()?;
    anyhow::ensure!(shards.len() == cfg.n_workers, "shards/workers mismatch");
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    // Reported objective: paper Eq. 22's global mean (weight 1/m);
    // each worker's f_i is its LOCAL mean (weight 1/m_i), which keeps
    // per-iteration progress p-independent (DESIGN.md "objective
    // scaling").
    let weight = 1.0 / ds.samples() as f32;
    let placement = make_placement(cfg.placement);
    let topo = Topology::build_with(shards, cfg.n_blocks, cfg.n_servers, placement.as_ref());
    let store = Arc::new(BlockStore::new(cfg.n_blocks, cfg.block_size));
    // Checkpoint resume: seed the store BEFORE the table and the
    // workers pull their z⁰ (both honor a non-zero initialization).
    if let Some(ck) = resume {
        anyhow::ensure!(
            ck.n_blocks == cfg.n_blocks && ck.block_size == cfg.block_size,
            "checkpoint geometry {}x{} does not match config {}x{}",
            ck.n_blocks,
            ck.block_size,
            cfg.n_blocks,
            cfg.block_size
        );
        for j in 0..cfg.n_blocks {
            store.write(j, &ck.z[j * cfg.block_size..(j + 1) * cfg.block_size]);
        }
    }
    // Deterministic fault injection (`--set faults=...`): an empty
    // plan short-circuits every hook to one branch.
    let fault_plan =
        Arc::new(FaultPlan::parse(&cfg.faults).context("invalid value for config key \"faults\"")?);
    let policy =
        DelayPolicy { net_mean_ms: cfg.net_delay_mean_ms, pull_hold: cfg.pull_hold.max(1) };

    // Theorem-1 feasibility report (logged; the paper itself runs with
    // infeasible-but-working γ=0.01, as do the defaults here).
    let shard_refs: Vec<&WorkerShard> = shards.iter().collect();
    let t1 = check_theorem1(
        &shard_refs,
        &problem,
        cfg.n_blocks,
        cfg.rho as f64,
        cfg.gamma as f64,
        cfg.max_delay,
    );
    // Elastic pool size: 0 = the classic one-thread-per-shard shape.
    let n_threads = if cfg.server_threads == 0 { cfg.n_servers } else { cfg.server_threads };
    let dynamic = cfg.placement == PlacementKind::Dynamic;
    // Resolve `--set kernel=` ONCE (CPU feature probe + fallback); every
    // worker engine and the shared block table dispatch through it.
    let kernels = Kernels::select(cfg.kernel);

    info!(
        "session",
        "theorem1: min_alpha={:.3e} min_beta={:.3e} feasible={} (strict bound; paper runs gamma=0.01 anyway); transport={} placement={} drain={} batch={} server_threads={} kernel={}",
        t1.min_alpha,
        t1.min_beta,
        t1.feasible,
        transport.name(),
        cfg.placement.as_str(),
        cfg.drain.as_str(),
        cfg.batch,
        n_threads,
        kernels.name
    );

    let manifest = match cfg.backend {
        Backend::Xla => Some(Manifest::load(&cfg.artifacts_dir)?),
        Backend::Native => None,
    };

    // The push-buffer pool never needs more buffers than can be in
    // flight at once under the global in-flight budget, plus slack for
    // recycle-channel latency, plus whatever the sender may hold in
    // un-flushed per-server batches (a pool smaller than the batch
    // residue could deadlock: every buffer parked in a pending batch
    // that only a further acquire-and-send would flush).  (A transport
    // whose own bound is larger just sees pool backpressure a little
    // earlier — same contract.)
    let pool_cap =
        push_inflight(cfg.n_workers) + 4 + cfg.n_servers * cfg.batch.saturating_sub(1);

    let progress: Vec<AtomicUsize> = (0..cfg.n_workers).map(|_| AtomicUsize::new(0)).collect();
    let gate = MonitorGate::new();
    let worker_results: Mutex<Vec<Option<(WorkerStats, Vec<f32>, Vec<f32>)>>> =
        Mutex::new((0..cfg.n_workers).map(|_| None).collect());
    // Degraded (force-retired) workers: excluded from the monitor's
    // min-epoch and liveness checks, tolerated missing at collection.
    let dead: Vec<AtomicBool> = (0..cfg.n_workers).map(|_| AtomicBool::new(false)).collect();
    // Per-(worker, slot) sent-seq watermarks, owned here so they
    // survive a worker panic: the restart path seeds the replacement's
    // seq counters from them once the in-flight tail has drained.
    let ledgers: Vec<Vec<AtomicU64>> = shards
        .iter()
        .map(|s| (0..s.n_slots()).map(|_| AtomicU64::new(0)).collect())
        .collect();

    // All per-block server state lives in ONE table shared by every
    // shard (the block write leases): with `drain=steal` any server
    // thread may service any shard, and with `placement=dynamic` a
    // block's pushes may arrive through two shards' lanes mid-migration
    // (`server.rs` documents the ownership handoff).
    let table = Arc::new(BlockTable::with_kernels(
        &topo,
        store.clone(),
        problem,
        cfg.rho,
        cfg.gamma,
        kernels,
    ));
    // The live routing map workers read per push.  Static placements
    // never touch it after this; `placement=dynamic` hands it to the
    // rebalancer below.
    let map = Arc::new(BlockMap::new(&topo.server_of_block));
    if let Some(ck) = resume {
        // v2 recovery state (empty on v1 files): the saved owner map
        // resumes a dynamic placement where it left off — a map from a
        // different shard count is ignored rather than mis-routed —
        // and the push counters resume the rebalancer's load signal.
        if dynamic
            && ck.block_owners.len() == cfg.n_blocks
            && ck.block_owners.iter().all(|&s| s < cfg.n_servers)
        {
            map.reset_owners(&ck.block_owners);
        }
        if ck.push_counts.len() == cfg.n_blocks {
            table.seed_push_counts(&ck.push_counts);
        }
    }
    // Live observability tap (`--set stats_addr=HOST:PORT`): a std-only
    // HTTP endpoint serving this run's counters while it executes —
    // per-shard load, per-block applied pushes, the live placement map,
    // the migration ledger and the fault-event log.  Stopped on drop at
    // the end of the run.
    let fault_log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let _stats_server = if cfg.stats_addr.is_empty() {
        None
    } else {
        use crate::util::json::{num, obj, s as jstr, Json};
        let table = table.clone();
        let map = map.clone();
        let log = fault_log.clone();
        let n_servers = cfg.n_servers;
        let server = super::net::StatsServer::spawn(
            &cfg.stats_addr,
            Arc::new(move || {
                let counts = table.push_counts();
                let owners = map.snapshot();
                let mut shard_load = vec![0usize; n_servers];
                for (j, &c) in counts.iter().enumerate() {
                    shard_load[owners[j]] += c;
                }
                obj(vec![
                    ("pushes_total", num(counts.iter().sum::<usize>() as f64)),
                    (
                        "push_counts",
                        Json::Arr(counts.iter().map(|&c| num(c as f64)).collect()),
                    ),
                    (
                        "placement",
                        Json::Arr(owners.iter().map(|&o| num(o as f64)).collect()),
                    ),
                    (
                        "shard_load",
                        Json::Arr(shard_load.iter().map(|&l| num(l as f64)).collect()),
                    ),
                    ("map_version", num(map.version() as f64)),
                    ("migrations", num(map.migrations() as f64)),
                    (
                        "faults",
                        Json::Arr(log.lock().unwrap().iter().map(|l| jstr(l)).collect()),
                    ),
                ])
            }),
        )?;
        info!("session", "stats endpoint on http://{}/stats", server.addr());
        Some(server)
    };

    let shard_rts: Vec<ShardRt> = (0..cfg.n_servers)
        .map(|sid| {
            let mut shard = ServerShard::with_table(sid, &topo, table.clone(), !dynamic);
            if !fault_plan.is_empty() {
                shard.set_faults(fault_plan.clone());
            }
            ShardRt::new(shard, transport.as_ref())
        })
        .collect();

    // Runtime re-placement: driven from the monitor thread's wakeups
    // (no extra thread, no locks any worker or server ever sees).
    let mut rebalancer = (dynamic && cfg.n_servers > 1)
        .then(|| Rebalancer::new(map.clone(), table.clone(), cfg.n_servers));
    let rebalance_every = Duration::from_millis(cfg.rebalance_ms);
    let mut last_scan = Instant::now();

    let start = Instant::now();
    let mut sampler = ObjectiveSampler::default();
    let mut fault_events: Vec<FaultEvent> = Vec::new();

    std::thread::scope(|scope| -> Result<()> {
        let mut server_handles = Vec::with_capacity(n_threads);
        let mut worker_handles = Vec::with_capacity(cfg.n_workers);
        // -- server threads ------------------------------------------------
        // The classic shape (`server_threads=0`) pins thread k to shard
        // k under the configured drain policy; an elastic pool
        // (`server_threads=N != n_servers`) runs N identical threads
        // that each service every shard's lanes, own-affinity first.
        for tid in 0..n_threads {
            let manifest = manifest.as_ref();
            let shard_rts = &shard_rts;
            server_handles.push(scope.spawn(move || {
                let prox = match manifest {
                    None => ProxBackend::Native,
                    Some(m) => match ServerProxXla::load(m, cfg.block_size) {
                        Ok(p) => ProxBackend::Xla(p),
                        Err(e) => {
                            eprintln!("server thread {tid}: XLA prox unavailable ({e:#}); native fallback");
                            ProxBackend::Native
                        }
                    },
                };
                // A failing server loop panics the thread: the monitor's
                // liveness check tears the run down and the scope join
                // re-raises, so a dead shard stays a hard error.
                if n_threads == cfg.n_servers {
                    run_server(shard_rts, tid, cfg.drain, &prox).expect("server loop failed");
                } else {
                    run_pool(shard_rts, tid, &prox).expect("server pool loop failed");
                }
            }));
        }

        // -- workers ---------------------------------------------------------
        for shard in shards {
            let wid = shard.worker_id;
            let tx = transport.connect_worker(wid);
            let transport_ref: &dyn Transport = transport.as_ref();
            let router: &BlockMap = &map;
            let store = &store;
            let table = &table;
            let progress = &progress[wid];
            let gate = &gate;
            let manifest = manifest.as_ref();
            let worker_results = &worker_results;
            let fault_plan = &fault_plan;
            let dead = &dead;
            let ledger: &[AtomicU64] = &ledgers[wid];
            let seed = cfg.seed ^ (0x9E37 + wid as u64 * 0x1000_0000_01B3);
            let local_weight = 1.0 / shard.samples().max(1) as f32;
            // Checkpoint-resume warm duals (geometry-gated; a v1 file
            // or a foreign shard layout falls back to y⁰ = 0).
            let resume_duals = resume
                .and_then(|ck| ck.duals.get(wid))
                .filter(|y| y.len() == shard.packed_dim())
                .cloned();
            worker_handles.push(scope.spawn(move || {
                // Crash containment (module docs "Failure model"): a
                // panic anywhere in an attempt unwinds to this loop —
                // dropping the attempt's sender, whose Drop-flush
                // delivers any batched remainder — and `cfg.failure`
                // picks die / degrade / restart.  Replacements run on
                // this same OS thread, so the dead endpoint is fully
                // dropped before `reconnect_worker` re-opens it (the
                // SPSC single-producer handoff is sequential).
                let mut first_tx = Some(tx);
                let mut attempt = 0usize;
                loop {
                    let tx = match first_tx.take() {
                        Some(tx) => tx,
                        None => transport_ref.reconnect_worker(wid),
                    };
                    let start_epoch = progress.load(Ordering::Acquire);
                    let run = catch_unwind(AssertUnwindSafe(
                        || -> (WorkerStats, Vec<f32>, Vec<f32>) {
                            let mut compute = make_compute(
                                cfg.backend,
                                shard,
                                problem,
                                local_weight,
                                manifest,
                                cfg.m_chunk,
                                cfg.d_pad,
                                kernels,
                            )
                            .expect("construct worker compute backend");
                            let mut ctx = WorkerCtx::new(
                                shard,
                                store,
                                router,
                                tx,
                                policy,
                                cfg.selection,
                                cfg.rho,
                                cfg.epochs,
                                cfg.max_delay,
                                cfg.enforce_delay_bound,
                                seed,
                                progress,
                                gate,
                                pool_cap,
                                fault_plan,
                                ledger,
                            );
                            if attempt > 0 {
                                // Warm-started replacement: resume the
                                // crashed worker's epoch and seq stream
                                // (the gate accepts `ledger + 1` next),
                                // duals re-derived from server state.
                                let seqs: Vec<u64> = ledger
                                    .iter()
                                    .map(|a| a.load(Ordering::Acquire))
                                    .collect();
                                ctx.resume_at(start_epoch, &seqs);
                                ctx.warm_duals(&approx_duals(
                                    table, store, shard, ledger, cfg.rho,
                                ));
                            } else if let Some(y) = resume_duals.as_deref() {
                                ctx.warm_duals(y);
                            }
                            let stats =
                                ctx.run(compute.as_mut()).expect("worker loop failed");
                            let (x, y) = ctx.into_state();
                            (stats, x, y)
                        },
                    ));
                    match run {
                        Ok(res) => {
                            worker_results.lock().unwrap()[wid] = Some(res);
                            break;
                        }
                        Err(payload) => {
                            let at = progress.load(Ordering::Acquire);
                            match cfg.failure {
                                // Pre-fault-model behavior: the scope
                                // join re-raises, the monitor's liveness
                                // check tears the run down.
                                FailurePolicy::Die => resume_unwind(payload),
                                FailurePolicy::Degrade => {
                                    degrade_worker(fault_plan, table, dead, gate, wid, at);
                                    break;
                                }
                                FailurePolicy::Restart => {
                                    fault_plan.record(FaultEvent::WorkerCrashed {
                                        worker: wid,
                                        epoch: at,
                                    });
                                    gate.wake();
                                    if !wait_tail_drained(table, shard, ledger) {
                                        // The in-flight tail never fully
                                        // applied (e.g. messages destroyed
                                        // against a closed lane): no
                                        // replacement stream can be
                                        // accepted — degrade instead.
                                        degrade_worker(
                                            fault_plan, table, dead, gate, wid, at,
                                        );
                                        break;
                                    }
                                    attempt += 1;
                                    fault_plan.record(FaultEvent::WorkerRestarted {
                                        worker: wid,
                                        epoch: at,
                                        attempt,
                                    });
                                    gate.wake();
                                }
                            }
                        }
                    }
                }
            }));
        }

        // -- monitor (this thread, parked between samples) -------------------
        let log_every = cfg.log_every.max(1);
        let mut next_epoch = 0usize;
        // Stall watchdog state (`--set stall_warn_ms=N`): one event
        // per no-progress episode, re-armed by any progress.
        let mut progress_sum = usize::MAX;
        let mut progress_at = Instant::now();
        let mut stall_fired = false;
        // Periodic checkpoint watermark (`--set checkpoint_every=N`).
        let mut next_ckpt =
            if cfg.checkpoint_every > 0 { cfg.checkpoint_every } else { usize::MAX };
        loop {
            // Min epoch over the workers still alive: a degraded
            // worker's frozen progress must not hold sampling (or
            // termination) hostage.  All dead → nothing left to wait
            // for.
            let min_epoch = progress
                .iter()
                .enumerate()
                .filter(|&(i, _)| !dead[i].load(Ordering::Acquire))
                .map(|(_, p)| p.load(Ordering::Acquire))
                .min();
            let Some(min_epoch) = min_epoch else { break };
            // Fault telemetry: deliver events recorded since the last
            // wakeup (injected faults, degrade/restart transitions) to
            // every observer, in order.
            for ev in fault_plan.take_events() {
                for obs in observers.iter_mut() {
                    obs.on_fault(&ev);
                }
                fault_log.lock().unwrap().push(ev.describe());
                fault_events.push(ev);
            }
            // Samples at `epoch == cfg.epochs` are the final-state row
            // appended after the join below — never emitted here, so no
            // sample ever lands past the configured budget.
            if min_epoch >= next_epoch && min_epoch < cfg.epochs {
                let prog = Progress::new_store(
                    min_epoch,
                    start.elapsed().as_secs_f64(),
                    &store,
                    shards,
                    &problem,
                    weight,
                );
                sampler.on_sample(&prog);
                for obs in observers.iter_mut() {
                    obs.on_sample(&prog);
                }
                next_epoch = next_epoch.max(min_epoch) + log_every;
            }
            if min_epoch >= cfg.epochs {
                // Final checkpoint at the budget watermark: with
                // checkpointing on, a resumable artifact exists even
                // when a fast run outpaced every periodic watermark.
                if cfg.checkpoint_every > 0 {
                    let ck = snapshot_checkpoint(
                        cfg, shards, &store, &table, &map, &ledgers, &problem, weight,
                        min_epoch,
                    );
                    if let Err(e) = ck.save(&cfg.checkpoint_path) {
                        eprintln!(
                            "final checkpoint -> {:?} failed: {e:#}",
                            cfg.checkpoint_path
                        );
                    }
                }
                break;
            }
            // Dynamic re-placement rides the monitor's wakeups: sample
            // the per-block applied-push counters and migrate hot
            // blocks when the observed rates say the map is stale.
            if let Some(rb) = rebalancer.as_mut() {
                if last_scan.elapsed() >= rebalance_every {
                    rb.scan();
                    last_scan = Instant::now();
                }
            }
            // Stall watchdog: TOTAL progress frozen for stall_warn_ms
            // (a stalled shard backpressures every worker pushing to
            // it) fires one `Stalled` event per episode.
            if cfg.stall_warn_ms > 0 {
                let sum: usize = progress.iter().map(|p| p.load(Ordering::Acquire)).sum();
                if sum != progress_sum {
                    progress_sum = sum;
                    progress_at = Instant::now();
                    stall_fired = false;
                } else if !stall_fired
                    && progress_at.elapsed() >= Duration::from_millis(cfg.stall_warn_ms)
                {
                    stall_fired = true;
                    let ev = FaultEvent::Stalled {
                        min_epoch,
                        waited_ms: progress_at.elapsed().as_millis() as u64,
                    };
                    for obs in observers.iter_mut() {
                        obs.on_fault(&ev);
                    }
                    fault_events.push(ev);
                }
            }
            // Periodic checkpointing, entirely off the worker/server
            // hot paths (this thread computes the approximate duals
            // from the shared table).  An IO failure is reported, not
            // fatal: persistence must never kill a healthy run.
            if min_epoch >= next_ckpt && min_epoch < cfg.epochs {
                while next_ckpt <= min_epoch {
                    next_ckpt += cfg.checkpoint_every;
                }
                let ck = snapshot_checkpoint(
                    cfg, shards, &store, &table, &map, &ledgers, &problem, weight, min_epoch,
                );
                if let Err(e) = ck.save(&cfg.checkpoint_path) {
                    eprintln!(
                        "checkpoint at epoch {min_epoch} -> {:?} failed: {e:#}",
                        cfg.checkpoint_path
                    );
                }
            }
            // Liveness: a server exiting before shutdown, or a worker
            // exiting below its epoch budget, died on a panic.  Stop
            // monitoring and shut the transport down so the remaining
            // threads fail their sends / drain out, and the scope join
            // re-raises the original panic — instead of parking here
            // forever on progress that will never come.
            let thread_died = server_handles.iter().any(|h| h.is_finished())
                || worker_handles.iter().enumerate().any(|(i, h)| {
                    h.is_finished()
                        && !dead[i].load(Ordering::Acquire)
                        && progress[i].load(Ordering::Acquire) < cfg.epochs
                });
            if thread_died {
                // A dead server thread can no longer drop its receivers
                // (they live in shard_rts, outliving the thread): force-
                // close its lanes so workers blocked in send() fail
                // loudly instead of hanging the scope join, and so
                // steal-mode peers stop waiting on lanes that are never
                // coming back.  Pool threads have no fixed shard — the
                // run is doomed either way (the scope join re-raises
                // the panic), so close every shard's lanes there.
                if n_threads == cfg.n_servers {
                    for (sid, h) in server_handles.iter().enumerate() {
                        if h.is_finished() {
                            shard_rts[sid].close_lanes();
                        }
                    }
                } else {
                    for rt in shard_rts.iter() {
                        rt.close_lanes();
                    }
                }
                break;
            }
            gate.park_until(next_epoch.min(cfg.epochs));
        }
        // Workers are done (or finishing); signal the transport so the
        // server shards drain their queues and exit.  The scope joins
        // everything on exit.
        transport.shutdown();
        Ok(())
    })?;
    let elapsed_s = start.elapsed().as_secs_f64();
    // Events recorded after the monitor's last drain (e.g. a degrade
    // racing the final wakeup) still reach observers and the report.
    for ev in fault_plan.take_events() {
        for obs in observers.iter_mut() {
            obs.on_fault(&ev);
        }
        fault_log.lock().unwrap().push(ev.describe());
        fault_events.push(ev);
    }

    // -- final metrics ---------------------------------------------------
    let z_final = store.snapshot();
    let final_objective = objective_at_z(shards, &problem, weight, &z_final);
    let collected = worker_results.into_inner().unwrap();
    let mut worker_stats = Vec::with_capacity(cfg.n_workers);
    let mut xs = Vec::with_capacity(cfg.n_workers);
    let mut ys = Vec::with_capacity(cfg.n_workers);
    let mut missing = false;
    for (i, r) in collected.into_iter().enumerate() {
        match r {
            Some((stats, x, y)) => {
                worker_stats.push(stats);
                xs.push(x);
                ys.push(y);
            }
            None => {
                // Only a degraded (force-retired) worker may fail to
                // report; anything else is a runtime bug.
                anyhow::ensure!(
                    dead[i].load(Ordering::Acquire),
                    "worker {i} did not report"
                );
                missing = true;
                worker_stats.push(WorkerStats::default());
            }
        }
    }
    // Per-shard stats live in the shared shard state (any thread may
    // have applied them under `drain=steal`); a dead server thread is
    // still a hard error — its panic re-raised at the scope join above.
    let server_stats: Vec<ServerStats> =
        shard_rts.iter().map(|rt| rt.shard.stats()).collect();
    // Eq. 14 / consensus need EVERY worker's final x/y: a degraded run
    // reports NaN rather than a number computed from the survivors
    // pretending to be the full set.
    let (stationarity, consensus_max) = if missing {
        (f64::NAN, f64::NAN)
    } else {
        let st = stationarity_residual(shards, &problem, cfg.rho, &xs, &ys, &z_final);
        let (cm, _) = consensus_gap(shards, &xs, &z_final);
        (st, cm)
    };

    // Ensure the last sample reflects the final state.
    let mut samples = sampler.samples;
    samples.push(ObjSample {
        time_s: elapsed_s,
        epoch: cfg.epochs,
        objective: final_objective.total(),
        data_loss: final_objective.data_loss,
        consensus_max,
    });
    debug_assert!(
        samples.iter().all(|s| s.epoch <= cfg.epochs),
        "monitor emitted a sample past the epoch budget"
    );

    Ok(TrainReport {
        samples,
        final_objective,
        z_final,
        elapsed_s,
        epochs: cfg.epochs,
        worker_stats,
        server_stats,
        stationarity,
        consensus_max,
        theorem1_feasible: t1.feasible,
        migrations: map.migrations(),
        faults: fault_events,
        pull_rounds: 0,
        pull_empty: 0,
        sim: None,
    })
}

/// Degrade transition: drop the dead worker's parked (seq-gapped)
/// messages so no gap blocks other streams, record the event, retire
/// the worker, and wake the monitor.  Its w̃ contributions stay frozen
/// in the table — the survivors' consensus still includes them.
fn degrade_worker(
    plan: &FaultPlan,
    table: &BlockTable,
    dead: &[AtomicBool],
    gate: &MonitorGate,
    wid: usize,
    epoch: usize,
) {
    let parked = table.purge_worker_pending(wid);
    plan.record(FaultEvent::WorkerDegraded { worker: wid, epoch, parked_dropped: parked });
    dead[wid].store(true, Ordering::Release);
    gate.wake();
}

/// Restart precondition: poll until every push the crashed endpoint
/// handed to the transport has been applied — the seq gate then sits
/// at `ledger + 1` on every slot, exactly where the replacement's
/// continuation stream begins.  Bounded: a tail that never drains
/// (messages destroyed mid-flight against a closed lane) times out and
/// the caller falls back to degrade.
fn wait_tail_drained(table: &BlockTable, shard: &WorkerShard, ledger: &[AtomicU64]) -> bool {
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let drained = shard.active_blocks.iter().enumerate().all(|(slot, &j)| {
            table.next_seq(j, shard.worker_id) == ledger[slot].load(Ordering::Acquire) + 1
        });
        if drained {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Per-worker dual approximation from server-side state: for every
/// slot the worker has pushed at least once (ledger > 0), the cached
/// w̃ = ρx + y and x ≈ z̃ give y ≈ w̃ − ρ·z̃; never-pushed slots keep
/// the fresh-worker y⁰ = 0.  Used to warm-start restarted workers and
/// to snapshot duals into checkpoints without touching worker threads.
pub(crate) fn approx_duals(
    table: &BlockTable,
    store: &BlockStore,
    shard: &WorkerShard,
    ledger: &[AtomicU64],
    rho: f32,
) -> Vec<f32> {
    let db = shard.block_size;
    let mut y = vec![0.0f32; shard.packed_dim()];
    let mut z = vec![0.0f32; db];
    for (slot, &j) in shard.active_blocks.iter().enumerate() {
        if ledger[slot].load(Ordering::Acquire) == 0 {
            continue;
        }
        let w = table.w_tilde_of(j, shard.worker_id);
        store.read_into(j, &mut z);
        for k in 0..db {
            y[slot * db + k] = w[k] - rho * z[k];
        }
    }
    y
}

/// Monitor-side v2 checkpoint assembly (see `report/checkpoint.rs`):
/// consensus z, live owner map, per-block push counters, and the
/// approximate per-worker duals.
#[allow(clippy::too_many_arguments)]
pub(crate) fn snapshot_checkpoint(
    cfg: &Config,
    shards: &[WorkerShard],
    store: &BlockStore,
    table: &BlockTable,
    map: &BlockMap,
    ledgers: &[Vec<AtomicU64>],
    problem: &Problem,
    weight: f32,
    epoch: usize,
) -> Checkpoint {
    let z = store.snapshot();
    let objective = objective_at_z(shards, problem, weight, &z).total();
    Checkpoint {
        config_summary: cfg.summary(),
        n_blocks: cfg.n_blocks,
        block_size: cfg.block_size,
        epoch,
        objective,
        block_owners: map.snapshot(),
        push_counts: (0..cfg.n_blocks).map(|j| table.push_count(j)).collect(),
        duals: shards
            .iter()
            .map(|sh| approx_duals(table, store, sh, &ledgers[sh.worker_id], cfg.rho))
            .collect(),
        z,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TransportKind;
    use crate::data::gen_partitioned;

    fn train(cfg: &Config, ds: &Dataset, shards: &[WorkerShard]) -> TrainReport {
        Session::builder(cfg).dataset(ds, shards).run().unwrap()
    }

    #[test]
    fn async_native_training_decreases_objective() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 240; // one random block per epoch => ~60 full passes
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = train(&cfg, &ds, &shards);

        let first = report.samples.first().unwrap().objective;
        let last = report.final_objective.total();
        assert!(
            last < first * 0.9,
            "objective should drop: {first} -> {last}"
        );
        assert!(report.total_pushes() >= cfg.epochs * cfg.n_workers);
        assert!(report.consensus_max.is_finite());
        assert_eq!(report.worker_stats.len(), cfg.n_workers);
        assert_eq!(report.server_stats.len(), cfg.n_servers);
        // Static placement never migrates.
        assert_eq!(report.migrations, 0);
        // Version-gated pulls: blocks nobody touched since the last
        // refresh skip the copy (a 240-epoch run always has some).
        let skips: usize = report.worker_stats.iter().map(|w| w.pull_skips).sum();
        assert!(skips > 0, "version gate never skipped a pull");
    }

    #[test]
    fn elastic_pool_runs_with_decoupled_thread_count() {
        // `server_threads != n_servers` must not change what is pushed
        // or where the objective lands — 1 thread for 2 shards
        // (scarcity) and 5 threads for 2 shards (oversubscription).
        let (ds, shards) = {
            let cfg = Config::tiny_test();
            gen_partitioned(&cfg.synth_spec(), cfg.n_workers)
        };
        for threads in [1usize, 5] {
            let mut cfg = Config::tiny_test();
            cfg.epochs = 120;
            cfg.server_threads = threads;
            let report = train(&cfg, &ds, &shards);
            assert_eq!(
                report.total_pushes(),
                cfg.epochs * cfg.n_workers,
                "threads={threads}: push accounting broke"
            );
            assert!(
                report.final_objective.total() < 0.68,
                "threads={threads}: {}",
                report.final_objective.total()
            );
        }
    }

    #[test]
    fn push_pool_high_water_bounded_by_channel_capacity_not_epochs() {
        // The no-allocation-per-epoch invariant: buffers allocated on the
        // push path are bounded by the in-flight capacity, not by the
        // number of epochs run — under BOTH transports.
        for kind in [TransportKind::Mpsc, TransportKind::SpscRing] {
            let mut cfg = Config::tiny_test();
            cfg.epochs = 400;
            cfg.transport = kind;
            let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
            let report = train(&cfg, &ds, &shards);
            let bound = push_inflight(cfg.n_workers) + 4;
            for w in &report.worker_stats {
                assert!(w.pool_high_water >= 1, "{kind:?}: pool never used");
                assert!(
                    w.pool_high_water <= bound,
                    "{kind:?}: pool allocated {} buffers (bound {bound}, epochs {})",
                    w.pool_high_water,
                    cfg.epochs
                );
                assert!(
                    w.pool_high_water < cfg.epochs / 8,
                    "{kind:?}: allocation scaled with epochs"
                );
            }
        }
    }

    #[test]
    fn delay_enforcement_caps_staleness() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 40;
        cfg.max_delay = 2;
        cfg.enforce_delay_bound = true;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = train(&cfg, &ds, &shards);
        for w in &report.worker_stats {
            assert!(
                w.max_staleness <= 2 + 1, // one concurrent write can land mid-step
                "staleness {} exceeds bound",
                w.max_staleness
            );
        }
    }

    #[test]
    fn no_sample_emitted_past_epoch_budget() {
        // The monitor must not spin out an extra sampling interval after
        // the run finishes: every sample's epoch is ≤ the budget and the
        // final-state row appears exactly once.
        let mut cfg = Config::tiny_test();
        cfg.epochs = 37; // not a multiple of log_every
        cfg.log_every = 5;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let report = train(&cfg, &ds, &shards);
        assert!(report.samples.iter().all(|s| s.epoch <= cfg.epochs));
        let at_budget =
            report.samples.iter().filter(|s| s.epoch == cfg.epochs).count();
        assert_eq!(at_budget, 1, "final sample duplicated or missing");
        // epochs are non-decreasing
        for w in report.samples.windows(2) {
            assert!(w[1].epoch >= w[0].epoch);
        }
    }

    #[test]
    fn observers_see_samples_and_completion() {
        struct Spy<'a> {
            samples: &'a mut Vec<(usize, f64)>,
            completed: &'a mut bool,
        }
        impl Observer for Spy<'_> {
            fn on_sample(&mut self, p: &Progress<'_>) {
                assert!(!p.z().is_empty(), "empty z snapshot");
                self.samples.push((p.epoch, p.objective().total()));
            }
            fn on_complete(&mut self, report: &TrainReport) {
                *self.completed = true;
                assert!(report.final_objective.total().is_finite());
            }
        }
        let mut cfg = Config::tiny_test();
        cfg.epochs = 60;
        cfg.log_every = 10;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let mut seen = Vec::new();
        let mut completed = false;
        let report = Session::builder(&cfg)
            .dataset(&ds, &shards)
            .observer(Spy { samples: &mut seen, completed: &mut completed })
            .run()
            .unwrap();
        assert!(completed, "on_complete not fired");
        assert!(!seen.is_empty(), "observer saw no samples");
        // The observer saw exactly the built-in sampler's rows (minus the
        // appended final-state row).
        assert_eq!(seen.len(), report.samples.len() - 1);
        for ((e, o), s) in seen.iter().zip(&report.samples) {
            assert_eq!(*e, s.epoch);
            assert!((o - s.objective).abs() < 1e-12);
        }
    }

    #[test]
    fn missing_dataset_is_a_clear_error() {
        let cfg = Config::tiny_test();
        let err = Session::builder(&cfg).run().unwrap_err();
        assert!(format!("{err:#}").contains("dataset"), "{err:#}");
    }

    #[test]
    fn baseline_algos_run_through_the_session_surface() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 60;
        cfg.gamma = 0.0;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        for algo in [Algo::SyncAdmm, Algo::LockedAdmm, Algo::HogwildSgd { step_size: 0.5 }] {
            let r = Session::builder(&cfg).dataset(&ds, &shards).algo(algo).run().unwrap();
            // log(2) is the logistic objective at z = 0: every method
            // must at least not diverge from the start point here.
            assert!(
                r.final_objective.total() < 0.72,
                "{algo:?} diverged: {}",
                r.final_objective.total()
            );
            assert!(r.sim.is_none());
            assert!(r.stationarity.is_nan());
        }
    }
}

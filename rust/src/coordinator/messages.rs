//! Worker → server push protocol (Algorithm 1 line 7 / server line 2).
//!
//! [`PushMsg`] is the *what* of the protocol; the *how* (queueing,
//! backpressure, shutdown) lives behind the
//! [`super::transport::Transport`] trait.

use std::sync::mpsc::Sender;

/// w_{i,j} push (Eq. 9).  `worker_epoch` and `z_version_used` implement
//  the staleness accounting for Assumption 3.
// Not `Clone`: each message owns one pooled buffer and one recycle
// ticket for it; a clone would return two buffers for one acquire.
#[derive(Debug)]
pub struct PushMsg {
    pub worker: usize,
    pub block: usize,
    /// The pushed w block.  Pooled: after `handle_push` the server shard
    /// sends it home on `recycle` instead of dropping it, so the steady
    /// state allocates nothing per epoch (see `coordinator::bufpool`).
    pub w: Vec<f32>,
    /// Worker's local epoch t when this w was produced.
    pub worker_epoch: usize,
    /// BlockStore version of z̃_j the worker used to compute this w.
    pub z_version_used: u64,
    /// Wall-clock send time (for queueing-delay stats).
    pub sent_at: std::time::Instant,
    /// Return address of the worker's buffer pool; `None` means the
    /// buffer is unpooled and the server just drops it (tests, benches).
    pub recycle: Option<Sender<Vec<f32>>>,
}

impl PushMsg {
    /// Send the pooled buffer home (the normal post-`handle_push` path).
    /// Idempotent: the return address is taken on first use.
    pub fn recycle_now(&mut self) {
        if let Some(home) = self.recycle.take() {
            // A pool whose worker already exited just ignores the send.
            let _ = home.send(std::mem::take(&mut self.w));
        }
    }
}

/// A destroyed message still returns its buffer: transports and error
/// paths can drop queued messages without stranding the owning worker
/// in `PushPool::acquire` (the pool keeps its own sender alive, so a
/// lost buffer would block `acquire` forever, not error).
impl Drop for PushMsg {
    fn drop(&mut self) {
        self.recycle_now();
    }
}

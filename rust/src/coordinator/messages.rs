//! Worker → server push protocol (Algorithm 1 line 7 / server line 2).

use std::sync::mpsc::Sender;

/// w_{i,j} push (Eq. 9).  `worker_epoch` and `z_version_used` implement
//  the staleness accounting for Assumption 3.
#[derive(Clone, Debug)]
pub struct PushMsg {
    pub worker: usize,
    pub block: usize,
    /// The pushed w block.  Pooled: after `handle_push` the server shard
    /// sends it home on `recycle` instead of dropping it, so the steady
    /// state allocates nothing per epoch (see `coordinator::bufpool`).
    pub w: Vec<f32>,
    /// Worker's local epoch t when this w was produced.
    pub worker_epoch: usize,
    /// BlockStore version of z̃_j the worker used to compute this w.
    pub z_version_used: u64,
    /// Wall-clock send time (for queueing-delay stats).
    pub sent_at: std::time::Instant,
    /// Return address of the worker's buffer pool; `None` means the
    /// buffer is unpooled and the server just drops it (tests, benches).
    pub recycle: Option<Sender<Vec<f32>>>,
}

pub enum ServerMsg {
    Push(PushMsg),
    /// Drain and exit (sent by the driver once all workers joined).
    Shutdown,
}

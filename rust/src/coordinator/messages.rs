//! Worker → server push protocol (Algorithm 1 line 7 / server line 2).
//!
//! [`PushMsg`] is the *what* of the protocol; the *how* (queueing,
//! backpressure, shutdown) lives behind the
//! [`super::transport::Transport`] trait.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::util::AlignedBuf;

/// w_{i,j} push (Eq. 9).  `worker_epoch` and `z_version_used` implement
//  the staleness accounting for Assumption 3.
// Not `Clone`: each message owns one pooled buffer and one recycle
// ticket for it; a clone would return two buffers for one acquire.
// (`detached` makes an explicitly unpooled copy for the rare deferral
// path.)
#[derive(Debug)]
pub struct PushMsg {
    pub worker: usize,
    pub block: usize,
    /// The pushed w block, in a 64-byte-aligned buffer (no false
    /// sharing between adjacent pooled buffers; SIMD kernels see
    /// aligned lanes).  Pooled: after `handle_push` the server shard
    /// sends it home on `recycle` instead of dropping it, so the steady
    /// state allocates nothing per epoch (see `coordinator::bufpool`).
    pub w: AlignedBuf,
    /// Worker's local epoch t when this w was produced.
    pub worker_epoch: usize,
    /// BlockStore version of z̃_j the worker used to compute this w.
    pub z_version_used: u64,
    /// 1-based per-(worker, block) send sequence number.  With dynamic
    /// re-placement a worker's stream for one block can split across
    /// two shards' lanes mid-migration; the server's seq-gated apply
    /// (`coordinator/server.rs`) uses this to keep per-(worker, block)
    /// application order exact.  `0` = unsequenced (tests/benches that
    /// never migrate): applied immediately, no gating.
    pub block_seq: u64,
    /// Wall-clock send time for queueing-delay stats.  Sampled (the
    /// worker stamps ~1 in 64 epochs) so the `Instant::now` syscall
    /// stays out of the steady-state hot loop; `None` = unsampled.
    pub sent_at: Option<Instant>,
    /// Return address of the worker's buffer pool; `None` means the
    /// buffer is unpooled and the server just drops it (tests, benches).
    pub recycle: Option<Sender<AlignedBuf>>,
}

impl PushMsg {
    /// Re-materialize a message decoded off the wire
    /// (`coordinator/net/wire.rs`): the timestamp is process-local and
    /// never crosses a socket, and `recycle` points at the *receiving*
    /// lane's buffer pool — the sender's pool got its buffer back at
    /// encode time, so pooled-buffer conservation holds independently
    /// on each side of the connection.
    pub fn from_wire(
        worker: usize,
        block: usize,
        w: AlignedBuf,
        worker_epoch: usize,
        z_version_used: u64,
        block_seq: u64,
        recycle: Option<Sender<AlignedBuf>>,
    ) -> PushMsg {
        PushMsg {
            worker,
            block,
            w,
            worker_epoch,
            z_version_used,
            block_seq,
            sent_at: None,
            recycle,
        }
    }

    /// Send the pooled buffer home (the normal post-`handle_push` path).
    /// Idempotent: the return address is taken on first use.
    pub fn recycle_now(&mut self) {
        if let Some(home) = self.recycle.take() {
            // A pool whose worker already exited just ignores the send.
            let _ = home.send(std::mem::take(&mut self.w));
        }
    }

    /// An unpooled copy for the seq-gated deferral path: the original's
    /// pooled buffer goes home immediately (the caller recycles as
    /// usual), the copy waits under the block lease until its missing
    /// predecessors arrive.  Deferral only happens in the short window
    /// where a migration splits a (worker, block) stream across lanes,
    /// so the clone is off the steady-state path.
    pub fn detached(&self) -> PushMsg {
        PushMsg {
            worker: self.worker,
            block: self.block,
            w: self.w.clone(),
            worker_epoch: self.worker_epoch,
            z_version_used: self.z_version_used,
            block_seq: self.block_seq,
            sent_at: self.sent_at,
            recycle: None,
        }
    }
}

/// A destroyed message still returns its buffer: transports and error
/// paths can drop queued messages without stranding the owning worker
/// in `PushPool::acquire` (the pool keeps its own sender alive, so a
/// lost buffer would block `acquire` forever, not error).
impl Drop for PushMsg {
    fn drop(&mut self) {
        self.recycle_now();
    }
}

//! Pluggable worker→server push transport.
//!
//! The paper's Fig. 1 runtime is defined by *what* travels (a
//! [`PushMsg`] per block update) and *how* it queues at the server
//! shards.  This module makes the "how" a first-class [`Transport`]
//! trait so queueing disciplines are one-file implementations instead
//! of driver rewrites:
//!
//! * [`MpscTransport`] — the original design: one bounded
//!   `std::sync::mpsc::sync_channel` per server shard.  Correct and
//!   simple, but every enqueue from every worker serializes on that
//!   channel's internal mutex — the last serialization point left on
//!   the push path after the seqlock store removed the read side's.
//! * [`SpscRingTransport`] — one array-backed single-producer
//!   single-consumer ring per (worker, server) pair with atomic
//!   head/tail indices.  No shared queue lock exists anywhere: a
//!   worker's enqueue touches only its own ring, and a server shard
//!   drains its workers' rings.  Each ring **slot holds a whole batch**
//!   of up to `batch` messages (the `--set batch=…` knob): the sender
//!   buffers per-server messages locally and swaps the full batch into
//!   one slot, amortizing the per-slot atomics when workers own many
//!   blocks.  The swap protocol is allocation-free in steady state —
//!   batch `Vec`s circulate between producer, slots, and consumer, so
//!   no shell is ever allocated after startup.
//!
//! ## Contract (what the conformance tests assert for every impl)
//!
//! * **Per-worker FIFO**: pushes from one worker to one server are
//!   received in send order — batching may *delay* messages (until the
//!   batch fills, the sender flushes, or the sender drops) but never
//!   reorders them.  (Cross-worker ordering is unspecified — Algorithm
//!   1 only needs per-edge order for its staleness accounting.)
//! * **Bounded in-flight**: at most [`Transport::inflight_bound`]
//!   pushes from one worker to one server may be un-received before
//!   `send` blocks.  This is the ps-lite-style backpressure the
//!   convergence analysis leans on: without it a fast worker can run
//!   its whole epoch budget against a starved queue, i.e. unbounded
//!   effective delay, violating Assumption 3.
//! * **Nothing left behind**: [`PushSender::flush`] delivers anything
//!   batch-buffered; dropping a sender flushes best-effort.  Callers
//!   that need the delivery *accounted* (the worker loop does, before
//!   publishing its final epoch) call `flush` explicitly.
//! * **Shutdown drains**: after [`Transport::shutdown`] (called once
//!   all workers finished and dropped their senders), each receiver
//!   yields every message still queued and only then reports end of
//!   stream.
//! * **Endpoints are single-take**: `connect_worker(w)` and
//!   `connect_server(s)` / `connect_server_lanes(s)` may each be called
//!   at most once per index (the ring transport's soundness depends on
//!   the single-producer / single-consumer discipline; both impls
//!   enforce it).
//!
//! ## Lanes (work-stealing units)
//!
//! [`Transport::connect_server_lanes`] exposes a server's inbound
//! stream as one or more *independently drainable* lanes for
//! `coordinator/sched.rs`: the ring transport returns one lane per
//! worker (its natural SPSC granularity), the mpsc transport one lane
//! total.  A lane preserves per-worker FIFO internally, so a scheduler
//! that drains whole lanes under an exclusive claim — never single
//! messages — preserves it globally.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::messages::PushMsg;
use crate::config::TransportKind;

/// Capacity of each server shard's bounded push queue for `n_workers`
/// workers.  Public so tests can assert the push-buffer pools' high-water
/// marks against the actual in-flight bound.
pub fn push_inflight(n_workers: usize) -> usize {
    (2 * n_workers).max(8)
}

/// Three-tier idle backoff for the polling loops (receivers and the
/// `sched.rs` drain loop): spin briefly, then yield, then sleep 50 µs —
/// the quantum that bounds how stale a shutdown/teardown signal can go
/// unnoticed.  One place to tune instead of three hand-rolled ladders.
pub(crate) struct Backoff {
    idle: u32,
}

impl Backoff {
    pub(crate) fn new() -> Self {
        Backoff { idle: 0 }
    }

    pub(crate) fn reset(&mut self) {
        self.idle = 0;
    }

    pub(crate) fn snooze(&mut self) {
        self.idle += 1;
        if self.idle < 16 {
            std::hint::spin_loop();
        } else if self.idle < 256 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

/// Result of a non-blocking receive attempt.
#[derive(Debug)]
pub enum TryRecv {
    /// A message was dequeued.
    Msg(PushMsg),
    /// Nothing queued right now, but producers may still send.
    Empty,
    /// Shut down (or disconnected) and fully drained — terminal.
    Done,
}

/// A queueing discipline for worker→server pushes.  Shared by reference
/// across the run's thread scope; endpoints move into their threads.
pub trait Transport: Send + Sync {
    /// Human-readable name (logs, benches, BENCH_hotpath.json keys).
    fn name(&self) -> &'static str;

    /// The sending endpoint for `worker`.  At most one call per worker.
    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender>;

    /// A *replacement* sending endpoint for a worker whose previous
    /// endpoint is gone (`failure=restart` in `session.rs`).  The
    /// caller must guarantee the original endpoint was dropped first —
    /// the restart path satisfies this trivially because the
    /// replacement runs on the thread that just unwound the original,
    /// so the ring transport's single-producer discipline transfers to
    /// the new endpoint without a race.  Panics if `worker` was never
    /// connected.
    fn reconnect_worker(&self, worker: usize) -> Box<dyn PushSender>;

    /// The receiving endpoint for `server`.  At most one call per server
    /// (shared with [`Transport::connect_server_lanes`]).
    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver>;

    /// The same stream as [`Transport::connect_server`], but split into
    /// independently drainable lanes (the work-stealing granularity of
    /// `coordinator/sched.rs`).  Default: one lane, the blocking
    /// endpoint.  Takes the same single-take slot as `connect_server`.
    fn connect_server_lanes(&self, server: usize) -> Vec<Box<dyn PushReceiver>> {
        vec![self.connect_server(server)]
    }

    /// How many consecutive [`PushSender::send`]s to one server are
    /// guaranteed to complete, starting from an empty queue, before a
    /// send may block — the backpressure bound.  (Batching shifts
    /// *where* messages wait — sender buffer vs queue — but each impl
    /// reports this same completed-sends-before-blocking quantity, and
    /// the conformance suite asserts it exactly.)
    fn inflight_bound(&self) -> usize;

    /// Signal end-of-stream.  Receivers drain what is queued and then
    /// report done.  Call only after every worker endpoint is dropped
    /// (the session does this once all workers joined).
    fn shutdown(&self);
}

/// Worker-side endpoint: blocking bounded enqueue to any server shard.
pub trait PushSender: Send {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()>;

    /// Deliver anything locally batch-buffered.  No-op for unbatched
    /// senders.  Dropping a sender flushes best-effort; call this when
    /// delivery must be *confirmed* (e.g. before reporting completion).
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Server-side endpoint: blocking or polling dequeue.
pub trait PushReceiver: Send {
    /// Blocking dequeue; `None` = shut down and drained.
    fn recv(&mut self) -> Option<PushMsg>;

    /// Non-blocking dequeue (work-stealing drain loops poll this).
    fn try_recv(&mut self) -> TryRecv;
}

/// Construct the configured transport for a run.
pub fn make_transport(
    kind: TransportKind,
    n_workers: usize,
    n_servers: usize,
    inflight: usize,
    batch: usize,
) -> Box<dyn Transport> {
    match kind {
        TransportKind::Mpsc => {
            Box::new(MpscTransport::new(n_workers, n_servers, inflight, batch))
        }
        TransportKind::SpscRing => {
            // Match the mpsc per-server budget: each of the worker's
            // rings holds its share of the channel capacity (in slots;
            // a slot carries up to `batch` messages).
            let ring_cap = inflight.div_ceil(n_workers.max(1)).max(2);
            Box::new(SpscRingTransport::new(n_workers, n_servers, ring_cap, batch))
        }
        TransportKind::Tcp => {
            // Same per-worker split as the ring: each (worker, server)
            // socket lane gets its share of the per-server budget,
            // enforced as a frame-credit window
            // (`coordinator/net/tcp.rs`).
            let lane_cap = inflight.div_ceil(n_workers.max(1)).max(2);
            Box::new(super::net::TcpTransport::new(n_workers, n_servers, lane_cap, batch))
        }
    }
}

// ---------------------------------------------------------------------------
// MpscTransport
// ---------------------------------------------------------------------------

/// One bounded `sync_channel` per server shard (the original driver
/// wiring, extracted).  All workers share a server's channel, so every
/// enqueue takes that channel's internal lock.  `batch > 1` wraps each
/// sender in a [`BatchingSender`], which buffers then forwards — same
/// delivery semantics as the ring's batched slots, without the slot
/// amortization (the channel is the bottleneck either way).
pub struct MpscTransport {
    /// Root senders; dropped on `shutdown` so receivers observe
    /// disconnect once worker clones are gone too.
    txs: Mutex<Vec<Option<SyncSender<PushMsg>>>>,
    rxs: Mutex<Vec<Option<Receiver<PushMsg>>>>,
    worker_taken: Mutex<Vec<bool>>,
    inflight: usize,
    batch: usize,
}

impl MpscTransport {
    pub fn new(n_workers: usize, n_servers: usize, inflight: usize, batch: usize) -> Self {
        let mut txs = Vec::with_capacity(n_servers);
        let mut rxs = Vec::with_capacity(n_servers);
        for _ in 0..n_servers {
            let (tx, rx) = sync_channel::<PushMsg>(inflight.max(1));
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        MpscTransport {
            txs: Mutex::new(txs),
            rxs: Mutex::new(rxs),
            worker_taken: Mutex::new(vec![false; n_workers]),
            inflight: inflight.max(1),
            batch: batch.max(1),
        }
    }

    fn make_sender(&self) -> Box<dyn PushSender> {
        let txs: Vec<SyncSender<PushMsg>> = self
            .txs
            .lock()
            .unwrap()
            .iter()
            .map(|t| t.as_ref().expect("transport already shut down").clone())
            .collect();
        let n_servers = txs.len();
        let inner = MpscSender { txs };
        if self.batch > 1 {
            Box::new(BatchingSender::new(inner, n_servers, self.batch))
        } else {
            Box::new(inner)
        }
    }
}

impl Transport for MpscTransport {
    fn name(&self) -> &'static str {
        "mpsc"
    }

    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let mut taken = self.worker_taken.lock().unwrap();
        assert!(!taken[worker], "worker {worker} endpoint already taken");
        taken[worker] = true;
        drop(taken);
        self.make_sender()
    }

    fn reconnect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let taken = self.worker_taken.lock().unwrap();
        assert!(taken[worker], "worker {worker} was never connected");
        drop(taken);
        // The channels are MPSC: a replacement clone of the root
        // senders is all a restarted worker needs.
        self.make_sender()
    }

    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver> {
        let rx = self.rxs.lock().unwrap()[server]
            .take()
            .unwrap_or_else(|| panic!("server {server} endpoint already taken"));
        Box::new(MpscReceiver { rx })
    }

    fn inflight_bound(&self) -> usize {
        // Completed sends before one can block: buffering absorbs sends
        // for free until a flush must push the (inflight+1)-th message
        // into the full channel.  Flushes fire at multiples of `batch`,
        // so that flush is triggered by send number
        // ceil((inflight+1)/batch)·batch, and every send before it
        // completed (batch=1 degenerates to plain `inflight`).
        (self.inflight + 1).div_ceil(self.batch) * self.batch - 1
    }

    fn shutdown(&self) {
        self.txs.lock().unwrap().iter_mut().for_each(|t| drop(t.take()));
    }
}

struct MpscSender {
    txs: Vec<SyncSender<PushMsg>>,
}

impl PushSender for MpscSender {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        self.txs[server]
            .send(msg)
            .map_err(|_| anyhow::anyhow!("server {server} hung up"))
    }
}

struct MpscReceiver {
    rx: Receiver<PushMsg>,
}

impl PushReceiver for MpscReceiver {
    fn recv(&mut self) -> Option<PushMsg> {
        // Err = all senders dropped (workers done + transport shut down)
        // AND the buffer is empty: exactly the drain-then-exit contract.
        self.rx.recv().ok()
    }

    fn try_recv(&mut self) -> TryRecv {
        match self.rx.try_recv() {
            Ok(msg) => TryRecv::Msg(msg),
            Err(TryRecvError::Empty) => TryRecv::Empty,
            Err(TryRecvError::Disconnected) => TryRecv::Done,
        }
    }
}

// ---------------------------------------------------------------------------
// BatchingSender — sender-side batching for transports without native
// batch slots (mpsc).
// ---------------------------------------------------------------------------

/// Buffers up to `batch` messages per server, then forwards them in
/// order through the inner sender.  Per-worker FIFO is preserved (each
/// server's buffer flushes front to back); a failed flush destroys the
/// remaining buffered messages, which recycle their pooled buffers via
/// `PushMsg::drop`.
struct BatchingSender<S: PushSender> {
    inner: S,
    batch: usize,
    pending: Vec<Vec<PushMsg>>,
}

impl<S: PushSender> BatchingSender<S> {
    fn new(inner: S, n_servers: usize, batch: usize) -> Self {
        BatchingSender {
            inner,
            batch: batch.max(1),
            pending: (0..n_servers).map(|_| Vec::with_capacity(batch)).collect(),
        }
    }

    fn flush_server(&mut self, server: usize) -> Result<()> {
        for msg in self.pending[server].drain(..) {
            self.inner.send(server, msg)?;
        }
        Ok(())
    }
}

impl<S: PushSender> PushSender for BatchingSender<S> {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        self.pending[server].push(msg);
        if self.pending[server].len() >= self.batch {
            self.flush_server(server)
        } else {
            Ok(())
        }
    }

    fn flush(&mut self) -> Result<()> {
        for s in 0..self.pending.len() {
            self.flush_server(s)?;
        }
        self.inner.flush()
    }
}

impl<S: PushSender> Drop for BatchingSender<S> {
    fn drop(&mut self) {
        // Best-effort: a hung-up server just destroys the remainder
        // (each destroyed message recycles its pooled buffer).
        let _ = self.flush();
    }
}

// ---------------------------------------------------------------------------
// SpscRingTransport
// ---------------------------------------------------------------------------

/// One single-producer single-consumer slot ring carrying message
/// *batches*.
///
/// `head`/`tail` are monotonically increasing operation counters
/// (batch `n` lives in slot `n % cap`); `tail - head` is the queue
/// length, full at `cap`.  The producer owns `tail`, the consumer owns
/// `head`; each reads the other's index with `Acquire` and publishes
/// its own with `Release`, so slot hand-off is properly ordered.
/// (With work-stealing the consumer *role* migrates between server
/// threads, but `sched.rs`'s lane claim serializes it, and the claim's
/// release/acquire pair carries the `head` updates across threads.)
///
/// Each slot is a `Mutex<Vec<PushMsg>>` that is **swapped whole**:
/// the producer exchanges its full pending batch for the slot's spent
/// (empty) `Vec`, the consumer exchanges an empty scratch `Vec` for the
/// slot's full one.  `Vec` shells therefore circulate — producer →
/// slot → consumer → slot → producer — and the steady state allocates
/// nothing.  The SPSC discipline makes every lock acquisition
/// **uncontended by construction**: the producer only touches slot
/// `tail % cap` after observing `tail - head < cap` (the consumer is
/// done with it), and the consumer only touches slot `head % cap`
/// after observing `head < tail` (the producer has published it).  An
/// uncontended lock is a single CAS each way — the point is that,
/// unlike the mpsc channel, no cell is ever shared between two workers
/// or two shards, so nothing on the push path serializes across
/// threads.  (Kept over an `UnsafeCell` ring to preserve the crate's
/// no-`unsafe` property; see DESIGN.md §2.1 for the same choice in the
/// seqlock store.)
struct Ring {
    head: AtomicUsize,
    tail: AtomicUsize,
    slots: Vec<Mutex<Vec<PushMsg>>>,
}

impl Ring {
    fn new(cap: usize, batch: usize) -> Self {
        Ring {
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            slots: (0..cap.max(1)).map(|_| Mutex::new(Vec::with_capacity(batch))).collect(),
        }
    }

    /// Producer side: swap the non-empty `batch` into the tail slot.
    /// On success `batch` comes back as the slot's previous spent
    /// (empty, capacity-preserving) `Vec`; on a full ring `batch` is
    /// untouched and `false` is returned.
    fn try_push(&self, batch: &mut Vec<PushMsg>) -> bool {
        debug_assert!(!batch.is_empty());
        let tail = self.tail.load(Ordering::Relaxed); // producer-owned
        if tail - self.head.load(Ordering::Acquire) == self.slots.len() {
            return false;
        }
        let mut slot = self.slots[tail % self.slots.len()].lock().unwrap();
        debug_assert!(slot.is_empty(), "unconsumed slot overwritten");
        std::mem::swap(&mut *slot, batch);
        drop(slot);
        self.tail.store(tail + 1, Ordering::Release);
        true
    }

    /// Consumer side: swap the **empty** `into` with the head slot's
    /// batch.  `false` = ring empty.
    fn try_pop(&self, into: &mut Vec<PushMsg>) -> bool {
        debug_assert!(into.is_empty());
        let head = self.head.load(Ordering::Relaxed); // claim-serialized
        if self.tail.load(Ordering::Acquire) == head {
            return false;
        }
        let mut slot = self.slots[head % self.slots.len()].lock().unwrap();
        std::mem::swap(&mut *slot, into);
        drop(slot);
        self.head.store(head + 1, Ordering::Release);
        debug_assert!(!into.is_empty(), "published slot was empty");
        true
    }
}

struct RingShared {
    /// `rings[worker][server]`.
    rings: Vec<Vec<Ring>>,
    shutdown: AtomicBool,
    /// Per-server "receiver is gone" flags: set when a receiver drops
    /// (normal exit after drain, or a server thread unwinding on
    /// error), so senders fail loudly like mpsc's disconnect instead of
    /// spinning on a full ring nobody will ever drain.
    closed: Vec<AtomicBool>,
}

impl RingShared {
    /// Close server `s` for producers and destroy anything still queued
    /// in `worker`'s ring to it — each destroyed message sends its
    /// pooled buffer home (`PushMsg::drop`), so a dead server cannot
    /// strand a worker in `PushPool::acquire`.
    fn close_and_drain(&self, worker: usize, server: usize) {
        self.closed[server].store(true, Ordering::Release);
        let mut scratch = Vec::new();
        while self.rings[worker][server].try_pop(&mut scratch) {
            scratch.clear(); // drop the batch; buffers recycle
        }
    }
}

/// Per-(worker, server) SPSC rings with batched slots; server shards
/// drain their workers' rings (round-robin via [`connect_server`], or
/// as independent lanes via [`connect_server_lanes`] for the
/// work-stealing scheduler).  No queue lock is shared between any two
/// threads.
///
/// [`connect_server`]: Transport::connect_server
/// [`connect_server_lanes`]: Transport::connect_server_lanes
pub struct SpscRingTransport {
    shared: Arc<RingShared>,
    worker_taken: Mutex<Vec<bool>>,
    server_taken: Mutex<Vec<bool>>,
    ring_cap: usize,
    batch: usize,
}

impl SpscRingTransport {
    pub fn new(n_workers: usize, n_servers: usize, ring_cap: usize, batch: usize) -> Self {
        let batch = batch.max(1);
        let rings = (0..n_workers)
            .map(|_| (0..n_servers).map(|_| Ring::new(ring_cap, batch)).collect())
            .collect();
        let closed = (0..n_servers).map(|_| AtomicBool::new(false)).collect();
        SpscRingTransport {
            shared: Arc::new(RingShared { rings, shutdown: AtomicBool::new(false), closed }),
            worker_taken: Mutex::new(vec![false; n_workers]),
            server_taken: Mutex::new(vec![false; n_servers]),
            ring_cap: ring_cap.max(1),
            batch,
        }
    }

    fn take_server_slot(&self, server: usize) {
        let mut taken = self.server_taken.lock().unwrap();
        assert!(!taken[server], "server {server} endpoint already taken (SPSC)");
        taken[server] = true;
    }

    fn make_sender(&self, worker: usize) -> Box<dyn PushSender> {
        let n_servers = self.shared.closed.len();
        Box::new(RingSender {
            shared: self.shared.clone(),
            worker,
            batch: self.batch,
            pending: (0..n_servers).map(|_| Vec::with_capacity(self.batch)).collect(),
        })
    }
}

impl Transport for SpscRingTransport {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let mut taken = self.worker_taken.lock().unwrap();
        assert!(!taken[worker], "worker {worker} endpoint already taken (SPSC)");
        taken[worker] = true;
        drop(taken);
        self.make_sender(worker)
    }

    fn reconnect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let taken = self.worker_taken.lock().unwrap();
        assert!(taken[worker], "worker {worker} was never connected (SPSC)");
        drop(taken);
        // Sound only because the caller guarantees the previous
        // producer was dropped (trait contract): exactly one producer
        // touches each `rings[worker][*]` at any time.
        self.make_sender(worker)
    }

    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver> {
        self.take_server_slot(server);
        Box::new(RingReceiver {
            shared: self.shared.clone(),
            server,
            cursor: 0,
            ready: Vec::with_capacity(self.batch),
        })
    }

    fn connect_server_lanes(&self, server: usize) -> Vec<Box<dyn PushReceiver>> {
        self.take_server_slot(server);
        (0..self.shared.rings.len())
            .map(|worker| {
                Box::new(SingleRingReceiver {
                    shared: self.shared.clone(),
                    worker,
                    server,
                    ready: Vec::with_capacity(self.batch),
                }) as Box<dyn PushReceiver>
            })
            .collect()
    }

    fn inflight_bound(&self) -> usize {
        // `ring_cap` full slots of `batch` messages, plus what the
        // sender can hold un-flushed before the next send forces a
        // (blocking) flush.
        self.ring_cap * self.batch + (self.batch - 1)
    }

    fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
    }
}

struct RingSender {
    shared: Arc<RingShared>,
    worker: usize,
    batch: usize,
    /// Per-server batch under construction (each keeps capacity
    /// `batch`; swapped whole into a ring slot on flush).
    pending: Vec<Vec<PushMsg>>,
}

impl RingSender {
    /// Swap the pending batch for `server` into its ring, spinning under
    /// backpressure.  On error the un-flushed messages stay in
    /// `pending` and are destroyed (→ recycled) when the sender drops.
    fn flush_server(&mut self, server: usize) -> Result<()> {
        if self.pending[server].is_empty() {
            return Ok(());
        }
        let ring = &self.shared.rings[self.worker][server];
        let mut spins = 0u32;
        loop {
            // Disconnect detection, matching mpsc semantics: a dropped
            // receiver fails the send (rejected messages recycle their
            // pooled buffers on drop).
            anyhow::ensure!(
                !self.shared.closed[server].load(Ordering::Acquire),
                "server {server} hung up"
            );
            if ring.try_push(&mut self.pending[server]) {
                return Ok(());
            }
            // Ring full: the bounded-in-flight backpressure.
            anyhow::ensure!(
                !self.shared.shutdown.load(Ordering::Relaxed),
                "transport shut down with pushes still in flight to server {server}"
            );
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

impl PushSender for RingSender {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        anyhow::ensure!(
            !self.shared.closed[server].load(Ordering::Acquire),
            "server {server} hung up"
        );
        self.pending[server].push(msg);
        if self.pending[server].len() >= self.batch {
            self.flush_server(server)
        } else {
            Ok(())
        }
    }

    fn flush(&mut self) -> Result<()> {
        for s in 0..self.pending.len() {
            self.flush_server(s)?;
        }
        Ok(())
    }
}

impl Drop for RingSender {
    fn drop(&mut self) {
        // Best-effort flush so normal teardown loses nothing; on a
        // closed lane / shutdown the remainder is destroyed and its
        // buffers recycle via `PushMsg::drop`.
        let _ = self.flush();
    }
}

/// Round-robin receiver over all of a server's worker rings (the
/// single-endpoint [`Transport::connect_server`] view).
struct RingReceiver {
    shared: Arc<RingShared>,
    server: usize,
    /// Round-robin fairness cursor over worker rings.
    cursor: usize,
    /// Current batch, **reversed** so `pop()` yields FIFO order; its
    /// shell is swapped back into a slot on the next refill.
    ready: Vec<PushMsg>,
}

impl RingReceiver {
    /// Refill `ready` (must be empty) from the next non-empty ring.
    fn poll_rings(&mut self) -> bool {
        let n_workers = self.shared.rings.len();
        for k in 0..n_workers {
            let w = (self.cursor + k) % n_workers;
            if self.shared.rings[w][self.server].try_pop(&mut self.ready) {
                self.ready.reverse(); // pop() from the back = send order
                self.cursor = (w + 1) % n_workers;
                return true;
            }
        }
        false
    }
}

impl PushReceiver for RingReceiver {
    fn recv(&mut self) -> Option<PushMsg> {
        let mut backoff = Backoff::new();
        loop {
            if let Some(msg) = self.ready.pop() {
                return Some(msg);
            }
            // Observe shutdown BEFORE the sweep: producers stop (and
            // flush) before `shutdown()` is called, so one clean sweep
            // after seeing the flag proves the rings are drained.
            let shutting_down = self.shared.shutdown.load(Ordering::Acquire);
            if self.poll_rings() {
                continue;
            }
            if shutting_down {
                return None;
            }
            // Empty but live: back off gently (dedicated server thread).
            backoff.snooze();
        }
    }

    fn try_recv(&mut self) -> TryRecv {
        if let Some(msg) = self.ready.pop() {
            return TryRecv::Msg(msg);
        }
        let shutting_down = self.shared.shutdown.load(Ordering::Acquire);
        if self.poll_rings() {
            return TryRecv::Msg(self.ready.pop().expect("refilled batch empty"));
        }
        if shutting_down {
            TryRecv::Done
        } else {
            TryRecv::Empty
        }
    }
}

impl Drop for RingReceiver {
    fn drop(&mut self) {
        // Close this server's lanes first so producers stop feeding
        // them, then destroy anything still queued (buffers recycle).
        for w in 0..self.shared.rings.len() {
            self.shared.close_and_drain(w, self.server);
        }
    }
}

/// One (worker, server) ring as an independently drainable lane — what
/// [`Transport::connect_server_lanes`] hands the work-stealing
/// scheduler.  SPSC soundness holds as long as at most one thread
/// drains it at a time; `sched.rs`'s CAS lane claim enforces that.
struct SingleRingReceiver {
    shared: Arc<RingShared>,
    worker: usize,
    server: usize,
    /// Current batch, reversed so `pop()` yields FIFO order.
    ready: Vec<PushMsg>,
}

impl SingleRingReceiver {
    fn poll_ring(&mut self) -> bool {
        if self.shared.rings[self.worker][self.server].try_pop(&mut self.ready) {
            self.ready.reverse();
            return true;
        }
        false
    }
}

impl PushReceiver for SingleRingReceiver {
    fn recv(&mut self) -> Option<PushMsg> {
        let mut backoff = Backoff::new();
        loop {
            match self.try_recv() {
                TryRecv::Msg(m) => return Some(m),
                TryRecv::Done => return None,
                TryRecv::Empty => backoff.snooze(),
            }
        }
    }

    fn try_recv(&mut self) -> TryRecv {
        if let Some(msg) = self.ready.pop() {
            return TryRecv::Msg(msg);
        }
        let shutting_down = self.shared.shutdown.load(Ordering::Acquire);
        if self.poll_ring() {
            return TryRecv::Msg(self.ready.pop().expect("refilled batch empty"));
        }
        if shutting_down {
            TryRecv::Done
        } else {
            TryRecv::Empty
        }
    }
}

impl Drop for SingleRingReceiver {
    fn drop(&mut self) {
        self.shared.close_and_drain(self.worker, self.server);
    }
}

// ---------------------------------------------------------------------------
// Conformance suite — every Transport impl must pass all of these,
// batched and unbatched.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn msg(worker: usize, epoch: usize) -> PushMsg {
        PushMsg {
            worker,
            block: 0,
            w: vec![epoch as f32; 4].into(),
            worker_epoch: epoch,
            z_version_used: 0,
            block_seq: 0,
            sent_at: None,
            recycle: None,
        }
    }

    /// All transports, batched and unbatched, same shape, for every
    /// conformance check.  batch=2 covers the capacity-misaligned case
    /// (8+1 not divisible by 2), batch=3 the aligned one.  The TCP
    /// transport runs the identical contract over loopback sockets.
    fn each_transport(n_workers: usize, n_servers: usize, f: impl Fn(Box<dyn Transport>)) {
        f(Box::new(MpscTransport::new(n_workers, n_servers, 8, 1)));
        f(Box::new(MpscTransport::new(n_workers, n_servers, 8, 2)));
        f(Box::new(MpscTransport::new(n_workers, n_servers, 8, 3)));
        f(Box::new(SpscRingTransport::new(n_workers, n_servers, 8, 1)));
        f(Box::new(SpscRingTransport::new(n_workers, n_servers, 8, 2)));
        f(Box::new(SpscRingTransport::new(n_workers, n_servers, 8, 3)));
        f(Box::new(super::super::net::TcpTransport::new(n_workers, n_servers, 8, 1)));
        f(Box::new(super::super::net::TcpTransport::new(n_workers, n_servers, 8, 2)));
        f(Box::new(super::super::net::TcpTransport::new(n_workers, n_servers, 8, 3)));
    }

    /// Poll `f` until it yields, bounded: networked transports deliver
    /// asynchronously (a flushed frame needs a socket round trip before
    /// `try_recv` can surface it), so non-blocking assertions poll with
    /// a deadline.  In-process transports still satisfy these on the
    /// first call.
    fn poll_until<T>(what: &str, mut f: impl FnMut() -> Option<T>) -> T {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            if let Some(v) = f() {
                return v;
            }
            assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::yield_now();
        }
    }

    #[test]
    fn fifo_per_worker_single_stream() {
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            let mut rx = t.connect_server(0);
            let h = std::thread::spawn({
                let total = 100usize;
                move || {
                    for i in 0..total {
                        tx.send(0, msg(0, i)).unwrap();
                    }
                    // tx drops here: any partial batch flushes.
                }
            });
            for i in 0..100 {
                let m = rx.recv().expect("stream ended early");
                assert_eq!(m.worker_epoch, i, "[{}] out of order", t.name());
                assert_eq!(m.w, vec![i as f32; 4], "[{}] payload torn", t.name());
            }
            h.join().unwrap();
            t.shutdown();
            assert!(rx.recv().is_none(), "[{}] not drained-empty after shutdown", t.name());
        });
    }

    #[test]
    fn fifo_per_worker_under_interleaving() {
        let (n_workers, per_worker) = (3usize, 50usize);
        each_transport(n_workers, 1, |t| {
            std::thread::scope(|s| {
                for w in 0..n_workers {
                    let mut tx = t.connect_worker(w);
                    s.spawn(move || {
                        for i in 0..per_worker {
                            tx.send(0, msg(w, i)).unwrap();
                        }
                    });
                }
                let mut rx = t.connect_server(0);
                let mut next = vec![0usize; n_workers];
                for _ in 0..n_workers * per_worker {
                    let m = rx.recv().expect("stream ended early");
                    assert_eq!(
                        m.worker_epoch,
                        next[m.worker],
                        "[{}] worker {} reordered",
                        t.name(),
                        m.worker
                    );
                    next[m.worker] += 1;
                }
                assert!(next.iter().all(|&n| n == per_worker));
            });
        });
    }

    #[test]
    fn send_blocks_at_inflight_bound() {
        each_transport(1, 1, |t| {
            let bound = t.inflight_bound();
            let sent = Arc::new(AtomicUsize::new(0));
            let mut tx = t.connect_worker(0);
            let h = std::thread::spawn({
                let sent = sent.clone();
                move || {
                    for i in 0..bound + 3 {
                        tx.send(0, msg(0, i)).unwrap();
                        sent.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
            // Nothing is receiving: the sender must stall exactly at the
            // advertised bound (backpressure), not run ahead of it.
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(
                sent.load(Ordering::SeqCst),
                bound,
                "[{}] in-flight bound not enforced",
                t.name()
            );
            let mut rx = t.connect_server(0);
            for i in 0..bound + 3 {
                assert_eq!(rx.recv().expect("ended early").worker_epoch, i);
            }
            h.join().unwrap();
        });
    }

    /// Coalesced credit return must keep the in-flight bound *exact*:
    /// draining one window's worth of messages hands the sender exactly
    /// one window of credits back — it advances to the new bound and
    /// stalls there, for every batch shape (1 = unbatched, 2 =
    /// capacity-misaligned, 3 = aligned).
    #[test]
    fn coalesced_credits_reopen_the_tcp_window_exactly() {
        for batch in [1usize, 2, 3] {
            let t = super::super::net::TcpTransport::new(1, 1, 8, batch);
            let bound = t.inflight_bound();
            // bound = cap_b*batch + (batch-1); the wire itself holds one
            // window (cap_b frames), the rest is the pending partial.
            let window = bound - (batch - 1);
            let total = 3 * window + bound;
            let sent = Arc::new(AtomicUsize::new(0));
            let mut tx = t.connect_worker(0);
            let h = std::thread::spawn({
                let sent = sent.clone();
                move || {
                    for i in 0..total {
                        tx.send(0, msg(0, i)).unwrap();
                        sent.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(sent.load(Ordering::SeqCst), bound, "[batch={batch}] initial bound");
            let mut rx = t.connect_server(0);
            let mut next = 0usize;
            for slice in 1..=3usize {
                for _ in 0..window {
                    assert_eq!(rx.recv().expect("ended early").worker_epoch, next);
                    next += 1;
                }
                let expect = slice * window + bound;
                poll_until("sender to spend returned credits", || {
                    (sent.load(Ordering::SeqCst) >= expect).then_some(())
                });
                // Exactness: the credits we just returned cover one
                // window, no more — the sender must not run past it.
                std::thread::sleep(Duration::from_millis(50));
                assert_eq!(
                    sent.load(Ordering::SeqCst),
                    expect,
                    "[batch={batch}] sender overran the coalesced-credit window"
                );
            }
            for _ in 0..total - next {
                rx.recv().expect("ended early");
            }
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_drains_queued_messages() {
        each_transport(1, 2, |t| {
            let mut tx = t.connect_worker(0);
            for i in 0..5 {
                tx.send(1, msg(0, i)).unwrap();
            }
            drop(tx); // worker done; partial batch flushes
            t.shutdown();
            // Everything enqueued before shutdown must still come out,
            // in order, on the right server; the untouched server is
            // immediately drained-empty.
            let mut rx1 = t.connect_server(1);
            for i in 0..5 {
                assert_eq!(
                    rx1.recv().expect("lost on shutdown").worker_epoch,
                    i,
                    "[{}] drain reordered",
                    t.name()
                );
            }
            assert!(rx1.recv().is_none());
            let mut rx0 = t.connect_server(0);
            assert!(rx0.recv().is_none(), "[{}] phantom message", t.name());
        });
    }

    #[test]
    fn explicit_flush_delivers_partial_batches() {
        // A flushed partial batch must be receivable WITHOUT dropping
        // the sender — the worker loop relies on this before publishing
        // its final epoch.
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            let mut rx = t.connect_server(0);
            tx.send(0, msg(0, 0)).unwrap();
            tx.flush().unwrap();
            let m = rx.recv().expect("flushed message not delivered");
            assert_eq!(m.worker_epoch, 0, "[{}]", t.name());
            // Sender stays usable after a flush.
            tx.send(0, msg(0, 1)).unwrap();
            tx.flush().unwrap();
            assert_eq!(rx.recv().unwrap().worker_epoch, 1, "[{}]", t.name());
        });
    }

    #[test]
    fn routes_by_server_index() {
        each_transport(2, 2, |t| {
            let mut tx0 = t.connect_worker(0);
            let mut tx1 = t.connect_worker(1);
            tx0.send(0, msg(0, 10)).unwrap();
            tx1.send(1, msg(1, 20)).unwrap();
            drop((tx0, tx1));
            t.shutdown();
            let mut rx0 = t.connect_server(0);
            let mut rx1 = t.connect_server(1);
            let a = rx0.recv().unwrap();
            assert_eq!((a.worker, a.worker_epoch), (0, 10));
            let b = rx1.recv().unwrap();
            assert_eq!((b.worker, b.worker_epoch), (1, 20));
            assert!(rx0.recv().is_none() && rx1.recv().is_none());
        });
    }

    #[test]
    fn recycle_channel_rides_through_intact() {
        // The pooled-buffer return path: the recycle sender must survive
        // the trip so the consumer can send the buffer home.
        each_transport(1, 1, |t| {
            let (home, inbox) = std::sync::mpsc::channel::<crate::util::AlignedBuf>();
            let mut tx = t.connect_worker(0);
            for i in 0..4 {
                let mut m = msg(0, i);
                m.recycle = Some(home.clone());
                tx.send(0, m).unwrap();
            }
            drop(tx);
            t.shutdown();
            let mut rx = t.connect_server(0);
            while let Some(mut m) = rx.recv() {
                m.recycle_now();
            }
            let returned: Vec<crate::util::AlignedBuf> = inbox.try_iter().collect();
            assert_eq!(returned.len(), 4, "[{}] buffers lost", t.name());
        });
    }

    #[test]
    fn sender_errors_when_server_endpoint_is_gone() {
        // mpsc semantics for every transport: a dead server shard must
        // fail the worker's send loudly, never let it spin forever.
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            drop(t.connect_server(0));
            let mut failed = false;
            for i in 0..t.inflight_bound() + 2 {
                if tx.send(0, msg(0, i)).is_err() {
                    failed = true;
                    break;
                }
            }
            assert!(failed, "[{}] send kept succeeding after server went away", t.name());
        });
    }

    #[test]
    fn dropped_queued_messages_still_recycle_their_buffers() {
        // A server dying with messages still queued must not destroy
        // the pooled buffers riding in them — the owning worker would
        // block in PushPool::acquire forever.
        each_transport(1, 1, |t| {
            let name = t.name();
            let (home, inbox) = std::sync::mpsc::channel::<crate::util::AlignedBuf>();
            let mut tx = t.connect_worker(0);
            for i in 0..4 {
                let mut m = msg(0, i);
                m.recycle = Some(home.clone());
                tx.send(0, m).unwrap();
            }
            drop(tx);
            drop(t.connect_server(0)); // server dies without draining
            drop(t); // full teardown must not lose buffers either
            assert_eq!(
                inbox.try_iter().count(),
                4,
                "[{name}] queued buffers lost on teardown"
            );
        });
    }

    #[test]
    fn flush_into_closed_lane_errors_like_send_and_strands_no_buffer() {
        // A lane force-closed mid-partial-batch: `flush()` must surface
        // the same "hung up" error `send` uses, never panic, and every
        // pooled buffer must come home — batched and not, both impls.
        let cases: Vec<(Box<dyn Transport>, usize)> = vec![
            (Box::new(MpscTransport::new(1, 1, 8, 1)), 1),
            (Box::new(MpscTransport::new(1, 1, 8, 2)), 2),
            (Box::new(MpscTransport::new(1, 1, 8, 3)), 3),
            (Box::new(SpscRingTransport::new(1, 1, 8, 1)), 1),
            (Box::new(SpscRingTransport::new(1, 1, 8, 2)), 2),
            (Box::new(SpscRingTransport::new(1, 1, 8, 3)), 3),
            (Box::new(super::super::net::TcpTransport::new(1, 1, 8, 1)), 1),
            (Box::new(super::super::net::TcpTransport::new(1, 1, 8, 2)), 2),
            (Box::new(super::super::net::TcpTransport::new(1, 1, 8, 3)), 3),
        ];
        for (t, batch) in cases {
            let name = t.name();
            let (home, inbox) = std::sync::mpsc::channel::<crate::util::AlignedBuf>();
            let mut created = 0usize;
            let mut make = |i: usize| {
                created += 1;
                let mut m = msg(0, i);
                m.recycle = Some(home.clone());
                m
            };
            let mut tx = t.connect_worker(0);
            // batch=1: delivered to the queue; batch>1: a partial batch
            // parked in the sender.
            tx.send(0, make(0)).unwrap();
            drop(t.connect_server(0)); // force-close the lane
            match tx.flush() {
                Err(e) => assert!(
                    e.to_string().contains("hung up"),
                    "[{name} b{batch}] flush error {e:#} != send convention"
                ),
                Ok(()) => assert_eq!(
                    batch, 1,
                    "[{name} b{batch}] flush swallowed a partial batch into a dead lane"
                ),
            }
            // `send` reports the same failure (a batched sender may
            // buffer a few first, but must fail within one batch).
            let mut send_err = None;
            for i in 1..=batch + 1 {
                if let Err(e) = tx.send(0, make(i)) {
                    send_err = Some(e);
                    break;
                }
            }
            let e = send_err.unwrap_or_else(|| {
                panic!("[{name} b{batch}] send kept succeeding into a closed lane")
            });
            assert!(e.to_string().contains("hung up"), "[{name} b{batch}] {e:#}");
            drop(tx);
            drop(t);
            assert_eq!(
                inbox.try_iter().count(),
                created,
                "[{name} b{batch}] pooled buffer stranded"
            );
        }
    }

    #[test]
    fn reconnected_worker_resumes_the_same_fifo_stream() {
        // The restart path: the first endpoint dies mid-stream (its
        // partial batch flushes on drop), a replacement endpoint
        // continues the stream, and the server sees one gap-free FIFO.
        each_transport(2, 1, |t| {
            let mut tx = t.connect_worker(1);
            for i in 0..5 {
                tx.send(0, msg(1, i)).unwrap();
            }
            drop(tx); // "crash": unwind drops the endpoint, flushing
            let mut tx2 = t.reconnect_worker(1);
            for i in 5..10 {
                tx2.send(0, msg(1, i)).unwrap();
            }
            drop(tx2);
            t.shutdown();
            let mut rx = t.connect_server(0);
            for i in 0..10 {
                let m = rx.recv().expect("stream ended early");
                assert_eq!(
                    (m.worker, m.worker_epoch),
                    (1, i),
                    "[{}] reorder across reconnect",
                    t.name()
                );
            }
            assert!(rx.recv().is_none(), "[{}] phantom message", t.name());
        });
    }

    #[test]
    #[should_panic(expected = "never connected")]
    fn reconnect_before_connect_is_rejected() {
        let t = SpscRingTransport::new(2, 1, 4, 1);
        let _ = t.reconnect_worker(0);
    }

    #[test]
    fn server_lanes_partition_the_stream_per_worker() {
        // The work-stealing granularity: every lane yields a per-worker
        // FIFO sub-stream, and together the lanes cover everything.
        each_transport(3, 1, |t| {
            let mut txs: Vec<_> = (0..3).map(|w| t.connect_worker(w)).collect();
            for i in 0..6 {
                for (w, tx) in txs.iter_mut().enumerate() {
                    tx.send(0, msg(w, i)).unwrap();
                }
            }
            drop(txs);
            t.shutdown();
            let mut lanes = t.connect_server_lanes(0);
            let mut next = vec![0usize; 3];
            let mut total = 0usize;
            for lane in lanes.iter_mut() {
                let mut lane_worker: Option<usize> = None;
                while let Some(m) = lane.recv() {
                    if lanes_are_per_worker(t.name()) {
                        // Ring lanes carry exactly one worker's stream.
                        assert_eq!(*lane_worker.get_or_insert(m.worker), m.worker);
                    }
                    assert_eq!(m.worker_epoch, next[m.worker], "[{}] lane reordered", t.name());
                    next[m.worker] += 1;
                    total += 1;
                }
                match lane.try_recv() {
                    TryRecv::Done => {}
                    other => panic!("[{}] drained lane not Done: {other:?}", t.name()),
                }
            }
            assert_eq!(total, 18, "[{}] lanes lost messages", t.name());
        });
    }

    fn lanes_are_per_worker(name: &str) -> bool {
        name == "ring" || name == "tcp"
    }

    #[test]
    fn try_recv_reports_empty_then_done() {
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            let mut rx = t.connect_server(0);
            assert!(matches!(rx.try_recv(), TryRecv::Empty), "[{}]", t.name());
            tx.send(0, msg(0, 0)).unwrap();
            tx.flush().unwrap();
            // The flush committed the message; polling must surface it
            // (first call for in-process impls, within the deadline for
            // the socket one) and never report Done early.
            let m = poll_until("flushed message", || match rx.try_recv() {
                TryRecv::Msg(m) => Some(m),
                TryRecv::Empty => None,
                TryRecv::Done => panic!("[{}] Done before shutdown", t.name()),
            });
            assert_eq!(m.worker_epoch, 0, "[{}]", t.name());
            assert!(matches!(rx.try_recv(), TryRecv::Empty), "[{}]", t.name());
            drop(tx);
            t.shutdown();
            poll_until("Done after shutdown", || match rx.try_recv() {
                TryRecv::Done => Some(()),
                TryRecv::Empty => None,
                TryRecv::Msg(m) => {
                    panic!("[{}] phantom message {}", t.name(), m.worker_epoch)
                }
            });
        });
    }

    #[test]
    #[should_panic(expected = "endpoint already taken")]
    fn ring_rejects_double_producer() {
        let t = SpscRingTransport::new(2, 1, 4, 1);
        let _a = t.connect_worker(1);
        let _b = t.connect_worker(1);
    }

    #[test]
    #[should_panic(expected = "endpoint already taken")]
    fn ring_rejects_lanes_after_single_endpoint() {
        let t = SpscRingTransport::new(2, 1, 4, 1);
        let _a = t.connect_server(0);
        let _b = t.connect_server_lanes(0);
    }

    #[test]
    fn make_transport_honors_kind_and_budget() {
        let m = make_transport(TransportKind::Mpsc, 4, 2, 8, 1);
        assert_eq!(m.name(), "mpsc");
        assert_eq!(m.inflight_bound(), 8);
        let r = make_transport(TransportKind::SpscRing, 4, 2, 8, 1);
        assert_eq!(r.name(), "ring");
        // 8 in flight per server, split over 4 workers' rings.
        assert_eq!(r.inflight_bound(), 2);
        // Batched: each of the 2 slots carries up to 3 messages, plus 2
        // more can sit in the sender's pending buffer.
        let rb = make_transport(TransportKind::SpscRing, 4, 2, 8, 3);
        assert_eq!(rb.inflight_bound(), 2 * 3 + 2);
        // Batched mpsc, capacity-misaligned: flushes land at multiples
        // of 2, so sends 1..=9 complete (channel 8 + 1 buffered) and
        // send 10's flush is the first that can block.
        let mb = make_transport(TransportKind::Mpsc, 4, 2, 8, 2);
        assert_eq!(mb.inflight_bound(), 9);
        // TCP mirrors the ring's per-worker split, counted in frame
        // credits: lane cap ceil(8/4)=2 → 2 unbatched frames...
        let tc = make_transport(TransportKind::Tcp, 4, 2, 8, 1);
        assert_eq!(tc.name(), "tcp");
        assert_eq!(tc.inflight_bound(), 2);
        // ...and batched, ceil(2/3)=1 credit of 3 messages plus 2 more
        // parked in the sender's partial batch.
        let tcb = make_transport(TransportKind::Tcp, 4, 2, 8, 3);
        assert_eq!(tcb.inflight_bound(), 5);
    }
}

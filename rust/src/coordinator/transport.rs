//! Pluggable worker→server push transport.
//!
//! The paper's Fig. 1 runtime is defined by *what* travels (a
//! [`PushMsg`] per block update) and *how* it queues at the server
//! shards.  This module makes the "how" a first-class [`Transport`]
//! trait so queueing disciplines are one-file implementations instead
//! of driver rewrites:
//!
//! * [`MpscTransport`] — the original design: one bounded
//!   `std::sync::mpsc::sync_channel` per server shard.  Correct and
//!   simple, but every enqueue from every worker serializes on that
//!   channel's internal mutex — the last serialization point left on
//!   the push path after the seqlock store removed the read side's.
//! * [`SpscRingTransport`] — one array-backed single-producer
//!   single-consumer ring per (worker, server) pair with atomic
//!   head/tail indices.  No shared queue lock exists anywhere: a
//!   worker's enqueue touches only its own ring, and a server shard
//!   round-robin-drains its workers' rings.  This realizes the
//!   ROADMAP's "per-worker SPSC rings" item.
//!
//! ## Contract (what the conformance tests assert for every impl)
//!
//! * **Per-worker FIFO**: pushes from one worker to one server are
//!   received in send order.  (Cross-worker ordering is unspecified —
//!   Algorithm 1 only needs per-edge order for its staleness
//!   accounting.)
//! * **Bounded in-flight**: at most [`Transport::inflight_bound`]
//!   pushes from one worker to one server may be un-received before
//!   `send` blocks.  This is the ps-lite-style backpressure the
//!   convergence analysis leans on: without it a fast worker can run
//!   its whole epoch budget against a starved queue, i.e. unbounded
//!   effective delay, violating Assumption 3.
//! * **Shutdown drains**: after [`Transport::shutdown`] (called once
//!   all workers finished and dropped their senders), each receiver
//!   yields every message still queued and only then returns `None`.
//! * **Endpoints are single-take**: `connect_worker(w)` and
//!   `connect_server(s)` may each be called at most once per index
//!   (the ring transport's soundness depends on the single-producer /
//!   single-consumer discipline; both impls enforce it).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::messages::PushMsg;
use crate::config::TransportKind;

/// Capacity of each server shard's bounded push queue for `n_workers`
/// workers.  Public so tests can assert the push-buffer pools' high-water
/// marks against the actual in-flight bound.
pub fn push_inflight(n_workers: usize) -> usize {
    (2 * n_workers).max(8)
}

/// A queueing discipline for worker→server pushes.  Shared by reference
/// across the run's thread scope; endpoints move into their threads.
pub trait Transport: Send + Sync {
    /// Human-readable name (logs, benches, BENCH_hotpath.json keys).
    fn name(&self) -> &'static str;

    /// The sending endpoint for `worker`.  At most one call per worker.
    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender>;

    /// The receiving endpoint for `server`.  At most one call per server.
    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver>;

    /// Max pushes one worker can have in flight to one server before
    /// [`PushSender::send`] blocks (the backpressure bound).
    fn inflight_bound(&self) -> usize;

    /// Signal end-of-stream.  Receivers drain what is queued and then
    /// return `None`.  Call only after every worker endpoint is dropped
    /// (the session does this once all workers joined).
    fn shutdown(&self);
}

/// Worker-side endpoint: blocking bounded enqueue to any server shard.
pub trait PushSender: Send {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()>;
}

/// Server-side endpoint: blocking dequeue; `None` = shut down and drained.
pub trait PushReceiver: Send {
    fn recv(&mut self) -> Option<PushMsg>;
}

/// Construct the configured transport for a run.
pub fn make_transport(
    kind: TransportKind,
    n_workers: usize,
    n_servers: usize,
    inflight: usize,
) -> Box<dyn Transport> {
    match kind {
        TransportKind::Mpsc => Box::new(MpscTransport::new(n_workers, n_servers, inflight)),
        TransportKind::SpscRing => {
            // Match the mpsc per-server budget: each of the worker's
            // rings holds its share of the channel capacity.
            let ring_cap = inflight.div_ceil(n_workers.max(1)).max(2);
            Box::new(SpscRingTransport::new(n_workers, n_servers, ring_cap))
        }
    }
}

// ---------------------------------------------------------------------------
// MpscTransport
// ---------------------------------------------------------------------------

/// One bounded `sync_channel` per server shard (the original driver
/// wiring, extracted).  All workers share a server's channel, so every
/// enqueue takes that channel's internal lock.
pub struct MpscTransport {
    /// Root senders; dropped on `shutdown` so receivers observe
    /// disconnect once worker clones are gone too.
    txs: Mutex<Vec<Option<SyncSender<PushMsg>>>>,
    rxs: Mutex<Vec<Option<Receiver<PushMsg>>>>,
    worker_taken: Mutex<Vec<bool>>,
    inflight: usize,
}

impl MpscTransport {
    pub fn new(n_workers: usize, n_servers: usize, inflight: usize) -> Self {
        let mut txs = Vec::with_capacity(n_servers);
        let mut rxs = Vec::with_capacity(n_servers);
        for _ in 0..n_servers {
            let (tx, rx) = sync_channel::<PushMsg>(inflight.max(1));
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        MpscTransport {
            txs: Mutex::new(txs),
            rxs: Mutex::new(rxs),
            worker_taken: Mutex::new(vec![false; n_workers]),
            inflight: inflight.max(1),
        }
    }
}

impl Transport for MpscTransport {
    fn name(&self) -> &'static str {
        "mpsc"
    }

    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let mut taken = self.worker_taken.lock().unwrap();
        assert!(!taken[worker], "worker {worker} endpoint already taken");
        taken[worker] = true;
        let txs: Vec<SyncSender<PushMsg>> = self
            .txs
            .lock()
            .unwrap()
            .iter()
            .map(|t| t.as_ref().expect("transport already shut down").clone())
            .collect();
        Box::new(MpscSender { txs })
    }

    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver> {
        let rx = self.rxs.lock().unwrap()[server]
            .take()
            .unwrap_or_else(|| panic!("server {server} endpoint already taken"));
        Box::new(MpscReceiver { rx })
    }

    fn inflight_bound(&self) -> usize {
        self.inflight
    }

    fn shutdown(&self) {
        self.txs.lock().unwrap().iter_mut().for_each(|t| drop(t.take()));
    }
}

struct MpscSender {
    txs: Vec<SyncSender<PushMsg>>,
}

impl PushSender for MpscSender {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        self.txs[server]
            .send(msg)
            .map_err(|_| anyhow::anyhow!("server {server} hung up"))
    }
}

struct MpscReceiver {
    rx: Receiver<PushMsg>,
}

impl PushReceiver for MpscReceiver {
    fn recv(&mut self) -> Option<PushMsg> {
        // Err = all senders dropped (workers done + transport shut down)
        // AND the buffer is empty: exactly the drain-then-exit contract.
        self.rx.recv().ok()
    }
}

// ---------------------------------------------------------------------------
// SpscRingTransport
// ---------------------------------------------------------------------------

/// One single-producer single-consumer slot ring.
///
/// `head`/`tail` are monotonically increasing operation counters
/// (message `n` lives in slot `n % cap`); `tail - head` is the queue
/// length, full at `cap`.  The producer owns `tail`, the consumer owns
/// `head`; each reads the other's index with `Acquire` and publishes
/// its own with `Release`, so slot hand-off is properly ordered.
///
/// The slot cells are `Mutex<Option<PushMsg>>`, but the SPSC
/// discipline makes every lock acquisition **uncontended by
/// construction**: the producer only touches slot `tail % cap` after
/// observing `tail - head < cap` (the consumer is done with it), and
/// the consumer only touches slot `head % cap` after observing
/// `head < tail` (the producer has published it).  An uncontended lock
/// is a single CAS each way — the point is that, unlike the mpsc
/// channel, no cell is ever shared between two workers or two shards,
/// so nothing on the push path serializes across threads.  (Kept over
/// an `UnsafeCell` ring to preserve the crate's no-`unsafe` property;
/// see DESIGN.md §2.1 for the same choice in the seqlock store.)
struct Ring {
    head: AtomicUsize,
    tail: AtomicUsize,
    slots: Vec<Mutex<Option<PushMsg>>>,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring {
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            slots: (0..cap.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Producer side; returns the message back if the ring is full.
    fn try_push(&self, msg: PushMsg) -> std::result::Result<(), PushMsg> {
        let tail = self.tail.load(Ordering::Relaxed); // producer-owned
        if tail - self.head.load(Ordering::Acquire) == self.slots.len() {
            return Err(msg);
        }
        *self.slots[tail % self.slots.len()].lock().unwrap() = Some(msg);
        self.tail.store(tail + 1, Ordering::Release);
        Ok(())
    }

    /// Consumer side; `None` = empty.
    fn try_pop(&self) -> Option<PushMsg> {
        let head = self.head.load(Ordering::Relaxed); // consumer-owned
        if self.tail.load(Ordering::Acquire) == head {
            return None;
        }
        let msg = self.slots[head % self.slots.len()].lock().unwrap().take();
        self.head.store(head + 1, Ordering::Release);
        debug_assert!(msg.is_some(), "published slot was empty");
        msg
    }
}

struct RingShared {
    /// `rings[worker][server]`.
    rings: Vec<Vec<Ring>>,
    shutdown: AtomicBool,
    /// Per-server "receiver is gone" flags: set when a [`RingReceiver`]
    /// drops (normal exit after drain, or a server thread unwinding on
    /// error), so senders fail loudly like mpsc's disconnect instead of
    /// spinning on a full ring nobody will ever drain.
    closed: Vec<AtomicBool>,
}

/// Per-(worker, server) SPSC rings; servers round-robin-drain their
/// workers' rings.  No queue lock is shared between any two threads.
pub struct SpscRingTransport {
    shared: Arc<RingShared>,
    worker_taken: Mutex<Vec<bool>>,
    server_taken: Mutex<Vec<bool>>,
    ring_cap: usize,
}

impl SpscRingTransport {
    pub fn new(n_workers: usize, n_servers: usize, ring_cap: usize) -> Self {
        let rings = (0..n_workers)
            .map(|_| (0..n_servers).map(|_| Ring::new(ring_cap)).collect())
            .collect();
        let closed = (0..n_servers).map(|_| AtomicBool::new(false)).collect();
        SpscRingTransport {
            shared: Arc::new(RingShared { rings, shutdown: AtomicBool::new(false), closed }),
            worker_taken: Mutex::new(vec![false; n_workers]),
            server_taken: Mutex::new(vec![false; n_servers]),
            ring_cap: ring_cap.max(1),
        }
    }
}

impl Transport for SpscRingTransport {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn connect_worker(&self, worker: usize) -> Box<dyn PushSender> {
        let mut taken = self.worker_taken.lock().unwrap();
        assert!(!taken[worker], "worker {worker} endpoint already taken (SPSC)");
        taken[worker] = true;
        Box::new(RingSender { shared: self.shared.clone(), worker })
    }

    fn connect_server(&self, server: usize) -> Box<dyn PushReceiver> {
        let mut taken = self.server_taken.lock().unwrap();
        assert!(!taken[server], "server {server} endpoint already taken (SPSC)");
        taken[server] = true;
        Box::new(RingReceiver { shared: self.shared.clone(), server, cursor: 0 })
    }

    fn inflight_bound(&self) -> usize {
        self.ring_cap
    }

    fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
    }
}

struct RingSender {
    shared: Arc<RingShared>,
    worker: usize,
}

impl PushSender for RingSender {
    fn send(&mut self, server: usize, msg: PushMsg) -> Result<()> {
        let ring = &self.shared.rings[self.worker][server];
        let mut msg = msg;
        let mut spins = 0u32;
        loop {
            // Disconnect detection, matching mpsc semantics: a dropped
            // receiver fails the send (the rejected `msg` recycles its
            // pooled buffer on drop).
            anyhow::ensure!(
                !self.shared.closed[server].load(Ordering::Acquire),
                "server {server} hung up"
            );
            match ring.try_push(msg) {
                Ok(()) => return Ok(()),
                Err(back) => {
                    // Ring full: the bounded-in-flight backpressure.
                    anyhow::ensure!(
                        !self.shared.shutdown.load(Ordering::Relaxed),
                        "transport shut down with pushes still in flight to server {server}"
                    );
                    msg = back;
                    spins += 1;
                    if spins < 64 {
                        std::hint::spin_loop();
                    } else {
                        std::thread::yield_now();
                    }
                }
            }
        }
    }
}

struct RingReceiver {
    shared: Arc<RingShared>,
    server: usize,
    /// Round-robin fairness cursor over worker rings.
    cursor: usize,
}

impl PushReceiver for RingReceiver {
    fn recv(&mut self) -> Option<PushMsg> {
        let n_workers = self.shared.rings.len();
        let mut idle = 0u32;
        loop {
            // Observe shutdown BEFORE the sweep: producers stop before
            // `shutdown()` is called, so one clean sweep after seeing
            // the flag proves the rings are drained.
            let shutting_down = self.shared.shutdown.load(Ordering::Acquire);
            for k in 0..n_workers {
                let w = (self.cursor + k) % n_workers;
                if let Some(msg) = self.shared.rings[w][self.server].try_pop() {
                    self.cursor = (w + 1) % n_workers;
                    return Some(msg);
                }
            }
            if shutting_down {
                return None;
            }
            // Empty but live: back off gently (dedicated server thread).
            idle += 1;
            if idle < 16 {
                std::hint::spin_loop();
            } else if idle < 256 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }
}

impl Drop for RingReceiver {
    fn drop(&mut self) {
        // Close this server's lane first so producers stop feeding it,
        // then destroy anything still queued — each dropped message
        // sends its pooled buffer home (`PushMsg::drop`), so a server
        // dying mid-queue cannot strand a worker in `PushPool::acquire`.
        self.shared.closed[self.server].store(true, Ordering::Release);
        for w in 0..self.shared.rings.len() {
            while self.shared.rings[w][self.server].try_pop().is_some() {}
        }
    }
}

// ---------------------------------------------------------------------------
// Conformance suite — every Transport impl must pass all of these.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn msg(worker: usize, epoch: usize) -> PushMsg {
        PushMsg {
            worker,
            block: 0,
            w: vec![epoch as f32; 4],
            worker_epoch: epoch,
            z_version_used: 0,
            sent_at: std::time::Instant::now(),
            recycle: None,
        }
    }

    /// Both transports, same shape, for every conformance check.
    fn each_transport(n_workers: usize, n_servers: usize, f: impl Fn(Box<dyn Transport>)) {
        f(Box::new(MpscTransport::new(n_workers, n_servers, 8)));
        f(Box::new(SpscRingTransport::new(n_workers, n_servers, 8)));
    }

    #[test]
    fn fifo_per_worker_single_stream() {
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            let mut rx = t.connect_server(0);
            let h = std::thread::spawn({
                let total = 100usize;
                move || {
                    for i in 0..total {
                        tx.send(0, msg(0, i)).unwrap();
                    }
                }
            });
            for i in 0..100 {
                let m = rx.recv().expect("stream ended early");
                assert_eq!(m.worker_epoch, i, "[{}] out of order", t.name());
                assert_eq!(m.w, vec![i as f32; 4], "[{}] payload torn", t.name());
            }
            h.join().unwrap();
            t.shutdown();
            assert!(rx.recv().is_none(), "[{}] not drained-empty after shutdown", t.name());
        });
    }

    #[test]
    fn fifo_per_worker_under_interleaving() {
        let (n_workers, per_worker) = (3usize, 50usize);
        each_transport(n_workers, 1, |t| {
            std::thread::scope(|s| {
                for w in 0..n_workers {
                    let mut tx = t.connect_worker(w);
                    s.spawn(move || {
                        for i in 0..per_worker {
                            tx.send(0, msg(w, i)).unwrap();
                        }
                    });
                }
                let mut rx = t.connect_server(0);
                let mut next = vec![0usize; n_workers];
                for _ in 0..n_workers * per_worker {
                    let m = rx.recv().expect("stream ended early");
                    assert_eq!(
                        m.worker_epoch,
                        next[m.worker],
                        "[{}] worker {} reordered",
                        t.name(),
                        m.worker
                    );
                    next[m.worker] += 1;
                }
                assert!(next.iter().all(|&n| n == per_worker));
            });
        });
    }

    #[test]
    fn send_blocks_at_inflight_bound() {
        each_transport(1, 1, |t| {
            let bound = t.inflight_bound();
            let sent = Arc::new(AtomicUsize::new(0));
            let mut tx = t.connect_worker(0);
            let h = std::thread::spawn({
                let sent = sent.clone();
                move || {
                    for i in 0..bound + 3 {
                        tx.send(0, msg(0, i)).unwrap();
                        sent.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
            // Nothing is receiving: the sender must stall exactly at the
            // advertised bound (backpressure), not run ahead of it.
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(
                sent.load(Ordering::SeqCst),
                bound,
                "[{}] in-flight bound not enforced",
                t.name()
            );
            let mut rx = t.connect_server(0);
            for i in 0..bound + 3 {
                assert_eq!(rx.recv().expect("ended early").worker_epoch, i);
            }
            h.join().unwrap();
        });
    }

    #[test]
    fn shutdown_drains_queued_messages() {
        each_transport(1, 2, |t| {
            let mut tx = t.connect_worker(0);
            for i in 0..5 {
                tx.send(1, msg(0, i)).unwrap();
            }
            drop(tx); // worker done
            t.shutdown();
            // Everything enqueued before shutdown must still come out,
            // in order, on the right server; the untouched server is
            // immediately drained-empty.
            let mut rx1 = t.connect_server(1);
            for i in 0..5 {
                assert_eq!(
                    rx1.recv().expect("lost on shutdown").worker_epoch,
                    i,
                    "[{}] drain reordered",
                    t.name()
                );
            }
            assert!(rx1.recv().is_none());
            let mut rx0 = t.connect_server(0);
            assert!(rx0.recv().is_none(), "[{}] phantom message", t.name());
        });
    }

    #[test]
    fn routes_by_server_index() {
        each_transport(2, 2, |t| {
            let mut tx0 = t.connect_worker(0);
            let mut tx1 = t.connect_worker(1);
            tx0.send(0, msg(0, 10)).unwrap();
            tx1.send(1, msg(1, 20)).unwrap();
            drop((tx0, tx1));
            t.shutdown();
            let mut rx0 = t.connect_server(0);
            let mut rx1 = t.connect_server(1);
            let a = rx0.recv().unwrap();
            assert_eq!((a.worker, a.worker_epoch), (0, 10));
            let b = rx1.recv().unwrap();
            assert_eq!((b.worker, b.worker_epoch), (1, 20));
            assert!(rx0.recv().is_none() && rx1.recv().is_none());
        });
    }

    #[test]
    fn recycle_channel_rides_through_intact() {
        // The pooled-buffer return path: the recycle sender must survive
        // the trip so the consumer can send the buffer home.
        each_transport(1, 1, |t| {
            let (home, inbox) = std::sync::mpsc::channel::<Vec<f32>>();
            let mut tx = t.connect_worker(0);
            for i in 0..4 {
                let mut m = msg(0, i);
                m.recycle = Some(home.clone());
                tx.send(0, m).unwrap();
            }
            drop(tx);
            t.shutdown();
            let mut rx = t.connect_server(0);
            while let Some(mut m) = rx.recv() {
                m.recycle_now();
            }
            let returned: Vec<Vec<f32>> = inbox.try_iter().collect();
            assert_eq!(returned.len(), 4, "[{}] buffers lost", t.name());
        });
    }

    #[test]
    fn sender_errors_when_server_endpoint_is_gone() {
        // mpsc semantics for every transport: a dead server shard must
        // fail the worker's send loudly, never let it spin forever.
        each_transport(1, 1, |t| {
            let mut tx = t.connect_worker(0);
            drop(t.connect_server(0));
            let mut failed = false;
            for i in 0..t.inflight_bound() + 2 {
                if tx.send(0, msg(0, i)).is_err() {
                    failed = true;
                    break;
                }
            }
            assert!(failed, "[{}] send kept succeeding after server went away", t.name());
        });
    }

    #[test]
    fn dropped_queued_messages_still_recycle_their_buffers() {
        // A server dying with messages still queued must not destroy
        // the pooled buffers riding in them — the owning worker would
        // block in PushPool::acquire forever.
        each_transport(1, 1, |t| {
            let name = t.name();
            let (home, inbox) = std::sync::mpsc::channel::<Vec<f32>>();
            let mut tx = t.connect_worker(0);
            for i in 0..4 {
                let mut m = msg(0, i);
                m.recycle = Some(home.clone());
                tx.send(0, m).unwrap();
            }
            drop(tx);
            drop(t.connect_server(0)); // server dies without draining
            drop(t); // full teardown must not lose buffers either
            assert_eq!(
                inbox.try_iter().count(),
                4,
                "[{name}] queued buffers lost on teardown"
            );
        });
    }

    #[test]
    #[should_panic(expected = "endpoint already taken")]
    fn ring_rejects_double_producer() {
        let t = SpscRingTransport::new(2, 1, 4);
        let _a = t.connect_worker(1);
        let _b = t.connect_worker(1);
    }

    #[test]
    fn make_transport_honors_kind_and_budget() {
        let m = make_transport(TransportKind::Mpsc, 4, 2, 8);
        assert_eq!(m.name(), "mpsc");
        assert_eq!(m.inflight_bound(), 8);
        let r = make_transport(TransportKind::SpscRing, 4, 2, 8);
        assert_eq!(r.name(), "ring");
        // 8 in flight per server, split over 4 workers' rings.
        assert_eq!(r.inflight_bound(), 2);
    }
}

//! Worker task: Algorithm 1 (worker side).
//!
//! Per local epoch t: select a block slot (uniform or cyclic), refresh
//! the cached z̃ per the delay policy, compute the fused step via the
//! configured backend, push w to the owning server shard, and advance.
//! No allocation happens inside the loop: all scratch is pre-sized, and
//! the pushed w buffer comes from a [`PushPool`] that the server shard
//! recycles after applying the update — the steady-state push path is
//! malloc-free end to end.
//!
//! Adaptive-runtime details on the push path (all lock-free):
//!
//! * the owning shard is re-read per push from the shared
//!   [`BlockMap`] (one `Acquire` atomic load), so dynamic re-placement
//!   re-targets a worker mid-run without any rendezvous;
//! * each push carries a per-(worker, block) sequence number so the
//!   server's seq-gated apply keeps per-edge FIFO exact across a
//!   migration (`coordinator/server.rs`);
//! * `z̃` refreshes are version-gated: a pull only re-copies blocks
//!   whose store version advanced (one atomic read replaces a db-float
//!   memcpy; skips counted in [`WorkerStats::pull_skips`]);
//! * the `Instant::now` queue-delay timestamp is sampled 1-in-64 epochs
//!   instead of taken every push — the syscall leaves the hot loop and
//!   the latency stat stays statistically intact.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Result;

use super::block_store::BlockStore;
use super::bufpool::PushPool;
use super::compute::WorkerCompute;
use super::delay::DelayPolicy;
use super::fault::FaultPlan;
use super::messages::PushMsg;
use super::rebalance::BlockMap;
use super::session::MonitorGate;
use super::transport::PushSender;
use crate::admm::WorkerState;
use crate::config::BlockSelection;
use crate::data::WorkerShard;
use crate::util::rng::Rng;

/// Stamp `sent_at` on one epoch in this many (keeps the queue-delay
/// histogram populated without a clock syscall per push).
const SENT_AT_SAMPLE: usize = 64;

#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub epochs: usize,
    /// Max staleness (in block versions) of any z̃ used in a step.
    pub max_staleness: u64,
    /// Number of forced refreshes from bound enforcement.
    pub forced_refreshes: usize,
    /// Cached-block re-copies skipped because the store version had not
    /// advanced since the last pull (the version-gated pull fast path).
    pub pull_skips: usize,
    pub last_loss: f32,
    /// Push buffers ever allocated by this worker's pool — bounded by the
    /// pool cap (≈ push channel capacity), NOT by `epochs`.
    pub pool_high_water: usize,
    /// Transient send failures survived (injected via `--set faults=
    /// sendfail:...`; each costs one bounded retry).
    pub send_retries: usize,
}

pub struct WorkerCtx<'a> {
    pub shard: &'a WorkerShard,
    store: &'a BlockStore,
    /// Live block→shard routing map (static placements never change it;
    /// `placement=dynamic` migrates owners mid-run).
    router: &'a BlockMap,
    sender: Box<dyn PushSender>,
    state: WorkerState,
    policy: DelayPolicy,
    selection: BlockSelection,
    rho: f32,
    epochs: usize,
    max_delay: usize,
    enforce_delay: bool,
    rng: Rng,
    /// Published progress for the monitor thread.
    progress: &'a AtomicUsize,
    /// Wakes the parked monitor when progress crosses its watermark.
    gate: &'a MonitorGate,
    /// Version of z̃ currently cached per slot.
    z_versions: Vec<u64>,
    /// Per-slot (= per active block) push sequence counters; stamped
    /// into [`PushMsg::block_seq`] for the server's migration-safe
    /// ordering gate.
    push_seq: Vec<u64>,
    /// Last shard each slot's push was routed to (usize::MAX = never):
    /// a change means the rebalancer migrated the block, and any
    /// batch-buffered predecessors must be flushed to the OLD shard's
    /// lane before the first push on the new route — otherwise a
    /// never-filling partial batch could strand them until the final
    /// flush while every successor parks at the new owner.
    last_server: Vec<usize>,
    /// Recycled push buffers (w rides to the server and comes back).
    pool: PushPool,
    /// Injected-fault schedule; `is_empty` short-circuits every hook.
    faults: &'a FaultPlan,
    /// Per-slot sent-seq watermarks, stamped after every successful
    /// send.  Lives *outside* the ctx (owned by the session) so it
    /// survives a worker panic: the restart path seeds the replacement's
    /// `push_seq` from it once the in-flight tail has drained.
    ledger: &'a [AtomicU64],
    /// First epoch of the loop (0 for a fresh worker; the crash epoch
    /// for a restarted one, so total pushes match the fault-free run).
    start_epoch: usize,
    // scratch
    y_new: Vec<f32>,
    x_new: Vec<f32>,
    pub stats: WorkerStats,
}

#[allow(clippy::too_many_arguments)]
impl<'a> WorkerCtx<'a> {
    pub fn new(
        shard: &'a WorkerShard,
        store: &'a BlockStore,
        router: &'a BlockMap,
        sender: Box<dyn PushSender>,
        policy: DelayPolicy,
        selection: BlockSelection,
        rho: f32,
        epochs: usize,
        max_delay: usize,
        enforce_delay: bool,
        seed: u64,
        progress: &'a AtomicUsize,
        gate: &'a MonitorGate,
        pool_cap: usize,
        faults: &'a FaultPlan,
        ledger: &'a [AtomicU64],
    ) -> Self {
        debug_assert_eq!(ledger.len(), shard.n_slots());
        let db = shard.block_size;
        // Algorithm 1 lines 1-2: pull z⁰, x⁰ = z⁰, y⁰ = 0.
        let mut z0 = vec![0.0f32; shard.packed_dim()];
        let mut z_versions = vec![0u64; shard.n_slots()];
        for (slot, &j) in shard.active_blocks.iter().enumerate() {
            z_versions[slot] = store.read_into(j, &mut z0[slot * db..(slot + 1) * db]);
        }
        WorkerCtx {
            shard,
            store,
            router,
            sender,
            state: WorkerState::init_from_z(z0),
            policy,
            selection,
            rho,
            epochs,
            max_delay,
            enforce_delay,
            rng: Rng::new(seed),
            progress,
            gate,
            z_versions,
            push_seq: vec![0u64; shard.n_slots()],
            last_server: vec![usize::MAX; shard.n_slots()],
            pool: PushPool::new(db, pool_cap),
            faults,
            ledger,
            start_epoch: 0,
            y_new: vec![0.0; db],
            x_new: vec![0.0; db],
            stats: WorkerStats::default(),
        }
    }

    /// Resume support (`failure=restart`, checkpoint resume): start the
    /// epoch loop at `start_epoch` and seed the per-slot seq counters so
    /// the server's gate accepts this stream as a continuation of the
    /// dead worker's — the next push on slot `s` carries `seqs[s] + 1`,
    /// exactly what the gate expects once the old tail drained.
    pub fn resume_at(&mut self, start_epoch: usize, seqs: &[u64]) {
        self.start_epoch = start_epoch.min(self.epochs);
        self.push_seq.copy_from_slice(seqs);
        self.state.epoch = self.start_epoch;
    }

    /// Overwrite the packed dual with a warm-start snapshot (the
    /// restart/resume paths compute y = w̃ − ρ·z̃ from server state, so
    /// the first replacement push is consistent with the shard's cache).
    pub fn warm_duals(&mut self, y: &[f32]) {
        self.state.y.copy_from_slice(y);
    }

    fn select_slot(&mut self, t: usize) -> usize {
        match self.selection {
            BlockSelection::UniformRandom => self.rng.below(self.shard.n_slots()),
            BlockSelection::Cyclic => t % self.shard.n_slots(),
        }
    }

    /// Pull fresh z̃ for all slots (Algorithm 1 line 8), version-gated:
    /// a slot whose block version has not advanced past the cached copy
    /// skips the db-float memcpy (one atomic read instead).
    fn refresh_z(&mut self) {
        let db = self.shard.block_size;
        for (slot, &j) in self.shard.active_blocks.iter().enumerate() {
            if self.store.version(j) == self.z_versions[slot] {
                self.stats.pull_skips += 1;
                continue;
            }
            self.z_versions[slot] =
                self.store.read_into(j, &mut self.state.z_local[slot * db..(slot + 1) * db]);
        }
    }

    /// Refresh only one slot (bound enforcement).
    fn refresh_slot(&mut self, slot: usize) {
        let db = self.shard.block_size;
        let j = self.shard.active_blocks[slot];
        self.z_versions[slot] =
            self.store.read_into(j, &mut self.state.z_local[slot * db..(slot + 1) * db]);
    }

    /// Run Algorithm 1 for `epochs` local epochs (from `start_epoch`,
    /// normally 0).
    pub fn run(&mut self, compute: &mut dyn WorkerCompute) -> Result<WorkerStats> {
        for t in self.start_epoch..self.epochs {
            let slot = self.select_slot(t);
            let j = self.shard.active_blocks[slot];

            if self.policy.should_pull(t) && t > 0 {
                self.refresh_z();
            }
            // Assumption-3 enforcement: if the cached copy is older than
            // the bound, force a refresh of that block before using it.
            let staleness = self.store.version(j).saturating_sub(self.z_versions[slot]);
            if self.enforce_delay && staleness > self.max_delay as u64 {
                self.refresh_slot(slot);
                self.stats.forced_refreshes += 1;
            }
            let used_version = self.z_versions[slot];
            self.stats.max_staleness = self
                .stats
                .max_staleness
                .max(self.store.version(j).saturating_sub(used_version));

            // Eqs. 11/12/9 via the backend, straight into a pooled push
            // buffer (no per-epoch clone on the send below).
            let db = self.shard.block_size;
            let (lo, hi) = (slot * db, (slot + 1) * db);
            let mut w_buf = self.pool.acquire();
            let loss = compute.step(
                &self.state.z_local,
                &self.state.y[lo..hi],
                slot,
                self.rho,
                &mut w_buf,
                &mut self.y_new,
                &mut self.x_new,
            )?;
            self.state.x[lo..hi].copy_from_slice(&self.x_new);
            self.state.y[lo..hi].copy_from_slice(&self.y_new);
            self.state.last_loss = loss;
            self.stats.last_loss = loss;

            // Push w to the owning server shard (with injected latency);
            // the shard returns the buffer on the pool's recycle channel.
            // Ownership is re-read from the live map each push — under
            // dynamic re-placement this is the migration re-target.
            self.policy.sleep_net(&mut self.rng);
            let server = self.router.owner(j);
            if self.last_server[slot] != server {
                // Migration re-target: deliver any batch-buffered
                // predecessors for this edge to the old shard's lane
                // NOW, so the server's seq-gate reorder window stays
                // bounded by the in-flight budget instead of a partial
                // batch that might never fill again.  Route changes
                // are rare (one flush per migration observation).
                if self.last_server[slot] != usize::MAX {
                    self.sender.flush()?;
                }
                self.last_server[slot] = server;
            }
            // Injected transient send failures: bounded retries before
            // the real send (one branch when the plan is empty).
            if !self.faults.is_empty() {
                let retries = self.faults.send_failures(self.shard.worker_id, t);
                for _ in 0..retries {
                    std::thread::yield_now();
                }
                self.stats.send_retries += retries;
            }
            self.push_seq[slot] += 1;
            let push = PushMsg {
                worker: self.shard.worker_id,
                block: j,
                w: w_buf,
                worker_epoch: t,
                z_version_used: used_version,
                block_seq: self.push_seq[slot],
                sent_at: (t % SENT_AT_SAMPLE == 0).then(Instant::now),
                recycle: Some(self.pool.recycler()),
            };
            self.sender.send(server, push)?;
            // Sent watermark for the crash-recovery ledger: this seq was
            // handed to the transport (a batched remainder still reaches
            // the queue via the sender's drop-flush during unwind).
            self.ledger[slot].store(self.push_seq[slot], Ordering::Release);

            // Deliver anything still batch-buffered BEFORE publishing
            // the final epoch: the monitor calls transport.shutdown()
            // as soon as min-epoch reaches the budget, and the
            // receivers' shutdown-drain proof assumes every producer
            // has flushed by then.  Flushing after the store would race
            // it and could strand the last (batch-1) pushes per server.
            if t + 1 == self.epochs {
                self.sender.flush()?;
            }
            self.state.epoch = t + 1;
            self.stats.epochs = t + 1;
            self.progress.store(t + 1, Ordering::Release);
            self.gate.notify_epoch(t + 1);
            // Injected crash: AFTER the epoch published, so the seq
            // stream has no hole and a restarted replacement resuming at
            // `progress` produces exactly the fault-free push count.
            if !self.faults.is_empty() && self.faults.should_crash(self.shard.worker_id, t + 1)
            {
                panic!(
                    "fault injection: worker {} crashed at epoch {}",
                    self.shard.worker_id,
                    t + 1
                );
            }
        }
        self.stats.pool_high_water = self.pool.high_water();
        Ok(self.stats.clone())
    }

    /// Final local variables (packed), consumed by the driver for
    /// stationarity metrics.
    pub fn into_state(self) -> (Vec<f32>, Vec<f32>) {
        (self.state.x, self.state.y)
    }
}

//! Block→shard placement policies — who owns each consensus block z_j.
//!
//! The paper's convergence argument (and Hong's incremental async-ADMM
//! analysis it leans on, arXiv:1412.6058) needs per-block atomicity and
//! bounded staleness, **not** any particular owner for a block.  That
//! freedom is what this module exploits: `Topology::build_with` delegates
//! the block→shard map to a [`Placement`] so the assignment is a policy,
//! not a hard-coded formula.
//!
//! Four policies ship:
//!
//! * [`ContiguousPlacement`] — equal contiguous ranges of block ids per
//!   shard (the default, and what a naive static partition does).  The
//!   synthetic workload's Zipf-hot shared blocks have *low indices*, so
//!   contiguous placement concentrates the whole hot head on shard 0 —
//!   exactly the serialization the `placement_skew` bench measures.
//! * [`RoundRobinPlacement`] — block j → shard j mod S, the assignment
//!   `Topology::build` hard-coded before this layer existed; kept
//!   selectable so the old behavior stays reproducible.  (Note the
//!   default therefore CHANGED in this PR: round-robin incidentally
//!   spread the low-index hot head, contiguous deliberately does not.)
//! * [`HashPlacement`] — production-PS style: a multiplicative hash of
//!   the block id picks the shard.  Spreads ids uniformly but is blind
//!   to per-block load.
//! * [`DegreePlacement`] — load-aware: blocks are assigned
//!   greedily (largest degree first) to the shard with the least total
//!   degree, so the Zipf head lands on *distinct* shards.  |𝒩(j)| is a
//!   static proxy for push traffic: every worker in 𝒩(j) pushes block j
//!   equally often in expectation under uniform selection.
//! * [`DynamicPlacement`] — the *initial* map of the adaptive runtime
//!   (`coordinator/rebalance.rs`): deliberately the naive contiguous
//!   layout, because the whole point of `placement=dynamic` is that
//!   the rebalancer discovers the hot head from observed push rates at
//!   runtime and migrates it off shard 0 — no static prior needed.
//!
//! Selection:
//! `--set placement=contiguous|roundrobin|hash|degree|dynamic`
//! ([`crate::config::PlacementKind`]).  The drain-side counterpart (which
//! *thread* services a shard's queues) is `coordinator/sched.rs`.

use crate::config::PlacementKind;

/// A block→server-shard assignment policy.
///
/// `place` returns `server_of_block` (one shard id `< n_servers` per
/// block).  `degree[j]` = |𝒩(j)|, the number of workers touching block
/// j — the static load proxy available at topology-build time.
pub trait Placement: Send + Sync {
    /// Human-readable name (logs, bench JSON keys).
    fn name(&self) -> &'static str;

    /// Assign every block to a shard.  Must return exactly `n_blocks`
    /// entries, each `< n_servers` (the topology asserts this).
    fn place(&self, n_blocks: usize, n_servers: usize, degree: &[usize]) -> Vec<usize>;
}

/// Construct the configured placement policy.
pub fn make_placement(kind: PlacementKind) -> Box<dyn Placement> {
    match kind {
        PlacementKind::Contiguous => Box::new(ContiguousPlacement),
        PlacementKind::RoundRobin => Box::new(RoundRobinPlacement),
        PlacementKind::Hash => Box::new(HashPlacement),
        PlacementKind::Degree => Box::new(DegreePlacement),
        PlacementKind::Dynamic => Box::new(DynamicPlacement),
    }
}

/// Initial map for `--set placement=dynamic`: contiguous ranges, i.e.
/// the least-informed static start.  The runtime rebalancer
/// (`coordinator/rebalance.rs`) owns the map from then on, migrating
/// hot blocks between shards from observed applied-push rates.
pub struct DynamicPlacement;

impl Placement for DynamicPlacement {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn place(&self, n_blocks: usize, n_servers: usize, degree: &[usize]) -> Vec<usize> {
        ContiguousPlacement.place(n_blocks, n_servers, degree)
    }
}

/// Equal contiguous block ranges per shard: block j → ⌊j·S/M⌋.
///
/// Balances block *count* (ranges differ by at most one block) but is
/// blind to load: the synthetic workload's hot shared blocks sit at low
/// indices, so they all land on shard 0.
pub struct ContiguousPlacement;

impl Placement for ContiguousPlacement {
    fn name(&self) -> &'static str {
        "contiguous"
    }

    fn place(&self, n_blocks: usize, n_servers: usize, _degree: &[usize]) -> Vec<usize> {
        (0..n_blocks)
            .map(|j| (j * n_servers / n_blocks.max(1)).min(n_servers - 1))
            .collect()
    }
}

/// Block j → shard j mod S — the hard-coded assignment `Topology::build`
/// used before placement became a policy.  Interleaves ids, which
/// incidentally spreads the low-index Zipf head one hot block per shard
/// (but, unlike [`DegreePlacement`], only by accident of indexing).
pub struct RoundRobinPlacement;

impl Placement for RoundRobinPlacement {
    fn name(&self) -> &'static str {
        "roundrobin"
    }

    fn place(&self, n_blocks: usize, n_servers: usize, _degree: &[usize]) -> Vec<usize> {
        (0..n_blocks).map(|j| j % n_servers).collect()
    }
}

/// Multiplicative (Fibonacci) hash of the block id → shard, like a
/// production parameter server that hashes keys to server nodes.
/// Spreads ids uniformly; per-block load is not considered.
pub struct HashPlacement;

impl Placement for HashPlacement {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn place(&self, n_blocks: usize, n_servers: usize, _degree: &[usize]) -> Vec<usize> {
        (0..n_blocks)
            .map(|j| {
                let h = (j as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 32) % n_servers as u64) as usize
            })
            .collect()
    }
}

/// Load-aware greedy placement: blocks sorted by |𝒩(j)| descending are
/// assigned to the shard with the smallest degree sum so far (longest-
/// processing-time bin packing).  The Zipf head — the handful of blocks
/// every worker touches — is guaranteed to land on distinct shards
/// until every shard holds one hot block.  Deterministic: ties break by
/// block id, then by shard id.
pub struct DegreePlacement;

impl Placement for DegreePlacement {
    fn name(&self) -> &'static str {
        "degree"
    }

    fn place(&self, n_blocks: usize, n_servers: usize, degree: &[usize]) -> Vec<usize> {
        debug_assert_eq!(degree.len(), n_blocks);
        let mut order: Vec<usize> = (0..n_blocks).collect();
        // Stable sort: equal-degree blocks keep id order, so the
        // assignment is reproducible run to run.
        order.sort_by(|&a, &b| degree[b].cmp(&degree[a]));
        let mut load = vec![0usize; n_servers];
        // Block-count tiebreak keeps counts balanced when many blocks
        // share a degree (e.g. all the degree-1 tail).
        let mut count = vec![0usize; n_servers];
        let mut server_of_block = vec![0usize; n_blocks];
        for j in order {
            let s = (0..n_servers)
                .min_by_key(|&s| (load[s], count[s], s))
                .expect("n_servers > 0");
            server_of_block[j] = s;
            load[s] += degree[j];
            count[s] += 1;
        }
        server_of_block
    }
}

/// Max shard load divided by mean shard load (load = Σ degree of owned
/// blocks), the skew statistic the `placement_skew` bench gates on.
/// 1.0 = perfectly balanced.
pub fn load_imbalance(server_of_block: &[usize], degree: &[usize], n_servers: usize) -> f64 {
    let mut load = vec![0usize; n_servers];
    for (j, &s) in server_of_block.iter().enumerate() {
        load[s] += degree[j];
    }
    let total: usize = load.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / n_servers as f64;
    *load.iter().max().unwrap() as f64 / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zipf_degrees(n_blocks: usize, workers: usize) -> Vec<usize> {
        // Hot head: first two blocks touched by every worker, tail by one.
        (0..n_blocks).map(|j| if j < 2 { workers } else { 1 }).collect()
    }

    #[test]
    fn all_placements_are_total_and_in_range() {
        let deg = zipf_degrees(16, 8);
        for kind in [
            PlacementKind::Contiguous,
            PlacementKind::RoundRobin,
            PlacementKind::Hash,
            PlacementKind::Degree,
            PlacementKind::Dynamic,
        ] {
            let p = make_placement(kind);
            let map = p.place(16, 3, &deg);
            assert_eq!(map.len(), 16, "{}", p.name());
            assert!(map.iter().all(|&s| s < 3), "{}", p.name());
        }
    }

    #[test]
    fn dynamic_initial_map_is_contiguous() {
        // The adaptive runtime starts from the naive layout on purpose
        // (rebalance.rs module docs); the rebalancer does the rest.
        let deg = zipf_degrees(8, 4);
        assert_eq!(
            DynamicPlacement.place(8, 3, &deg),
            ContiguousPlacement.place(8, 3, &deg)
        );
    }

    #[test]
    fn roundrobin_matches_the_pre_placement_layer_assignment() {
        // Continuity: `roundrobin` must reproduce the exact block→shard
        // map Topology::build hard-coded before this layer (j % S).
        let map = RoundRobinPlacement.place(8, 3, &[1; 8]);
        assert_eq!(map, (0..8).map(|j| j % 3).collect::<Vec<_>>());
    }

    #[test]
    fn contiguous_assigns_balanced_ranges() {
        let map = ContiguousPlacement.place(8, 3, &[1; 8]);
        assert_eq!(map, vec![0, 0, 0, 1, 1, 1, 2, 2]);
        // Monotone non-decreasing = contiguous ranges.
        assert!(map.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn degree_placement_splits_the_hot_head() {
        let deg = zipf_degrees(16, 8);
        let map = DegreePlacement.place(16, 2, &deg);
        // The two hot blocks must land on distinct shards; contiguous
        // puts both on shard 0.
        assert_ne!(map[0], map[1], "hot head not split: {map:?}");
        let contig = ContiguousPlacement.place(16, 2, &deg);
        assert_eq!(contig[0], contig[1]);
        assert!(
            load_imbalance(&map, &deg, 2) < load_imbalance(&contig, &deg, 2),
            "degree placement did not reduce skew"
        );
    }

    #[test]
    fn degree_placement_balances_uniform_degrees() {
        // All blocks equal: degenerates to balanced counts per shard.
        let map = DegreePlacement.place(9, 3, &[2; 9]);
        let mut counts = [0usize; 3];
        for &s in &map {
            counts[s] += 1;
        }
        assert_eq!(counts, [3, 3, 3]);
    }

    #[test]
    fn hash_placement_is_deterministic_and_spread() {
        let deg = vec![1usize; 64];
        let a = HashPlacement.place(64, 4, &deg);
        let b = HashPlacement.place(64, 4, &deg);
        assert_eq!(a, b);
        let mut counts = [0usize; 4];
        for &s in &a {
            counts[s] += 1;
        }
        // Not all on one shard (uniform-ish spread).
        assert!(counts.iter().all(|&c| c > 0), "hash clumped: {counts:?}");
    }

    #[test]
    fn load_imbalance_statistic() {
        // 2 shards, all load on shard 0 -> max/mean = 2.0.
        assert_eq!(load_imbalance(&[0, 0], &[3, 5], 2), 2.0);
        assert_eq!(load_imbalance(&[0, 1], &[4, 4], 2), 1.0);
    }
}

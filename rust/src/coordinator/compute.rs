//! Worker compute backends: the same Algorithm-1 numerics via either the
//! AOT XLA artifacts (production three-layer path) or the native CSR
//! engine (ablation + simulator).  Both are constructed *inside* the
//! worker thread (XLA types are not `Send`).

use anyhow::Result;

use crate::admm::{worker_update, NativeEngine};
use crate::config::Backend;
use crate::data::WorkerShard;
use crate::problem::Problem;
use crate::runtime::{Manifest, WorkerXla, XlaEngine};
use crate::sparse::Kernels;

/// One worker iteration's numerics: block gradient at z̃ + Eq. 9/11/12
/// epilogue.  Returns the shard data loss observed at z̃.
pub trait WorkerCompute {
    fn step(
        &mut self,
        z_local: &[f32],
        y_blk: &[f32],
        slot: usize,
        rho: f32,
        w_out: &mut [f32],
        y_out: &mut [f32],
        x_out: &mut [f32],
    ) -> Result<f32>;

    /// Shard data loss at an arbitrary packed point (monitoring).
    fn data_loss(&mut self, point: &[f32]) -> Result<f32>;
}

pub struct NativeCompute<'a> {
    engine: NativeEngine<'a>,
    g: Vec<f32>,
}

impl<'a> NativeCompute<'a> {
    pub fn new(shard: &'a WorkerShard, problem: Problem, sample_weight: f32) -> Self {
        Self::with_kernels(shard, problem, sample_weight, Kernels::auto())
    }

    pub fn with_kernels(
        shard: &'a WorkerShard,
        problem: Problem,
        sample_weight: f32,
        kernels: &'static Kernels,
    ) -> Self {
        let g = vec![0.0; shard.block_size];
        NativeCompute {
            engine: NativeEngine::with_kernels(shard, problem, sample_weight, kernels),
            g,
        }
    }
}

impl WorkerCompute for NativeCompute<'_> {
    fn step(
        &mut self,
        z_local: &[f32],
        y_blk: &[f32],
        slot: usize,
        rho: f32,
        w_out: &mut [f32],
        y_out: &mut [f32],
        x_out: &mut [f32],
    ) -> Result<f32> {
        let loss = self.engine.grad_block(z_local, slot, &mut self.g);
        let (lo, hi) = self.engine.shard.slot_range(slot);
        worker_update(&self.g, y_blk, &z_local[lo..hi], rho, w_out, y_out, x_out);
        Ok(loss)
    }

    fn data_loss(&mut self, point: &[f32]) -> Result<f32> {
        Ok(self.engine.data_loss(point))
    }
}

pub struct XlaCompute {
    inner: WorkerXla,
}

impl XlaCompute {
    pub fn new(
        manifest: &Manifest,
        shard: &WorkerShard,
        problem: Problem,
        sample_weight: f32,
        m_chunk: usize,
        d_pad: usize,
    ) -> Result<Self> {
        let engine = XlaEngine::new(
            manifest,
            problem.kind.as_str(),
            m_chunk,
            d_pad,
            shard.block_size,
        )?;
        Ok(XlaCompute { inner: WorkerXla::new(engine, shard, sample_weight)? })
    }
}

impl WorkerCompute for XlaCompute {
    fn step(
        &mut self,
        z_local: &[f32],
        y_blk: &[f32],
        slot: usize,
        rho: f32,
        w_out: &mut [f32],
        y_out: &mut [f32],
        x_out: &mut [f32],
    ) -> Result<f32> {
        let (w, y_new, x, loss) = self.inner.step(z_local, y_blk, slot, rho)?;
        w_out.copy_from_slice(&w);
        y_out.copy_from_slice(&y_new);
        x_out.copy_from_slice(&x);
        Ok(loss)
    }

    fn data_loss(&mut self, point: &[f32]) -> Result<f32> {
        self.inner.data_loss(point)
    }
}

/// Construct the configured backend for one worker, inside its thread.
/// `kernels` is the session-resolved dispatch table (`--set kernel=`);
/// only the native backend consumes it (XLA ships its own codegen).
#[allow(clippy::too_many_arguments)]
pub fn make_compute<'a>(
    backend: Backend,
    shard: &'a WorkerShard,
    problem: Problem,
    sample_weight: f32,
    manifest: Option<&Manifest>,
    m_chunk: usize,
    d_pad: usize,
    kernels: &'static Kernels,
) -> Result<Box<dyn WorkerCompute + 'a>> {
    match backend {
        Backend::Native => {
            Ok(Box::new(NativeCompute::with_kernels(shard, problem, sample_weight, kernels)))
        }
        Backend::Xla => {
            let manifest = manifest
                .ok_or_else(|| anyhow::anyhow!("XLA backend requires a loaded manifest"))?;
            Ok(Box::new(XlaCompute::new(
                manifest,
                shard,
                problem,
                sample_weight,
                m_chunk,
                d_pad,
            )?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};

    #[test]
    fn native_step_matches_manual_composition() {
        let spec = SynthSpec {
            samples: 32,
            geometry: BlockGeometry::new(4, 8),
            nnz_per_row: 4,
            blocks_per_worker: 2,
            shared_blocks: 1,
            ..Default::default()
        };
        let (ds, shards) = gen_partitioned(&spec, 2);
        let shard = &shards[0];
        let p = Problem::new(LossKind::Logistic, 1e-4, 1e4);
        let w_s = 1.0 / ds.samples() as f32;
        let mut c = NativeCompute::new(shard, p, w_s);

        let dim = shard.packed_dim();
        let z: Vec<f32> = (0..dim).map(|k| (k as f32 * 0.01).sin()).collect();
        let y = vec![0.1f32; 8];
        let (mut w, mut yn, mut x) = (vec![0.0; 8], vec![0.0; 8], vec![0.0; 8]);
        let loss = c.step(&z, &y, 1, 50.0, &mut w, &mut yn, &mut x).unwrap();
        assert!(loss.is_finite() && loss > 0.0);

        // manual: grad then epilogue
        let mut eng = NativeEngine::new(shard, p, w_s);
        let mut g = vec![0.0f32; 8];
        eng.grad_block(&z, 1, &mut g);
        for k in 0..8 {
            let xe = z[8 + k] - (g[k] + y[k]) / 50.0;
            assert!((x[k] - xe).abs() < 1e-6);
            assert!((yn[k] + g[k]).abs() < 1e-4); // y' = -g identity
        }
    }
}

//! The bipartite sparsity graph ℰ (paper §2.2) and the block→server
//! placement.
//!
//! 𝒩(i) = blocks worker i touches (from its shard's active set);
//! 𝒩(j) = workers touching block j.  The block→shard assignment is
//! delegated to a [`Placement`] policy (`coordinator/placement.rs`):
//! the default [`Topology::build`] uses `Placement::contiguous` — equal
//! contiguous block-id ranges per shard, which balances block *count*
//! but, because the synthetic workload's hot shared blocks have low
//! indices, concentrates the Zipf head on shard 0.  `hash` spreads ids
//! like a production PS key hash; `degree` packs by |𝒩(j)| so the hot
//! head lands on distinct shards.  Use [`Topology::build_with`] to pick.

use super::placement::{ContiguousPlacement, Placement};
use crate::data::WorkerShard;

#[derive(Clone, Debug)]
pub struct Topology {
    pub n_workers: usize,
    pub n_servers: usize,
    pub n_blocks: usize,
    pub block_size: usize,
    /// server shard owning each block.
    pub server_of_block: Vec<usize>,
    /// blocks owned by each server shard.
    pub blocks_of_server: Vec<Vec<usize>>,
    /// 𝒩(j): workers touching each block.
    pub workers_of_block: Vec<Vec<usize>>,
    /// 𝒩(i): blocks touched by each worker (== shard.active_blocks).
    pub blocks_of_worker: Vec<Vec<usize>>,
}

impl Topology {
    /// Build with the default contiguous placement.
    pub fn build(shards: &[WorkerShard], n_blocks: usize, n_servers: usize) -> Self {
        Self::build_with(shards, n_blocks, n_servers, &ContiguousPlacement)
    }

    /// Build with an explicit block→shard [`Placement`] policy.
    pub fn build_with(
        shards: &[WorkerShard],
        n_blocks: usize,
        n_servers: usize,
        placement: &dyn Placement,
    ) -> Self {
        assert!(!shards.is_empty());
        let block_size = shards[0].block_size;
        let n_workers = shards.len();

        // Adjacency first: placement policies may consult |𝒩(j)|.
        let mut workers_of_block = vec![Vec::new(); n_blocks];
        let mut blocks_of_worker = Vec::with_capacity(n_workers);
        for shard in shards {
            debug_assert_eq!(shard.worker_id, blocks_of_worker.len());
            for &j in &shard.active_blocks {
                workers_of_block[j].push(shard.worker_id);
            }
            blocks_of_worker.push(shard.active_blocks.clone());
        }
        let degree: Vec<usize> = workers_of_block.iter().map(Vec::len).collect();

        let server_of_block = placement.place(n_blocks, n_servers, &degree);
        assert_eq!(
            server_of_block.len(),
            n_blocks,
            "placement {:?} returned a partial map",
            placement.name()
        );
        let mut blocks_of_server = vec![Vec::new(); n_servers];
        for (j, &s) in server_of_block.iter().enumerate() {
            assert!(s < n_servers, "placement {:?} placed block {j} on shard {s}", placement.name());
            blocks_of_server[s].push(j);
        }

        Topology {
            n_workers,
            n_servers,
            n_blocks,
            block_size,
            server_of_block,
            blocks_of_server,
            workers_of_block,
            blocks_of_worker,
        }
    }

    /// |𝒩(j)| — the Eq. 13 denominator is γ + ρ·|𝒩(j)| for uniform ρ.
    pub fn degree_of_block(&self, j: usize) -> usize {
        self.workers_of_block[j].len()
    }

    /// Blocks nobody touches (padding blocks; stay at prox fixed point).
    pub fn orphan_blocks(&self) -> Vec<usize> {
        (0..self.n_blocks).filter(|&j| self.workers_of_block[j].is_empty()).collect()
    }

    /// Edge count |ℰ|.
    pub fn n_edges(&self) -> usize {
        self.blocks_of_worker.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{DegreePlacement, HashPlacement};
    use crate::data::{gen_partitioned, BlockGeometry, SynthSpec};

    fn shards() -> Vec<WorkerShard> {
        let spec = SynthSpec {
            samples: 64,
            geometry: BlockGeometry::new(8, 8),
            nnz_per_row: 4,
            blocks_per_worker: 3,
            shared_blocks: 1,
            ..Default::default()
        };
        gen_partitioned(&spec, 4).1
    }

    #[test]
    fn default_contiguous_placement_partitions_blocks() {
        let t = Topology::build(&shards(), 8, 3);
        let mut all: Vec<usize> = t.blocks_of_server.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        // Contiguous ranges: block 5 of 8 over 3 shards -> shard 5*3/8 = 1.
        assert_eq!(t.server_of_block[5], 1);
        assert!(t.server_of_block.windows(2).all(|w| w[0] <= w[1]), "not contiguous");
        for (s, blocks) in t.blocks_of_server.iter().enumerate() {
            for &j in blocks {
                assert_eq!(t.server_of_block[j], s);
            }
        }
    }

    #[test]
    fn every_placement_owns_each_block_exactly_once() {
        for placement in
            [&ContiguousPlacement as &dyn Placement, &HashPlacement, &DegreePlacement]
        {
            let t = Topology::build_with(&shards(), 8, 3, placement);
            let mut all: Vec<usize> = t.blocks_of_server.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..8).collect::<Vec<_>>(), "{}", placement.name());
            for (s, blocks) in t.blocks_of_server.iter().enumerate() {
                for &j in blocks {
                    assert_eq!(t.server_of_block[j], s, "{}", placement.name());
                }
            }
        }
    }

    #[test]
    fn degree_placement_splits_hot_blocks_across_shards() {
        // shared_blocks=1 -> block 0 is touched by all 4 workers; under
        // degree placement the busiest shard must not also hoard the
        // rest of the load.
        let t = Topology::build_with(&shards(), 8, 2, &DegreePlacement);
        let deg: Vec<usize> = (0..8).map(|j| t.degree_of_block(j)).collect();
        let hot_shard = t.server_of_block[0];
        let load = |s: usize| -> usize {
            t.blocks_of_server[s].iter().map(|&j| deg[j]).sum()
        };
        let other = 1 - hot_shard;
        assert!(
            load(hot_shard) <= load(other) + deg[0],
            "degree placement left the hot shard overloaded: {} vs {}",
            load(hot_shard),
            load(other)
        );
    }

    #[test]
    fn adjacency_is_consistent() {
        let t = Topology::build(&shards(), 8, 2);
        for (i, blocks) in t.blocks_of_worker.iter().enumerate() {
            for &j in blocks {
                assert!(t.workers_of_block[j].contains(&i), "edge ({i},{j}) asymmetric");
            }
        }
        for (j, workers) in t.workers_of_block.iter().enumerate() {
            for &i in workers {
                assert!(t.blocks_of_worker[i].contains(&j));
            }
        }
        assert_eq!(
            t.n_edges(),
            t.workers_of_block.iter().map(Vec::len).sum::<usize>()
        );
    }

    #[test]
    fn shared_block_has_full_degree() {
        let t = Topology::build(&shards(), 8, 2);
        assert_eq!(t.degree_of_block(0), 4); // shared_blocks=1 -> block 0 hot
    }
}

//! The bipartite sparsity graph ℰ (paper §2.2) and the block→server
//! placement.
//!
//! 𝒩(i) = blocks worker i touches (from its shard's active set);
//! 𝒩(j) = workers touching block j.  Blocks are placed on server shards
//! round-robin, which balances both block count and — because the
//! synthetic workload's hot shared blocks have low indices — spreads the
//! hot blocks across shards like a production PS hash placement would.

use crate::data::WorkerShard;

#[derive(Clone, Debug)]
pub struct Topology {
    pub n_workers: usize,
    pub n_servers: usize,
    pub n_blocks: usize,
    pub block_size: usize,
    /// server shard owning each block.
    pub server_of_block: Vec<usize>,
    /// blocks owned by each server shard.
    pub blocks_of_server: Vec<Vec<usize>>,
    /// 𝒩(j): workers touching each block.
    pub workers_of_block: Vec<Vec<usize>>,
    /// 𝒩(i): blocks touched by each worker (== shard.active_blocks).
    pub blocks_of_worker: Vec<Vec<usize>>,
}

impl Topology {
    pub fn build(shards: &[WorkerShard], n_blocks: usize, n_servers: usize) -> Self {
        assert!(!shards.is_empty());
        let block_size = shards[0].block_size;
        let n_workers = shards.len();

        let server_of_block: Vec<usize> = (0..n_blocks).map(|j| j % n_servers).collect();
        let mut blocks_of_server = vec![Vec::new(); n_servers];
        for (j, &s) in server_of_block.iter().enumerate() {
            blocks_of_server[s].push(j);
        }

        let mut workers_of_block = vec![Vec::new(); n_blocks];
        let mut blocks_of_worker = Vec::with_capacity(n_workers);
        for shard in shards {
            debug_assert_eq!(shard.worker_id, blocks_of_worker.len());
            for &j in &shard.active_blocks {
                workers_of_block[j].push(shard.worker_id);
            }
            blocks_of_worker.push(shard.active_blocks.clone());
        }

        Topology {
            n_workers,
            n_servers,
            n_blocks,
            block_size,
            server_of_block,
            blocks_of_server,
            workers_of_block,
            blocks_of_worker,
        }
    }

    /// |𝒩(j)| — the Eq. 13 denominator is γ + ρ·|𝒩(j)| for uniform ρ.
    pub fn degree_of_block(&self, j: usize) -> usize {
        self.workers_of_block[j].len()
    }

    /// Blocks nobody touches (padding blocks; stay at prox fixed point).
    pub fn orphan_blocks(&self) -> Vec<usize> {
        (0..self.n_blocks).filter(|&j| self.workers_of_block[j].is_empty()).collect()
    }

    /// Edge count |ℰ|.
    pub fn n_edges(&self) -> usize {
        self.blocks_of_worker.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, SynthSpec};

    fn shards() -> Vec<WorkerShard> {
        let spec = SynthSpec {
            samples: 64,
            geometry: BlockGeometry::new(8, 8),
            nnz_per_row: 4,
            blocks_per_worker: 3,
            shared_blocks: 1,
            ..Default::default()
        };
        gen_partitioned(&spec, 4).1
    }

    #[test]
    fn round_robin_placement_partitions_blocks() {
        let t = Topology::build(&shards(), 8, 3);
        let mut all: Vec<usize> = t.blocks_of_server.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        assert_eq!(t.server_of_block[5], 5 % 3);
        for (s, blocks) in t.blocks_of_server.iter().enumerate() {
            for &j in blocks {
                assert_eq!(t.server_of_block[j], s);
            }
        }
    }

    #[test]
    fn adjacency_is_consistent() {
        let t = Topology::build(&shards(), 8, 2);
        for (i, blocks) in t.blocks_of_worker.iter().enumerate() {
            for &j in blocks {
                assert!(t.workers_of_block[j].contains(&i), "edge ({i},{j}) asymmetric");
            }
        }
        for (j, workers) in t.workers_of_block.iter().enumerate() {
            for &i in workers {
                assert!(t.blocks_of_worker[i].contains(&j));
            }
        }
        assert_eq!(
            t.n_edges(),
            t.workers_of_block.iter().map(Vec::len).sum::<usize>()
        );
    }

    #[test]
    fn shared_block_has_full_degree() {
        let t = Topology::build(&shards(), 8, 2);
        assert_eq!(t.degree_of_block(0), 4); // shared_blocks=1 -> block 0 hot
    }
}

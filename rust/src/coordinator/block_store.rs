//! The shared consensus-variable store — the concurrency heart of the
//! paper's contribution.
//!
//! One slot per block z_j, each an independent **seqlock-style versioned
//! double buffer**.  There is no lock on the read path at all: readers
//! copy the stable buffer optimistically and retry only if a torn
//! snapshot is detected, so reads never block writes and writes never
//! block reads — the property the paper calls "lock-free" in contrast to
//! prior full-vector asynchronous ADMMs that serialize every model
//! update through one latch.  Distinct blocks share no state, so updates
//! to different blocks are fully parallel.  Block versions implement the
//! staleness accounting of Assumption 3 (bounded delay).
//!
//! ## Protocol (per slot)
//!
//! The slot holds two buffers and a sequence word `seq`:
//!
//! * `seq` even: stable; `version = seq >> 1`, current data lives in
//!   `bufs[version & 1]`.
//! * `seq` odd: a write of `version + 1` is in progress on the *other*
//!   buffer `bufs[(version + 1) & 1]`; the stable buffer is untouched.
//!
//! Writer (serialized per block by a writer mutex that readers never
//! touch):
//!
//! 1. `seq ← seq + 1` (release) — mark the write before any data store;
//! 2. `fence(Release)` — order the mark before the data stores;
//! 3. store the new value into the inactive buffer (relaxed stores);
//! 4. `seq ← seq + 2` relative to start (release) — publish; the stable
//!    buffer flips.
//!
//! Reader: load `seq` (acquire), copy `bufs[(seq >> 1) & 1]` with relaxed
//! loads, `fence(Acquire)`, reload `seq`; the copy is valid iff the slot
//! advanced by at most one whole write (`seq' − (seq & !1) ≤ 2`), because
//! only the *second* write after the snapshot touches the buffer being
//! copied.  Thanks to the double buffer a reader therefore retries only
//! when the writer laps it twice mid-copy — under one writer per block
//! reads are effectively wait-free.
//!
//! ## Safety argument
//!
//! The buffers are `AtomicU32` words (f32 bit patterns), so the
//! concurrent plain-data access of a classic C seqlock is replaced by
//! relaxed atomics — no data race exists in the Rust memory model and no
//! `unsafe` is needed.  Consistency of the *snapshot* (not just of each
//! word) follows from the fence pairing: if any torn word from write
//! `v+2` were observed, the writer's release fence (step 2) synchronizes
//! with the reader's acquire fence, forcing the reader's final `seq` load
//! to observe `≥ 2v+3` and the validation to fail.  Observing `seq = 2v`
//! (or the odd mark `2v+1`, also a release store) via the acquire load
//! likewise makes all data stores of write `v` visible before the copy.
//! This is the construction of Boehm, *"Can seqlocks get along with
//! programming language memory models?"* (MSPC '12), as used by
//! crossbeam's `SeqLock`.

use std::sync::atomic::{fence, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

pub struct BlockStore {
    blocks: Vec<Slot>,
    db: usize,
    /// Global publish counter: bumped once per published write, any
    /// block.  `Arc`ed so the networked runtime can piggyback it on
    /// Credit frames (the pull-cadence version hint) without holding a
    /// store reference — a relaxed `fetch_add` next to the seqlock
    /// publish, invisible to the hot path.
    publishes: Arc<AtomicU64>,
}

struct Slot {
    /// Double buffer: after `v` published writes the stable copy is
    /// `bufs[v & 1]` and the next write goes to `bufs[(v + 1) & 1]`.
    bufs: [Box<[AtomicU32]>; 2],
    /// Seqlock word: even = stable (version = `seq >> 1`), odd = write in
    /// progress on the inactive buffer.
    seq: AtomicU64,
    /// Serializes writers to THIS block only — readers never touch it, so
    /// reads cannot block writes and distinct blocks stay independent.
    /// The guarded vector doubles as the read-modify-write scratch for
    /// [`BlockStore::update_with`].
    writer: Mutex<Vec<f32>>,
}

fn zero_buf(db: usize) -> Box<[AtomicU32]> {
    (0..db).map(|_| AtomicU32::new(0)).collect()
}

impl Slot {
    fn new(db: usize) -> Self {
        Slot {
            bufs: [zero_buf(db), zero_buf(db)],
            seq: AtomicU64::new(0),
            writer: Mutex::new(vec![0.0; db]),
        }
    }

    /// Write protocol steps 1-4; caller must hold `self.writer`.
    fn write_locked(&self, data: &[f32]) -> u64 {
        let s0 = self.seq.load(Ordering::Relaxed);
        debug_assert_eq!(s0 & 1, 0, "write while another write in progress");
        let target = &self.bufs[(((s0 >> 1) + 1) & 1) as usize];
        // Release so a reader that observes the odd mark still inherits
        // the previous writer's data stores (writers may be different
        // threads; happens-before chains through the writer mutex).
        self.seq.store(s0 + 1, Ordering::Release);
        fence(Ordering::Release);
        for (a, &v) in target.iter().zip(data) {
            a.store(v.to_bits(), Ordering::Relaxed);
        }
        self.seq.store(s0 + 2, Ordering::Release);
        (s0 >> 1) + 1
    }

    /// Optimistic snapshot into `out`; returns the version read.
    fn read_into(&self, out: &mut [f32]) -> u64 {
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            let base = s1 & !1; // 2 * version of the stable buffer
            let src = &self.bufs[((base >> 1) & 1) as usize];
            for (o, a) in out.iter_mut().zip(src.iter()) {
                *o = f32::from_bits(a.load(Ordering::Relaxed));
            }
            fence(Ordering::Acquire);
            let s2 = self.seq.load(Ordering::Relaxed);
            // The write of version v+1 targets the other buffer; only the
            // write of v+2 (seq = base + 3) can tear this copy.
            if s2.wrapping_sub(base) <= 2 {
                return base >> 1;
            }
            std::hint::spin_loop();
        }
    }
}

impl BlockStore {
    pub fn new(n_blocks: usize, db: usize) -> Self {
        let blocks = (0..n_blocks).map(|_| Slot::new(db)).collect();
        BlockStore { blocks, db, publishes: Arc::new(AtomicU64::new(0)) }
    }

    /// Handle on the global publish counter (see the field docs).  The
    /// counter is monotone and starts at 0; equal observed values mean
    /// "no block has been republished since".
    pub fn publish_counter(&self) -> Arc<AtomicU64> {
        self.publishes.clone()
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_size(&self) -> usize {
        self.db
    }

    /// Pull block j into `out`; returns the version read.  Lock-free:
    /// retries only if a concurrent writer lapped the copy (see module
    /// docs), never blocks a writer.
    pub fn read_into(&self, j: usize, out: &mut [f32]) -> u64 {
        debug_assert_eq!(out.len(), self.db);
        self.blocks[j].read_into(out)
    }

    /// Publish a new value of block j; returns the new version.  Writers
    /// to the same block serialize on a per-block mutex; writers to
    /// distinct blocks share nothing.
    pub fn write(&self, j: usize, data: &[f32]) -> u64 {
        debug_assert_eq!(data.len(), self.db);
        let slot = &self.blocks[j];
        let _guard = slot.writer.lock().unwrap();
        let v = slot.write_locked(data);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        v
    }

    /// Atomic read-modify-write of block j (HOGWILD-SGD baseline): the
    /// per-block writer mutex pins the stable buffer, so the read needs
    /// no retry and the f→write sequence is atomic w.r.t. other writers.
    pub fn update_with(&self, j: usize, f: impl FnOnce(&mut [f32])) -> u64 {
        let slot = &self.blocks[j];
        let mut scratch = slot.writer.lock().unwrap();
        let s0 = slot.seq.load(Ordering::Relaxed);
        let src = &slot.bufs[((s0 >> 1) & 1) as usize];
        for (o, a) in scratch.iter_mut().zip(src.iter()) {
            *o = f32::from_bits(a.load(Ordering::Relaxed));
        }
        f(&mut scratch);
        let v = slot.write_locked(&scratch[..]);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        v
    }

    /// Adopt block j at an externally assigned `version` — the mirror-
    /// sync primitive of the networked runtime: a worker process's local
    /// replica adopts the coordinator's (value, version) pairs from pull
    /// responses, so the staleness accounting (`z_version_used`) refers
    /// to the same version numbers on both sides of the socket.  No-op
    /// (returns `false`) unless `version` is newer than the published
    /// one, so reordered or duplicated sync frames cannot roll the
    /// replica back.
    ///
    /// Seqlock-safe for any forward jump: the in-progress mark is set to
    /// `2·version − 1`, so a reader that snapshotted version `v` revalidates
    /// against `seq − 2v ≤ 2` — true only for the `v → v+1` step, which
    /// (like [`BlockStore::write`]) targets the inactive buffer; any
    /// larger jump forces the reader to retry.
    pub fn write_versioned(&self, j: usize, data: &[f32], version: u64) -> bool {
        debug_assert_eq!(data.len(), self.db);
        let slot = &self.blocks[j];
        let _guard = slot.writer.lock().unwrap();
        let s0 = slot.seq.load(Ordering::Relaxed);
        debug_assert_eq!(s0 & 1, 0, "write while another write in progress");
        if version <= (s0 >> 1) {
            return false;
        }
        // Stable buffer for version v is bufs[v & 1] — same invariant as
        // the increment-by-one writer, generalized to jumps.
        let target = &slot.bufs[(version & 1) as usize];
        slot.seq.store((version << 1) - 1, Ordering::Release);
        fence(Ordering::Release);
        for (a, &v) in target.iter().zip(data) {
            a.store(v.to_bits(), Ordering::Relaxed);
        }
        slot.seq.store(version << 1, Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        true
    }

    pub fn version(&self, j: usize) -> u64 {
        // Odd (in-progress) states round down to the published version.
        self.blocks[j].seq.load(Ordering::Acquire) >> 1
    }

    /// Snapshot the whole model (monitoring only, never on the hot path;
    /// per-block optimistic reads — no global freeze).
    pub fn snapshot(&self) -> Vec<f32> {
        let mut z = vec![0.0f32; self.blocks.len() * self.db];
        for (j, chunk) in z.chunks_mut(self.db).enumerate() {
            self.read_into(j, chunk);
        }
        z
    }

    /// Initialize all blocks without bumping versions.  Must run before
    /// concurrent readers exist (it stores straight into the stable
    /// buffer).
    pub fn init_from(&self, z0: &[f32]) {
        assert_eq!(z0.len(), self.blocks.len() * self.db);
        for (j, chunk) in z0.chunks(self.db).enumerate() {
            let slot = &self.blocks[j];
            let _guard = slot.writer.lock().unwrap();
            let s = slot.seq.load(Ordering::Relaxed);
            let buf = &slot.bufs[((s >> 1) & 1) as usize];
            for (a, &v) in buf.iter().zip(chunk) {
                a.store(v.to_bits(), Ordering::Relaxed);
            }
        }
    }
}

/// The pre-seqlock store: one `RwLock` per block with copy-under-lock
/// reads.  Kept as (a) the baseline the `locking_ablation` bench compares
/// the seqlock against, and (b) a differential-testing oracle for the
/// seqlock's sequential semantics (`rust/tests/proptests.rs`).
pub struct RwBlockStore {
    blocks: Vec<RwSlot>,
    db: usize,
}

struct RwSlot {
    data: RwLock<Vec<f32>>,
    version: AtomicU64,
}

impl RwBlockStore {
    pub fn new(n_blocks: usize, db: usize) -> Self {
        let blocks = (0..n_blocks)
            .map(|_| RwSlot { data: RwLock::new(vec![0.0; db]), version: AtomicU64::new(0) })
            .collect();
        RwBlockStore { blocks, db }
    }

    pub fn block_size(&self) -> usize {
        self.db
    }

    pub fn read_into(&self, j: usize, out: &mut [f32]) -> u64 {
        debug_assert_eq!(out.len(), self.db);
        let slot = &self.blocks[j];
        let guard = slot.data.read().unwrap();
        out.copy_from_slice(&guard);
        slot.version.load(Ordering::Acquire)
    }

    pub fn write(&self, j: usize, data: &[f32]) -> u64 {
        debug_assert_eq!(data.len(), self.db);
        let slot = &self.blocks[j];
        let mut guard = slot.data.write().unwrap();
        guard.copy_from_slice(data);
        slot.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    pub fn update_with(&self, j: usize, f: impl FnOnce(&mut [f32])) -> u64 {
        let slot = &self.blocks[j];
        let mut guard = slot.data.write().unwrap();
        f(&mut guard);
        slot.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    pub fn version(&self, j: usize) -> u64 {
        self.blocks[j].version.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_write_roundtrip_with_versions() {
        let s = BlockStore::new(3, 4);
        assert_eq!(s.version(1), 0);
        let v = s.write(1, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v, 1);
        let mut out = [0.0f32; 4];
        let rv = s.read_into(1, &mut out);
        assert_eq!(rv, 1);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
        // untouched block still zero/v0
        assert_eq!(s.version(0), 0);
    }

    #[test]
    fn snapshot_concatenates_blocks() {
        let s = BlockStore::new(2, 2);
        s.write(0, &[1.0, 2.0]);
        s.write(1, &[3.0, 4.0]);
        assert_eq!(s.snapshot(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn double_buffer_keeps_previous_version_readable() {
        // Two consecutive writes land in alternating buffers; each read
        // returns the value matching the version it reports.
        let s = BlockStore::new(1, 3);
        for v in 1..=6u64 {
            let x = v as f32;
            assert_eq!(s.write(0, &[x, x, x]), v);
            let mut out = [0.0f32; 3];
            assert_eq!(s.read_into(0, &mut out), v);
            assert_eq!(out, [x, x, x]);
        }
    }

    #[test]
    fn init_from_does_not_bump_versions() {
        let s = BlockStore::new(2, 2);
        s.init_from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.version(0), 0);
        assert_eq!(s.version(1), 0);
        assert_eq!(s.snapshot(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn concurrent_writers_to_distinct_blocks_do_not_serialize_results() {
        // Smoke test for torn reads: hammer two blocks from two writers
        // while a reader checks each block is internally consistent
        // (all elements equal — each write uses a constant vector).
        let s = Arc::new(BlockStore::new(2, 64));
        let mut handles = Vec::new();
        for j in 0..2usize {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for it in 0..500u64 {
                    let v = (it * 2 + j as u64) as f32;
                    s.write(j, &[v; 64]);
                }
            }));
        }
        let reader = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut buf = vec![0.0f32; 64];
                for _ in 0..2000 {
                    for j in 0..2 {
                        s.read_into(j, &mut buf);
                        let first = buf[0];
                        assert!(buf.iter().all(|&x| x == first), "torn read");
                    }
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(s.version(0), 500);
        assert_eq!(s.version(1), 500);
    }

    #[test]
    fn seqlock_torture_same_block_writers_and_readers() {
        // The seqlock torture mirror of the torn-read test: multiple
        // writers contend on ONE block (exercising the writer mutex and
        // both buffers) while several readers hammer the optimistic read
        // path.  Every observed snapshot must be internally consistent
        // AND consistent with the version it reports (value == version).
        let s = Arc::new(BlockStore::new(1, 48));
        let writers = 3usize;
        let per_writer = 400u64;
        let mut handles = Vec::new();
        for _ in 0..writers {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..per_writer {
                    s.update_with(0, |z| {
                        // value tracks the version: every element = v.
                        let next = z[0] + 1.0;
                        z.iter_mut().for_each(|x| *x = next);
                    });
                }
            }));
        }
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut buf = vec![0.0f32; 48];
                let mut last = 0u64;
                for _ in 0..3000 {
                    let v = s.read_into(0, &mut buf);
                    let first = buf[0];
                    assert!(buf.iter().all(|&x| x == first), "torn read");
                    assert_eq!(first as u64, v, "value {first} disagrees with version {v}");
                    assert!(v >= last, "version went backwards: {last} -> {v}");
                    last = v;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.version(0), writers as u64 * per_writer);
        let mut out = vec![0.0f32; 48];
        s.read_into(0, &mut out);
        assert_eq!(out[0] as u64, writers as u64 * per_writer);
    }

    #[test]
    fn write_versioned_adopts_only_newer_versions() {
        let s = BlockStore::new(1, 2);
        assert!(s.write_versioned(0, &[1.0, 1.0], 3));
        assert_eq!(s.version(0), 3);
        let mut out = [0.0f32; 2];
        assert_eq!(s.read_into(0, &mut out), 3);
        assert_eq!(out, [1.0, 1.0]);
        // Stale and duplicate versions are ignored (reordered sync).
        assert!(!s.write_versioned(0, &[9.0, 9.0], 3));
        assert!(!s.write_versioned(0, &[9.0, 9.0], 2));
        s.read_into(0, &mut out);
        assert_eq!(out, [1.0, 1.0]);
        // Forward jumps and +1 steps both land with the right value.
        assert!(s.write_versioned(0, &[2.0, 2.0], 4));
        assert!(s.write_versioned(0, &[7.0, 7.0], 9));
        assert_eq!(s.read_into(0, &mut out), 9);
        assert_eq!(out, [7.0, 7.0]);
        // A plain write continues the sequence from the adopted version.
        assert_eq!(s.write(0, &[8.0, 8.0]), 10);
    }

    #[test]
    fn write_versioned_keeps_snapshots_consistent_under_races() {
        // Readers must never observe a torn mix while a versioned
        // writer jumps the block forward (the mirror-sync race).
        let s = Arc::new(BlockStore::new(1, 32));
        let writer = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut v = 0u64;
                for step in 1..=400u64 {
                    v += 1 + (step % 3); // mix of +1 steps and jumps
                    s.write_versioned(0, &[v as f32; 32], v);
                }
            })
        };
        let mut buf = vec![0.0f32; 32];
        for _ in 0..2000 {
            let v = s.read_into(0, &mut buf);
            let first = buf[0];
            assert!(buf.iter().all(|&x| x == first), "torn read");
            assert_eq!(first as u64, v, "value {first} disagrees with version {v}");
        }
        writer.join().unwrap();
    }

    #[test]
    fn update_with_applies_in_place() {
        let s = BlockStore::new(1, 2);
        s.write(0, &[1.0, 2.0]);
        let v = s.update_with(0, |z| {
            for x in z.iter_mut() {
                *x *= 10.0;
            }
        });
        assert_eq!(v, 2);
        let mut out = [0.0f32; 2];
        s.read_into(0, &mut out);
        assert_eq!(out, [10.0, 20.0]);
    }

    #[test]
    fn rwlock_baseline_matches_api() {
        let s = RwBlockStore::new(2, 2);
        assert_eq!(s.write(1, &[5.0, 6.0]), 1);
        let mut out = [0.0f32; 2];
        assert_eq!(s.read_into(1, &mut out), 1);
        assert_eq!(out, [5.0, 6.0]);
        assert_eq!(s.update_with(1, |z| z[0] = 9.0), 2);
        s.read_into(1, &mut out);
        assert_eq!(out, [9.0, 6.0]);
        assert_eq!(s.version(0), 0);
        assert_eq!(s.block_size(), 2);
    }
}

//! The shared consensus-variable store — the concurrency heart of the
//! paper's contribution.
//!
//! One slot per block z_j, each with its own `RwLock` and a monotonically
//! increasing version counter.  There is **no global lock**: readers
//! (workers pulling z̃) and the writer (the owning server shard) contend
//! only per block, so updates to different blocks are fully parallel —
//! the property the paper calls "lock-free" in contrast to prior
//! full-vector asynchronous ADMMs that serialize every model update
//! through one latch.  Block versions implement the staleness accounting
//! of Assumption 3 (bounded delay).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

pub struct BlockStore {
    blocks: Vec<Slot>,
    db: usize,
}

struct Slot {
    data: RwLock<Vec<f32>>,
    /// Bumped on every write; staleness of a read = current - observed.
    version: AtomicU64,
}

impl BlockStore {
    pub fn new(n_blocks: usize, db: usize) -> Self {
        let blocks = (0..n_blocks)
            .map(|_| Slot { data: RwLock::new(vec![0.0; db]), version: AtomicU64::new(0) })
            .collect();
        BlockStore { blocks, db }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_size(&self) -> usize {
        self.db
    }

    /// Pull block j into `out`; returns the version read (torn-free: the
    /// read lock guarantees a consistent snapshot of the block).
    pub fn read_into(&self, j: usize, out: &mut [f32]) -> u64 {
        debug_assert_eq!(out.len(), self.db);
        let slot = &self.blocks[j];
        let guard = slot.data.read().unwrap();
        out.copy_from_slice(&guard);
        // Version is read under the lock so it matches the data.
        slot.version.load(Ordering::Acquire)
    }

    /// Publish a new value of block j; returns the new version.
    pub fn write(&self, j: usize, data: &[f32]) -> u64 {
        debug_assert_eq!(data.len(), self.db);
        let slot = &self.blocks[j];
        let mut guard = slot.data.write().unwrap();
        guard.copy_from_slice(data);
        slot.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Read-modify-write of block j under its (single-block) write lock;
    /// used by the HOGWILD-SGD baseline.
    pub fn update_with(&self, j: usize, f: impl FnOnce(&mut [f32])) -> u64 {
        let slot = &self.blocks[j];
        let mut guard = slot.data.write().unwrap();
        f(&mut guard);
        slot.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    pub fn version(&self, j: usize) -> u64 {
        self.blocks[j].version.load(Ordering::Acquire)
    }

    /// Snapshot the whole model (monitoring only, never on the hot path;
    /// takes block read-locks one at a time — no global freeze).
    pub fn snapshot(&self) -> Vec<f32> {
        let mut z = vec![0.0f32; self.blocks.len() * self.db];
        for (j, chunk) in z.chunks_mut(self.db).enumerate() {
            self.read_into(j, chunk);
        }
        z
    }

    /// Initialize all blocks (before threads start).
    pub fn init_from(&self, z0: &[f32]) {
        assert_eq!(z0.len(), self.blocks.len() * self.db);
        for (j, chunk) in z0.chunks(self.db).enumerate() {
            let mut guard = self.blocks[j].data.write().unwrap();
            guard.copy_from_slice(chunk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_write_roundtrip_with_versions() {
        let s = BlockStore::new(3, 4);
        assert_eq!(s.version(1), 0);
        let v = s.write(1, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v, 1);
        let mut out = [0.0f32; 4];
        let rv = s.read_into(1, &mut out);
        assert_eq!(rv, 1);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
        // untouched block still zero/v0
        assert_eq!(s.version(0), 0);
    }

    #[test]
    fn snapshot_concatenates_blocks() {
        let s = BlockStore::new(2, 2);
        s.write(0, &[1.0, 2.0]);
        s.write(1, &[3.0, 4.0]);
        assert_eq!(s.snapshot(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn concurrent_writers_to_distinct_blocks_do_not_serialize_results() {
        // Smoke test for torn reads: hammer two blocks from two writers
        // while a reader checks each block is internally consistent
        // (all elements equal — each write uses a constant vector).
        let s = Arc::new(BlockStore::new(2, 64));
        let mut handles = Vec::new();
        for j in 0..2usize {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for it in 0..500u64 {
                    let v = (it * 2 + j as u64) as f32;
                    s.write(j, &vec![v; 64]);
                }
            }));
        }
        let reader = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut buf = vec![0.0f32; 64];
                for _ in 0..2000 {
                    for j in 0..2 {
                        s.read_into(j, &mut buf);
                        let first = buf[0];
                        assert!(buf.iter().all(|&x| x == first), "torn read");
                    }
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(s.version(0), 500);
        assert_eq!(s.version(1), 500);
    }

    #[test]
    fn update_with_applies_in_place() {
        let s = BlockStore::new(1, 2);
        s.write(0, &[1.0, 2.0]);
        let v = s.update_with(0, |z| {
            for x in z.iter_mut() {
                *x *= 10.0;
            }
        });
        assert_eq!(v, 2);
        let mut out = [0.0f32; 2];
        s.read_into(0, &mut out);
        assert_eq!(out, [10.0, 20.0]);
    }
}

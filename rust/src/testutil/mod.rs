//! Seeded property-test driver (no `proptest` available offline).
//!
//! `forall` runs a property over `n` generated cases from deterministic
//! seeds; on failure it reports the seed so the case replays exactly.
//! No shrinking — generators here produce small cases by construction.

use crate::util::rng::Rng;

/// Run `prop` over `n` cases produced by `gen` from seeds 0..n (XORed
/// with a fixed salt so different call sites decorrelate).  Panics with
/// the failing seed and message.
pub fn forall<T>(
    name: &str,
    n: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for seed in 0..n {
        let mut rng = Rng::new(seed ^ 0xA11C_E0F0);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("element {k}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs-nonneg", 50, |rng| rng.normal(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn forall_reports_seed_on_failure() {
        forall("always-false", 3, |rng| rng.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0001], 1e-3, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-3, 0.0).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-3, 0.0).is_err());
    }
}

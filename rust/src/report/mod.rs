//! Report emission (S7): CSV series for the figures, markdown tables for
//! Table 1, and JSON run records — everything EXPERIMENTS.md cites is
//! regenerated through this module into `reports/`.

mod checkpoint;

use std::path::Path;

pub use checkpoint::Checkpoint;

use anyhow::{Context, Result};

use crate::coordinator::ObjSample;
use crate::util::json::{num, obj, s, Json};

/// Write an objective-trace CSV (one series; Fig. 2a/2b plot several of
/// these files together).
pub fn write_trace_csv(path: &Path, samples: &[ObjSample]) -> Result<()> {
    let mut out = String::from(ObjSample::csv_header());
    out.push('\n');
    for smp in samples {
        out.push_str(&smp.to_csv());
        out.push('\n');
    }
    write_file(path, &out)
}

/// Table 1 of the paper: rows (workers p) × columns (iteration counts k)
/// of time-to-k, plus the speedup column T_k(1)/T_k(p) at the largest k.
#[derive(Debug, Clone)]
pub struct SpeedupTable {
    pub ks: Vec<usize>,
    /// (p, time_at_k seconds per k in `ks`).
    pub rows: Vec<(usize, Vec<f64>)>,
}

impl SpeedupTable {
    pub fn speedups(&self) -> Vec<(usize, f64)> {
        let Some(base) = self.rows.iter().find(|(p, _)| *p == 1) else {
            return Vec::new();
        };
        let k_last = self.ks.len() - 1;
        self.rows
            .iter()
            .map(|(p, ts)| (*p, base.1[k_last] / ts[k_last].max(1e-12)))
            .collect()
    }

    pub fn to_markdown(&self) -> String {
        let mut md = String::from("| Workers p |");
        for k in &self.ks {
            md.push_str(&format!(" k = {k} |"));
        }
        md.push_str(" Speedup |\n|---|");
        for _ in &self.ks {
            md.push_str("---|");
        }
        md.push_str("---|\n");
        let sp = self.speedups();
        for (p, ts) in &self.rows {
            md.push_str(&format!("| {p} |"));
            for t in ts {
                md.push_str(&format!(" {t:.1} |"));
            }
            let s = sp.iter().find(|(pp, _)| pp == p).map(|(_, s)| *s).unwrap_or(f64::NAN);
            md.push_str(&format!(" {s:.2} |\n"));
        }
        md
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("workers");
        for k in &self.ks {
            out.push_str(&format!(",t_k{k}_s"));
        }
        out.push_str(",speedup\n");
        let sp = self.speedups();
        for (p, ts) in &self.rows {
            out.push_str(&p.to_string());
            for t in ts {
                out.push_str(&format!(",{t:.6}"));
            }
            let s = sp.iter().find(|(pp, _)| pp == p).map(|(_, s)| *s).unwrap_or(f64::NAN);
            out.push_str(&format!(",{s:.4}\n"));
        }
        out
    }
}

/// JSON run record (config summary + headline numbers) for EXPERIMENTS.md
/// provenance.
pub fn run_record(
    experiment: &str,
    config_summary: &str,
    fields: Vec<(&str, f64)>,
) -> Json {
    let mut pairs = vec![("experiment", s(experiment)), ("config", s(config_summary))];
    for (k, v) in fields {
        pairs.push((k, num(v)));
    }
    obj(pairs)
}

pub fn write_file(path: &Path, content: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    }
    std::fs::write(path, content).with_context(|| format!("write {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SpeedupTable {
        SpeedupTable {
            ks: vec![20, 50, 100],
            rows: vec![
                (1, vec![1404.0, 3688.0, 6802.0]),
                (4, vec![363.0, 952.0, 1758.0]),
                (32, vec![47.0, 124.0, 228.0]),
            ],
        }
    }

    #[test]
    fn speedup_matches_paper_arithmetic() {
        // Using the paper's own Table 1 numbers: speedup(32) = 6802/228.
        let sp = table().speedups();
        let s32 = sp.iter().find(|(p, _)| *p == 32).unwrap().1;
        assert!((s32 - 29.83).abs() < 0.01, "{s32}");
        let s1 = sp.iter().find(|(p, _)| *p == 1).unwrap().1;
        assert!((s1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn markdown_and_csv_shapes() {
        let t = table();
        let md = t.to_markdown();
        assert!(md.contains("| Workers p | k = 20 | k = 50 | k = 100 | Speedup |"));
        assert_eq!(md.lines().count(), 2 + 3);
        let csv = t.to_csv();
        assert!(csv.starts_with("workers,t_k20_s,t_k50_s,t_k100_s,speedup"));
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    fn trace_csv_written() {
        let dir = std::env::temp_dir().join("asybadmm_report_test");
        let p = dir.join("trace.csv");
        let samples = vec![ObjSample {
            time_s: 0.5,
            epoch: 10,
            objective: 0.6,
            data_loss: 0.59,
            consensus_max: 0.0,
        }];
        write_trace_csv(&p, &samples).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn run_record_is_valid_json() {
        let r = run_record("table1", "p=4", vec![("speedup", 3.9)]);
        let parsed = Json::parse(&r.to_string_pretty()).unwrap();
        assert_eq!(parsed.req_str("experiment").unwrap(), "table1");
    }
}

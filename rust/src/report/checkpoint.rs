//! Training-state checkpointing: persist/restore the consensus model
//! (and optionally per-worker duals) so long runs survive restarts and
//! trained models ship to serving.
//!
//! Format: a small JSON header (config summary, geometry, seed, epoch)
//! followed by base64-free raw little-endian f32 payload in a sidecar
//! `.bin` file — human-inspectable metadata, zero-copy-ish data.
//!
//! ## Versions
//!
//! * **v1** — consensus z only; sidecar is `dim` f32s.
//! * **v2** (this PR) — adds the survivable-runtime recovery state
//!   (DESIGN.md §2.0.3): the dynamic placement's block→shard owner map,
//!   the per-block applied-push counters (the rebalancer's load
//!   signal), and the per-worker packed dual vectors y_i.  The sidecar
//!   becomes `[z | y_0 | y_1 | ...]`; the header records each dual's
//!   length in `dual_dims` so the payload stays self-describing.
//!
//! `save` always writes v2; `load` accepts both (a v1 header simply
//! yields empty recovery state), so pre-existing checkpoints keep
//! loading.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub config_summary: String,
    pub n_blocks: usize,
    pub block_size: usize,
    pub epoch: usize,
    pub objective: f64,
    pub z: Vec<f32>,
    /// Live block→shard owner map at snapshot time (empty = static
    /// placement or a v1 file; resume keeps the initial map).
    pub block_owners: Vec<usize>,
    /// Per-block applied-push counters (the rebalancer's load signal;
    /// empty = v1 file).
    pub push_counts: Vec<usize>,
    /// Per-worker packed dual vectors y_i (empty = v1 file; lengths may
    /// differ per worker — each is `n_slots * block_size` of its shard).
    pub duals: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// A v2 checkpoint carrying only the consensus model (what the CLI
    /// writes after baselines and the DES, which have no recovery
    /// state).
    pub fn model_only(
        config_summary: String,
        n_blocks: usize,
        block_size: usize,
        epoch: usize,
        objective: f64,
        z: Vec<f32>,
    ) -> Self {
        Checkpoint {
            config_summary,
            n_blocks,
            block_size,
            epoch,
            objective,
            z,
            block_owners: Vec::new(),
            push_counts: Vec::new(),
            duals: Vec::new(),
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        anyhow::ensure!(
            self.z.len() == self.n_blocks * self.block_size,
            "z length {} != geometry {}x{}",
            self.z.len(),
            self.n_blocks,
            self.block_size
        );
        anyhow::ensure!(
            self.block_owners.is_empty() || self.block_owners.len() == self.n_blocks,
            "block_owners length {} != n_blocks {}",
            self.block_owners.len(),
            self.n_blocks
        );
        anyhow::ensure!(
            self.push_counts.is_empty() || self.push_counts.len() == self.n_blocks,
            "push_counts length {} != n_blocks {}",
            self.push_counts.len(),
            self.n_blocks
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let usize_arr =
            |v: &[usize]| Json::Arr(v.iter().map(|&x| num(x as f64)).collect());
        let header = obj(vec![
            ("format", s("asybadmm-checkpoint")),
            ("version", num(2.0)),
            ("config", s(&self.config_summary)),
            ("n_blocks", num(self.n_blocks as f64)),
            ("block_size", num(self.block_size as f64)),
            ("epoch", num(self.epoch as f64)),
            ("objective", num(self.objective)),
            ("dim", num(self.z.len() as f64)),
            ("block_owners", usize_arr(&self.block_owners)),
            ("push_counts", usize_arr(&self.push_counts)),
            (
                "dual_dims",
                Json::Arr(self.duals.iter().map(|d| num(d.len() as f64)).collect()),
            ),
        ]);
        std::fs::write(path, header.to_string_pretty())
            .with_context(|| format!("write {path:?}"))?;
        let bin = path.with_extension("bin");
        let mut f = std::fs::File::create(&bin).with_context(|| format!("create {bin:?}"))?;
        let total = self.z.len() + self.duals.iter().map(Vec::len).sum::<usize>();
        let mut bytes = Vec::with_capacity(total * 4);
        for v in self.z.iter().chain(self.duals.iter().flatten()) {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let header = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        anyhow::ensure!(
            header.req_str("format")? == "asybadmm-checkpoint",
            "not an asybadmm checkpoint"
        );
        let version =
            header.get("version").and_then(Json::as_usize).unwrap_or(1);
        anyhow::ensure!(
            (1..=2).contains(&version),
            "unsupported checkpoint version {version} (this build reads 1-2)"
        );
        let n_blocks = header.req_usize("n_blocks")?;
        let block_size = header.req_usize("block_size")?;
        let dim = header.req_usize("dim")?;
        anyhow::ensure!(dim == n_blocks * block_size, "corrupt header: dim mismatch");

        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            match header.get(key) {
                None | Some(Json::Null) => Ok(Vec::new()),
                Some(j) => j
                    .as_arr()
                    .with_context(|| format!("corrupt header: {key} is not an array"))?
                    .iter()
                    .map(|v| {
                        v.as_usize()
                            .with_context(|| format!("corrupt header: bad entry in {key}"))
                    })
                    .collect(),
            }
        };
        let block_owners = usize_arr("block_owners")?;
        let push_counts = usize_arr("push_counts")?;
        let dual_dims = usize_arr("dual_dims")?;
        anyhow::ensure!(
            block_owners.is_empty() || block_owners.len() == n_blocks,
            "corrupt header: block_owners length {} != n_blocks {n_blocks}",
            block_owners.len()
        );
        anyhow::ensure!(
            push_counts.is_empty() || push_counts.len() == n_blocks,
            "corrupt header: push_counts length {} != n_blocks {n_blocks}",
            push_counts.len()
        );

        let bin = path.with_extension("bin");
        let mut bytes = Vec::new();
        std::fs::File::open(&bin)
            .with_context(|| format!("open checkpoint sidecar {bin:?}"))?
            .read_to_end(&mut bytes)
            .with_context(|| format!("read checkpoint sidecar {bin:?}"))?;
        // Validate the payload against the header BEFORE decoding: a
        // truncated copy or a half-written sidecar must fail loudly with
        // the file named, not deserialize into a silently-short model.
        let total = dim + dual_dims.iter().sum::<usize>();
        anyhow::ensure!(
            bytes.len() == total * 4,
            "checkpoint sidecar {bin:?} is {} bytes but the header promises {} ({} f32s): \
             truncated or corrupt",
            bytes.len(),
            total * 4,
            total
        );
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let z = floats[..dim].to_vec();
        let mut duals = Vec::with_capacity(dual_dims.len());
        let mut off = dim;
        for &d in &dual_dims {
            duals.push(floats[off..off + d].to_vec());
            off += d;
        }
        Ok(Checkpoint {
            config_summary: header.req_str("config")?.to_string(),
            n_blocks,
            block_size,
            epoch: header.req_usize("epoch")?,
            objective: header.get("objective").and_then(Json::as_f64).unwrap_or(f64::NAN),
            z,
            block_owners,
            push_counts,
            duals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("asybadmm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn full(name: &str) -> (Checkpoint, std::path::PathBuf) {
        let mut rng = Rng::new(3);
        let z: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let ck = Checkpoint {
            config_summary: "rho=1.5 gamma=0.01".into(),
            n_blocks: 4,
            block_size: 16,
            epoch: 1234,
            objective: 0.512345,
            z,
            block_owners: vec![0, 1, 1, 0],
            push_counts: vec![10, 200, 3, 0],
            duals: vec![
                (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                (0..48).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            ],
        };
        (ck, tmp(name))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (ck, p) = full("rt.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn model_only_roundtrips_with_empty_recovery_state() {
        let ck = Checkpoint::model_only("g=1".into(), 2, 4, 7, 0.25, vec![0.5; 8]);
        let p = tmp("model_only.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        assert!(back.duals.is_empty());
    }

    #[test]
    fn v1_header_loads_with_empty_recovery_state() {
        // A pre-v2 checkpoint pair, byte-for-byte what the old writer
        // produced: no version-2 arrays, sidecar = dim f32s.
        let p = tmp("v1.ckpt");
        std::fs::write(
            &p,
            r#"{
  "format": "asybadmm-checkpoint",
  "version": 1,
  "config": "legacy",
  "n_blocks": 2,
  "block_size": 4,
  "epoch": 9,
  "objective": 0.5,
  "dim": 8
}"#,
        )
        .unwrap();
        let mut bytes = Vec::new();
        for v in [1.0f32; 8] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(p.with_extension("bin"), bytes).unwrap();
        let ck = Checkpoint::load(&p).unwrap();
        assert_eq!(ck.epoch, 9);
        assert_eq!(ck.z, vec![1.0; 8]);
        assert!(ck.block_owners.is_empty());
        assert!(ck.push_counts.is_empty());
        assert!(ck.duals.is_empty());
    }

    #[test]
    fn rejects_wrong_geometry() {
        let ck = Checkpoint::model_only(String::new(), 2, 4, 0, 0.0, vec![0.0; 7]); // != 8
        assert!(ck.save(&tmp("bad.ckpt")).is_err());
    }

    #[test]
    fn truncated_sidecar_error_names_the_file_and_both_sizes() {
        let (ck, p) = full("trunc.ckpt");
        ck.save(&p).unwrap();
        std::fs::write(p.with_extension("bin"), [0u8; 12]).unwrap();
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("trunc.bin"), "error does not name the sidecar: {err}");
        assert!(err.contains("12 bytes"), "error lacks the actual size: {err}");
        // header promises z (64) + duals (32 + 48) f32s
        assert!(err.contains(&((64 + 32 + 48) * 4).to_string()), "{err}");
        assert!(err.contains("truncated or corrupt"), "{err}");
    }

    #[test]
    fn missing_sidecar_error_names_the_file() {
        let (ck, p) = full("nosidecar.ckpt");
        ck.save(&p).unwrap();
        std::fs::remove_file(p.with_extension("bin")).unwrap();
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("nosidecar.bin"), "{err}");
    }

    #[test]
    fn corrupted_header_is_rejected_not_misread() {
        let (ck, p) = full("bitflip.ckpt");
        ck.save(&p).unwrap();
        // A "bit flip" in the geometry: dim no longer matches
        // n_blocks * block_size.
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, text.replace("\"dim\": 64", "\"dim\": 65")).unwrap();
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("corrupt header"), "{err}");
        // And garbage that no longer parses as JSON names the file.
        std::fs::write(&p, "{\"format\": \"asybadmm-ch\u{0}rupt").unwrap();
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("bitflip.ckpt"), "{err}");
    }

    #[test]
    fn future_version_is_rejected() {
        let (ck, p) = full("future.ckpt");
        ck.save(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, text.replace("\"version\": 2", "\"version\": 3")).unwrap();
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("unsupported checkpoint version 3"), "{err}");
    }

    #[test]
    fn rejects_foreign_json() {
        let p = tmp("foreign.ckpt");
        std::fs::write(&p, "{\"format\": \"something-else\"}").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}

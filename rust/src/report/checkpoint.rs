//! Training-state checkpointing: persist/restore the consensus model
//! (and optionally per-worker duals) so long runs survive restarts and
//! trained models ship to serving.
//!
//! Format: a small JSON header (config summary, geometry, seed, epoch)
//! followed by base64-free raw little-endian f32 payload in a sidecar
//! `.bin` file — human-inspectable metadata, zero-copy-ish data.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub config_summary: String,
    pub n_blocks: usize,
    pub block_size: usize,
    pub epoch: usize,
    pub objective: f64,
    pub z: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        anyhow::ensure!(
            self.z.len() == self.n_blocks * self.block_size,
            "z length {} != geometry {}x{}",
            self.z.len(),
            self.n_blocks,
            self.block_size
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let header = obj(vec![
            ("format", s("asybadmm-checkpoint")),
            ("version", num(1.0)),
            ("config", s(&self.config_summary)),
            ("n_blocks", num(self.n_blocks as f64)),
            ("block_size", num(self.block_size as f64)),
            ("epoch", num(self.epoch as f64)),
            ("objective", num(self.objective)),
            ("dim", num(self.z.len() as f64)),
        ]);
        std::fs::write(path, header.to_string_pretty())
            .with_context(|| format!("write {path:?}"))?;
        let bin = path.with_extension("bin");
        let mut f = std::fs::File::create(&bin).with_context(|| format!("create {bin:?}"))?;
        let mut bytes = Vec::with_capacity(self.z.len() * 4);
        for v in &self.z {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let header = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        anyhow::ensure!(
            header.req_str("format")? == "asybadmm-checkpoint",
            "not an asybadmm checkpoint"
        );
        let n_blocks = header.req_usize("n_blocks")?;
        let block_size = header.req_usize("block_size")?;
        let dim = header.req_usize("dim")?;
        anyhow::ensure!(dim == n_blocks * block_size, "corrupt header: dim mismatch");

        let bin = path.with_extension("bin");
        let mut bytes = Vec::new();
        std::fs::File::open(&bin)
            .with_context(|| format!("open {bin:?}"))?
            .read_to_end(&mut bytes)?;
        anyhow::ensure!(
            bytes.len() == dim * 4,
            "payload size {} != expected {}",
            bytes.len(),
            dim * 4
        );
        let z = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint {
            config_summary: header.req_str("config")?.to_string(),
            n_blocks,
            block_size,
            epoch: header.req_usize("epoch")?,
            objective: header.get("objective").and_then(Json::as_f64).unwrap_or(f64::NAN),
            z,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("asybadmm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Rng::new(3);
        let z: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let ck = Checkpoint {
            config_summary: "rho=1.5 gamma=0.01".into(),
            n_blocks: 4,
            block_size: 16,
            epoch: 1234,
            objective: 0.512345,
            z,
        };
        let p = tmp("rt.ckpt");
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn rejects_wrong_geometry() {
        let ck = Checkpoint {
            config_summary: String::new(),
            n_blocks: 2,
            block_size: 4,
            epoch: 0,
            objective: 0.0,
            z: vec![0.0; 7], // != 8
        };
        assert!(ck.save(&tmp("bad.ckpt")).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let ck = Checkpoint {
            config_summary: String::new(),
            n_blocks: 2,
            block_size: 4,
            epoch: 5,
            objective: 0.1,
            z: vec![1.0; 8],
        };
        let p = tmp("trunc.ckpt");
        ck.save(&p).unwrap();
        std::fs::write(p.with_extension("bin"), [0u8; 12]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn rejects_foreign_json() {
        let p = tmp("foreign.ckpt");
        std::fs::write(&p, "{\"format\": \"something-else\"}").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}

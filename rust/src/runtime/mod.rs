//! PJRT runtime (S4): load AOT HLO-text artifacts, compile once per
//! thread, execute from the L3 hot path.
//!
//! The `xla` crate's types are `Rc`-based (!Send), so an [`XlaEngine`]
//! must live and die on one thread; each worker/server thread constructs
//! its own from the shared [`Manifest`] (file parsing is cheap; XLA
//! compilation of these small modules takes milliseconds).

mod engine;
mod manifest;

pub use engine::{ServerProxXla, WorkerXla, XlaEngine};
pub use manifest::{ArtifactEntry, Manifest};

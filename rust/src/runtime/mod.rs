//! PJRT runtime (S4): load AOT HLO-text artifacts, compile once per
//! thread, execute from the L3 hot path.
//!
//! The `xla` crate's types are `Rc`-based (!Send), so an [`XlaEngine`]
//! must live and die on one thread; each worker/server thread constructs
//! its own from the shared [`Manifest`] (file parsing is cheap; XLA
//! compilation of these small modules takes milliseconds).
//!
//! The real engine needs the `xla` crate (xla-rs), which is not
//! available in the offline build environment.  It is therefore gated
//! behind the `xla` cargo feature; the default build compiles
//! `engine_stub.rs` — identical API, every constructor returns an error
//! — so the coordinator's native fallback kicks in and the whole crate
//! builds and tests without the dependency (DESIGN.md
//! "environment-driven design decisions").

#[cfg(feature = "xla")]
mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
mod engine;
mod manifest;

pub use engine::{ServerProxXla, WorkerXla, XlaEngine};
pub use manifest::{ArtifactEntry, Manifest};

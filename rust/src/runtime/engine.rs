//! XLA execution engines: compile the HLO-text artifacts on a per-thread
//! PJRT CPU client and run them with device-resident data buffers.
//!
//! Hot-path discipline: the worker's data chunks (the big `A` matrices)
//! are transferred to the device once at construction; per-iteration
//! calls upload only the small dynamic inputs (z_local, y block, scalars)
//! and download only the small outputs (w/y/x blocks + loss scalar).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::WorkerShard;
use crate::runtime::Manifest;

/// Per-thread compiled artifact set for one (kind, shape set).
pub struct XlaEngine {
    pub client: xla::PjRtClient,
    worker_step: xla::PjRtLoadedExecutable,
    grad_chunk: xla::PjRtLoadedExecutable,
    worker_update: xla::PjRtLoadedExecutable,
    server_prox: xla::PjRtLoadedExecutable,
    objective: xla::PjRtLoadedExecutable,
    pub m_chunk: usize,
    pub d_pad: usize,
    pub db: usize,
}

fn compile(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .with_context(|| format!("parse HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("XLA compile {path:?}"))
}

impl XlaEngine {
    /// Compile all five entry points for `kind` ("logistic"|"squared")
    /// at shape (m_chunk, d_pad, db). One per thread — `xla` types are
    /// not `Send`.
    pub fn new(
        manifest: &Manifest,
        kind: &str,
        m_chunk: usize,
        d_pad: usize,
        db: usize,
    ) -> Result<Rc<Self>> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let find = |entry: &str| -> Result<xla::PjRtLoadedExecutable> {
            let e = manifest.find(entry, Some(kind), m_chunk, d_pad, db)?;
            compile(&client, &e.path)
        };
        Ok(Rc::new(XlaEngine {
            worker_step: find("worker_step")?,
            grad_chunk: find("grad_chunk")?,
            worker_update: find("worker_update")?,
            server_prox: find("server_prox")?,
            objective: find("objective")?,
            client,
            m_chunk,
            d_pad,
            db,
        }))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Eq. 13 server update via the `server_prox` artifact.
    pub fn server_prox(
        &self,
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(z_tilde.len(), self.db);
        let args = [
            self.upload_f32(z_tilde, &[self.db])?,
            self.upload_f32(w_sum, &[self.db])?,
            self.upload_f32(&[gamma], &[1])?,
            self.upload_f32(&[denom], &[1])?,
            self.upload_f32(&[lambda], &[1])?,
            self.upload_f32(&[clip], &[1])?,
        ];
        let out = self.server_prox.execute_b(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Eq. 9/11/12 epilogue via the `worker_update` artifact.
    pub fn worker_update(
        &self,
        g: &[f32],
        y: &[f32],
        z_blk: &[f32],
        rho: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let args = [
            self.upload_f32(g, &[self.db])?,
            self.upload_f32(y, &[self.db])?,
            self.upload_f32(z_blk, &[self.db])?,
            self.upload_f32(&[rho], &[1])?,
        ];
        let out = self.worker_update.execute_b(&args)?[0][0].to_literal_sync()?;
        let (w, y_new, x) = out.to_tuple3()?;
        Ok((w.to_vec::<f32>()?, y_new.to_vec::<f32>()?, x.to_vec::<f32>()?))
    }
}

/// One device-resident data chunk of a worker shard.
struct Chunk {
    a: xla::PjRtBuffer,
    labels: xla::PjRtBuffer,
    weights: xla::PjRtBuffer,
}

/// A worker's XLA execution context: engine + chunked device data.
///
/// PERF (EXPERIMENTS.md §Perf, L3): besides the data chunks, the
/// per-slot offset literals and the ρ scalar are uploaded once at
/// construction — the per-iteration uploads are only z_local and the
/// y block.
pub struct WorkerXla {
    pub engine: Rc<XlaEngine>,
    chunks: Vec<Chunk>,
    /// Scratch for padding the packed z to d_pad.
    z_pad: Vec<f32>,
    /// Device-resident block offsets, one per packed slot.
    offsets: Vec<xla::PjRtBuffer>,
    /// Device-resident ρ (invalidated if a different ρ is requested).
    rho_buf: Option<(f32, xla::PjRtBuffer)>,
}

impl WorkerXla {
    /// Densify the shard into `ceil(m / m_chunk)` row chunks of width
    /// d_pad (zero rows weighted 0 pad the tail) and park them on device.
    pub fn new(engine: Rc<XlaEngine>, shard: &WorkerShard, sample_weight: f32) -> Result<Self> {
        let (mc, dp) = (engine.m_chunk, engine.d_pad);
        anyhow::ensure!(
            shard.packed_dim() <= dp,
            "worker {} packed dim {} exceeds artifact d_pad {}",
            shard.worker_id,
            shard.packed_dim(),
            dp
        );
        let m = shard.samples();
        let n_chunks = m.div_ceil(mc).max(1);
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut a_host = vec![0.0f32; mc * dp];
        for c in 0..n_chunks {
            let lo = c * mc;
            let hi = ((c + 1) * mc).min(m);
            a_host.fill(0.0);
            for r in lo..hi {
                let (idx, vals) = shard.a_packed.row(r);
                let base = (r - lo) * dp;
                for (&j, &v) in idx.iter().zip(vals) {
                    a_host[base + j as usize] = v;
                }
            }
            let mut labels = vec![1.0f32; mc];
            labels[..hi - lo].copy_from_slice(&shard.labels[lo..hi]);
            let mut weights = vec![0.0f32; mc];
            weights[..hi - lo].fill(sample_weight);
            chunks.push(Chunk {
                a: engine.upload_f32(&a_host, &[mc, dp])?,
                labels: engine.upload_f32(&labels, &[mc])?,
                weights: engine.upload_f32(&weights, &[mc])?,
            });
        }
        let db = engine.db;
        let n_slots = shard.n_slots();
        let mut offsets = Vec::with_capacity(n_slots);
        for slot in 0..n_slots {
            let off = [(slot * db) as i32];
            offsets.push(engine.client.buffer_from_host_buffer(&off, &[1], None)?);
        }
        Ok(WorkerXla { engine, chunks, z_pad: vec![0.0f32; dp], offsets, rho_buf: None })
    }

    fn rho_buffer(&mut self, rho: f32) -> Result<&xla::PjRtBuffer> {
        let stale = !matches!(&self.rho_buf, Some((r, _)) if *r == rho);
        if stale {
            let buf = self.engine.upload_f32(&[rho], &[1])?;
            self.rho_buf = Some((rho, buf));
        }
        Ok(&self.rho_buf.as_ref().unwrap().1)
    }

    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    fn pad_z(&mut self, z_local: &[f32]) {
        self.z_pad.fill(0.0);
        self.z_pad[..z_local.len()].copy_from_slice(z_local);
    }

    /// Fused worker iteration (Algorithm 1 lines 5-7 numerics): returns
    /// (w_blk, y_new, x_blk, shard data loss at z̃).
    ///
    /// Single-chunk shards use the fused `worker_step` artifact; larger
    /// shards run `grad_chunk` per chunk, reduce on host (db floats), and
    /// finish with the `worker_update` artifact.
    pub fn step(
        &mut self,
        z_local: &[f32],
        y_blk: &[f32],
        slot: usize,
        rho: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let eng = self.engine.clone();
        let db = eng.db;
        self.pad_z(z_local);
        if self.chunks.len() == 1 {
            let z_buf = eng.upload_f32(&self.z_pad, &[eng.d_pad])?;
            let y_buf = eng.upload_f32(y_blk, &[db])?;
            self.rho_buffer(rho)?; // refresh cache before sharing borrows
            let rho_buf = &self.rho_buf.as_ref().unwrap().1;
            let c = &self.chunks[0];
            let args =
                [&c.a, &c.labels, &c.weights, &z_buf, &y_buf, &self.offsets[slot], rho_buf];
            let out = eng.worker_step.execute_b(&args)?[0][0].to_literal_sync()?;
            let (w, y_new, x, loss) = out.to_tuple4()?;
            return Ok((
                w.to_vec::<f32>()?,
                y_new.to_vec::<f32>()?,
                x.to_vec::<f32>()?,
                loss.to_vec::<f32>()?[0],
            ));
        }
        let (g, loss) = self.grad_block_inner(slot)?;
        let z_blk = &self.z_pad[slot * db..(slot + 1) * db];
        let (w, y_new, x) = eng.worker_update(&g, y_blk, z_blk, rho)?;
        Ok((w, y_new, x, loss))
    }

    /// Block gradient + loss at z̃ (multi-chunk reduction).
    pub fn grad_block(&mut self, z_local: &[f32], slot: usize) -> Result<(Vec<f32>, f32)> {
        self.pad_z(z_local);
        self.grad_block_inner(slot)
    }

    fn grad_block_inner(&mut self, slot: usize) -> Result<(Vec<f32>, f32)> {
        let eng = self.engine.clone();
        let db = eng.db;
        let z_buf = eng.upload_f32(&self.z_pad, &[eng.d_pad])?;
        let off_buf = &self.offsets[slot];
        let mut g = vec![0.0f32; db];
        let mut loss = 0.0f32;
        for c in &self.chunks {
            let args = [&c.a, &c.labels, &c.weights, &z_buf, off_buf];
            let out = eng.grad_chunk.execute_b(&args)?[0][0].to_literal_sync()?;
            let (gc, lc) = out.to_tuple2()?;
            let gc = gc.to_vec::<f32>()?;
            for (acc, v) in g.iter_mut().zip(&gc) {
                *acc += v;
            }
            loss += lc.to_vec::<f32>()?[0];
        }
        Ok((g, loss))
    }

    /// Shard data loss at an arbitrary packed point (objective artifact).
    pub fn data_loss(&mut self, x_local: &[f32]) -> Result<f32> {
        let eng = self.engine.clone();
        self.pad_z(x_local);
        let x_buf = eng.upload_f32(&self.z_pad, &[eng.d_pad])?;
        let mut loss = 0.0f32;
        for c in &self.chunks {
            let args = [&c.a, &c.labels, &c.weights, &x_buf];
            let out = eng.objective.execute_b(&args)?[0][0].to_literal_sync()?;
            loss += out.to_tuple1()?.to_vec::<f32>()?[0];
        }
        Ok(loss)
    }
}

/// Server-side prox context: a standalone client + the single
/// `server_prox` executable (server threads don't need the worker
/// artifacts, so this avoids compiling them).
pub struct ServerProxXla {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    db: usize,
}

impl ServerProxXla {
    /// Compile just the prox artifact for block size `db`.
    pub fn load(manifest: &Manifest, db: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let e = manifest.find("server_prox", None, 0, 0, db)?;
        let exe = compile(&client, &e.path)?;
        Ok(ServerProxXla { client, exe, db })
    }

    pub fn prox(
        &self,
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(z_tilde.len(), self.db);
        let up = |d: &[f32], dims: &[usize]| self.client.buffer_from_host_buffer(d, dims, None);
        let args = [
            up(z_tilde, &[self.db])?,
            up(w_sum, &[self.db])?,
            up(&[gamma], &[1])?,
            up(&[denom], &[1])?,
            up(&[lambda], &[1])?,
            up(&[clip], &[1])?,
        ];
        let out = self.exe.execute_b(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }
}

//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime.  Thread-safe (plain data), shared across worker
//! threads; each thread compiles its own executables from the files.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    /// One of: worker_step, grad_chunk, objective, worker_update,
    /// server_prox.
    pub entry: String,
    /// "logistic" | "squared" | "any".
    pub kind: String,
    pub m_chunk: usize,
    pub d_pad: usize,
    pub db: usize,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("read {path:?} — run `make artifacts` first")
        })?;
        let root = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        anyhow::ensure!(
            root.req_usize("version")? == 1,
            "unsupported manifest version"
        );
        let mut entries = Vec::new();
        for e in root.req_arr("entries")? {
            let entry = ArtifactEntry {
                name: e.req_str("name")?.to_string(),
                path: dir.join(e.req_str("file")?),
                entry: e.req_str("entry")?.to_string(),
                kind: e.req_str("kind")?.to_string(),
                m_chunk: e.req_usize("m_chunk")?,
                d_pad: e.req_usize("d_pad")?,
                db: e.req_usize("db")?,
                n_inputs: e.req_arr("inputs")?.len(),
                n_outputs: e.req_arr("outputs")?.len(),
            };
            anyhow::ensure!(
                entry.path.exists(),
                "manifest references missing artifact {:?}",
                entry.path
            );
            entries.push(entry);
        }
        anyhow::ensure!(!entries.is_empty(), "empty manifest");
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find the artifact for an entry point + loss kind + shape triple.
    pub fn find(
        &self,
        entry: &str,
        kind: Option<&str>,
        m_chunk: usize,
        d_pad: usize,
        db: usize,
    ) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| {
                e.entry == entry
                    && kind.is_none_or(|k| e.kind == k || e.kind == "any")
                    && (e.entry == "worker_update" || e.entry == "server_prox"
                        || (e.m_chunk == m_chunk && e.d_pad == d_pad))
                    && e.db == db
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for entry={entry} kind={kind:?} m_chunk={m_chunk} \
                     d_pad={d_pad} db={db}; have: {:?}. Re-run `make artifacts` \
                     with a matching shape set.",
                    self.entries.iter().map(|e| &e.name).collect::<Vec<_>>()
                )
            })
    }

    /// Shape sets present (distinct (m_chunk, d_pad, db) triples of
    /// worker_step entries).
    pub fn shape_sets(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self
            .entries
            .iter()
            .filter(|e| e.entry == "worker_step")
            .map(|e| (e.m_chunk, e.d_pad, e.db))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_repo_manifest_when_built() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        assert!(!m.entries.is_empty());
        // tiny set must exist for the integration tests
        let e = m.find("worker_step", Some("logistic"), 32, 64, 16).unwrap();
        assert_eq!(e.n_inputs, 7);
        assert_eq!(e.n_outputs, 4);
        let p = m.find("server_prox", None, 32, 64, 16).unwrap();
        assert_eq!(p.n_inputs, 6);
        assert!(!m.shape_sets().is_empty());
    }

    #[test]
    fn missing_dir_errors_with_hint() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

//! Stub XLA engines, compiled when the `xla` cargo feature is off (the
//! offline default).  Same API surface as `engine.rs`; every constructor
//! fails with a clear message so callers fall back to the native
//! backend (the server thread does this automatically, the worker path
//! surfaces the error).  This keeps every test, bench and example
//! compiling without the `xla` crate — the artifact-parity tests skip
//! themselves when no manifest is present, which is always the case in
//! an environment that cannot build the real engine.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::data::WorkerShard;
use crate::runtime::Manifest;

const UNAVAILABLE: &str =
    "XLA/PJRT runtime not compiled in: rebuild with `--features xla` (needs the vendored `xla` \
     crate) and run `make artifacts`; the native backend needs neither";

/// Per-thread compiled artifact set for one (kind, shape set) — stub.
pub struct XlaEngine {
    pub m_chunk: usize,
    pub d_pad: usize,
    pub db: usize,
}

impl XlaEngine {
    pub fn new(
        _manifest: &Manifest,
        _kind: &str,
        _m_chunk: usize,
        _d_pad: usize,
        _db: usize,
    ) -> Result<Rc<Self>> {
        bail!(UNAVAILABLE)
    }
}

/// A worker's XLA execution context — stub (unconstructable: the engine
/// constructor above always fails first).
pub struct WorkerXla {
    _engine: Rc<XlaEngine>,
}

impl WorkerXla {
    pub fn new(_engine: Rc<XlaEngine>, _shard: &WorkerShard, _sample_weight: f32) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn n_chunks(&self) -> usize {
        0
    }

    pub fn step(
        &mut self,
        _z_local: &[f32],
        _y_blk: &[f32],
        _slot: usize,
        _rho: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        bail!(UNAVAILABLE)
    }

    pub fn grad_block(&mut self, _z_local: &[f32], _slot: usize) -> Result<(Vec<f32>, f32)> {
        bail!(UNAVAILABLE)
    }

    pub fn data_loss(&mut self, _x_local: &[f32]) -> Result<f32> {
        bail!(UNAVAILABLE)
    }
}

/// Server-side prox context — stub.
pub struct ServerProxXla {
    _db: usize,
}

impl ServerProxXla {
    pub fn load(_manifest: &Manifest, _db: usize) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn prox(
        &self,
        _z_tilde: &[f32],
        _w_sum: &[f32],
        _gamma: f32,
        _denom: f32,
        _lambda: f32,
        _clip: f32,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}

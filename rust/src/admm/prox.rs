//! Proximal operators — native mirror of the L1 Pallas prox kernel
//! (`python/compile/kernels/prox.py`), bit-compatible in f32 up to
//! rounding.  The server update (paper Eq. 13) is
//!
//! ```text
//! z_j <- prox_h^mu( (gamma*z~_j + sum_i w~_ij) / mu ),  mu = gamma + sum_i rho_i
//! ```
//!
//! with h = λ‖·‖₁ + box(C), whose prox is soft-threshold then clip.
//!
//! The hot entry points ([`prox_l1_box`], [`add_assign_diff`]) run
//! 4-wide unrolled inner loops (ROADMAP "SIMD prox"): `chunks_exact(4)`
//! bodies with no cross-lane dependence, which LLVM turns into packed
//! SSE/NEON ops.  Both operators are purely element-wise, so the
//! unrolled forms compute exactly the same f32 expression per element as
//! the `_scalar` references — the `server_prox` bench gates on
//! bit-identity, not approximate agreement.

#[inline]
pub fn soft_threshold(v: f32, thr: f32) -> f32 {
    v.signum() * (v.abs() - thr).max(0.0)
}

/// One element of Eq. 13: `clip(soft((γ z̃ + w) / denom, λ/denom), ±C)`.
/// Single source of truth for both the scalar and unrolled paths (so
/// bit-identity between them is by construction, and stays that way).
/// The division is kept (not strength-reduced to a reciprocal multiply)
/// so results are bit-identical to the pre-unrolled implementation too;
/// `divps` vectorizes the same way.
#[inline(always)]
fn prox_elem(zt: f32, ws: f32, gamma: f32, denom: f32, thr: f32, clip: f32) -> f32 {
    let v = (gamma * zt + ws) / denom;
    soft_threshold(v, thr).clamp(-clip, clip)
}

/// In-place Eq. 13: `z[k] = clip(soft((γ z̃[k] + w_sum[k]) / denom, λ/denom), ±C)`.
///
/// 4-wide unrolled hot path; [`prox_l1_box_scalar`] is the plain-loop
/// reference it must match bit for bit.
pub fn prox_l1_box(
    z_tilde: &[f32],
    w_sum: &[f32],
    gamma: f32,
    denom: f32,
    lambda: f32,
    clip: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(z_tilde.len(), w_sum.len());
    debug_assert_eq!(z_tilde.len(), out.len());
    debug_assert!(denom > 0.0);
    let thr = lambda / denom;
    let mut o4 = out.chunks_exact_mut(4);
    let mut z4 = z_tilde.chunks_exact(4);
    let mut w4 = w_sum.chunks_exact(4);
    for ((o, zt), ws) in (&mut o4).zip(&mut z4).zip(&mut w4) {
        o[0] = prox_elem(zt[0], ws[0], gamma, denom, thr, clip);
        o[1] = prox_elem(zt[1], ws[1], gamma, denom, thr, clip);
        o[2] = prox_elem(zt[2], ws[2], gamma, denom, thr, clip);
        o[3] = prox_elem(zt[3], ws[3], gamma, denom, thr, clip);
    }
    for ((o, &zt), &ws) in o4
        .into_remainder()
        .iter_mut()
        .zip(z4.remainder())
        .zip(w4.remainder())
    {
        *o = prox_elem(zt, ws, gamma, denom, thr, clip);
    }
}

/// Plain-loop reference for [`prox_l1_box`]; the `server_prox` bench
/// asserts the unrolled path is bit-identical to this one.
pub fn prox_l1_box_scalar(
    z_tilde: &[f32],
    w_sum: &[f32],
    gamma: f32,
    denom: f32,
    lambda: f32,
    clip: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(z_tilde.len(), w_sum.len());
    debug_assert_eq!(z_tilde.len(), out.len());
    debug_assert!(denom > 0.0);
    let thr = lambda / denom;
    for ((o, &zt), &ws) in out.iter_mut().zip(z_tilde).zip(w_sum) {
        *o = prox_elem(zt, ws, gamma, denom, thr, clip);
    }
}

/// The server's w̃-sum maintenance (Eq. 13 incremental form):
/// `sum[k] += new[k] - old[k]`, 4-wide unrolled.  Element-wise with no
/// reduction, so unrolling cannot reorder any f32 addition —
/// [`add_assign_diff_scalar`] is bit-identical by construction.
pub fn add_assign_diff(sum: &mut [f32], new: &[f32], old: &[f32]) {
    debug_assert_eq!(sum.len(), new.len());
    debug_assert_eq!(sum.len(), old.len());
    let mut s4 = sum.chunks_exact_mut(4);
    let mut n4 = new.chunks_exact(4);
    let mut o4 = old.chunks_exact(4);
    for ((s, n), o) in (&mut s4).zip(&mut n4).zip(&mut o4) {
        s[0] += n[0] - o[0];
        s[1] += n[1] - o[1];
        s[2] += n[2] - o[2];
        s[3] += n[3] - o[3];
    }
    for ((s, &n), &o) in s4
        .into_remainder()
        .iter_mut()
        .zip(n4.remainder())
        .zip(o4.remainder())
    {
        *s += n - o;
    }
}

/// Plain-loop reference for [`add_assign_diff`].
pub fn add_assign_diff_scalar(sum: &mut [f32], new: &[f32], old: &[f32]) {
    debug_assert_eq!(sum.len(), new.len());
    debug_assert_eq!(sum.len(), old.len());
    for ((s, &n), &o) in sum.iter_mut().zip(new).zip(old) {
        *s += n - o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }

    #[test]
    fn prox_analytic_case() {
        // gamma=1, denom=2, lam=0.4 => thr=0.2
        // v = (1*1.0 + 1.0)/2 = 1.0 -> soft 0.8
        let mut out = [0.0f32; 1];
        prox_l1_box(&[1.0], &[1.0], 1.0, 2.0, 0.4, 10.0, &mut out);
        assert!((out[0] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn prox_clips_to_box() {
        let mut out = [0.0f32; 2];
        prox_l1_box(&[1e6, -1e6], &[0.0, 0.0], 1.0, 1.0, 0.0, 3.0, &mut out);
        assert_eq!(out, [3.0, -3.0]);
    }

    #[test]
    fn prox_zero_lambda_is_projection_of_average() {
        // lam=0: out = clip((gamma z + w)/denom)
        let mut out = [0.0f32; 1];
        prox_l1_box(&[2.0], &[4.0], 0.5, 2.5, 0.0, 100.0, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6); // (1 + 4)/2.5
    }

    #[test]
    fn unrolled_prox_bit_identical_to_scalar_all_lengths() {
        // Cover every remainder length 0..3 and both sides of the
        // threshold/clip, across many random vectors.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for db in [1usize, 2, 3, 4, 5, 7, 8, 15, 16, 64, 257] {
            for _ in 0..20 {
                let zt: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
                let ws: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
                let gamma = rng.f32() * 2.0;
                let denom = 0.1 + rng.f32() * 20.0;
                let lambda = rng.f32();
                let clip = 0.5 + rng.f32() * 4.0;
                let mut fast = vec![0.0f32; db];
                let mut slow = vec![0.0f32; db];
                prox_l1_box(&zt, &ws, gamma, denom, lambda, clip, &mut fast);
                prox_l1_box_scalar(&zt, &ws, gamma, denom, lambda, clip, &mut slow);
                for (a, b) in fast.iter().zip(&slow) {
                    assert_eq!(a.to_bits(), b.to_bits(), "db={db}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn unrolled_add_assign_diff_bit_identical_to_scalar() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for db in [1usize, 3, 4, 6, 8, 13, 64] {
            let base: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let new: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let old: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let mut fast = base.clone();
            let mut slow = base.clone();
            add_assign_diff(&mut fast, &new, &old);
            add_assign_diff_scalar(&mut slow, &new, &old);
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(a.to_bits(), b.to_bits(), "db={db}");
            }
        }
    }

    #[test]
    fn prox_nonexpansive() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let u: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let v: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let zero = vec![0.0f32; 8];
            let (mut pu, mut pv) = (vec![0.0f32; 8], vec![0.0f32; 8]);
            prox_l1_box(&zero, &u, 0.0, 1.0, 0.3, 50.0, &mut pu);
            prox_l1_box(&zero, &v, 0.0, 1.0, 0.3, 50.0, &mut pv);
            let d_in: f32 = u.iter().zip(&v).map(|(a, b)| (a - b).powi(2)).sum();
            let d_out: f32 = pu.iter().zip(&pv).map(|(a, b)| (a - b).powi(2)).sum();
            assert!(d_out <= d_in + 1e-5);
        }
    }
}

//! Proximal operators — native mirror of the L1 Pallas prox kernel
//! (`python/compile/kernels/prox.py`), bit-compatible in f32 up to
//! rounding.  The server update (paper Eq. 13) is
//!
//! ```text
//! z_j <- prox_h^mu( (gamma*z~_j + sum_i w~_ij) / mu ),  mu = gamma + sum_i rho_i
//! ```
//!
//! with h = λ‖·‖₁ + box(C), whose prox is soft-threshold then clip.

#[inline]
pub fn soft_threshold(v: f32, thr: f32) -> f32 {
    v.signum() * (v.abs() - thr).max(0.0)
}

/// In-place Eq. 13: `z[k] = clip(soft((γ z̃[k] + w_sum[k]) / denom, λ/denom), ±C)`.
pub fn prox_l1_box(
    z_tilde: &[f32],
    w_sum: &[f32],
    gamma: f32,
    denom: f32,
    lambda: f32,
    clip: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(z_tilde.len(), w_sum.len());
    debug_assert_eq!(z_tilde.len(), out.len());
    debug_assert!(denom > 0.0);
    let thr = lambda / denom;
    for ((o, &zt), &ws) in out.iter_mut().zip(z_tilde).zip(w_sum) {
        let v = (gamma * zt + ws) / denom;
        *o = soft_threshold(v, thr).clamp(-clip, clip);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }

    #[test]
    fn prox_analytic_case() {
        // gamma=1, denom=2, lam=0.4 => thr=0.2
        // v = (1*1.0 + 1.0)/2 = 1.0 -> soft 0.8
        let mut out = [0.0f32; 1];
        prox_l1_box(&[1.0], &[1.0], 1.0, 2.0, 0.4, 10.0, &mut out);
        assert!((out[0] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn prox_clips_to_box() {
        let mut out = [0.0f32; 2];
        prox_l1_box(&[1e6, -1e6], &[0.0, 0.0], 1.0, 1.0, 0.0, 3.0, &mut out);
        assert_eq!(out, [3.0, -3.0]);
    }

    #[test]
    fn prox_zero_lambda_is_projection_of_average() {
        // lam=0: out = clip((gamma z + w)/denom)
        let mut out = [0.0f32; 1];
        prox_l1_box(&[2.0], &[4.0], 0.5, 2.5, 0.0, 100.0, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6); // (1 + 4)/2.5
    }

    #[test]
    fn prox_nonexpansive() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let u: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let v: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 5.0)).collect();
            let zero = vec![0.0f32; 8];
            let (mut pu, mut pv) = (vec![0.0f32; 8], vec![0.0f32; 8]);
            prox_l1_box(&zero, &u, 0.0, 1.0, 0.3, 50.0, &mut pu);
            prox_l1_box(&zero, &v, 0.0, 1.0, 0.3, 50.0, &mut pv);
            let d_in: f32 = u.iter().zip(&v).map(|(a, b)| (a - b).powi(2)).sum();
            let d_out: f32 = pu.iter().zip(&pv).map(|(a, b)| (a - b).powi(2)).sum();
            assert!(d_out <= d_in + 1e-5);
        }
    }
}

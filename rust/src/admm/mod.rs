//! ADMM math core (S3): update rules (paper Eqs. 9, 11-13), proximal
//! operators, Theorem-1 penalty feasibility, and convergence metrics
//! (Eq. 14 stationarity residual).  Everything here is coordinator-free
//! pure math, reusable by the threaded runtime, the DES simulator, and
//! the baselines.

mod metrics;
mod native;
mod penalty;
mod prox;
mod state;

pub use metrics::{consensus_gap, gather_packed, objective_at_z, stationarity_residual, Objective};
pub use native::{worker_update, NativeEngine};
pub use penalty::{check_theorem1, estimate_block_lipschitz, suggest_gamma, Theorem1Report};
pub use prox::{
    add_assign_diff, add_assign_diff_scalar, prox_l1_box, prox_l1_box_scalar, soft_threshold,
};
pub use state::WorkerState;

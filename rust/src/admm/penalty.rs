//! Theorem-1 hyper-parameter feasibility (paper Eqs. 16-18).
//!
//! The theorem requires, for every server block j and worker i,
//!
//!   α_j = (γ + ρ) − Σ_{i∈𝒩(j)} (1/2 + 1/ρ_i) L_ij² (T_ij+1)²
//!                  − Σ_{i∈𝒩(j)} (4L_ij + ρ_i + 1) T_ij² / 2  > 0     (17)
//!   β_i = (ρ_i − 4 max_{j∈𝒩(i)} L_ij) / (2|𝒩(i)|)            > 0     (18)
//!
//! These are *sufficient* conditions and (as in the paper's own
//! experiments, which use γ = 0.01) wildly conservative in practice; the
//! checker reports both the strict verdict and the practical
//! recommendation, and the driver logs it at startup.

use crate::data::WorkerShard;
use crate::problem::Problem;

/// Upper-bound estimate of the block Lipschitz constants L_ij
/// (Assumption 1) for worker i: for a generalized linear loss with
/// curvature bound c (= max φ''), ‖∇_j f(u) − ∇_j f(v)‖ ≤
/// weight·c·σ_max(A_j)²·‖u_j − v_j‖ ≤ weight·c·‖A_j‖_F²·‖u_j − v_j‖.
/// Returns one L per packed slot.
pub fn estimate_block_lipschitz(
    shard: &WorkerShard,
    problem: &Problem,
    sample_weight: f32,
) -> Vec<f64> {
    let c = problem.curvature_bound() as f64 * sample_weight as f64;
    let mut frob2 = vec![0.0f64; shard.n_slots()];
    for r in 0..shard.a_packed.rows() {
        let (idx, vals) = shard.a_packed.row(r);
        for (&col, &v) in idx.iter().zip(vals) {
            frob2[col as usize / shard.block_size] += (v as f64) * (v as f64);
        }
    }
    frob2.iter().map(|f| c * f).collect()
}

#[derive(Debug, Clone)]
pub struct Theorem1Report {
    /// α_j per global block (Eq. 17); only blocks with 𝒩(j) ≠ ∅.
    pub alpha: Vec<(usize, f64)>,
    /// β_i per worker (Eq. 18).
    pub beta: Vec<f64>,
    pub min_alpha: f64,
    pub min_beta: f64,
    /// Strict Theorem-1 feasibility.
    pub feasible: bool,
    /// γ that would make min α_j = margin > 0 with everything else fixed.
    pub gamma_needed: f64,
    /// ρ that would make all β_i > 0.
    pub rho_needed: f64,
}

/// Evaluate Eqs. 16-18 for uniform ρ_i = ρ and uniform delay bound T.
pub fn check_theorem1(
    shards: &[&WorkerShard],
    problem: &Problem,
    n_blocks: usize,
    rho: f64,
    gamma: f64,
    delay_bound: usize,
) -> Theorem1Report {
    let t = delay_bound as f64;
    // Per-block accumulators over i ∈ 𝒩(j).
    let mut alpha_penalty = vec![0.0f64; n_blocks];
    let mut block_used = vec![false; n_blocks];
    let mut beta = Vec::with_capacity(shards.len());
    let mut max_l_all: f64 = 0.0;

    for shard in shards {
        // f_i = local mean loss => weight 1/m_i.
        let w_i = 1.0 / shard.samples().max(1) as f32;
        let l = estimate_block_lipschitz(shard, problem, w_i);
        let mut max_l: f64 = 0.0;
        for (slot, &lij) in l.iter().enumerate() {
            let j = shard.block_of_slot(slot);
            block_used[j] = true;
            alpha_penalty[j] += (0.5 + 1.0 / rho) * lij * lij * (t + 1.0) * (t + 1.0)
                + (4.0 * lij + rho + 1.0) * t * t / 2.0;
            max_l = max_l.max(lij);
        }
        max_l_all = max_l_all.max(max_l);
        beta.push((rho - 4.0 * max_l) / (2.0 * shard.n_slots() as f64));
    }

    let alpha: Vec<(usize, f64)> = (0..n_blocks)
        .filter(|&j| block_used[j])
        .map(|j| (j, gamma + rho - alpha_penalty[j]))
        .collect();
    let min_alpha = alpha.iter().map(|&(_, a)| a).fold(f64::INFINITY, f64::min);
    let min_beta = beta.iter().copied().fold(f64::INFINITY, f64::min);
    let worst_penalty = alpha_penalty.iter().copied().fold(0.0f64, f64::max);

    Theorem1Report {
        alpha,
        beta,
        min_alpha,
        min_beta,
        feasible: min_alpha > 0.0 && min_beta > 0.0,
        gamma_needed: (worst_penalty - rho + 1e-9).max(0.0),
        rho_needed: 4.0 * max_l_all + 1e-9,
    }
}

/// Paper §4 remark: γ must grow with the delay bound. Practical rule
/// used by the driver when auto-tuning: γ ∝ (T/T₀)² scaled from the
/// paper's (γ=0.01, observed small delay) operating point.
pub fn suggest_gamma(base_gamma: f64, delay_bound: usize) -> f64 {
    let t = delay_bound.max(1) as f64;
    base_gamma * t * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, LossKind, SynthSpec};

    fn setup() -> (Vec<crate::data::WorkerShard>, Problem) {
        let spec = SynthSpec {
            samples: 64,
            geometry: crate::data::BlockGeometry::new(8, 8),
            nnz_per_row: 6,
            blocks_per_worker: 4,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 3);
        (shards, Problem::new(LossKind::Logistic, 0.0, 1e4))
    }

    #[test]
    fn lipschitz_positive_and_scales_with_weight() {
        let (shards, p) = setup();
        let l1 = estimate_block_lipschitz(&shards[0], &p, 1.0 / 64.0);
        let l2 = estimate_block_lipschitz(&shards[0], &p, 2.0 / 64.0);
        assert_eq!(l1.len(), shards[0].n_slots());
        for (a, b) in l1.iter().zip(&l2) {
            assert!(*a >= 0.0);
            assert!((b - 2.0 * a).abs() < 1e-12);
        }
        assert!(l1.iter().any(|&x| x > 0.0));
    }

    #[test]
    fn zero_delay_large_rho_is_feasible() {
        let (shards, p) = setup();
        let refs: Vec<&_> = shards.iter().collect();
        // T=0 kills the delay penalty; rho large beats 4L.
        let r = check_theorem1(&refs, &p, 8, 10.0, 0.0, 0);
        assert!(r.feasible, "{r:?}");
        assert!(r.min_alpha > 0.0 && r.min_beta > 0.0);
    }

    #[test]
    fn large_delay_needs_large_gamma() {
        let (shards, p) = setup();
        let refs: Vec<&_> = shards.iter().collect();
        let r0 = check_theorem1(&refs, &p, 8, 10.0, 0.01, 0);
        let r16 = check_theorem1(&refs, &p, 8, 10.0, 0.01, 16);
        assert!(r16.min_alpha < r0.min_alpha);
        assert!(!r16.feasible); // rho*T²/2 term dominates at T=16, gamma=0.01
        assert!(r16.gamma_needed > 0.0);
        // And the suggested gamma indeed repairs alpha:
        let fixed = check_theorem1(&refs, &p, 8, 10.0, r16.gamma_needed + 1.0, 16);
        assert!(fixed.min_alpha > 0.0);
    }

    #[test]
    fn small_rho_fails_beta() {
        let (shards, p) = setup();
        let refs: Vec<&_> = shards.iter().collect();
        // Absurdly small rho vs Lipschitz -> beta < 0 (L > rho/4).
        let r = check_theorem1(&refs, &p, 8, 1e-6, 0.0, 0);
        assert!(r.min_beta < 0.0);
        assert!(r.rho_needed > 1e-6);
    }

    #[test]
    fn suggest_gamma_grows_quadratically() {
        assert_eq!(suggest_gamma(0.01, 1), 0.01);
        assert!((suggest_gamma(0.01, 4) - 0.16).abs() < 1e-12);
    }
}

//! Per-worker ADMM state: local primal/dual variables in packed
//! coordinates (slot s ↔ global block `shard.active_blocks[s]`).

use crate::data::WorkerShard;

/// Worker i's local variables (paper notation in packed layout):
/// `x[s*db..(s+1)*db]` is x_{i,j} and `y[..]` is y_{i,j} for
/// j = active_blocks[s]; `z_local` caches the latest pulled z̃ blocks.
#[derive(Clone, Debug)]
pub struct WorkerState {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub z_local: Vec<f32>,
    /// Local epoch t (Algorithm 1 line 3).
    pub epoch: usize,
    /// Data loss observed at the last gradient evaluation (for logging).
    pub last_loss: f32,
}

impl WorkerState {
    /// Algorithm 1 lines 1-2: pull z⁰, x⁰ = z⁰, y⁰ = 0.
    pub fn init_from_z(z_local: Vec<f32>) -> Self {
        let x = z_local.clone();
        let y = vec![0.0; z_local.len()];
        WorkerState { x, y, z_local, epoch: 0, last_loss: f32::NAN }
    }

    pub fn packed_dim(&self) -> usize {
        self.x.len()
    }

    /// Mutable views of one slot across the three packed vectors.
    pub fn slot_mut(
        &mut self,
        shard: &WorkerShard,
        slot: usize,
    ) -> (&mut [f32], &mut [f32], &[f32]) {
        let (lo, hi) = shard.slot_range(slot);
        // Disjoint-field borrows: x, y mutable, z_local shared.
        (&mut self.x[lo..hi], &mut self.y[lo..hi], &self.z_local[lo..hi])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_sets_x_to_z_and_y_to_zero() {
        let s = WorkerState::init_from_z(vec![1.0, 2.0, 3.0]);
        assert_eq!(s.x, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.y, vec![0.0; 3]);
        assert_eq!(s.epoch, 0);
    }
}

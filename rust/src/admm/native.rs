//! Native (pure-rust, CSR) compute engine — the exact same math as the
//! AOT artifacts, over the packed per-worker shard.
//!
//! Two roles: (a) the numeric engine of the DES cluster simulator and the
//! baselines; (b) the reference the XLA backend is cross-checked against
//! in `rust/tests/artifact_parity.rs`.  Keep formulas in lock-step with
//! `python/compile/model.py` / `kernels/ref.py`.

use crate::data::WorkerShard;
use crate::problem::Problem;
use crate::sparse::Kernels;

/// Eq. 11/12/9 epilogue — mirror of `model.worker_update`:
/// x = z̃ − (g + y)/ρ,  y' = y + ρ(x − z̃),  w = ρx + y'.
pub fn worker_update(
    g: &[f32],
    y: &[f32],
    z_blk: &[f32],
    rho: f32,
    w_out: &mut [f32],
    y_out: &mut [f32],
    x_out: &mut [f32],
) {
    let n = g.len();
    debug_assert!(y.len() == n && z_blk.len() == n);
    debug_assert!(w_out.len() == n && y_out.len() == n && x_out.len() == n);
    for k in 0..n {
        let x = z_blk[k] - (g[k] + y[k]) / rho;
        let y_new = y[k] + rho * (x - z_blk[k]);
        w_out[k] = rho * x + y_new;
        y_out[k] = y_new;
        x_out[k] = x;
    }
}

/// Per-worker compute engine with reusable scratch buffers (no
/// allocation on the iteration hot path).
pub struct NativeEngine<'a> {
    pub shard: &'a WorkerShard,
    pub problem: Problem,
    /// Uniform per-sample weight (1/m_total so that Σ_i f_i equals the
    /// global mean loss of paper Eq. 22).
    pub sample_weight: f32,
    /// Resolved kernel family for the spmv / block-gradient hot spots
    /// (`sparse::simd`); `new` defaults to `kernel=auto`.
    kernels: &'static Kernels,
    margins: Vec<f32>,
    slopes: Vec<f32>,
}

impl<'a> NativeEngine<'a> {
    pub fn new(shard: &'a WorkerShard, problem: Problem, sample_weight: f32) -> Self {
        Self::with_kernels(shard, problem, sample_weight, Kernels::auto())
    }

    /// Like [`NativeEngine::new`] with an explicit kernel family (the
    /// session resolves `--set kernel=` once and threads it here).
    pub fn with_kernels(
        shard: &'a WorkerShard,
        problem: Problem,
        sample_weight: f32,
        kernels: &'static Kernels,
    ) -> Self {
        let m = shard.samples();
        NativeEngine {
            shard,
            problem,
            sample_weight,
            kernels,
            margins: vec![0.0; m],
            slopes: vec![0.0; m],
        }
    }

    /// Fused margins + slopes pass; returns total (weighted) data loss at
    /// `point` (packed coordinates).  Mirrors one grid pass of the L1
    /// Pallas kernel.
    fn margins_pass(&mut self, point: &[f32]) -> f32 {
        debug_assert_eq!(point.len(), self.shard.packed_dim());
        (self.kernels.matvec)(&self.shard.a_packed, point, &mut self.margins);
        let mut loss = 0.0f32;
        for (k, &m) in self.margins.iter().enumerate() {
            let (l, s) = self.problem.loss_slope(m, self.shard.labels[k]);
            loss += self.sample_weight * l;
            self.slopes[k] = self.sample_weight * s;
        }
        loss
    }

    /// ∇_slot f_i(point): block gradient at packed slot, plus shard data
    /// loss at `point` — mirror of the `grad_chunk` artifact.  Uses the
    /// shard's precomputed block-slice index: the accumulate touches
    /// exactly the in-block nonzeros (no per-row binary search).
    pub fn grad_block(&mut self, point: &[f32], slot: usize, g: &mut [f32]) -> f32 {
        let (lo, hi) = self.shard.slot_range(slot);
        debug_assert_eq!(g.len(), hi - lo);
        let loss = self.margins_pass(point);
        g.fill(0.0);
        (self.kernels.tmatvec_block_sliced)(
            &self.shard.a_packed,
            &self.slopes,
            &self.shard.slices,
            slot,
            g,
        );
        loss
    }

    /// Full packed gradient (used by baselines + stationarity metric).
    pub fn grad_full(&mut self, point: &[f32], g: &mut [f32]) -> f32 {
        debug_assert_eq!(g.len(), self.shard.packed_dim());
        let loss = self.margins_pass(point);
        g.fill(0.0);
        self.shard.a_packed.tmatvec_acc(&self.slopes, g);
        loss
    }

    /// Weighted data loss at `point` — mirror of the `objective`
    /// artifact.
    pub fn data_loss(&mut self, point: &[f32]) -> f32 {
        self.margins_pass(point)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BlockGeometry, Dataset, LossKind};
    use crate::sparse::{dense, CsrBuilder};
    use crate::util::rng::Rng;

    fn toy_shard(rng: &mut Rng, m: usize, blocks: usize, db: usize) -> (Dataset, WorkerShard) {
        let d = blocks * db;
        let mut b = CsrBuilder::new(m, d);
        for r in 0..m {
            for c in 0..d {
                if rng.bernoulli(0.4) {
                    b.push(r, c, rng.normal_f32(0.0, 1.0));
                }
            }
        }
        let ds = Dataset {
            name: "toy".into(),
            kind: LossKind::Logistic,
            a: b.build(),
            labels: (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            geometry: BlockGeometry::new(blocks, db),
        };
        let shard = WorkerShard::from_rows(0, &ds, 0, m, None);
        (ds, shard)
    }

    /// Finite-difference check of the block gradient.
    #[test]
    fn grad_block_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let (_, shard) = toy_shard(&mut rng, 12, 3, 4);
        let p = Problem::new(LossKind::Logistic, 0.0, 1e4);
        let w = 1.0 / 12.0;
        let mut eng = NativeEngine::new(&shard, p, w);
        let z: Vec<f32> = (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        for slot in 0..shard.n_slots() {
            let mut g = vec![0.0f32; 4];
            eng.grad_block(&z, slot, &mut g);
            let (lo, _) = shard.slot_range(slot);
            for k in 0..4 {
                let eps = 1e-2f32;
                let mut zp = z.clone();
                zp[lo + k] += eps;
                let mut zm = z.clone();
                zm[lo + k] -= eps;
                let fd = (eng.data_loss(&zp) - eng.data_loss(&zm)) / (2.0 * eps);
                assert!((fd - g[k]).abs() < 2e-3, "slot {slot} k {k}: fd {fd} vs {}", g[k]);
            }
        }
    }

    #[test]
    fn grad_full_equals_dense_formula() {
        let mut rng = Rng::new(2);
        let (_, shard) = toy_shard(&mut rng, 10, 2, 4);
        let p = Problem::new(LossKind::Squared, 0.0, 1e4);
        // squared loss with labels y: grad = w * A^T (A z - y)
        let mut eng = NativeEngine::new(&shard, p, 0.1);
        let z: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0f32; 8];
        eng.grad_full(&z, &mut g);

        let mut a_dense = vec![0.0f32; 10 * 8];
        shard.a_packed.densify_rows(0, 10, &mut a_dense);
        let margins = dense::matvec(&a_dense, 10, 8, &z);
        let resid: Vec<f32> =
            margins.iter().zip(&shard.labels).map(|(m, y)| 0.1 * (m - y)).collect();
        let expect = dense::tmatvec(&a_dense, 10, 8, &resid);
        for (a, b) in g.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn block_grads_concatenate_to_full() {
        let mut rng = Rng::new(3);
        let (_, shard) = toy_shard(&mut rng, 9, 3, 4);
        let p = Problem::new(LossKind::Logistic, 0.0, 1e4);
        let mut eng = NativeEngine::new(&shard, p, 1.0 / 9.0);
        let z: Vec<f32> = (0..12).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut full = vec![0.0f32; 12];
        eng.grad_full(&z, &mut full);
        for slot in 0..3 {
            let mut g = vec![0.0f32; 4];
            eng.grad_block(&z, slot, &mut g);
            assert_eq!(&full[slot * 4..(slot + 1) * 4], &g[..]);
        }
    }

    #[test]
    fn worker_update_identities() {
        let mut rng = Rng::new(4);
        let n = 16;
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let z: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let rho = 50.0;
        let (mut w, mut yn, mut x) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        worker_update(&g, &y, &z, rho, &mut w, &mut yn, &mut x);
        for k in 0..n {
            // Eq. 25: y' = -g
            assert!((yn[k] + g[k]).abs() < 1e-4);
            // closed form w = rho z - 2g - y
            assert!((w[k] - (rho * z[k] - 2.0 * g[k] - y[k])).abs() < 1e-3);
            // Eq. 11
            assert!((x[k] - (z[k] - (g[k] + y[k]) / rho)).abs() < 1e-5);
        }
    }

    #[test]
    fn loss_at_zero_is_log2_for_logistic() {
        let mut rng = Rng::new(5);
        let (_, shard) = toy_shard(&mut rng, 20, 2, 4);
        let p = Problem::new(LossKind::Logistic, 0.0, 1e4);
        let mut eng = NativeEngine::new(&shard, p, 1.0 / 20.0);
        let z = vec![0.0f32; shard.packed_dim()];
        let loss = eng.data_loss(&z);
        assert!((loss - std::f32::consts::LN_2).abs() < 1e-5);
    }
}

//! Convergence metrics: global objective, consensus gap, and the paper's
//! Eq. 14 stationarity residual P(X, Y, z) whose decay to 0 certifies
//! convergence to a KKT point (Theorem 1 part 3).

use super::native::NativeEngine;
use super::prox::soft_threshold;
use crate::data::WorkerShard;
use crate::problem::Problem;

/// Objective decomposition at the consensus point z:
/// F(z) = Σ_i f_i(z) + h(z)  (what Fig. 2 plots).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objective {
    pub data_loss: f64,
    pub reg: f64,
}

impl Objective {
    pub fn total(&self) -> f64 {
        self.data_loss + self.reg
    }
}

/// Evaluate F(z) by gathering each worker's packed view of z.
pub fn objective_at_z(
    shards: &[WorkerShard],
    problem: &Problem,
    sample_weight: f32,
    z_global: &[f32],
) -> Objective {
    let mut data_loss = 0.0f64;
    for shard in shards {
        let z_local = gather_packed(shard, z_global);
        let mut eng = NativeEngine::new(shard, *problem, sample_weight);
        data_loss += eng.data_loss(&z_local) as f64;
    }
    Objective { data_loss, reg: problem.h(z_global) }
}

/// Copy the worker's active blocks of the global z into packed layout.
pub fn gather_packed(shard: &WorkerShard, z_global: &[f32]) -> Vec<f32> {
    let db = shard.block_size;
    let mut out = vec![0.0f32; shard.packed_dim()];
    for (slot, &j) in shard.active_blocks.iter().enumerate() {
        out[slot * db..(slot + 1) * db].copy_from_slice(&z_global[j * db..(j + 1) * db]);
    }
    out
}

/// Consensus gap statistics: max and mean ‖x_ij − z_j‖ over ℰ.
pub fn consensus_gap(
    shards: &[WorkerShard],
    xs: &[Vec<f32>],
    z_global: &[f32],
) -> (f64, f64) {
    let mut max_gap = 0.0f64;
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for (shard, x) in shards.iter().zip(xs) {
        let z_local = gather_packed(shard, z_global);
        let db = shard.block_size;
        for slot in 0..shard.n_slots() {
            let (lo, hi) = (slot * db, (slot + 1) * db);
            let gap: f64 = x[lo..hi]
                .iter()
                .zip(&z_local[lo..hi])
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            max_gap = max_gap.max(gap);
            sum += gap;
            count += 1;
        }
    }
    (max_gap, sum / count.max(1) as f64)
}

/// Paper Eq. 14: P(X,Y,z) = ‖z − ẑ‖² + Σ‖∇_{x_ij} L‖² + Σ‖x_ij − z_j‖²,
/// with ẑ_j = prox_h(z_j − ∇_{z_j}(L − h)) (Eq. 15).
///
/// Gradients:
///   ∇_{x_ij} L = ∇_j f_i(x_i) + y_ij + ρ_i (x_ij − z_j)
///   ∇_{z_j}(L−h) = −Σ_{i∈𝒩(j)} [ y_ij + ρ_i (x_ij − z_j) ]
pub fn stationarity_residual(
    shards: &[WorkerShard],
    problem: &Problem,
    rho: f32,
    xs: &[Vec<f32>],
    ys: &[Vec<f32>],
    z_global: &[f32],
) -> f64 {
    let db = shards.first().map(|s| s.block_size).unwrap_or(0);
    let mut grad_x_sq = 0.0f64;
    let mut gap_sq = 0.0f64;
    // ∇_{z_j}(L−h) accumulated per global coordinate.
    let mut grad_z = vec![0.0f32; z_global.len()];

    for ((shard, x), y) in shards.iter().zip(xs).zip(ys) {
        let z_local = gather_packed(shard, z_global);
        // f_i is the worker's LOCAL mean loss (same convention as
        // training; see DESIGN.md "objective scaling").
        let w_i = 1.0 / shard.samples().max(1) as f32;
        let mut eng = NativeEngine::new(shard, *problem, w_i);
        let mut g_full = vec![0.0f32; shard.packed_dim()];
        eng.grad_full(x, &mut g_full);
        for slot in 0..shard.n_slots() {
            let j = shard.block_of_slot(slot);
            let (lo, hi) = (slot * db, (slot + 1) * db);
            for k in lo..hi {
                let resid = x[k] - z_local[k];
                let gx = g_full[k] + y[k] + rho * resid;
                grad_x_sq += (gx as f64) * (gx as f64);
                gap_sq += (resid as f64) * (resid as f64);
                grad_z[j * db + (k - lo)] -= y[k] + rho * resid;
            }
        }
    }

    // ‖z − ẑ‖² with ẑ = prox_h(z − ∇_z(L−h)): soft-threshold λ then box.
    let mut z_hat_sq = 0.0f64;
    for (k, &z) in z_global.iter().enumerate() {
        let v = z - grad_z[k];
        let zh = soft_threshold(v, problem.lambda).clamp(-problem.clip, problem.clip);
        z_hat_sq += ((z - zh) as f64).powi(2);
    }

    z_hat_sq + grad_x_sq + gap_sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};

    #[allow(clippy::type_complexity)]
    fn setup() -> (Vec<WorkerShard>, Problem, f32, usize) {
        let spec = SynthSpec {
            samples: 48,
            geometry: BlockGeometry::new(6, 8),
            nnz_per_row: 5,
            blocks_per_worker: 3,
            shared_blocks: 1,
            ..Default::default()
        };
        let (ds, shards) = gen_partitioned(&spec, 3);
        let w = 1.0 / ds.samples() as f32;
        (shards, Problem::new(LossKind::Logistic, 1e-3, 1e4), w, ds.dim())
    }

    #[test]
    fn objective_at_zero_is_log2_plus_zero_reg() {
        let (shards, p, w, d) = setup();
        let obj = objective_at_z(&shards, &p, w, &vec![0.0; d]);
        assert!((obj.data_loss - std::f64::consts::LN_2).abs() < 1e-4, "{obj:?}");
        assert_eq!(obj.reg, 0.0);
    }

    #[test]
    fn gather_packed_roundtrip() {
        let (shards, _, _, d) = setup();
        let z: Vec<f32> = (0..d).map(|k| k as f32).collect();
        for shard in &shards {
            let packed = gather_packed(shard, &z);
            for (slot, &j) in shard.active_blocks.iter().enumerate() {
                let db = shard.block_size;
                assert_eq!(packed[slot * db], (j * db) as f32);
            }
        }
    }

    #[test]
    fn consensus_gap_zero_when_x_equals_z() {
        let (shards, _, _, d) = setup();
        let z: Vec<f32> = (0..d).map(|k| (k % 7) as f32 * 0.1).collect();
        let xs: Vec<Vec<f32>> = shards.iter().map(|s| gather_packed(s, &z)).collect();
        let (max_gap, mean_gap) = consensus_gap(&shards, &xs, &z);
        assert!(max_gap < 1e-12);
        assert!(mean_gap < 1e-12);
    }

    #[test]
    fn residual_nonnegative_and_detects_disagreement() {
        let (shards, p, _w, d) = setup();
        let z = vec![0.0f32; d];
        let xs_agree: Vec<Vec<f32>> = shards.iter().map(|s| gather_packed(s, &z)).collect();
        let ys: Vec<Vec<f32>> = shards.iter().map(|s| vec![0.0f32; s.packed_dim()]).collect();
        let p0 = stationarity_residual(&shards, &p, 10.0, &xs_agree, &ys, &z);
        assert!(p0 >= 0.0);

        // Perturb x away from z: residual must grow.
        let xs_off: Vec<Vec<f32>> = xs_agree
            .iter()
            .map(|x| x.iter().map(|v| v + 1.0).collect())
            .collect();
        let p1 = stationarity_residual(&shards, &p, 10.0, &xs_off, &ys, &z);
        assert!(p1 > p0 + 1.0, "{p1} vs {p0}");
    }
}

//! Criterion-style micro-bench harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, adaptive iteration count, robust stats (mean ± std, p50/p95),
//! and aligned terminal output.  Results can also be dumped as CSV for
//! EXPERIMENTS.md.

use std::time::{Duration, Instant};

use crate::util::{mean_std, percentile};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration samples.
    pub samples: Vec<f64>,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        format!(
            "{:<40} {:>12}/s",
            self.name,
            human(per_iter / self.mean_s, unit)
        )
    }
}

fn human(v: f64, unit: &str) -> String {
    if v >= 1e9 {
        format!("{:.2}G{unit}", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M{unit}", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k{unit}", v / 1e3)
    } else {
        format!("{v:.2}{unit}")
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

pub struct Harness {
    /// Target measurement time per benchmark.
    pub measure_s: f64,
    pub warmup_s: f64,
    pub min_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Harness {
    fn default() -> Self {
        // Modest defaults: the full suite has many benches and one core.
        Harness { measure_s: 2.0, warmup_s: 0.3, min_samples: 5, results: Vec::new() }
    }
}

impl Harness {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick harness for smoke runs (CI / tests).
    pub fn quick() -> Self {
        Harness { measure_s: 0.2, warmup_s: 0.05, min_samples: 3, results: Vec::new() }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // Warmup + cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed().as_secs_f64() < self.warmup_s || warm_iters < 1 {
            f();
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Sample loop: batch iterations so timer overhead stays <1%.
        let batch = ((1e-4 / est.max(1e-9)).ceil() as u64).max(1);
        let n_samples = ((self.measure_s / (est * batch as f64).max(1e-9)) as usize)
            .clamp(self.min_samples, 200);
        let mut samples = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        let (mean_s, std_s) = mean_std(&samples);
        let result = BenchResult {
            name: name.to_string(),
            p50_s: percentile(&samples, 50.0),
            p95_s: percentile(&samples, 95.0),
            samples,
            mean_s,
            std_s,
        };
        println!(
            "{:<44} {:>10} ± {:>9}   p50 {:>10}  p95 {:>10}  ({} samples)",
            result.name,
            fmt_t(result.mean_s),
            fmt_t(result.std_s),
            fmt_t(result.p50_s),
            fmt_t(result.p95_s),
            result.samples.len(),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single one-shot run (for end-to-end benches where one
    /// "iteration" is a whole training run).
    pub fn once(&mut self, name: &str, f: impl FnOnce()) -> Duration {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        println!("{:<44} {:>10}   (single run)", name, fmt_t(dt.as_secs_f64()));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: vec![dt.as_secs_f64()],
            mean_s: dt.as_secs_f64(),
            std_s: 0.0,
            p50_s: dt.as_secs_f64(),
            p95_s: dt.as_secs_f64(),
        });
        dt
    }

    pub fn csv(&self) -> String {
        let mut s = String::from("name,mean_s,std_s,p50_s,p95_s,samples\n");
        for r in &self.results {
            s.push_str(&format!(
                "{},{:.9},{:.9},{:.9},{:.9},{}\n",
                r.name,
                r.mean_s,
                r.std_s,
                r.p50_s,
                r.p95_s,
                r.samples.len()
            ));
        }
        s
    }
}

/// Whether benches should run in quick mode (smoke): set BENCH_QUICK=1.
pub fn harness_from_env() -> Harness {
    if std::env::var("BENCH_QUICK").as_deref() == Ok("1") {
        Harness::quick()
    } else {
        Harness::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut h = Harness::quick();
        let r = h.bench("noop-ish", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.mean_s > 0.0 && r.mean_s < 1e-3);
        assert!(r.samples.len() >= 3);
        assert!(r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut h = Harness::quick();
        h.bench("a", || std::hint::black_box(()));
        let csv = h.csv();
        assert!(csv.starts_with("name,mean_s"));
        assert_eq!(csv.lines().count(), 2);
    }
}

//! Criterion-style micro-bench harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, adaptive iteration count, robust stats (mean ± std, p50/p95),
//! and aligned terminal output.  Results can also be dumped as CSV for
//! EXPERIMENTS.md, and — for the hot-path benches — merged into
//! `BENCH_hotpath.json` (pass `--json` to the bench binary) so the perf
//! trajectory is tracked across PRs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::{num, obj, s as jstr, Json};
use crate::util::{mean_std, percentile};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration samples.
    pub samples: Vec<f64>,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        format!(
            "{:<40} {:>12}/s",
            self.name,
            human(per_iter / self.mean_s, unit)
        )
    }
}

fn human(v: f64, unit: &str) -> String {
    if v >= 1e9 {
        format!("{:.2}G{unit}", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M{unit}", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k{unit}", v / 1e3)
    } else {
        format!("{v:.2}{unit}")
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

pub struct Harness {
    /// Target measurement time per benchmark.
    pub measure_s: f64,
    pub warmup_s: f64,
    pub min_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Harness {
    fn default() -> Self {
        // Modest defaults: the full suite has many benches and one core.
        Harness { measure_s: 2.0, warmup_s: 0.3, min_samples: 5, results: Vec::new() }
    }
}

impl Harness {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick harness for smoke runs (CI / tests).
    pub fn quick() -> Self {
        Harness { measure_s: 0.2, warmup_s: 0.05, min_samples: 3, results: Vec::new() }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // Warmup + cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed().as_secs_f64() < self.warmup_s || warm_iters < 1 {
            f();
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Sample loop: batch iterations so timer overhead stays <1%.
        let batch = ((1e-4 / est.max(1e-9)).ceil() as u64).max(1);
        let n_samples = ((self.measure_s / (est * batch as f64).max(1e-9)) as usize)
            .clamp(self.min_samples, 200);
        let mut samples = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        let (mean_s, std_s) = mean_std(&samples);
        let result = BenchResult {
            name: name.to_string(),
            p50_s: percentile(&samples, 50.0),
            p95_s: percentile(&samples, 95.0),
            samples,
            mean_s,
            std_s,
        };
        println!(
            "{:<44} {:>10} ± {:>9}   p50 {:>10}  p95 {:>10}  ({} samples)",
            result.name,
            fmt_t(result.mean_s),
            fmt_t(result.std_s),
            fmt_t(result.p50_s),
            fmt_t(result.p95_s),
            result.samples.len(),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single one-shot run (for end-to-end benches where one
    /// "iteration" is a whole training run).
    pub fn once(&mut self, name: &str, f: impl FnOnce()) -> Duration {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        println!("{:<44} {:>10}   (single run)", name, fmt_t(dt.as_secs_f64()));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: vec![dt.as_secs_f64()],
            mean_s: dt.as_secs_f64(),
            std_s: 0.0,
            p50_s: dt.as_secs_f64(),
            p95_s: dt.as_secs_f64(),
        });
        dt
    }

    pub fn csv(&self) -> String {
        let mut s = String::from("name,mean_s,std_s,p50_s,p95_s,samples\n");
        for r in &self.results {
            s.push_str(&format!(
                "{},{:.9},{:.9},{:.9},{:.9},{}\n",
                r.name,
                r.mean_s,
                r.std_s,
                r.p50_s,
                r.p95_s,
                r.samples.len()
            ));
        }
        s
    }
}

/// Whether benches should run in quick mode (smoke): set BENCH_QUICK=1.
pub fn harness_from_env() -> Harness {
    if std::env::var("BENCH_QUICK").as_deref() == Ok("1") {
        Harness::quick()
    } else {
        Harness::new()
    }
}

/// Whether the bench binary was invoked with `--json`
/// (`cargo bench --bench <name> -- --json`).
pub fn json_requested() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Every perf-gate key the smoke suite must emit into
/// `BENCH_hotpath.json` — the single source of truth.  CI derives its
/// presence check from `--list-gates` output instead of a
/// hand-maintained shell list, so adding a key here (plus the emitting
/// bench) is the whole registration.  Grouped by emitting bench.
pub const GATE_KEYS: &[&str] = &[
    // locking_ablation
    "seqlock_vs_rwlock",
    "ring_vs_mpsc_enqueue",
    "tcp_loopback_vs_ring_enqueue",
    "credit_coalescing_frames",
    // placement_skew
    "steal_vs_owned_drain",
    "degree_vs_contiguous_skew",
    "ring_batch_amortization",
    "dynamic_vs_degree_skew",
    "dynamic_migrations",
    "elastic_threads_throughput",
    "service_time_vs_rate_rebalance",
    // fault_recovery
    "fault_hooks_overhead",
    "recovery_vs_faultfree_epochs",
    "net_fault_hooks_overhead",
    "net_recovery_vs_faultfree_epochs",
    // net_wire
    "tcp_frame_encode_throughput",
    "delta_pull_bytes",
    // kernel_gradient
    "sliced_vs_scan_min_speedup",
    "simd_vs_unrolled_spmv",
    // server_prox
    "prox_unrolled_vs_scalar",
    "wsum_unrolled_vs_scalar",
    "simd_prox_speedup",
];

/// Standard `--list-gates` handling for bench mains: when the flag is
/// present, print every gate key (one per line) and return `true` so
/// the bench exits without measuring anything.
pub fn maybe_list_gates() -> bool {
    if std::env::args().any(|a| a == "--list-gates") {
        for key in GATE_KEYS {
            println!("{key}");
        }
        true
    } else {
        false
    }
}

/// Default output file for [`emit_hotpath_json_at`]; relative to the
/// bench's working directory (the `rust/` package root under cargo).
pub const HOTPATH_JSON: &str = "BENCH_hotpath.json";

/// Merge this harness's results (plus free-form scalar `extras`, e.g. a
/// measured speedup ratio) into the hot-path JSON at `path` under
/// `section`, preserving every other bench's section so the three
/// hot-path benches accumulate into one file.
pub fn emit_hotpath_json_at(
    path: &Path,
    section: &str,
    h: &Harness,
    extras: &[(&str, f64)],
) -> anyhow::Result<()> {
    let mut root = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(v @ Json::Obj(_)) => v,
            Ok(_) => {
                eprintln!(
                    "warning: {} has a non-object root; starting a fresh file",
                    path.display()
                );
                obj(vec![])
            }
            Err(e) => {
                eprintln!(
                    "warning: existing {} is unparsable ({e}); starting a fresh file \
                     (prior sections lost)",
                    path.display()
                );
                obj(vec![])
            }
        },
        Err(_) => obj(vec![]),
    };
    let results: Vec<Json> = h
        .results
        .iter()
        .map(|r| {
            obj(vec![
                ("name", jstr(&r.name)),
                ("mean_s", num(r.mean_s)),
                ("std_s", num(r.std_s)),
                ("p50_s", num(r.p50_s)),
                ("p95_s", num(r.p95_s)),
                ("samples", num(r.samples.len() as f64)),
            ])
        })
        .collect();
    let mut pairs = vec![("results", Json::Arr(results))];
    for (k, v) in extras {
        pairs.push((k, num(*v)));
    }
    let section_json = obj(pairs);
    if let Json::Obj(m) = &mut root {
        m.insert(section.to_string(), section_json);
    }
    crate::report::write_file(path, &root.to_string_pretty())
}

/// [`emit_hotpath_json_at`] into the default `BENCH_hotpath.json`,
/// printing where the section landed.
pub fn emit_hotpath_json(section: &str, h: &Harness, extras: &[(&str, f64)]) {
    let path = PathBuf::from(HOTPATH_JSON);
    match emit_hotpath_json_at(&path, section, h, extras) {
        Ok(()) => println!("[{section}] results merged into {}", path.display()),
        Err(e) => eprintln!("[{section}] FAILED to write {}: {e:#}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_keys_are_unique_and_cover_the_simd_pr() {
        let mut seen = std::collections::HashSet::new();
        for key in GATE_KEYS {
            assert!(seen.insert(*key), "duplicate gate key {key:?}");
            assert!(
                key.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "gate key {key:?} is not a lower_snake_case token"
            );
        }
        for key in
            ["simd_vs_unrolled_spmv", "simd_prox_speedup", "service_time_vs_rate_rebalance"]
        {
            assert!(GATE_KEYS.contains(&key), "missing gate key {key:?}");
        }
    }

    #[test]
    fn bench_produces_sane_stats() {
        let mut h = Harness::quick();
        let r = h.bench("noop-ish", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.mean_s > 0.0 && r.mean_s < 1e-3);
        assert!(r.samples.len() >= 3);
        assert!(r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut h = Harness::quick();
        h.bench("a", || std::hint::black_box(()));
        let csv = h.csv();
        assert!(csv.starts_with("name,mean_s"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn hotpath_json_merges_sections() {
        let dir = std::env::temp_dir().join("asybadmm_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_hotpath.json");
        let _ = std::fs::remove_file(&path);

        let mut h1 = Harness::quick();
        h1.bench("store read", || std::hint::black_box(()));
        emit_hotpath_json_at(&path, "locking_ablation", &h1, &[("seqlock_vs_rwlock", 3.5)])
            .unwrap();

        let mut h2 = Harness::quick();
        h2.bench("grad sliced", || std::hint::black_box(()));
        emit_hotpath_json_at(&path, "kernel_gradient", &h2, &[]).unwrap();

        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // Second emit must preserve the first section.
        let lock = root.get("locking_ablation").expect("section dropped on merge");
        assert_eq!(lock.get("seqlock_vs_rwlock").and_then(Json::as_f64), Some(3.5));
        assert_eq!(lock.req_arr("results").unwrap().len(), 1);
        let kern = root.get("kernel_gradient").unwrap();
        assert_eq!(
            kern.req_arr("results").unwrap()[0].req_str("name").unwrap(),
            "grad sliced"
        );
    }
}

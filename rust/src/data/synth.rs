//! Synthetic KDDa-like dataset generator (the paper's dataset is 2.5 GB
//! and not redistributable; see DESIGN.md §3 for the substitution
//! argument).
//!
//! Reproduced structural properties of sparse text/CTR data that
//! AsyBADMM's block-wise design exploits:
//!
//! * extreme sparsity: `nnz_per_row` out of `geometry.dim()` features;
//! * skewed (Zipf) feature popularity inside each worker's vocabulary;
//! * **block-sparse worker footprints**: each worker's local corpus only
//!   touches `blocks_per_worker` of the `n_blocks` consensus blocks (a
//!   few globally-hot shared blocks plus worker-local ones), which is
//!   exactly the general-form-consensus graph ℰ of paper Eq. 4;
//! * labels from a sparse ground-truth weight vector + noise, so the
//!   optimization problem has signal and the l1 regularizer has a
//!   meaningful support to recover.

use super::dataset::{BlockGeometry, Dataset, LossKind};
use super::partition::WorkerShard;
use crate::sparse::CsrBuilder;
use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub kind: LossKind,
    /// Total samples across all workers.
    pub samples: usize,
    pub geometry: BlockGeometry,
    /// Average non-zeros per row.
    pub nnz_per_row: usize,
    /// Blocks each worker touches (|N(i)| in the paper), including the
    /// shared hot blocks.
    pub blocks_per_worker: usize,
    /// First `shared_blocks` blocks are in every worker's footprint
    /// (globally hot vocabulary).
    pub shared_blocks: usize,
    /// Zipf exponent for feature popularity within a worker vocabulary.
    pub zipf_s: f64,
    /// Fraction of ground-truth weights that are non-zero.
    pub truth_density: f64,
    /// Label noise: flip probability (logistic) or additive sigma
    /// (squared).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            kind: LossKind::Logistic,
            samples: 8192,
            geometry: BlockGeometry::new(32, 512),
            nnz_per_row: 40,
            blocks_per_worker: 8,
            shared_blocks: 2,
            zipf_s: 1.1,
            truth_density: 0.05,
            noise: 0.05,
            seed: 42,
        }
    }
}

/// Generate the global dataset *and* per-worker shards in one pass, so
/// the block-sparse footprint ℰ is genuine (not an artifact of
/// post-hoc partitioning).
///
/// Returns `(dataset, shards)`; `shards[i]` holds worker i's packed
/// local matrix, labels, and active block list. The concatenation of all
/// shard rows is exactly the dataset (row order = worker order).
pub fn gen_partitioned(spec: &SynthSpec, n_workers: usize) -> (Dataset, Vec<WorkerShard>) {
    assert!(n_workers > 0);
    let g = spec.geometry;
    assert!(
        spec.blocks_per_worker >= spec.shared_blocks && spec.blocks_per_worker <= g.n_blocks,
        "blocks_per_worker must be within [shared_blocks, n_blocks]"
    );
    let mut rng = Rng::new(spec.seed);
    let d = g.dim();

    // Sparse ground truth over the full model.
    let mut truth = vec![0.0f32; d];
    for t in truth.iter_mut() {
        if rng.bernoulli(spec.truth_density) {
            *t = rng.normal_f32(0.0, 1.0);
        }
    }

    // Per-worker active block sets: shared head + random private tail.
    let mut worker_blocks: Vec<Vec<usize>> = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let mut blocks: Vec<usize> = (0..spec.shared_blocks).collect();
        let extra = spec.blocks_per_worker - spec.shared_blocks;
        if extra > 0 && g.n_blocks > spec.shared_blocks {
            let pool = g.n_blocks - spec.shared_blocks;
            let mut picks = rng.sample_indices(pool, extra.min(pool));
            for p in picks.drain(..) {
                blocks.push(spec.shared_blocks + p);
            }
        }
        blocks.sort_unstable();
        worker_blocks.push(blocks);
    }

    // Row counts: spread samples as evenly as possible.
    let base = spec.samples / n_workers;
    let rem = spec.samples % n_workers;
    let rows_of = |i: usize| base + usize::from(i < rem);

    let mut builder = CsrBuilder::new(spec.samples, d);
    let mut labels = vec![0.0f32; spec.samples];
    let mut shard_rows: Vec<(usize, usize)> = Vec::with_capacity(n_workers);
    let mut row = 0usize;

    for (i, blocks) in worker_blocks.iter().enumerate() {
        let vocab: usize = blocks.len() * g.block_size;
        let zipf = Zipf::new(vocab, spec.zipf_s);
        // Map local vocabulary rank -> global feature id. Ranks are
        // shuffled so popularity isn't aligned with feature index.
        let mut rank_to_feature: Vec<u32> = blocks
            .iter()
            .flat_map(|&b| {
                let (lo, hi) = g.range(b);
                (lo..hi).map(|f| f as u32)
            })
            .collect();
        rng.shuffle(&mut rank_to_feature);

        let lo = row;
        for _ in 0..rows_of(i) {
            // Distinct feature draw with a bounded retry loop.
            let mut feats: Vec<u32> = Vec::with_capacity(spec.nnz_per_row);
            let mut tries = 0;
            while feats.len() < spec.nnz_per_row.min(vocab) && tries < spec.nnz_per_row * 30 {
                let f = rank_to_feature[zipf.sample(&mut rng)];
                if !feats.contains(&f) {
                    feats.push(f);
                }
                tries += 1;
            }
            let mut margin = 0.0f64;
            for &f in &feats {
                let v = rng.normal_f32(0.0, 1.0);
                builder.push(row, f as usize, v);
                margin += (v * truth[f as usize]) as f64;
            }
            labels[row] = match spec.kind {
                LossKind::Logistic => {
                    let y = if margin >= 0.0 { 1.0 } else { -1.0 };
                    if rng.bernoulli(spec.noise) {
                        -y
                    } else {
                        y
                    }
                }
                LossKind::Squared => (margin + spec.noise * rng.normal()) as f32,
            };
            row += 1;
        }
        shard_rows.push((lo, row));
    }
    debug_assert_eq!(row, spec.samples);

    let dataset = Dataset {
        name: format!(
            "synth-{}-m{}-d{}-b{}x{}",
            spec.kind.as_str(),
            spec.samples,
            d,
            g.n_blocks,
            g.block_size
        ),
        kind: spec.kind,
        a: builder.build(),
        labels,
        geometry: g,
    };

    let shards = shard_rows
        .iter()
        .zip(&worker_blocks)
        .enumerate()
        .map(|(i, (&(lo, hi), blocks))| {
            WorkerShard::from_rows(i, &dataset, lo, hi, Some(blocks.clone()))
        })
        .collect();

    (dataset, shards)
}

/// Generate the dataset ONCE with `n_virtual` fine-grained shards, then
/// regroup them onto `p` real workers (`p` must divide `n_virtual`).
///
/// This is how the paper's scaling study partitions a FIXED dataset
/// across different worker counts: the optimization problem (data,
/// labels, footprint union) is identical for every p, so Fig. 2 / Table
/// 1 rows are comparable.  A real worker's active set is the union of
/// its virtual shards' footprints (fewer workers each see more blocks —
/// inherent to general-form consensus).
pub fn gen_virtual_partitioned(
    spec: &SynthSpec,
    n_virtual: usize,
    p: usize,
) -> (Dataset, Vec<WorkerShard>) {
    assert!(p > 0 && n_virtual % p == 0, "p={p} must divide n_virtual={n_virtual}");
    let (ds, virt) = gen_partitioned(spec, n_virtual);
    let group = n_virtual / p;
    let shards = (0..p)
        .map(|w| {
            let members = &virt[w * group..(w + 1) * group];
            let lo = members.first().unwrap().rows.0;
            let hi = members.last().unwrap().rows.1;
            let mut blocks: Vec<usize> =
                members.iter().flat_map(|s| s.active_blocks.iter().copied()).collect();
            blocks.sort_unstable();
            blocks.dedup();
            WorkerShard::from_rows(w, &ds, lo, hi, Some(blocks))
        })
        .collect();
    (ds, shards)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SynthSpec {
        SynthSpec {
            samples: 64,
            geometry: BlockGeometry::new(8, 16),
            nnz_per_row: 6,
            blocks_per_worker: 3,
            shared_blocks: 1,
            ..Default::default()
        }
    }

    #[test]
    fn shapes_and_labels() {
        let (ds, shards) = gen_partitioned(&tiny_spec(), 4);
        ds.validate().unwrap();
        assert_eq!(ds.samples(), 64);
        assert_eq!(ds.dim(), 128);
        assert_eq!(shards.len(), 4);
        assert_eq!(shards.iter().map(|s| s.samples()).sum::<usize>(), 64);
    }

    #[test]
    fn footprint_respects_block_budget() {
        let (_, shards) = gen_partitioned(&tiny_spec(), 4);
        for s in &shards {
            assert!(s.active_blocks.len() <= 3, "{:?}", s.active_blocks);
            // shared block 0 must be present (hot vocabulary)
            assert!(s.active_blocks.contains(&0));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = gen_partitioned(&tiny_spec(), 2);
        let (b, _) = gen_partitioned(&tiny_spec(), 2);
        assert_eq!(a.a, b.a);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seed_differs() {
        let mut s2 = tiny_spec();
        s2.seed = 7;
        let (a, _) = gen_partitioned(&tiny_spec(), 2);
        let (b, _) = gen_partitioned(&s2, 2);
        assert_ne!(a.a, b.a);
    }

    #[test]
    fn logistic_labels_pm1_and_nnz_bounded() {
        let (ds, _) = gen_partitioned(&tiny_spec(), 3);
        assert!(ds.labels.iter().all(|&y| y == 1.0 || y == -1.0));
        for r in 0..ds.samples() {
            let (idx, _) = ds.a.row(r);
            assert!(idx.len() <= 6);
            assert!(!idx.is_empty());
        }
    }

    #[test]
    fn squared_kind_generates_real_labels() {
        let mut spec = tiny_spec();
        spec.kind = LossKind::Squared;
        let (ds, _) = gen_partitioned(&spec, 2);
        ds.validate().unwrap();
        assert!(ds.labels.iter().any(|&y| y != y.round()));
    }

    #[test]
    fn virtual_regroup_preserves_problem() {
        let spec = tiny_spec();
        let (ds8, v8) = gen_partitioned(&spec, 8);
        let (ds_a, g2) = gen_virtual_partitioned(&spec, 8, 2);
        let (ds_b, g1) = gen_virtual_partitioned(&spec, 8, 1);
        // Same dataset regardless of regrouping.
        assert_eq!(ds8.a, ds_a.a);
        assert_eq!(ds_a.a, ds_b.a);
        assert_eq!(ds_a.labels, ds_b.labels);
        // Row cover + footprint union.
        assert_eq!(g2.iter().map(|s| s.samples()).sum::<usize>(), ds_a.samples());
        assert_eq!(g1[0].samples(), ds_b.samples());
        let union_blocks: usize = {
            let mut b: Vec<usize> =
                v8.iter().flat_map(|s| s.active_blocks.iter().copied()).collect();
            b.sort_unstable();
            b.dedup();
            b.len()
        };
        assert_eq!(g1[0].active_blocks.len(), union_blocks);
    }

    #[test]
    fn uneven_split_covers_all_samples() {
        let mut spec = tiny_spec();
        spec.samples = 65; // 65 % 4 != 0
        let (ds, shards) = gen_partitioned(&spec, 4);
        assert_eq!(shards.iter().map(|s| s.samples()).sum::<usize>(), ds.samples());
        assert_eq!(shards[0].samples(), 17);
        assert_eq!(shards[3].samples(), 16);
    }
}

//! Dataset substrate (S2): synthetic KDDa-like generation, libsvm-format
//! loading, sample partitioning, and the feature-block geometry that
//! defines the general-form-consensus sparsity graph ℰ.

mod dataset;
mod libsvm;
mod partition;
mod synth;

pub use dataset::{BlockGeometry, Dataset, LossKind};
pub use libsvm::{load_libsvm, parse_libsvm};
pub use partition::{partition_even, WorkerShard};
pub use synth::{gen_partitioned, gen_virtual_partitioned, SynthSpec};

//! libsvm/svmlight format parser, so real datasets (including the paper's
//! KDDa, if available) drop into the pipeline:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices may be 0- or 1-based (auto-detected: a 0 index anywhere means
//! 0-based).  Labels: for `LossKind::Logistic`, values <= 0 (or 0/1
//! encodings) map to -1/+1; for `Squared` they pass through.

use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::Context;

use super::dataset::{BlockGeometry, Dataset, LossKind};
use crate::sparse::CsrBuilder;

/// Parse libsvm text. `block_size` fixes the consensus geometry; the
/// feature dimension is padded up to a whole number of blocks.
pub fn parse_libsvm(text: &str, kind: LossKind, block_size: usize) -> anyhow::Result<Dataset> {
    let mut rows: Vec<(f32, Vec<(usize, f32)>)> = Vec::new();
    let mut max_idx = 0usize;
    let mut saw_zero = false;

    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f32 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("line {}: expected idx:val, got {tok:?}", lineno + 1))?;
            let idx: usize = i
                .parse()
                .with_context(|| format!("line {}: bad index {i:?}", lineno + 1))?;
            let val: f32 = v
                .parse()
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            saw_zero |= idx == 0;
            max_idx = max_idx.max(idx);
            feats.push((idx, val));
        }
        rows.push((label, feats));
    }
    anyhow::ensure!(!rows.is_empty(), "empty libsvm file");

    let offset = usize::from(!saw_zero); // 1-based unless a 0 index appeared
    let dim = max_idx + 1 - offset;
    let geometry = BlockGeometry::covering(dim.max(1), block_size);

    let mut b = CsrBuilder::new(rows.len(), geometry.dim());
    let mut labels = Vec::with_capacity(rows.len());
    for (r, (label, feats)) in rows.into_iter().enumerate() {
        labels.push(match kind {
            LossKind::Logistic => {
                if label > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            LossKind::Squared => label,
        });
        for (idx, val) in feats {
            b.push(r, idx - offset, val);
        }
    }

    let ds = Dataset {
        name: "libsvm".into(),
        kind,
        a: b.build(),
        labels,
        geometry,
    };
    ds.validate()?;
    Ok(ds)
}

pub fn load_libsvm(path: &Path, kind: LossKind, block_size: usize) -> anyhow::Result<Dataset> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut text = String::new();
    BufReader::new(file)
        .read_to_string(&mut text)
        .with_context(|| format!("read {path:?}"))?;
    let mut ds = parse_libsvm(&text, kind, block_size)?;
    ds.name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_one_based() {
        let ds = parse_libsvm("+1 1:0.5 3:2.0\n-1 2:1.0\n", LossKind::Logistic, 2).unwrap();
        assert_eq!(ds.samples(), 2);
        assert_eq!(ds.geometry.n_blocks, 2); // dim 3 -> padded 4
        assert_eq!(ds.labels, vec![1.0, -1.0]);
        assert_eq!(ds.a.row(0), (&[0u32, 2u32][..], &[0.5f32, 2.0f32][..]));
    }

    #[test]
    fn parses_zero_based() {
        let ds = parse_libsvm("1 0:1.0 2:1.0\n0 1:3.0\n", LossKind::Logistic, 4).unwrap();
        assert_eq!(ds.dim(), 4);
        assert_eq!(ds.a.row(0).0, &[0, 2]);
        assert_eq!(ds.labels, vec![1.0, -1.0]); // 0 label -> -1
    }

    #[test]
    fn squared_labels_pass_through() {
        let ds = parse_libsvm("2.5 1:1\n-0.5 2:1\n", LossKind::Squared, 2).unwrap();
        assert_eq!(ds.labels, vec![2.5, -0.5]);
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let ds =
            parse_libsvm("# header\n\n+1 1:1.0 # trailing\n\n-1 2:1.0\n", LossKind::Logistic, 2)
                .unwrap();
        assert_eq!(ds.samples(), 2);
    }

    #[test]
    fn malformed_rejected() {
        assert!(parse_libsvm("", LossKind::Logistic, 2).is_err());
        assert!(parse_libsvm("+1 nonsense\n", LossKind::Logistic, 2).is_err());
        assert!(parse_libsvm("abc 1:1\n", LossKind::Logistic, 2).is_err());
        assert!(parse_libsvm("+1 1:xyz\n", LossKind::Logistic, 2).is_err());
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("asybadmm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.svm");
        std::fs::write(&p, "+1 1:1.5\n-1 2:-0.5\n").unwrap();
        let ds = load_libsvm(&p, LossKind::Logistic, 2).unwrap();
        assert_eq!(ds.name, "toy");
        assert_eq!(ds.samples(), 2);
    }
}

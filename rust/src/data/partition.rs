//! Sample partitioning and per-worker packed shards.
//!
//! A `WorkerShard` is worker i's view of the problem: its local rows, and
//! its *packed* feature space — the worker's active consensus blocks
//! 𝒩(i) laid out contiguously in "slots" [0, |𝒩(i)|). Packing is what
//! lets one fixed-shape AOT artifact (d_pad columns) serve every worker:
//! slot s columns are global block `active_blocks[s]`, slots beyond
//! `n_slots` are zero padding.

use super::dataset::Dataset;
use crate::sparse::{BlockSliceIndex, CsrMatrix};

#[derive(Clone, Debug)]
pub struct WorkerShard {
    pub worker_id: usize,
    /// Global row range [lo, hi) in the originating dataset.
    pub rows: (usize, usize),
    pub labels: Vec<f32>,
    /// Sorted global block ids this worker touches (𝒩(i) in the paper).
    pub active_blocks: Vec<usize>,
    /// Local matrix with columns remapped to packed slots;
    /// `a_packed.cols() == active_blocks.len() * block_size`.
    pub a_packed: CsrMatrix,
    pub block_size: usize,
    /// Per-(slot, row) nonzero ranges of `a_packed`, built once here so
    /// the block-gradient kernel iterates exactly the in-block nonzeros
    /// instead of binary-searching every row per step.
    pub slices: BlockSliceIndex,
}

impl WorkerShard {
    /// Build a shard from dataset rows [lo, hi).
    ///
    /// `forced_blocks`: use this active set (must cover every feature the
    /// rows touch) — the synthetic generator passes the designed
    /// footprint so empty-but-assigned blocks stay in ℰ. `None` derives
    /// the minimal active set from the data.
    pub fn from_rows(
        worker_id: usize,
        ds: &Dataset,
        lo: usize,
        hi: usize,
        forced_blocks: Option<Vec<usize>>,
    ) -> Self {
        let g = ds.geometry;
        let slice = ds.a.row_slice(lo, hi);
        let mut active: Vec<usize> = match forced_blocks {
            Some(b) => b,
            None => {
                let mut seen = vec![false; g.n_blocks];
                for r in 0..slice.rows() {
                    for &j in slice.row(r).0 {
                        seen[g.block_of(j as usize)] = true;
                    }
                }
                (0..g.n_blocks).filter(|&b| seen[b]).collect()
            }
        };
        active.sort_unstable();
        active.dedup();

        // Global feature -> packed column map.
        let mut map = vec![u32::MAX; g.dim()];
        for (slot, &b) in active.iter().enumerate() {
            let (flo, fhi) = g.range(b);
            for (k, f) in (flo..fhi).enumerate() {
                map[f] = (slot * g.block_size + k) as u32;
            }
        }
        // All touched features must be covered by the active set.
        for r in 0..slice.rows() {
            for &j in slice.row(r).0 {
                assert!(
                    map[j as usize] != u32::MAX,
                    "feature {j} outside forced active blocks"
                );
            }
        }
        let a_packed = slice.remap_cols(&map, active.len() * g.block_size);
        let slices = a_packed.block_slices(g.block_size);

        WorkerShard {
            worker_id,
            rows: (lo, hi),
            labels: ds.labels[lo..hi].to_vec(),
            active_blocks: active,
            a_packed,
            block_size: g.block_size,
            slices,
        }
    }

    pub fn samples(&self) -> usize {
        self.a_packed.rows()
    }

    /// Number of packed block slots (|𝒩(i)|).
    pub fn n_slots(&self) -> usize {
        self.active_blocks.len()
    }

    pub fn packed_dim(&self) -> usize {
        self.a_packed.cols()
    }

    /// Packed slot of global block j, if active.
    pub fn slot_of_block(&self, j: usize) -> Option<usize> {
        self.active_blocks.binary_search(&j).ok()
    }

    pub fn block_of_slot(&self, slot: usize) -> usize {
        self.active_blocks[slot]
    }

    /// Packed column range of slot s.
    pub fn slot_range(&self, slot: usize) -> (usize, usize) {
        (slot * self.block_size, (slot + 1) * self.block_size)
    }
}

/// Partition an arbitrary dataset into `n_workers` even contiguous row
/// shards (the paper: "the whole dataset will be evenly split").
/// Active blocks are derived from each shard's data.
pub fn partition_even(ds: &Dataset, n_workers: usize) -> Vec<WorkerShard> {
    assert!(n_workers > 0);
    let m = ds.samples();
    let base = m / n_workers;
    let rem = m % n_workers;
    let mut out = Vec::with_capacity(n_workers);
    let mut lo = 0;
    for i in 0..n_workers {
        let hi = lo + base + usize::from(i < rem);
        out.push(WorkerShard::from_rows(i, ds, lo, hi, None));
        lo = hi;
    }
    assert_eq!(lo, m);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{BlockGeometry, LossKind};
    use crate::sparse::CsrBuilder;

    fn toy_dataset() -> Dataset {
        // 6 samples, 4 blocks of 4 features = dim 16.
        let mut b = CsrBuilder::new(6, 16);
        // rows 0-2 touch blocks {0,1}; rows 3-5 touch blocks {2,3}
        b.push(0, 0, 1.0);
        b.push(0, 5, 2.0);
        b.push(1, 1, 1.0);
        b.push(2, 6, -1.0);
        b.push(3, 8, 1.0);
        b.push(4, 12, 2.0);
        b.push(5, 15, -2.0);
        Dataset {
            name: "toy".into(),
            kind: LossKind::Logistic,
            a: b.build(),
            labels: vec![1.0, -1.0, 1.0, 1.0, -1.0, 1.0],
            geometry: BlockGeometry::new(4, 4),
        }
    }

    #[test]
    fn partition_covers_all_rows_once() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 2);
        assert_eq!(shards[0].rows, (0, 3));
        assert_eq!(shards[1].rows, (3, 6));
        assert_eq!(shards.iter().map(|s| s.samples()).sum::<usize>(), 6);
        assert_eq!(
            shards.iter().map(|s| s.a_packed.nnz()).sum::<usize>(),
            ds.a.nnz()
        );
    }

    #[test]
    fn active_blocks_match_footprint() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 2);
        assert_eq!(shards[0].active_blocks, vec![0, 1]);
        assert_eq!(shards[1].active_blocks, vec![2, 3]);
    }

    #[test]
    fn packing_remaps_features_consistently() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 2);
        let s1 = &shards[1];
        // global feature 8 (block 2, offset 0) -> slot 0, col 0
        // global feature 12 (block 3, offset 0) -> slot 1, col 4
        // global feature 15 (block 3, offset 3) -> slot 1, col 7
        assert_eq!(s1.a_packed.row(0), (&[0u32][..], &[1.0f32][..]));
        assert_eq!(s1.a_packed.row(1), (&[4u32][..], &[2.0f32][..]));
        assert_eq!(s1.a_packed.row(2), (&[7u32][..], &[-2.0f32][..]));
        assert_eq!(s1.packed_dim(), 8);
    }

    #[test]
    fn slot_lookup() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 2);
        let s = &shards[1];
        assert_eq!(s.slot_of_block(2), Some(0));
        assert_eq!(s.slot_of_block(3), Some(1));
        assert_eq!(s.slot_of_block(0), None);
        assert_eq!(s.block_of_slot(1), 3);
        assert_eq!(s.slot_range(1), (4, 8));
    }

    #[test]
    fn forced_blocks_keep_empty_slots() {
        let ds = toy_dataset();
        let s = WorkerShard::from_rows(0, &ds, 0, 3, Some(vec![0, 1, 2]));
        assert_eq!(s.n_slots(), 3);
        assert_eq!(s.packed_dim(), 12);
    }

    #[test]
    #[should_panic(expected = "outside forced active blocks")]
    fn forced_blocks_must_cover_data() {
        let ds = toy_dataset();
        let _ = WorkerShard::from_rows(0, &ds, 0, 3, Some(vec![0])); // row 0 touches block 1
    }

    #[test]
    fn shard_slice_index_matches_packed_matrix() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 2);
        for s in &shards {
            assert_eq!(s.slices.n_blocks(), s.n_slots());
            assert_eq!(s.slices.block_size(), s.block_size);
            let covered: usize = (0..s.n_slots()).map(|b| s.slices.block_nnz(b)).sum();
            assert_eq!(covered, s.a_packed.nnz());
        }
    }

    #[test]
    fn single_worker_gets_everything() {
        let ds = toy_dataset();
        let shards = partition_even(&ds, 1);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].samples(), 6);
        assert_eq!(shards[0].active_blocks, vec![0, 1, 2, 3]);
    }
}

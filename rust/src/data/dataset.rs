//! Core dataset types and the consensus block geometry.

use crate::sparse::CsrMatrix;

/// Which generalized-linear loss the problem uses. Must match the `kind`
/// of the AOT artifacts the runtime loads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// log(1 + exp(-y <a, x>)), labels in {-1, +1}  (paper Eq. 22)
    Logistic,
    /// 0.5 (<a, x> - y)^2, real labels (lasso / robust MC example)
    Squared,
}

impl LossKind {
    pub fn as_str(self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::Squared => "squared",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "logistic" => Ok(LossKind::Logistic),
            "squared" => Ok(LossKind::Squared),
            other => anyhow::bail!("unknown loss kind {other:?} (logistic|squared)"),
        }
    }
}

/// How the global model vector is cut into consensus blocks z_j.
///
/// The model dimension is padded up to `n_blocks * block_size`; features
/// in the padding never appear in data, so their z entries stay at the
/// prox fixed point (0 for l1) and do not affect anything.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockGeometry {
    pub n_blocks: usize,
    pub block_size: usize,
}

impl BlockGeometry {
    pub fn new(n_blocks: usize, block_size: usize) -> Self {
        assert!(n_blocks > 0 && block_size > 0);
        BlockGeometry { n_blocks, block_size }
    }

    /// Smallest geometry with `block_size` covering `d` features.
    pub fn covering(d: usize, block_size: usize) -> Self {
        let n_blocks = d.div_ceil(block_size).max(1);
        BlockGeometry { n_blocks, block_size }
    }

    pub fn dim(&self) -> usize {
        self.n_blocks * self.block_size
    }

    pub fn block_of(&self, feature: usize) -> usize {
        debug_assert!(feature < self.dim());
        feature / self.block_size
    }

    /// Global feature range [lo, hi) of block j.
    pub fn range(&self, j: usize) -> (usize, usize) {
        assert!(j < self.n_blocks);
        (j * self.block_size, (j + 1) * self.block_size)
    }
}

/// A labeled sparse dataset. `a.cols()` equals `geometry.dim()`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub kind: LossKind,
    pub a: CsrMatrix,
    pub labels: Vec<f32>,
    pub geometry: BlockGeometry,
}

impl Dataset {
    pub fn samples(&self) -> usize {
        self.a.rows()
    }

    pub fn dim(&self) -> usize {
        self.a.cols()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.a.rows() == self.labels.len(), "labels/rows mismatch");
        anyhow::ensure!(self.a.cols() == self.geometry.dim(), "cols/geometry mismatch");
        if self.kind == LossKind::Logistic {
            anyhow::ensure!(
                self.labels.iter().all(|&y| y == 1.0 || y == -1.0),
                "logistic labels must be ±1"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_ranges() {
        let g = BlockGeometry::new(4, 8);
        assert_eq!(g.dim(), 32);
        assert_eq!(g.range(0), (0, 8));
        assert_eq!(g.range(3), (24, 32));
        assert_eq!(g.block_of(0), 0);
        assert_eq!(g.block_of(31), 3);
    }

    #[test]
    fn covering_rounds_up() {
        let g = BlockGeometry::covering(17, 8);
        assert_eq!(g.n_blocks, 3);
        assert_eq!(g.dim(), 24);
        let g1 = BlockGeometry::covering(16, 8);
        assert_eq!(g1.n_blocks, 2);
    }

    #[test]
    fn loss_kind_parse_roundtrip() {
        for k in [LossKind::Logistic, LossKind::Squared] {
            assert_eq!(LossKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(LossKind::parse("huber").is_err());
    }
}

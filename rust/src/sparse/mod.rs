//! Sparse matrix substrate (S1): CSR storage and the kernels the native
//! compute backend and the data pipeline need.
//!
//! KDDa-like workloads are extremely sparse (~40 nnz out of 20M features
//! per row); everything data-side stays CSR.  The XLA backend densifies
//! *packed* per-worker chunks (active feature columns only) once at
//! startup — see `data::partition`.

mod csr;
pub mod simd;
pub use csr::{BlockSliceIndex, CsrBuilder, CsrMatrix};
pub use simd::{simd_available, Kernels};

/// Dense reference ops used by tests and small utilities.
pub mod dense {
    /// y = A x for row-major `a` of shape (rows, cols).
    pub fn matvec(a: &[f32], rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), rows * cols);
        assert_eq!(x.len(), cols);
        (0..rows)
            .map(|r| {
                let row = &a[r * cols..(r + 1) * cols];
                row.iter().zip(x).map(|(v, w)| v * w).sum()
            })
            .collect()
    }

    /// g = A^T s.
    pub fn tmatvec(a: &[f32], rows: usize, cols: usize, s: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), rows * cols);
        assert_eq!(s.len(), rows);
        let mut g = vec![0.0f32; cols];
        for r in 0..rows {
            let row = &a[r * cols..(r + 1) * cols];
            let sr = s[r];
            for (gj, v) in g.iter_mut().zip(row) {
                *gj += v * sr;
            }
        }
        g
    }
}
